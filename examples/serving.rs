//! Serving demo: run the vLLM-router-style coordinator (ingress queue →
//! dynamic batcher → worker fan-out) over a built search index, fire a
//! load burst, and report QPS + latency percentiles (the §B experiment).
//!
//! Run: `cargo run --release --example serving`

use qinco2::data::{self, Flavor};
use qinco2::experiments as exp;
use qinco2::index::{BuildCfg, SearchIndex, SearchParams};
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::runtime::Engine;
use qinco2::server::{Router, ServerCfg};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let ds = data::load(Flavor::Deep, 6_000, 20_000, 1_000, 32, 777);
    let bcfg = BuildCfg { k_ivf: 128, m_tilde: 2, ..Default::default() };
    let ivf = qinco2::index::ivf::Ivf::build(&ds.train, &ds.train, bcfg.k_ivf, bcfg.seed);
    let residuals = ivf.residuals(&ds.train);
    let cfg = TrainCfg { epochs: 5, a: 8, b: 8, seed: 0xA11CE ^ 0x1F, ..Default::default() };
    let params = exp::trained_model(&mut engine, "qinco2_xs", "deep_ivfres_srv", &residuals, &cfg)?;
    let codec = Codec::new(&engine, "qinco2_xs", 8, 8)?;
    let index = Arc::new(SearchIndex::build(
        &mut engine, &codec, params, &ds.train, &ds.database, &bcfg)?);

    for workers in [1usize, 4, qinco2::util::pool::default_threads()] {
        let router = Router::start(index.clone(), ServerCfg { workers, ..Default::default() });
        let sp = SearchParams {
            nprobe: 8, ef_search: 64, n_aq: 256, n_pairs: 32, n_final: 10,
            ..Default::default()
        };
        let n = 2_000;
        let t0 = std::time::Instant::now();
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            pending.push(router.submit(ds.queries.row(i % ds.queries.rows).to_vec(), sp)?);
        }
        for rx in pending {
            // exactly one reply per accepted request: the response, or a
            // typed RouterError (never a silently dropped channel)
            rx.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))??;
        }
        let secs = t0.elapsed().as_secs_f64();
        let st = router.stats();
        println!(
            "workers {workers:2}: {:7.0} QPS | latency mean {:>9.2?} p50 {:>9.2?} p99 {:>9.2?}",
            n as f64 / secs, st.mean_latency, st.p50, st.p99
        );
        router.shutdown();
    }
    println!("serving demo OK");
    Ok(())
}
