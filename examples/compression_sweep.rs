//! Compare every quantizer in the library (PQ, OPQ, RQ, LSQ, QINCo2) on
//! one dataset flavor — a compact version of the paper's Table 3.
//!
//! Run: `cargo run --release --example compression_sweep [-- deep]`

use qinco2::data::{self, Flavor};
use qinco2::experiments as exp;
use qinco2::metrics::recall_at;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::quantizers::{lsq::Lsq, opq::Opq, pq::Pq, rq::Rq, VectorQuantizer};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let flavor = std::env::args()
        .nth(1)
        .and_then(|s| Flavor::parse(&s))
        .unwrap_or(Flavor::Deep);
    let ds = data::load(flavor, 5_000, 5_000, 500, 32, 123);
    println!("=== compression sweep on {}-like (d=32, 8 codes, K=64) ===", flavor.name());
    println!("{:<10} {:>10} {:>8} {:>12}", "method", "MSE", "R@1", "train+enc(s)");

    let report = |label: &str, dec: &qinco2::tensor::Matrix, secs: f64, ds: &data::Dataset| {
        let mse = qinco2::tensor::mse(&ds.database, dec);
        let res = data::brute_force_gt_k(dec, &ds.queries, 1);
        let r1 = recall_at(&res, &ds.ground_truth, 1);
        println!("{label:<10} {mse:>10.5} {:>7.1}% {secs:>12.1}", 100.0 * r1);
    };

    let t = std::time::Instant::now();
    let pq = Pq::train(&ds.train, 8, 64, 1);
    report("PQ", &pq.decode(&pq.encode(&ds.database)), t.elapsed().as_secs_f64(), &ds);

    let t = std::time::Instant::now();
    let opq = Opq::train(&ds.train, 8, 64, 3, 2);
    report("OPQ", &opq.decode(&opq.encode(&ds.database)), t.elapsed().as_secs_f64(), &ds);

    let t = std::time::Instant::now();
    let rq = Rq::train(&ds.train, 8, 64, 5, 3);
    report("RQ(B=5)", &rq.decode(&rq.encode(&ds.database)), t.elapsed().as_secs_f64(), &ds);

    let t = std::time::Instant::now();
    let lsq = Lsq::train(&ds.train, 8, 64, 3, 4);
    report("LSQ", &lsq.decode(&lsq.encode(&ds.database)), t.elapsed().as_secs_f64(), &ds);

    // QINCo2 through the three-layer stack (prefix of the M=16 model)
    let t = std::time::Instant::now();
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let cfg = TrainCfg { epochs: 6, a: 8, b: 8, ..Default::default() };
    let params = exp::trained_model(&mut engine, "qinco2_xs",
                                    &format!("{}_sweep", flavor.name()), &ds.train, &cfg)?;
    let codec = Codec::new(&engine, "qinco2_xs", 16, 16)?;
    let (codes, _, _) = codec.encode(&mut engine, &params, &ds.database)?;
    let partials = codec.decode_partial(&mut engine, &params, &codes)?;
    report("QINCo2", &partials[7], t.elapsed().as_secs_f64(), &ds);

    println!("\n(expected ordering, as in paper Table 3: PQ < OPQ < RQ < LSQ < QINCo2)");
    Ok(())
}
