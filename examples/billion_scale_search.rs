//! Billion-scale-style search, scaled down: builds the full Fig. 3
//! pipeline (IVF + HNSW + QINCo2 residual codes + AQ LUT scan + pairwise
//! re-rank + neural re-rank) over a synthetic database and walks the
//! speed/accuracy tradeoff like Fig. 6.
//!
//! Run: `cargo run --release --example billion_scale_search [-- deep]`

use qinco2::data::{self, Flavor};
use qinco2::experiments as exp;
use qinco2::index::{BuildCfg, SearchIndex, SearchParams};
use qinco2::metrics::recall_at;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let flavor = std::env::args()
        .nth(1)
        .and_then(|s| Flavor::parse(&s))
        .unwrap_or(Flavor::BigAnn);
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let ds = data::load(flavor, 8_000, 30_000, 500, 32, 321);
    println!("=== IVF-QINCo2 search on {}-like: {} db vectors ===", flavor.name(), ds.database.rows);

    let bcfg = BuildCfg { k_ivf: 256, m_tilde: 2, ..Default::default() };
    // fine quantizer trained on IVF residuals (the pipeline's input space)
    let ivf = qinco2::index::ivf::Ivf::build(&ds.train, &ds.train, bcfg.k_ivf, bcfg.seed);
    let residuals = ivf.residuals(&ds.train);
    let cfg = TrainCfg { epochs: 6, a: 8, b: 8, seed: 0xA11CE ^ 0x1F, ..Default::default() };
    let params = exp::trained_model(
        &mut engine, "qinco2_xs", &format!("{}_ivfres_ex", flavor.name()), &residuals, &cfg)?;
    let codec = Codec::new(&engine, "qinco2_xs", 8, 8)?;

    let t0 = std::time::Instant::now();
    let index = SearchIndex::build(&mut engine, &codec, params, &ds.train, &ds.database, &bcfg)?;
    println!("index built in {:.1}s — {:.1} bytes/vector (codes + caches)",
             t0.elapsed().as_secs_f64(), index.bytes_per_vector());

    println!("\n{:>7} {:>6} {:>6} {:>8} {:>9} {:>7} {:>7}",
             "nprobe", "ef", "n_aq", "n_pairs", "QPS", "R@1", "R@10");
    for (nprobe, ef, n_aq, n_pairs) in
        [(1usize, 16usize, 32usize, 8usize), (4, 32, 128, 32), (16, 64, 512, 64), (64, 128, 2048, 128)]
    {
        let sp = SearchParams { nprobe, ef_search: ef, n_aq, n_pairs, n_final: 10 };
        let t0 = std::time::Instant::now();
        let results = qinco2::metrics::ids_only(&index.search_batch(&ds.queries, &sp));
        let qps = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
        let r1 = recall_at(&results, &ds.ground_truth, 1);
        let r10 = recall_at(&results, &ds.ground_truth, 10);
        println!("{nprobe:>7} {ef:>6} {n_aq:>6} {n_pairs:>8} {qps:>9.0} {:>6.1}% {:>6.1}%",
                 100.0 * r1, 100.0 * r10);
    }
    println!("\n(low budgets: fast but LUT-bound accuracy; high budgets: the neural");
    println!(" re-rank pushes recall toward the quantizer's ceiling — Fig. 6's shape)");
    Ok(())
}
