//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Generates a small synthetic workload, trains a QINCo2 model *from
//! Rust* (AdamW over the AOT `train_step` HLO artifact, with beam-search
//! encoding, cosine LR, gradient clipping and dead-codeword resets),
//! logs the loss curve, then compresses a database and reports the
//! paper's headline metrics (MSE, R@1) plus a beam-vs-greedy ablation.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use qinco2::data::{self, Flavor};
use qinco2::experiments as exp;
use qinco2::metrics::recall_triple;
use qinco2::qinco::{Codec, ParamStore, TrainCfg, Trainer};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    println!("=== QINCo2 quickstart ===");
    let mut engine = Engine::open(exp::artifacts_dir())?;
    println!("PJRT platform: {}", engine.platform());

    // 1. data: a scaled BigANN-like corpus (see DESIGN.md §Substitutions)
    let ds = data::load(Flavor::BigAnn, 6_000, 8_000, 500, 32, 42);
    println!(
        "dataset: bigann-like, d=32, {} train / {} db / {} queries",
        ds.train.rows, ds.database.rows, ds.queries.rows
    );

    // 2. train QINCo2-XS from Rust over the HLO train_step artifact
    let model = "qinco2_xs";
    let spec = engine.manifest.model(model)?.clone();
    let mut params = ParamStore::init(&spec, model, &ds.train, 7);
    let cfg = TrainCfg { epochs: 8, a: 8, b: 8, log_every: 1, ..Default::default() };
    let trainer = Trainer::new(&engine, model, cfg)?;
    let stats = trainer.train(&mut engine, &mut params, &ds.train)?;
    println!("\nloss curve (per-epoch mean of the per-step reconstruction loss):");
    for (e, l) in stats.epoch_losses.iter().enumerate() {
        println!("  epoch {e:2}: {l:.5}   ({} dead codewords reset)", stats.resets[e]);
    }
    println!("trained {} steps in {:.1}s", stats.steps, stats.secs);

    // 3. compress the database and evaluate (greedy vs beam, Table 3 style)
    for (label, a, b) in [("greedy A=8,B=1", 8usize, 1usize), ("beam   A=8,B=8", 8, 8),
                          ("eval beam A=16,B=16", 16, 16)] {
        let codec = Codec::new(&engine, model, a, b)?;
        let t0 = std::time::Instant::now();
        let (codes, _, _) = codec.encode(&mut engine, &params, &ds.database)?;
        let enc_s = t0.elapsed().as_secs_f64();
        let dec = codec.decode(&mut engine, &params, &codes)?;
        let mse = qinco2::tensor::mse(&ds.database, &dec);
        let results = data::brute_force_gt_k(&dec, &ds.queries, 100);
        let (r1, r10, r100) = recall_triple(&results, &ds.ground_truth);
        println!(
            "{label:>20}: MSE {mse:.5}  R@1 {:.1}%  R@10 {:.1}%  R@100 {:.1}%  ({:.0} µs/vec encode)",
            100.0 * r1, 100.0 * r10, 100.0 * r100, enc_s * 1e6 / ds.database.rows as f64
        );
    }
    println!("\n16 codes x 6 bits = 12 bytes/vector (vs 128 bytes raw = 10.7x compression)");
    println!("quickstart OK");
    Ok(())
}
