"""L1 Pallas kernel: fused QINCo2 candidate evaluator f_theta.

This is the compute hot-spot of the whole system: during encoding every
vector evaluates f_theta over A pre-selected candidates for each of B beam
hypotheses at each of M steps, i.e. rows = N*B*A evaluations of a small
residual MLP. The kernel fuses the whole network (input projection,
concat-conditioning, L residual blocks, output projection, final codeword
skip) over a tile of candidate rows so the intermediate activations never
leave VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the candidate
rows; per-step weights (a few hundred KiB) use a constant index_map so
they stay VMEM-resident across the grid, and each tile issues
[TILE, de] x [de, dh] MXU matmuls. interpret=True is mandatory here — the
CPU PJRT client cannot execute Mosaic custom-calls — so correctness flows
through the interpreter while the BlockSpec structure documents the real
HBM<->VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of candidate rows processed per grid step.
#
# TPU sizing: 512 rows x de floats of activations (3 live tensors) stays
# well under VMEM for every config in the catalog (see DESIGN.md §Perf),
# so 512 is the tile the BlockSpec schedule is designed around.
#
# CPU-artifact sizing: interpret=True lowers the grid into a serial XLA
# while-loop of small matmuls, which the CPU backend cannot parallelize.
# A large tile (grid of 1 for every catalog shape) turns the kernel into
# a handful of big matmuls that Eigen threads across cores — measured 20x
# faster end-to-end encode (EXPERIMENTS.md §Perf L1). The TPU tiling
# remains documented/enforced by vmem_footprint_bytes.
DEFAULT_TILE = 32768
TPU_TILE = 512


def _kernel(c_ref, xhat_ref, in_w_ref, cond_w_ref, cond_b_ref, up_w_ref,
            down_w_ref, out_w_ref, o_ref):
    c = c_ref[...]
    xh = xhat_ref[...]
    c_emb = c @ in_w_ref[...]
    v = c_emb + (jnp.concatenate([c_emb, xh], axis=-1) @ cond_w_ref[...]
                 + cond_b_ref[...])
    num_blocks = up_w_ref.shape[0]
    for i in range(num_blocks):  # static unroll over residual blocks
        v = v + jnp.maximum(v @ up_w_ref[i], 0.0) @ down_w_ref[i]
    o_ref[...] = c + v @ out_w_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def f_theta(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w,
            tile: int = DEFAULT_TILE):
    """Fused f_theta(c | xhat) over a batch of candidate rows.

    Shapes as in kernels.ref.f_theta_ref. Rows are padded up to a multiple
    of the tile size and the pad is stripped afterwards, so any N works.
    """
    n, d = c.shape
    de = in_w.shape[1]
    if up_w.shape[0] == 0:
        # L=0: pallas rejects zero-sized blocks; a single zeroed block is
        # mathematically identical (v + relu(v@0)@0 = v).
        dh = max(up_w.shape[2], 1) if up_w.ndim == 3 else 1
        up_w = jnp.zeros((1, de, dh), c.dtype)
        down_w = jnp.zeros((1, dh, de), c.dtype)
    t = min(tile, max(n, 1))
    n_pad = (-n) % t
    if n_pad:
        c = jnp.concatenate([c, jnp.zeros((n_pad, d), c.dtype)], axis=0)
        xhat = jnp.concatenate([xhat, jnp.zeros((n_pad, d), xhat.dtype)], axis=0)
    rows = c.shape[0]
    grid = (rows // t,)

    def row_tiled(_d):
        return pl.BlockSpec((t, _d), lambda i: (i, 0))

    def resident(shape):
        # index_map pinned to block 0: the whole tensor is one block that
        # stays resident in VMEM across every grid step.
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            row_tiled(d),
            row_tiled(d),
            resident(in_w.shape),
            resident(cond_w.shape),
            resident(cond_b.shape),
            resident(up_w.shape),
            resident(down_w.shape),
            resident(out_w.shape),
        ],
        out_specs=row_tiled(d),
        out_shape=jax.ShapeDtypeStruct((rows, d), c.dtype),
        interpret=True,
    )(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w)
    return out[:n]


def vmem_footprint_bytes(d, de, dh, L, tile=DEFAULT_TILE, bytes_per=4):
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf).

    Weights (resident) + activation tiles (c, xhat, c_emb, v, hidden, out).
    """
    weights = d * de + (de + d) * de + de + L * (de * dh + dh * de) + de * d
    acts = tile * (2 * d + 2 * de + dh + d)
    return (weights + acts) * bytes_per


def mxu_flops(d, de, dh, L):
    """Matmul FLOPs per candidate row (2*m*k per output elem)."""
    return 2 * (d * de + (de + d) * de + L * (de * dh + dh * de) + de * d)
