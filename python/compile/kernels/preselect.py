"""L1 Pallas kernel: pre-selection distance scoring.

Computes the [rows, K] matrix of squared L2 distances between residuals
and the pre-selection codebook C~^m (paper Eq. 6 with L_s = 0, where
g(c|x) = c). Expressed as a norm-expanded matmul so the MXU does the heavy
lifting: ||r - c||^2 = ||r||^2 - 2 r.c + ||c||^2.

The top-A cut itself is done outside the kernel with jax.lax.top_k, which
XLA lowers to an efficient sort-free selection.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# large tile => grid of 1 on CPU artifacts (see qinco_step.DEFAULT_TILE)
DEFAULT_TILE = 32768


def _kernel(r_ref, cb_ref, o_ref):
    r = r_ref[...]
    cb = cb_ref[...]
    rn = jnp.sum(r * r, axis=-1, keepdims=True)
    cn = jnp.sum(cb * cb, axis=-1)[None, :]
    o_ref[...] = rn - 2.0 * (r @ cb.T) + cn


@functools.partial(jax.jit, static_argnames=("tile",))
def presel_scores(r, cb, tile: int = DEFAULT_TILE):
    """[N, d] residuals x [K, d] codebook -> [N, K] squared distances."""
    n, d = r.shape
    k = cb.shape[0]
    t = min(tile, max(n, 1))
    n_pad = (-n) % t
    if n_pad:
        r = jnp.concatenate([r, jnp.zeros((n_pad, d), r.dtype)], axis=0)
    rows = r.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(rows // t,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # codebook VMEM-resident
        ],
        out_specs=pl.BlockSpec((t, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), r.dtype),
        interpret=True,
    )(r, cb)
    return out[:n]
