"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the Pallas kernels are validated against in
``python/tests``: same math, no tiling, no pallas machinery. Keep them
boring and obviously correct.
"""

import jax.numpy as jnp


def f_theta_ref(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w):
    """QINCo2 implicit-codebook network f_theta (paper Eqs. 10-13).

    Args:
      c:      [N, d]  base codewords for the candidates.
      xhat:   [N, d]  partial reconstruction x^{m-1} per candidate.
      in_w:   [d, de]   P_d^{de} input projection (identity-initialized
              when d == de, matching the paper's P convention).
      cond_w: [de+d, de] concat-conditioning layer (the only biased layer).
      cond_b: [de]
      up_w:   [L, de, dh] residual block up projections.
      down_w: [L, dh, de] residual block down projections.
      out_w:  [de, d]   P_{de}^d output projection.

    Returns:
      [N, d] f_theta(c | xhat) = c + P(v_L), per Eq. 13.
    """
    c_emb = c @ in_w  # Eq. 10
    v = c_emb + (jnp.concatenate([c_emb, xhat], axis=-1) @ cond_w + cond_b)  # Eq. 11
    for i in range(up_w.shape[0]):  # Eq. 12, static unroll
        v = v + jnp.maximum(v @ up_w[i], 0.0) @ down_w[i]
    return c + v @ out_w  # Eq. 13


def presel_scores_ref(r, cb):
    """Squared L2 distances between residuals and a lookup codebook.

    Pre-selection with L_s = 0 (paper Sec. 3.2): g(c|x) = c, so candidate
    scores are plain ||r - c~_k||^2.

    Args:
      r:  [N, d] residuals.
      cb: [K, d] pre-selection codebook C~^m.

    Returns:
      [N, K] squared distances.
    """
    diff = r[:, None, :] - cb[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
