"""L2: the QINCo2 model as pure JAX functions over an explicit pytree.

Everything here is a *pure function* of (params, data): the Rust
coordinator owns the parameter store, and these functions are AOT-lowered
to HLO text by ``aot.py`` so the Rust runtime can execute them via PJRT.
The compute hot-spot (f_theta over candidate rows, pre-selection scoring)
is delegated to the L1 Pallas kernels in ``kernels/``.

Paper mapping:
  decode        -> Eq. 4 (F_QI) with f_theta per Eqs. 10-13
  encode        -> Q_QI-B: pre-selection (Eq. 6) + beam search (Fig. 2);
                   Q_QI-A and greedy RQ are the B=1 / A=K special cases
  train_step    -> App. A.2: alternating optimization outer step — the
                   inner encode is done by a separate artifact, this one
                   does the forward-backward on the selected codes with
                   AdamW(+clip) or Adam (the "old recipe" ablation)
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import preselect as presel_kernel
from compile.kernels import qinco_step as qinco_kernel
from compile.kernels import ref as kref


# ---------------------------------------------------------------------------
# Config and parameter pytree
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Static architecture of a QINCo2 model (Table 2 of the paper)."""

    d: int      # data dimension
    M: int      # number of quantization steps (bytes when K=256)
    K: int      # codebook size per step
    L: int      # residual blocks in f_theta
    de: int     # embedding (backbone) dimension
    dh: int     # hidden dimension of the residual MLPs
    Ls: int = 0     # depth of the pre-selection network g (0 = pure lookup)
    dhg: int = 128  # hidden dim of g when Ls > 0 (paper fixes 128)

    @property
    def name(self) -> str:
        s = f"d{self.d}_M{self.M}_K{self.K}_L{self.L}_de{self.de}_dh{self.dh}"
        if self.Ls:
            s += f"_Ls{self.Ls}"
        return s


# Parameter order is the ABI between aot.py, the manifest and the Rust
# runtime: artifacts take/return tensors in exactly this order.
PARAM_NAMES: List[str] = [
    "codebooks",  # [M, K, d]   base codebooks C^m
    "presel",     # [M, K, d]   pre-selection codebooks C~^m
    "in_w",       # [M, d, de]  P_d^{de}
    "cond_w",     # [M, de+d, de]
    "cond_b",     # [M, de]
    "up_w",       # [M, L, de, dh]
    "down_w",     # [M, L, dh, de]
    "out_w",      # [M, de, d]  P_{de}^d
]

G_PARAM_NAMES: List[str] = [  # only present when cfg.Ls > 0
    "g_cond_w",  # [M, 2d, d]
    "g_cond_b",  # [M, d]
    "g_up_w",    # [M, Ls, d, dhg]
    "g_down_w",  # [M, Ls, dhg, d]
]

# Parameters that receive weight decay under AdamW (weight matrices only;
# codebooks, pre-selection codebooks and biases are exempt).
DECAYED = {"in_w", "cond_w", "up_w", "down_w", "out_w", "g_cond_w", "g_up_w", "g_down_w"}


def param_names(cfg: ModelCfg) -> List[str]:
    return PARAM_NAMES + (G_PARAM_NAMES if cfg.Ls > 0 else [])


def param_shapes(cfg: ModelCfg) -> Dict[str, Tuple[int, ...]]:
    d, M, K, L, de, dh = cfg.d, cfg.M, cfg.K, cfg.L, cfg.de, cfg.dh
    shapes = {
        "codebooks": (M, K, d),
        "presel": (M, K, d),
        "in_w": (M, d, de),
        "cond_w": (M, de + d, de),
        "cond_b": (M, de),
        "up_w": (M, L, de, dh),
        "down_w": (M, L, dh, de),
        "out_w": (M, de, d),
    }
    if cfg.Ls > 0:
        shapes.update({
            "g_cond_w": (M, 2 * d, d),
            "g_cond_b": (M, d),
            "g_up_w": (M, cfg.Ls, d, cfg.dhg),
            "g_down_w": (M, cfg.Ls, cfg.dhg, d),
        })
    return shapes


def num_params(cfg: ModelCfg) -> int:
    """Trainable parameter count (Table S1)."""
    return sum(
        functools.reduce(lambda a, b: a * b, shp, 1)
        for shp in param_shapes(cfg).values()
    )


def init_params(cfg: ModelCfg, key) -> Dict[str, jnp.ndarray]:
    """Reference initializer (App. A.2), mirrored by the Rust trainer.

    Kaiming-uniform weights, zero biases and zero down-projections,
    identity-initialized P projections when square. Codebooks here are
    N(0,1); the Rust side overwrites them with noisy RQ codebooks trained
    on the actual data (the paper's init), which aot.py cannot know.
    """
    d, M, K, L, de, dh = cfg.d, cfg.M, cfg.K, cfg.L, cfg.de, cfg.dh
    ks = jax.random.split(key, 8)

    def kaiming(key, shape, fan_in):
        bound = (6.0 / fan_in) ** 0.5
        return jax.random.uniform(key, shape, jnp.float32, -bound, bound)

    def proj(key, rows, cols, zero=False):
        if rows == cols:
            return jnp.eye(rows, dtype=jnp.float32)
        if zero:
            return jnp.zeros((rows, cols), jnp.float32)
        return kaiming(key, (rows, cols), rows)

    params = {
        "codebooks": jax.random.normal(ks[0], (M, K, d), jnp.float32) * 0.1,
        "presel": jax.random.normal(ks[1], (M, K, d), jnp.float32) * 0.1,
        "in_w": jnp.stack([proj(k, d, de) for k in jax.random.split(ks[2], M)]),
        # zero: keeps f independent of xhat at init so the M-step
        # recursion cannot compound (mirrors the Rust initializer)
        "cond_w": jnp.zeros((M, de + d, de), jnp.float32),
        "cond_b": jnp.zeros((M, de), jnp.float32),
        "up_w": kaiming(ks[4], (M, L, de, dh), de),
        "down_w": jnp.zeros((M, L, dh, de), jnp.float32),
        # zero-init when de != d so f_theta(c|x) == c at init: training
        # starts exactly at the RQ operating point (the QINCo guarantee)
        # instead of compounding random projections across M steps, which
        # destabilizes the first epochs at small batch sizes.
        "out_w": jnp.stack([proj(k, de, d, zero=True) for k in jax.random.split(ks[5], M)]),
    }
    if cfg.Ls > 0:
        params.update({
            "g_cond_w": kaiming(ks[6], (M, 2 * d, d), 2 * d),
            "g_cond_b": jnp.zeros((M, d), jnp.float32),
            "g_up_w": kaiming(ks[7], (M, cfg.Ls, d, cfg.dhg), d),
            "g_down_w": jnp.zeros((M, cfg.Ls, cfg.dhg, d), jnp.float32),
        })
    return params


# ---------------------------------------------------------------------------
# f_theta and pre-selection
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _f_eval_pallas(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w):
    """Pallas forward with a pure-jnp VJP (interpret-mode pallas_call does
    not support reverse-mode AD; the ref oracle is mathematically
    identical, so gradients are exact)."""
    return qinco_kernel.f_theta(c, xhat, in_w, cond_w, cond_b, up_w,
                                down_w, out_w)


def _f_eval_fwd(*args):
    return _f_eval_pallas(*args), args


def _f_eval_bwd(res, g):
    _, vjp = jax.vjp(kref.f_theta_ref, *res)
    return vjp(g)


_f_eval_pallas.defvjp(_f_eval_fwd, _f_eval_bwd)


def f_eval(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w, use_pallas=True):
    """One-step implicit codebook network over candidate rows."""
    if use_pallas:
        return _f_eval_pallas(c, xhat, in_w, cond_w, cond_b, up_w, down_w,
                              out_w)
    return kref.f_theta_ref(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w)


def presel_eval(r, cb, use_pallas=True):
    """[rows, K] squared distances for pre-selection (L_s = 0)."""
    if use_pallas:
        return presel_kernel.presel_scores(r, cb)
    return kref.presel_scores_ref(r, cb)


def g_eval(cb, xhat, g_cond_w, g_cond_b, g_up_w, g_down_w):
    """Pre-selection network g (L_s >= 1): same architecture as f_theta but
    operating in data space (identity P projections) with hidden dim dhg.

    Args:
      cb:   [K, d] pre-selection codebook.
      xhat: [rows, d] partial reconstructions.
    Returns:
      [rows, K, d] transformed candidates g(c~_k | xhat).
    """
    rows, d = xhat.shape
    k = cb.shape[0]
    c = jnp.broadcast_to(cb[None, :, :], (rows, k, d)).reshape(-1, d)
    xh = jnp.broadcast_to(xhat[:, None, :], (rows, k, d)).reshape(-1, d)
    v = c + (jnp.concatenate([c, xh], axis=-1) @ g_cond_w + g_cond_b)
    for i in range(g_up_w.shape[0]):
        v = v + jnp.maximum(v @ g_up_w[i], 0.0) @ g_down_w[i]
    return (c + v).reshape(rows, k, d)


def _step_params(params, names):
    """Tuple of per-name arrays, for lax.scan stacking over the M axis."""
    return tuple(params[n] for n in names)


def smallest_k(scores, k):
    """Indices of the k smallest entries along the last axis (ascending).

    Implemented with a stable argsort rather than lax.top_k: jax lowers
    top_k to the `topk(..., largest=true)` HLO op, which the pinned
    xla_extension 0.5.1 text parser rejects; `sort` is a classic HLO op
    and round-trips fine. K here is small (<= a few hundred), so the
    O(K log K) sort is immaterial.
    """
    return jnp.argsort(scores, axis=-1, stable=True)[..., :k]


_F_NAMES = ["in_w", "cond_w", "cond_b", "up_w", "down_w", "out_w"]


# ---------------------------------------------------------------------------
# Decoding (Eq. 4)
# ---------------------------------------------------------------------------


def decode(params, codes, use_pallas=True):
    """Reconstruct x_hat from codes.

    Args:
      params: parameter dict.
      codes:  [N, M] int32.
    Returns:
      [N, d] reconstructions.
    """
    n = codes.shape[0]
    d = params["codebooks"].shape[2]

    def step(xhat, xs):
        code_m, cb, fw = xs[0], xs[1], xs[2:]
        c = cb[code_m]
        f = f_eval(c, xhat, *fw, use_pallas=use_pallas)
        return xhat + f, None

    xs = (codes.T, params["codebooks"]) + _step_params(params, _F_NAMES)
    xhat, _ = lax.scan(step, jnp.zeros((n, d), jnp.float32), xs)
    return xhat


def decode_partial(params, codes, use_pallas=True):
    """Like decode but returns every partial reconstruction.

    Returns:
      [M, N, d]: x_hat^1 .. x_hat^M (multi-rate decoding, Fig. S3).
    """
    n = codes.shape[0]
    d = params["codebooks"].shape[2]

    def step(xhat, xs):
        code_m, cb, fw = xs[0], xs[1], xs[2:]
        f = f_eval(cb[code_m], xhat, *fw, use_pallas=use_pallas)
        nxt = xhat + f
        return nxt, nxt

    xs = (codes.T, params["codebooks"]) + _step_params(params, _F_NAMES)
    _, partials = lax.scan(step, jnp.zeros((n, d), jnp.float32), xs)
    return partials


# ---------------------------------------------------------------------------
# Encoding: pre-selection + beam search (Q_QI-B, Fig. 2)
# ---------------------------------------------------------------------------


def encode(params, x, A: int, B: int, use_pallas=True):
    """Beam-search encoding with codeword pre-selection.

    Maintains B hypotheses; each step scores the K pre-selection codewords
    per hypothesis (L1 kernel), keeps the top-A, evaluates f_theta on the
    A*B expansions (L1 kernel), and keeps the best B by exact
    reconstruction error. B=1 gives greedy Q_QI-A; A=K disables
    pre-selection (exact QINCo-style greedy when also B=1).

    Args:
      x: [N, d] vectors to encode.
    Returns:
      codes [N, M] int32, xhat [N, d], err [N] (squared L2).
    """
    n, d = x.shape
    cfg_m, k = params["codebooks"].shape[0], params["codebooks"].shape[1]
    m_steps = cfg_m
    use_g = "g_cond_w" in params

    xhat0 = jnp.zeros((n, B, d), jnp.float32)
    err0 = jnp.full((n, B), jnp.inf, jnp.float32).at[:, 0].set(0.0)
    codes0 = jnp.zeros((n, B, m_steps), jnp.int32)

    g_names = G_PARAM_NAMES if use_g else []

    def step(carry, xs):
        xhat, err, codes = carry
        m_idx = xs[0]
        cb, pcb = xs[1], xs[2]
        fw = xs[3:3 + len(_F_NAMES)]
        gw = xs[3 + len(_F_NAMES):]

        r = (x[:, None, :] - xhat).reshape(-1, d)          # [n*B, d]
        if use_g:
            gcand = g_eval(pcb, xhat.reshape(-1, d), *gw)  # [n*B, K, d]
            diff = r[:, None, :] - gcand
            scores = jnp.sum(diff * diff, axis=-1)         # [n*B, K]
        else:
            scores = presel_eval(r, pcb, use_pallas)       # [n*B, K]
        top_a = smallest_k(scores, A).reshape(n, B, A)     # [n, B, A]

        c = cb[top_a].reshape(-1, d)                       # [n*B*A, d]
        xh_b = jnp.broadcast_to(xhat[:, :, None, :], (n, B, A, d))
        f = f_eval(c, xh_b.reshape(-1, d), *fw, use_pallas=use_pallas)
        new_xhat = xh_b + f.reshape(n, B, A, d)

        diff = x[:, None, None, :] - new_xhat
        e = jnp.sum(diff * diff, axis=-1)                  # [n, B, A]
        e = jnp.where(jnp.isinf(err)[:, :, None], jnp.inf, e)

        e_flat = e.reshape(n, B * A)
        sel = smallest_k(e_flat, B)                        # best B expansions
        nxt_err = jnp.take_along_axis(e_flat, sel, axis=1)
        b_idx, a_idx = sel // A, sel % A
        batch = jnp.arange(n)[:, None]
        nxt_xhat = new_xhat[batch, b_idx, a_idx]
        nxt_codes = codes[batch, b_idx]
        chosen = top_a[batch, b_idx, a_idx]
        nxt_codes = nxt_codes.at[:, :, m_idx].set(chosen)
        return (nxt_xhat, nxt_err, nxt_codes), None

    xs = (jnp.arange(m_steps), params["codebooks"], params["presel"]) \
        + _step_params(params, _F_NAMES) + _step_params(params, g_names)
    (xhat, err, codes), _ = lax.scan(step, (xhat0, err0, codes0), xs)
    return codes[:, 0, :], xhat[:, 0, :], err[:, 0]


# ---------------------------------------------------------------------------
# Training step (App. A.2)
# ---------------------------------------------------------------------------


def _loss_and_stats(params, x, codes, use_pallas=True):
    """Differentiable reconstruction loss on fixed codes + residual stats.

    Loss = mean over steps of per-step reconstruction MSE (trains every
    prefix, enabling multi-rate use, Fig. S3) + auxiliary pre-selection
    loss pulling C~^m (and g when present) toward the step-m residuals
    (stop-gradient on the target, k-means-flavoured).
    """
    n, d = x.shape
    use_g = "g_cond_w" in params
    g_names = G_PARAM_NAMES if use_g else []

    def step(xhat, xs):
        code_m, cb, pcb = xs[0], xs[1], xs[2]
        fw = xs[3:3 + len(_F_NAMES)]
        gw = xs[3 + len(_F_NAMES):]
        r = lax.stop_gradient(x - xhat)                    # residual r^m
        c = cb[code_m]
        f = f_eval(c, xhat, *fw, use_pallas=use_pallas)
        nxt = xhat + f
        step_loss = jnp.mean(jnp.sum((x - nxt) ** 2, axis=-1))
        if use_g:
            gsel = g_eval(pcb, lax.stop_gradient(xhat), *gw)  # [n, K, d]
            psel = jnp.take_along_axis(
                gsel, code_m[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            psel = pcb[code_m]
        aux = jnp.mean(jnp.sum((r - psel) ** 2, axis=-1))
        stats = (jnp.mean(r, axis=0), jnp.mean(r * r, axis=0))
        return nxt, (step_loss, aux, stats)

    xs = (codes.T, params["codebooks"], params["presel"]) \
        + _step_params(params, _F_NAMES) + _step_params(params, g_names)
    _, (step_losses, auxes, (res_mean, res_m2)) = lax.scan(
        step, jnp.zeros((n, d), jnp.float32), xs)
    loss_main = jnp.mean(step_losses)
    loss = loss_main + jnp.mean(auxes)
    return loss, (loss_main, step_losses, res_mean, res_m2)


def train_step(params, m_state, v_state, x, codes, lr, t,
               optimizer="adamw", clip=0.1, wd=0.1, use_pallas=True):
    """One outer optimization step on pre-encoded codes.

    Args:
      params/m_state/v_state: parameter dict + Adam moments (same keys).
      x: [N, d] batch. codes: [N, M] int32 (from the encode artifact).
      lr: scalar learning rate (schedule lives in the Rust driver).
      t: scalar step count (1-based) for bias correction.
      optimizer: "adamw" (new recipe: clip + decoupled wd) or "adam"
        (QINCo's old recipe: no clip, no wd) — the Table 3 ablation.
    Returns:
      (new_params, new_m, new_v, loss, step_losses [M], res_mean [M,d],
       res_m2 [M,d]).
    """
    grad_fn = jax.value_and_grad(_loss_and_stats, has_aux=True)
    (loss, (loss_main, step_losses, res_mean, res_m2)), grads = grad_fn(
        params, x, codes, use_pallas)

    if optimizer == "adamw" and clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, clip / (gnorm + 1e-12))
        grads = {k: g * scale for k, g in grads.items()}

    b1, b2, eps = 0.9, 0.999, 1e-8
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    for name, g in grads.items():
        m = b1 * m_state[name] + (1 - b1) * g
        v = b2 * v_state[name] + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        p = params[name] - lr * upd
        if optimizer == "adamw" and name in DECAYED:
            p = p - lr * wd * params[name]
        new_p[name], new_m[name], new_v[name] = p, m, v
    return new_p, new_m, new_v, loss_main, step_losses, res_mean, res_m2
