"""AOT pipeline: lower the L2 model to HLO *text* artifacts + manifest.

Python runs ONCE, at build time (`make artifacts`); the Rust coordinator
loads `artifacts/<name>.hlo.txt` through the PJRT CPU client and never
imports Python again.

Interchange format is HLO text, NOT `lowered.compile()`/`.serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Every artifact is a pure function: (params..., data...) -> outputs. The
manifest (artifacts/manifest.json) is the ABI: it lists, per artifact, the
exact input/output tensor names, shapes and dtypes in positional order,
plus per-model parameter inventories so Rust can allocate/initialize the
parameter store itself.
"""

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_specs(cfg):
    return [_spec(n, s) for n, s in M.param_shapes(cfg).items()]


# ---------------------------------------------------------------------------
# Artifact builders: each returns (jitted_fn, example_args, in_specs, out_specs)
# ---------------------------------------------------------------------------


def _params_struct(cfg):
    return {n: jax.ShapeDtypeStruct(s, jnp.float32)
            for n, s in M.param_shapes(cfg).items()}


def _build_encode(cfg, A, B, n):
    names = M.param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return M.encode(params, args[-1], A, B)

    struct = _params_struct(cfg)
    ex = [struct[n_] for n_ in names] + [
        jax.ShapeDtypeStruct((n, cfg.d), jnp.float32)]
    ins = _param_specs(cfg) + [_spec("x", (n, cfg.d))]
    outs = [_spec("codes", (n, cfg.M), "i32"), _spec("xhat", (n, cfg.d)),
            _spec("err", (n,))]
    return fn, ex, ins, outs


_DEC_NAMES = ["codebooks"] + M._F_NAMES


def _build_decode(cfg, n, partial=False):
    def fn(*args):
        params = dict(zip(_DEC_NAMES, args[:-1]))
        if partial:
            return (M.decode_partial(params, args[-1]),)
        return (M.decode(params, args[-1]),)

    struct = _params_struct(cfg)
    ex = [struct[n_] for n_ in _DEC_NAMES] + [
        jax.ShapeDtypeStruct((n, cfg.M), jnp.int32)]
    shapes = M.param_shapes(cfg)
    ins = [_spec(n_, shapes[n_]) for n_ in _DEC_NAMES] + [
        _spec("codes", (n, cfg.M), "i32")]
    if partial:
        outs = [_spec("xhat_partial", (cfg.M, n, cfg.d))]
    else:
        outs = [_spec("xhat", (n, cfg.d))]
    return fn, ex, ins, outs


def _build_train(cfg, n, optimizer):
    names = M.param_names(cfg)
    np_ = len(names)

    def fn(*args):
        params = dict(zip(names, args[:np_]))
        m_state = dict(zip(names, args[np_:2 * np_]))
        v_state = dict(zip(names, args[2 * np_:3 * np_]))
        x, codes, lr, t = args[3 * np_:]
        new_p, new_m, new_v, loss, step_losses, res_mean, res_m2 = M.train_step(
            params, m_state, v_state, x, codes, lr, t, optimizer=optimizer)
        flat = [new_p[n_] for n_ in names] + [new_m[n_] for n_ in names] \
            + [new_v[n_] for n_ in names]
        return tuple(flat) + (loss, step_losses, res_mean, res_m2)

    struct = _params_struct(cfg)
    pex = [struct[n_] for n_ in names]
    ex = pex * 3 + [
        jax.ShapeDtypeStruct((n, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((n, cfg.M), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    ps = _param_specs(cfg)
    ins = ps \
        + [_spec("m_" + s["name"], s["shape"]) for s in ps] \
        + [_spec("v_" + s["name"], s["shape"]) for s in ps] \
        + [_spec("x", (n, cfg.d)), _spec("codes", (n, cfg.M), "i32"),
           _spec("lr", ()), _spec("t", ())]
    outs = [_spec("new_" + s["name"], s["shape"]) for s in ps] \
        + [_spec("new_m_" + s["name"], s["shape"]) for s in ps] \
        + [_spec("new_v_" + s["name"], s["shape"]) for s in ps] \
        + [_spec("loss", ()), _spec("step_losses", (cfg.M,)),
           _spec("res_mean", (cfg.M, cfg.d)), _spec("res_m2", (cfg.M, cfg.d))]
    return fn, ex, ins, outs


def _build_f_step(cfg, n):
    """Single f_theta application (per-step weights) — runtime smoke tests
    and Table S2 decode micro-timing."""

    def fn(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w):
        return (M.f_eval(c, xhat, in_w, cond_w, cond_b, up_w, down_w, out_w),)

    d, de, dh, L = cfg.d, cfg.de, cfg.dh, cfg.L
    shapes = [(n, d), (n, d), (d, de), (de + d, de), (de,),
              (L, de, dh), (L, dh, de), (de, d)]
    names = ["c", "xhat", "in_w", "cond_w", "cond_b", "up_w", "down_w", "out_w"]
    ex = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    ins = [_spec(nm, s) for nm, s in zip(names, shapes)]
    outs = [_spec("f", (n, d))]
    return fn, ex, ins, outs


# ---------------------------------------------------------------------------
# Catalogs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Art:
    name: str
    kind: str  # encode | decode | decode_partial | train_adamw | train_adam | f_step
    model: str
    A: int = 0
    B: int = 0
    N: int = 0


# Model registry: scaled-down counterparts of the paper's Table 2, sized
# for CPU training (see DESIGN.md §Substitutions). d=32 synthetic data,
# K=64 codebooks, M=16 steps (8-code operating points use prefixes, which
# the per-step loss trains directly — Fig. S3 justifies multi-rate use).
MODELS: Dict[str, M.ModelCfg] = {
    # tiny config for unit/integration tests
    "test": M.ModelCfg(d=8, M=3, K=8, L=1, de=8, dh=16),
    "test_g": M.ModelCfg(d=8, M=2, K=8, L=1, de=8, dh=16, Ls=1, dhg=16),
    # "QINCo (reproduction)": de = d, QINCo-ish width, greedy encoding
    "qinco1": M.ModelCfg(d=32, M=16, K=64, L=2, de=32, dh=64),
    # QINCo2 improved architecture (de != d, wider, deeper)
    "qinco2_xs": M.ModelCfg(d=32, M=16, K=64, L=2, de=48, dh=96),
    "qinco2_s": M.ModelCfg(d=32, M=16, K=64, L=4, de=48, dh=96),
    "qinco2_m": M.ModelCfg(d=32, M=16, K=64, L=8, de=64, dh=128),
    # shorter-code variants of XS for the multi-rate study (Fig. S3)
    "qinco2_xs_m8": M.ModelCfg(d=32, M=8, K=64, L=2, de=48, dh=96),
    "qinco2_xs_m4": M.ModelCfg(d=32, M=4, K=64, L=2, de=48, dh=96),
}

# Fig. 5 sweep grid (L, de, dh)
for _L in (1, 2, 4):
    for _de, _dh in ((32, 64), (48, 96), (64, 128)):
        MODELS[f"sw_L{_L}_de{_de}"] = M.ModelCfg(
            d=32, M=8, K=64, L=_L, de=_de, dh=_dh)
# Fig. 4-left: pre-selection network depth L_s
for _ls in (1, 2):
    MODELS[f"qinco2_xs_Ls{_ls}"] = M.ModelCfg(
        d=32, M=16, K=64, L=2, de=48, dh=96, Ls=_ls, dhg=64)


def _model_arts(model, train_ab, eval_abs, n_enc=512, n_dec=512, n_train=256,
                optimizers=("adamw",)):
    """Standard artifact set for one model."""
    arts = []
    seen = set()
    for a, b in [train_ab] + list(eval_abs):
        if (a, b) in seen:
            continue
        seen.add((a, b))
        arts.append(Art(f"enc_{model}_A{a}_B{b}_N{n_enc}", "encode", model,
                        a, b, n_enc))
    arts.append(Art(f"dec_{model}_N{n_dec}", "decode", model, N=n_dec))
    arts.append(Art(f"dec_{model}_N32", "decode", model, N=32))
    arts.append(Art(f"decp_{model}_N{n_dec}", "decode_partial", model, N=n_dec))
    for opt in optimizers:
        arts.append(Art(f"train_{opt}_{model}_N{n_train}", f"train_{opt}",
                        model, N=n_train))
    return arts


def catalog(which: str) -> List[Art]:
    if which == "test":
        arts = []
        arts += _model_arts("test", (4, 4), [(8, 1), (4, 1)], n_enc=16,
                            n_dec=16, n_train=16,
                            optimizers=("adamw", "adam"))
        arts += _model_arts("test_g", (4, 2), [], n_enc=16, n_dec=16,
                            n_train=16)
        arts.append(Art("fstep_test_N16", "f_step", "test", N=16))
        return arts
    if which == "base":
        arts = []
        # QINCo reproduction: exact greedy (A=K, B=1), old + new recipe
        arts += _model_arts("qinco1", (64, 1), [], optimizers=("adamw", "adam"))
        # QINCo2: pre-selection-only (A8 B1), beam (A8 B8), larger eval beam
        arts += _model_arts("qinco2_xs", (8, 8),
                            [(8, 1), (16, 16), (64, 1), (8, 4)])
        arts += _model_arts("qinco2_s", (8, 8), [(16, 16)])
        arts += _model_arts("qinco2_m", (8, 8), [(16, 16)])
        arts += _model_arts("qinco2_xs_m8", (8, 8), [(16, 16)])
        arts += _model_arts("qinco2_xs_m4", (8, 8), [(16, 16)])
        arts.append(Art("fstep_qinco2_xs_N512", "f_step", "qinco2_xs", N=512))
        # single-vector-ish encode for latency-style timing (Table S2)
        arts.append(Art("enc_qinco2_xs_A8_B8_N32", "encode", "qinco2_xs",
                        8, 8, 32))
        return arts
    if which == "sweep":  # Fig. 5
        arts = []
        for name in MODELS:
            if name.startswith("sw_"):
                arts += _model_arts(name, (8, 8),
                                    [(4, 1), (8, 4), (16, 16), (16, 32)])
        return arts
    if which == "fig4":  # pre-selection depth + enc/dec tradeoff
        arts = []
        for name in ("qinco2_xs_Ls1", "qinco2_xs_Ls2"):
            arts += _model_arts(name, (8, 8), [(4, 4), (16, 16)])
        # extra A/B eval points on the base models (Fig. 4 right, S4, S5)
        for a, b in [(2, 8), (4, 8), (16, 8), (8, 2), (8, 16), (8, 32),
                     (2, 16), (4, 16), (32, 16), (16, 64)]:
            arts.append(Art(f"enc_qinco2_xs_A{a}_B{b}_N512", "encode",
                            "qinco2_xs", a, b, 512))
        return arts
    raise ValueError(f"unknown catalog {which!r}")


BUILDERS = {
    "encode": lambda cfg, a: _build_encode(cfg, a.A, a.B, a.N),
    "decode": lambda cfg, a: _build_decode(cfg, a.N),
    "decode_partial": lambda cfg, a: _build_decode(cfg, a.N, partial=True),
    "train_adamw": lambda cfg, a: _build_train(cfg, a.N, "adamw"),
    "train_adam": lambda cfg, a: _build_train(cfg, a.N, "adam"),
    "f_step": lambda cfg, a: _build_f_step(cfg, a.N),
}


def build(arts: List[Art], out_dir: str, manifest_path: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "models": {}, "artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    used_models = {a.model for a in arts}
    for name in used_models:
        cfg = MODELS[name]
        manifest["models"][name] = {
            "cfg": dataclasses.asdict(cfg),
            "params": _param_specs(cfg),
            "num_params": M.num_params(cfg),
        }

    existing = {a["name"] for a in manifest["artifacts"]}
    for art in arts:
        if art.name in existing:
            continue
        cfg = MODELS[art.model]
        t0 = time.time()
        fn, ex, ins, outs = BUILDERS[art.kind](cfg, art)
        lowered = jax.jit(fn).lower(*ex)
        text = to_hlo_text(lowered)
        fname = f"{art.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": art.name, "file": fname, "kind": art.kind,
            "model": art.model, "A": art.A, "B": art.B, "N": art.N,
            "inputs": ins, "outputs": outs,
        })
        print(f"  {art.name}: {len(text) / 1e6:.2f} MB HLO "
              f"({time.time() - t0:.1f}s)")
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--catalog", default="test,base",
                    help="comma-separated catalogs: test,base,sweep,fig4")
    args = ap.parse_args()

    arts, seen = [], set()
    for c in args.catalog.split(","):
        for a in catalog(c.strip()):
            if a.name not in seen:
                seen.add(a.name)
                arts.append(a)
    print(f"lowering {len(arts)} artifacts -> {args.out}")
    t0 = time.time()
    build(arts, args.out, os.path.join(args.out, "manifest.json"))
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
