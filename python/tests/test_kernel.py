"""pytest: L1 Pallas kernels vs the pure-jnp oracle — the CORE
correctness signal for the kernel layer.

hypothesis sweeps shapes and value ranges; dtype coverage is f32 (the
model ABI) plus a bf16 smoke check for the TPU story.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import preselect, qinco_step, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _weights(key, d, de, dh, L, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return dict(
        in_w=_rand(ks[0], (d, de), dtype, 0.5),
        cond_w=_rand(ks[1], (de + d, de), dtype, 0.3),
        cond_b=_rand(ks[2], (de,), dtype, 0.1),
        up_w=_rand(ks[3], (L, de, dh), dtype, 0.3),
        down_w=_rand(ks[4], (L, dh, de), dtype, 0.3),
        out_w=_rand(ks[5], (de, d), dtype, 0.5),
    )


# ---------------------------------------------------------------------------
# f_theta kernel
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 600),
    d=st.integers(2, 24),
    de=st.integers(2, 24),
    dh=st.integers(2, 32),
    L=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_f_theta_matches_ref(n, d, de, dh, L, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    w = _weights(k1, d, de, dh, L)
    c = _rand(k2, (n, d))
    xhat = _rand(k3, (n, d))
    got = qinco_step.f_theta(c, xhat, **w)
    want = ref.f_theta_ref(c, xhat, **w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tile", [1, 3, 64, 512, 1024])
def test_f_theta_tile_sizes(tile):
    """Tiling (incl. padding path) must not change results."""
    key = jax.random.PRNGKey(0)
    w = _weights(key, 8, 12, 16, 2)
    c = _rand(jax.random.PRNGKey(1), (130, 8))
    xhat = _rand(jax.random.PRNGKey(2), (130, 8))
    got = qinco_step.f_theta(c, xhat, tile=tile, **w)
    want = ref.f_theta_ref(c, xhat, **w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_f_theta_zero_blocks_is_affine_residual():
    """With L=0 and zeroed cond layer, f(c|x) = c + P_out(P_in(c))."""
    d, de = 6, 6
    w = dict(
        in_w=jnp.eye(d), cond_w=jnp.zeros((de + d, de)),
        cond_b=jnp.zeros((de,)), up_w=jnp.zeros((0, de, 8)),
        down_w=jnp.zeros((0, 8, de)), out_w=jnp.eye(de),
    )
    c = _rand(jax.random.PRNGKey(3), (17, d))
    xhat = _rand(jax.random.PRNGKey(4), (17, d))
    got = qinco_step.f_theta(c, xhat, **w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(2 * c), rtol=1e-6)


def test_f_theta_bf16_smoke():
    """bf16 path (the MXU dtype) must run and stay close to f32 ref."""
    w = _weights(jax.random.PRNGKey(5), 8, 8, 16, 1, jnp.bfloat16)
    c = _rand(jax.random.PRNGKey(6), (32, 8), jnp.bfloat16)
    xhat = _rand(jax.random.PRNGKey(7), (32, 8), jnp.bfloat16)
    got = qinco_step.f_theta(c, xhat, **w).astype(jnp.float32)
    wf = {k: v.astype(jnp.float32) for k, v in w.items()}
    want = ref.f_theta_ref(c.astype(jnp.float32), xhat.astype(jnp.float32), **wf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.1, atol=0.1)


# ---------------------------------------------------------------------------
# pre-selection kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 700),
    k=st.integers(1, 64),
    d=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_presel_matches_ref(n, k, d, seed):
    key = jax.random.PRNGKey(seed)
    r = _rand(key, (n, d))
    cb = _rand(jax.random.fold_in(key, 1), (k, d))
    got = preselect.presel_scores(r, cb)
    want = ref.presel_scores_ref(r, cb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_presel_self_distance_zero():
    cb = _rand(jax.random.PRNGKey(8), (16, 12))
    got = preselect.presel_scores(cb, cb)
    diag = np.asarray(jnp.diagonal(got))
    np.testing.assert_allclose(diag, np.zeros(16), atol=1e-4)


def test_presel_argmin_is_nearest():
    """Argmin over kernel scores == brute-force nearest neighbor."""
    r = _rand(jax.random.PRNGKey(9), (50, 16))
    cb = _rand(jax.random.PRNGKey(10), (32, 16))
    got = np.asarray(jnp.argmin(preselect.presel_scores(r, cb), axis=1))
    want = np.asarray(jnp.argmin(ref.presel_scores_ref(r, cb), axis=1))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# footprint / flops helpers (used by DESIGN.md §Perf numbers)
# ---------------------------------------------------------------------------


def test_vmem_footprint_model():
    # At the documented TPU tile (512 rows): QINCo2-S/M fit fully
    # resident; QINCo2-L (L=16) exceeds 16 MiB and would stream per-block
    # weights on real TPU (DESIGN.md §Perf). The CPU artifacts use a much
    # larger tile because interpret-mode grids serialize on CPU.
    t = qinco_step.TPU_TILE
    assert qinco_step.vmem_footprint_bytes(d=128, de=128, dh=256, L=2, tile=t) < 16 * 2**20
    assert qinco_step.vmem_footprint_bytes(d=128, de=384, dh=384, L=4, tile=t) < 16 * 2**20
    assert qinco_step.vmem_footprint_bytes(d=128, de=384, dh=384, L=16, tile=t) > 16 * 2**20


def test_mxu_flops_positive():
    assert qinco_step.mxu_flops(32, 48, 96, 2) > 0
