"""pytest: L2 model invariants (encode/decode/train) on tiny configs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelCfg(d=8, M=3, K=8, L=1, de=8, dh=16)
CFG_G = M.ModelCfg(d=8, M=2, K=8, L=1, de=8, dh=16, Ls=1, dhg=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def data():
    return jax.random.normal(jax.random.PRNGKey(1), (32, CFG.d))


def test_encode_decode_roundtrip(params, data):
    """decode(encode(x)) must equal the xhat the encoder reports."""
    codes, xhat, err = M.encode(params, data, A=4, B=4)
    xh2 = M.decode(params, codes)
    np.testing.assert_allclose(np.asarray(xh2), np.asarray(xhat),
                               rtol=1e-4, atol=1e-4)
    want_err = np.sum((np.asarray(data) - np.asarray(xhat)) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(err), want_err, rtol=1e-3, atol=1e-3)


def test_codes_in_range(params, data):
    codes, _, _ = M.encode(params, data, A=4, B=2)
    c = np.asarray(codes)
    assert c.dtype == np.int32
    assert c.min() >= 0 and c.max() < CFG.K


def test_beam_no_worse_than_greedy(params, data):
    """Beam search explores a superset of greedy paths: with the same A,
    mean error must not increase with B."""
    _, _, e1 = M.encode(params, data, A=4, B=1)
    _, _, e8 = M.encode(params, data, A=4, B=8)
    assert float(e8.mean()) <= float(e1.mean()) + 1e-6


def test_larger_a_no_worse_when_greedy(params, data):
    """With B=1 the candidate set grows monotonically with A."""
    _, _, e4 = M.encode(params, data, A=4, B=1)
    _, _, e8 = M.encode(params, data, A=8, B=1)
    assert float(e8.mean()) <= float(e4.mean()) + 1e-6


def test_decode_partial_prefix_consistency(params, data):
    """Partial reconstructions must chain: partial[m] - partial[m-1] is the
    step-m contribution, and partial[M-1] == full decode."""
    codes, _, _ = M.encode(params, data, A=4, B=2)
    partials = M.decode_partial(params, codes)
    full = M.decode(params, codes)
    np.testing.assert_allclose(np.asarray(partials[-1]), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
    assert partials.shape == (CFG.M, data.shape[0], CFG.d)


def test_encoder_is_greedy_optimal_per_step(params, data):
    """With B=1 and A=K the encoder must pick, at every step, the code
    minimizing the exact reconstruction error among all K candidates."""
    codes, _, _ = M.encode(params, data, A=CFG.K, B=1)
    x = np.asarray(data)
    xhat = np.zeros_like(x)
    for m in range(CFG.M):
        best = None
        errs = []
        for k in range(CFG.K):
            c = np.broadcast_to(np.asarray(params["codebooks"][m][k]), x.shape)
            f = np.asarray(M.f_eval(jnp.asarray(c), jnp.asarray(xhat),
                                    *(params[n][m] for n in M._F_NAMES)))
            errs.append(np.sum((x - (xhat + f)) ** 2, axis=1))
        errs = np.stack(errs, axis=1)  # [N, K]
        best = errs.argmin(axis=1)
        np.testing.assert_array_equal(np.asarray(codes)[:, m], best)
        # advance xhat along the chosen path
        chosen = np.asarray(params["codebooks"])[m][best]
        f = np.asarray(M.f_eval(jnp.asarray(chosen), jnp.asarray(xhat),
                                *(params[n][m] for n in M._F_NAMES)))
        xhat = xhat + f


@settings(max_examples=10, deadline=None)
@given(a=st.integers(1, 8), b=st.integers(1, 8), seed=st.integers(0, 10**6))
def test_encode_valid_for_any_ab(a, b, seed):
    params = M.init_params(CFG, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, CFG.d))
    codes, xhat, err = M.encode(params, x, A=a, B=b)
    c = np.asarray(codes)
    assert c.min() >= 0 and c.max() < CFG.K
    assert np.isfinite(np.asarray(err)).all()
    np.testing.assert_allclose(np.asarray(M.decode(params, codes)),
                               np.asarray(xhat), rtol=1e-4, atol=1e-4)


def test_train_step_reduces_loss(params, data):
    """A few AdamW steps on fixed codes must reduce the loss."""
    codes, _, _ = M.encode(params, data, A=4, B=2)
    p = params
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in p.items()}
    losses = []
    for t in range(1, 6):
        p, m, v, loss, _, _, _ = M.train_step(
            p, m, v, data, codes, jnp.float32(1e-2), jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_train_step_residual_stats(params, data):
    """res_mean/res_m2 returned by train_step must match the residuals of
    a straight decode pass."""
    codes, _, _ = M.encode(params, data, A=4, B=2)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    _, _, _, _, _, res_mean, res_m2 = M.train_step(
        params, m, m, data, codes, jnp.float32(0.0), jnp.float32(1.0))
    partials = np.asarray(M.decode_partial(params, codes))
    x = np.asarray(data)
    xhat_prev = np.zeros_like(x)
    for step in range(CFG.M):
        r = x - xhat_prev
        np.testing.assert_allclose(np.asarray(res_mean)[step], r.mean(0),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(res_m2)[step], (r * r).mean(0),
                                   rtol=1e-3, atol=1e-3)
        xhat_prev = partials[step]


def test_adam_and_adamw_both_step(params, data):
    codes, _, _ = M.encode(params, data, A=4, B=2)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    for opt in ("adam", "adamw"):
        p2 = M.train_step(params, m, m, data, codes, jnp.float32(1e-3),
                          jnp.float32(1.0), optimizer=opt)[0]
        delta = max(float(jnp.abs(p2[k] - params[k]).max()) for k in params)
        assert delta > 0, opt


def test_lr_zero_adam_keeps_params(params, data):
    codes, _, _ = M.encode(params, data, A=4, B=2)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    p2 = M.train_step(params, m, m, data, codes, jnp.float32(0.0),
                      jnp.float32(1.0), optimizer="adam")[0]
    for k in params:
        np.testing.assert_allclose(np.asarray(p2[k]), np.asarray(params[k]))


def test_g_network_model(params, data):
    """L_s >= 1 pre-selection network: encode + train must work and decode
    must be independent of g."""
    pg = M.init_params(CFG_G, jax.random.PRNGKey(3))
    codes, xhat, err = M.encode(pg, data, A=4, B=2)
    assert np.isfinite(np.asarray(err)).all()
    m = {k: jnp.zeros_like(v) for k, v in pg.items()}
    out = M.train_step(pg, m, m, data, codes, jnp.float32(1e-3),
                       jnp.float32(1.0))
    assert np.isfinite(float(out[3]))


def test_num_params_table_s1_scaling():
    """Table S1: QINCo2 param counts grow S < M < L (paper's native dims)."""
    s = M.num_params(M.ModelCfg(d=128, M=8, K=256, L=2, de=128, dh=256))
    mm = M.num_params(M.ModelCfg(d=128, M=8, K=256, L=4, de=384, dh=384))
    ll = M.num_params(M.ModelCfg(d=128, M=8, K=256, L=16, de=384, dh=384))
    assert s < mm < ll
    # paper reports 1.6M / 10.8M / 35.6M (incl. both codebooks); ours must
    # land in the same ballpark (within 2x) to validate the arch wiring.
    assert 0.5e6 < s < 3.2e6
    assert 5e6 < mm < 22e6
    assert 18e6 < ll < 71e6
