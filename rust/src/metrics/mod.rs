//! Evaluation metrics: reconstruction MSE and recall@rank — the two axes
//! of every table in the paper.

use crate::tensor::Matrix;

/// Mean squared error between original and reconstructed vectors
/// (sum over dims, mean over rows — the paper's convention).
pub fn mse(xs: &Matrix, xhat: &Matrix) -> f64 {
    crate::tensor::mse(xs, xhat)
}

/// Recall@rank: fraction of queries whose true nearest neighbor appears
/// in the first `rank` results. `results[q]` is the ranked candidate list
/// for query q.
pub fn recall_at(results: &[Vec<u32>], ground_truth: &[u32], rank: usize) -> f64 {
    assert_eq!(results.len(), ground_truth.len());
    if results.is_empty() {
        return 0.0;
    }
    let hits = results
        .iter()
        .zip(ground_truth)
        .filter(|(r, &g)| r.iter().take(rank).any(|&x| x == g))
        .count();
    hits as f64 / results.len() as f64
}

/// R@1 / R@10 / R@100 triple (Table S4).
pub fn recall_triple(results: &[Vec<u32>], gt: &[u32]) -> (f64, f64, f64) {
    (
        recall_at(results, gt, 1),
        recall_at(results, gt, 10),
        recall_at(results, gt, 100),
    )
}

/// Strip scores from ranked `(score, id)` result lists — the recall
/// helpers take plain id lists, while the search paths
/// ([`crate::index::SearchIndex::search_batch`] and the per-query
/// search) both return scored results.
pub fn ids_only(results: &[Vec<(f32, u32)>]) -> Vec<Vec<u32>> {
    results
        .iter()
        .map(|r| r.iter().map(|&(_, id)| id).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recall_exact() {
        let results = vec![vec![5, 1, 2], vec![0, 7, 9], vec![3, 3, 3]];
        let gt = vec![5, 9, 4];
        assert!((recall_at(&results, &gt, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((recall_at(&results, &gt, 3) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn recall_monotone_in_rank() {
        let results = vec![vec![1, 2, 3, 4], vec![4, 3, 2, 1]];
        let gt = vec![4, 1];
        let r1 = recall_at(&results, &gt, 1);
        let r2 = recall_at(&results, &gt, 2);
        let r4 = recall_at(&results, &gt, 4);
        assert!(r1 <= r2 && r2 <= r4);
        assert_eq!(r4, 1.0);
    }

    #[test]
    fn empty_results_zero() {
        assert_eq!(recall_at(&[], &[], 1), 0.0);
    }

    #[test]
    fn shorter_lists_than_rank() {
        let results = vec![vec![7]];
        let gt = vec![7];
        assert_eq!(recall_at(&results, &gt, 100), 1.0);
    }
}
