//! `qinco2` CLI — the L3 coordinator entrypoint. See `qinco2 help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = qinco2::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
