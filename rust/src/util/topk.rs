//! Bounded top-k shortlist: a binary max-heap over `(score, id)` pairs.
//!
//! Replaces the sorted-`Vec::insert` shortlist of the stage-1 scan, whose
//! O(k) memmove per accepted candidate dominated large-`n_aq` settings;
//! the heap does O(log k) swaps instead. Ordering is the *total* order
//! (score, then id): ties at the capacity boundary resolve by id, so the
//! kept set — and therefore the whole search pipeline — is independent of
//! candidate visit order. That invariant is what lets the bucket-grouped
//! batch engine ([`crate::index::batch`]) visit candidates in a different
//! order than the per-query path yet return identical results (the
//! `batch_equivalence` and `coordinator_props` suites pin this).

/// Strict "a ranks before b" under the (score, id) total order.
/// `total_cmp` keeps the comparison total even for non-finite scores.
#[inline]
fn before(a: (f32, u32), b: (f32, u32)) -> bool {
    match a.0.total_cmp(&b.0) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.1 < b.1,
    }
}

/// A fixed-capacity "keep the k smallest" collector.
#[derive(Clone, Debug)]
pub struct Shortlist {
    cap: usize,
    /// max-heap: `heap[0]` is the worst-ranked kept entry
    heap: Vec<(f32, u32)>,
}

impl Shortlist {
    pub fn new(cap: usize) -> Shortlist {
        Shortlist { cap, heap: Vec::with_capacity(cap.min(4096)) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The worst (highest-ranked) kept entry, if any.
    #[inline]
    pub fn worst(&self) -> Option<(f32, u32)> {
        self.heap.first().copied()
    }

    /// Offer a candidate; keeps it iff it ranks among the `cap` best seen.
    #[inline]
    pub fn push(&mut self, score: f32, id: u32) {
        if self.heap.len() < self.cap {
            self.heap.push((score, id));
            self.sift_up(self.heap.len() - 1);
        } else if self.cap > 0 && before((score, id), self.heap[0]) {
            self.heap[0] = (score, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if before(self.heap[parent], self.heap[i]) {
                self.heap.swap(parent, i);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut top = i;
            if l < n && before(self.heap[top], self.heap[l]) {
                top = l;
            }
            if r < n && before(self.heap[top], self.heap[r]) {
                top = r;
            }
            if top == i {
                return;
            }
            self.heap.swap(i, top);
            i = top;
        }
    }

    /// Consume into an ascending (score, id) ranking.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.heap
            .sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        self.heap
    }

    /// Absorb every entry of `other`, keeping the `cap` best of the
    /// union under the total (score, id) order. Because the order is
    /// total, merging partial shortlists in any order (or pushing all
    /// candidates into one list directly) yields the same kept set —
    /// the gather step of the parallel scan and the per-shard scatter
    /// path both rely on this.
    pub fn merge_from(&mut self, other: Shortlist) {
        for (s, id) in other.heap {
            self.push(s, id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn keeps_exactly_the_k_smallest() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(30);
            let scores: Vec<f32> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let mut sl = Shortlist::new(k);
            for (id, &s) in scores.iter().enumerate() {
                sl.push(s, id as u32);
            }
            let got = sl.into_sorted();
            let mut want: Vec<(f32, u32)> =
                scores.iter().enumerate().map(|(i, &s)| (s, i as u32)).collect();
            want.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            want.truncate(k);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn insertion_order_independent_under_ties() {
        // many equal scores: the kept set must be the same for any order
        let items: Vec<(f32, u32)> =
            vec![(1.0, 9), (1.0, 2), (0.5, 7), (1.0, 4), (0.5, 1), (2.0, 0)];
        let mut fwd = Shortlist::new(3);
        let mut rev = Shortlist::new(3);
        for &(s, id) in &items {
            fwd.push(s, id);
        }
        for &(s, id) in items.iter().rev() {
            rev.push(s, id);
        }
        let (a, b) = (fwd.into_sorted(), rev.into_sorted());
        assert_eq!(a, b);
        assert_eq!(a, vec![(0.5, 1), (0.5, 7), (1.0, 2)]);
    }

    #[test]
    fn zero_capacity_accepts_nothing() {
        let mut sl = Shortlist::new(0);
        sl.push(0.0, 1);
        assert!(sl.is_empty());
        assert!(sl.into_sorted().is_empty());
    }

    #[test]
    fn merge_from_equals_direct_push() {
        // property: partition a candidate stream into arbitrary partial
        // shortlists, merge them — same kept set as one direct pass
        crate::util::prop::check("merge-from", 60, 120, |g| {
            let n = 1 + g.usize_in(0, g.size);
            let cap = g.usize_in(0, 16);
            let items: Vec<(f32, u32)> = (0..n)
                .map(|id| {
                    // coarse grid forces plenty of score ties
                    let s = (g.rng.uniform(-4.0, 4.0) as i32) as f32;
                    (s, id as u32)
                })
                .collect();
            let mut direct = Shortlist::new(cap);
            for &(s, id) in &items {
                direct.push(s, id);
            }
            let n_parts = 1 + g.usize_in(0, 4);
            let mut parts: Vec<Shortlist> =
                (0..n_parts).map(|_| Shortlist::new(cap)).collect();
            for &(s, id) in &items {
                parts[g.usize_in(0, n_parts - 1)].push(s, id);
            }
            let mut merged = Shortlist::new(cap);
            for p in parts {
                merged.merge_from(p);
            }
            let (a, b) = (merged.into_sorted(), direct.into_sorted());
            if a == b {
                Ok(())
            } else {
                Err(format!("merged {a:?} != direct {b:?}"))
            }
        });
    }

    #[test]
    fn worst_tracks_the_boundary_entry() {
        let mut sl = Shortlist::new(2);
        assert_eq!(sl.worst(), None);
        sl.push(3.0, 0);
        sl.push(1.0, 1);
        assert_eq!(sl.worst(), Some((3.0, 0)));
        sl.push(2.0, 2); // evicts (3.0, 0)
        assert_eq!(sl.worst(), Some((2.0, 2)));
        assert_eq!(sl.into_sorted(), vec![(1.0, 1), (2.0, 2)]);
    }
}
