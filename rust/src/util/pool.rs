//! Scoped thread pool (rayon/tokio are unavailable offline).
//!
//! `scope_chunks` is the workhorse: split an index range into contiguous
//! chunks and run a closure per chunk on `nthreads` OS threads. Used by
//! k-means assignment, LUT scans, database encoding and the brute-force
//! ground-truth computation.

/// Number of worker threads to use by default (respects `QINCO2_THREADS`).
pub fn default_threads() -> usize {
    match std::env::var("QINCO2_THREADS") {
        Ok(v) => parse_threads(&v),
        Err(_) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Parse a `QINCO2_THREADS` override. A malformed value is a hard error:
/// silently falling back to all cores would run e.g. a
/// `QINCO2_THREADS=4x` benchmark at the wrong thread count and skew its
/// numbers — the same bug class as malformed CLI flags (`cli::Args`).
/// `0` means "let the runtime decide", clamped to 1.
fn parse_threads(v: &str) -> usize {
    match v.trim().parse::<usize>() {
        Ok(n) => n.max(1),
        Err(_) => panic!("QINCO2_THREADS must be an unsigned integer, got {v:?}"),
    }
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into roughly equal
/// contiguous chunks, one per thread. `f` runs on borrowed state thanks to
/// `std::thread::scope`.
pub fn scope_chunks<F>(n: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nthreads = nthreads.min(n).max(1);
    if nthreads == 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(lo, hi));
        }
    });
}

/// Map over `[0, n)` in parallel, collecting one result per index.
/// Results are written into a pre-allocated buffer through chunked
/// disjoint mutable slices (no locking on the hot path).
pub fn par_map_into<T, F>(out: &mut [T], nthreads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let nthreads = nthreads.min(n).max(1);
    if nthreads == 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunk = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let fr = &f;
            s.spawn(move || {
                for (j, slot) in slice.iter_mut().enumerate() {
                    fr(t * chunk + j, slot);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 7, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_and_empty() {
        scope_chunks(0, 4, |lo, hi| assert_eq!((lo, hi), (0, 0)));
        let calls = AtomicUsize::new(0);
        scope_chunks(5, 1, |lo, hi| {
            assert_eq!((lo, hi), (0, 5));
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn par_map_into_fills_all() {
        let mut out = vec![0usize; 503];
        par_map_into(&mut out, 8, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn thread_env_parses_or_panics() {
        assert_eq!(parse_threads("4"), 4);
        assert_eq!(parse_threads(" 2 "), 2);
        // 0 is "auto", clamped to at least one thread
        assert_eq!(parse_threads("0"), 1);
    }

    #[test]
    #[should_panic(expected = "QINCO2_THREADS")]
    fn malformed_thread_env_is_a_hard_error() {
        parse_threads("4x");
    }

    #[test]
    fn more_threads_than_items() {
        let mut out = vec![0usize; 3];
        par_map_into(&mut out, 64, |i, slot| *slot = i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
