//! `.qnpz`: a tiny named-tensor container (numpy's .npz is unavailable —
//! no serde / zip stack offline). Little-endian, sequential:
//!
//! ```text
//! magic  b"QNPZ1\0"
//! u32    tensor count
//! per tensor:
//!   u16      name length, then name bytes (utf-8)
//!   u8       dtype: 0 = f32, 1 = i32
//!   u8       ndim
//!   u32*ndim dims
//!   data     row-major, 4 bytes/elem
//! ```
//!
//! Used for model checkpoints, codebooks and dataset caches; written and
//! read by both the Rust trainer and (structurally) by aot.py.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"QNPZ1\0";

#[derive(Clone, Debug, PartialEq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// raw storage; f32 bit patterns for F32, i32 bit patterns for I32
    pub data_f32: Vec<f32>,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { dtype: Dtype::F32, shape, data_f32: data }
    }

    pub fn i32(shape: Vec<usize>, data: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            dtype: Dtype::I32,
            shape,
            data_f32: data.iter().map(|&x| f32::from_bits(x as u32)).collect(),
        }
    }

    pub fn as_i32(&self) -> Vec<i32> {
        self.data_f32.iter().map(|&x| x.to_bits() as i32).collect()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug, Default)]
pub struct Store {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Store {
    pub fn new() -> Store {
        Store::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.push(match t.dtype {
                Dtype::F32 => 0,
                Dtype::I32 => 1,
            });
            buf.push(t.shape.len() as u8);
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data_f32 {
                buf.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        let tmp = path.with_extension("tmp");
        std::fs::File::create(&tmp)?.write_all(&buf)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Store> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut buf)?;
        let mut i = 0usize;
        let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
            if *i + n > buf.len() {
                bail!("truncated qnpz file {path:?}");
            }
            let s = &buf[*i..*i + n];
            *i += n;
            Ok(s)
        };
        if take(&mut i, 6)? != MAGIC {
            bail!("bad magic in {path:?}");
        }
        let count = u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap());
        let mut store = Store::new();
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut i, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut i, nlen)?.to_vec())?;
            let dtype = match take(&mut i, 1)?[0] {
                0 => Dtype::F32,
                1 => Dtype::I32,
                x => bail!("bad dtype {x}"),
            };
            let ndim = take(&mut i, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut i, 4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = shape.iter().product();
            let raw = take(&mut i, numel * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect();
            store.tensors.insert(name, Tensor { dtype, shape, data_f32: data });
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("qnpz_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.qnpz");
        let mut s = Store::new();
        s.insert("a", Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., -6.5]));
        s.insert("codes", Tensor::i32(vec![4], &[0, 7, -1, 2147483647]));
        s.save(&p).unwrap();
        let s2 = Store::load(&p).unwrap();
        assert_eq!(s2.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(s2.get("a").unwrap().data_f32, vec![1., 2., 3., 4., 5., -6.5]);
        assert_eq!(s2.get("codes").unwrap().as_i32(), vec![0, 7, -1, 2147483647]);
        assert_eq!(s2.get("codes").unwrap().dtype, Dtype::I32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let s = Store::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn truncated_file_errors() {
        let dir = std::env::temp_dir().join(format!("qnpz_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.qnpz");
        std::fs::write(&p, b"QNPZ1\0\x05\x00\x00\x00").unwrap();
        assert!(Store::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
