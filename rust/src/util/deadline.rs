//! Request deadlines for the serving stack.
//!
//! A [`Deadline`] is an optional wall-clock instant carried on every
//! [`Request`](crate::server::Request) /
//! [`WriteRequest`](crate::server::WriteRequest) and threaded through
//! the batched engine. It is the one currency of the failure model:
//! the batcher drops requests whose deadline passed before dispatch
//! (typed `DeadlineExceeded`), the engine checks it between bucket-group
//! scans and before stage 3 (degrading to the stage-1/2 shortlist
//! ranking instead of timing out — see
//! [`BatchSearcher::execute_within`](crate::index::BatchSearcher::execute_within)),
//! and the blocking helpers derive their `recv_timeout` from it so no
//! caller can hang on a dead worker.
//!
//! `Deadline::none()` (the default) disables every check: all the
//! deadline-aware paths reduce to their historical behavior, which is
//! what keeps the bit-identity suites (`batch_equivalence`,
//! `mutation_invariants`) pinned.

use std::time::{Duration, Instant};

/// An optional point in time a request must complete by. `Copy`, cheap
/// to carry, cheap to check (`expired` is one `Instant::now()` when set,
/// a branch on `None` otherwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub struct Deadline(Option<Instant>);

impl Deadline {
    /// No deadline: every check passes, every wait is unbounded (the
    /// blocking helpers still apply their own generous default).
    pub const fn none() -> Deadline {
        Deadline(None)
    }

    /// Deadline at a specific instant.
    pub fn at(t: Instant) -> Deadline {
        Deadline(Some(t))
    }

    /// Deadline `d` from now.
    pub fn after(d: Duration) -> Deadline {
        Deadline(Some(Instant::now() + d))
    }

    /// CLI convention: `0` means disabled, anything else is milliseconds
    /// from now.
    pub fn from_ms(ms: u64) -> Deadline {
        if ms == 0 {
            Deadline::none()
        } else {
            Deadline::after(Duration::from_millis(ms))
        }
    }

    /// True when no deadline is set.
    pub fn is_none(&self) -> bool {
        self.0.is_none()
    }

    /// True when a deadline is set and has passed.
    pub fn expired(&self) -> bool {
        match self.0 {
            Some(t) => Instant::now() >= t,
            None => false,
        }
    }

    /// Time left before expiry: `None` when no deadline is set,
    /// `Some(ZERO)` when already expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.0.map(|t| t.saturating_duration_since(Instant::now()))
    }

    /// The earlier of two deadlines (`none` acts as +infinity). Batch
    /// groups execute under the tightest member's deadline — the whole
    /// group degrades together (documented on
    /// [`serve_batch`](crate::server)-level semantics).
    pub fn earliest(self, other: Deadline) -> Deadline {
        match (self.0, other.0) {
            (Some(a), Some(b)) => Deadline(Some(a.min(b))),
            (Some(a), None) => Deadline(Some(a)),
            (None, b) => Deadline(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_expires() {
        let d = Deadline::none();
        assert!(d.is_none());
        assert!(!d.expired());
        assert_eq!(d.remaining(), None);
        assert_eq!(Deadline::default(), Deadline::none());
    }

    #[test]
    fn from_ms_zero_is_disabled() {
        assert!(Deadline::from_ms(0).is_none());
        assert!(!Deadline::from_ms(60_000).is_none());
    }

    #[test]
    fn past_deadline_is_expired_with_zero_remaining() {
        let d = Deadline::at(Instant::now() - Duration::from_millis(5));
        assert!(d.expired());
        assert_eq!(d.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn future_deadline_is_live() {
        let d = Deadline::after(Duration::from_secs(3600));
        assert!(!d.expired());
        let rem = d.remaining().unwrap();
        assert!(rem > Duration::from_secs(3599));
    }

    #[test]
    fn earliest_treats_none_as_infinity() {
        let soon = Deadline::at(Instant::now() + Duration::from_millis(1));
        let late = Deadline::at(Instant::now() + Duration::from_secs(60));
        assert_eq!(soon.earliest(late), soon);
        assert_eq!(late.earliest(soon), soon);
        assert_eq!(Deadline::none().earliest(soon), soon);
        assert_eq!(soon.earliest(Deadline::none()), soon);
        assert_eq!(Deadline::none().earliest(Deadline::none()), Deadline::none());
    }
}
