//! Deterministic pseudo-random numbers (SplitMix64 core).
//!
//! `rand` is unavailable offline; experiments need *reproducible* seeds
//! anyway, so a small, well-understood generator is the right tool.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller output
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(mu, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        for v in out.iter_mut() {
            *v = mu + sigma * self.normal_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices sampled from [0, n) (reservoir).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut res: Vec<usize> = (0..k).collect();
        for i in k..n {
            let j = self.below(i + 1);
            if j < k {
                res[j] = i;
            }
        }
        res
    }

    /// Derive an independent stream (for per-thread / per-module seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut s = xs.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
