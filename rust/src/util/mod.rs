//! Offline-build substrates: JSON, PRNG, tensor checkpoint format, tiny
//! property-testing harness, timers, thread pool.
//!
//! The usual crates (serde, rand, rayon, proptest, criterion) are not
//! available in this offline environment, so the pieces the system needs
//! are implemented here from scratch (see DESIGN.md §Substitutions).

pub mod deadline;
pub mod fault;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod qnpz;
pub mod timer;
pub mod topk;
