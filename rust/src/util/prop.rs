//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a predicate over `n` randomly generated cases; on failure
//! it performs a simple halving shrink over the generator's size
//! parameter and reports the smallest failing (seed, size) so the case
//! can be replayed deterministically.

use crate::util::prng::Rng;

/// A generated case: owns a size hint and a fresh RNG stream.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
}

/// Run `prop` over `cases` random cases with sizes up to `max_size`.
/// Panics with a replayable (seed, size) on the smallest failure found.
pub fn check<P>(name: &str, cases: usize, max_size: usize, prop: P)
where
    P: Fn(&mut Gen) -> Result<(), String>,
{
    run_check(name, 0xC0FFEE, cases, max_size, prop)
}

pub fn run_check<P>(name: &str, seed0: u64, cases: usize, max_size: usize, prop: P)
where
    P: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = seed0.wrapping_add(case as u64);
        let size = 1 + (case * max_size) / cases.max(1);
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            // shrink: halve the size while the failure persists
            let mut best = (seed, size, msg);
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut g2 = Gen { rng: Rng::new(best.0), size: s };
                match prop(&mut g2) {
                    Err(m) => best = (best.0, s, m),
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{}' failed (seed={}, size={}): {}",
                name, best.0, best.1, best.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-nonneg", 50, 100, |g| {
            let v = g.vec_f32(g.size, 0.0, 1.0);
            if v.iter().sum::<f32>() >= 0.0 {
                Ok(())
            } else {
                Err("negative sum".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 10, |_| Err("nope".into()));
    }

    #[test]
    fn usize_in_bounds() {
        check("usize-bounds", 100, 50, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(1, 10);
            let x = g.usize_in(lo, hi);
            if x >= lo && x <= hi {
                Ok(())
            } else {
                Err(format!("{x} not in [{lo},{hi}]"))
            }
        });
    }
}
