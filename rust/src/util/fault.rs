//! Deterministic, seeded fault injection for the serving stack.
//!
//! The serving code is laced with **named injection points** — places a
//! real deployment fails: the batcher stalling, a worker panicking
//! mid-batch, a stage-3 decoder erroring, an ingress queue rejecting, a
//! scan running slow. Each point calls [`fire`], which is a no-op unless
//! (a) the crate is built with the `fault-injection` feature AND (b) a
//! test has installed a [`FaultPlan`]. Production builds compile the
//! probes down to an inlined `None`; even fault-enabled builds pay one
//! mutex lock per probe only while a plan is installed.
//!
//! Determinism: a plan is a set of per-point [`FaultRule`]s keyed by a
//! hit counter — "skip the first `skip` passages, then fire `fires`
//! times" — with any delay jittered by a SplitMix64 stream derived from
//! the plan seed, the point, and the hit index. The same plan against
//! the same request sequence injects the same faults; there is no global
//! randomness and no time dependence. `tests/fault_injection.rs` uses
//! this to prove every injected fault surfaces as a **typed error or a
//! flagged degraded reply** — never a hang, a poisoned lock, or an
//! abort.
//!
//! Plans are process-global (the probes live deep in worker threads that
//! can't be parameterized per-call), so [`install`] also serializes:
//! the returned [`FaultGuard`] holds a static mutex for its lifetime,
//! keeping concurrently-running `#[test]`s from interleaving plans, and
//! uninstalls the plan on drop.

use crate::util::prng::Rng;
use std::time::Duration;

/// The named places a fault can be injected. Each maps to exactly one
/// probe in the serving stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// The batcher sleeps before dispatching a formed batch — models a
    /// stalled dispatch thread; drives deadline-expiry-at-dispatch.
    BatcherDelay = 0,
    /// A read worker panics mid-batch, **while holding its latency-ring
    /// lock** — the worst-case poison scenario for `Router::stats()`.
    WorkerPanic = 1,
    /// Both stage-3 decoders (thread-local and index-held) fail for one
    /// batch group — models a corrupted artifact / runtime fault.
    DecoderError = 2,
    /// A submit is rejected as if the admission gate tripped — models
    /// ingress overload independent of real queue depth.
    QueueFull = 3,
    /// The stage-1 scan sleeps before a bucket group — models a slow /
    /// stalled scan; drives mid-scan deadline degradation.
    SlowScan = 4,
}

/// Number of distinct [`FaultPoint`]s (rule/hit-counter array size).
pub const N_FAULT_POINTS: usize = 5;

/// When and how one [`FaultPoint`] fires: pass `skip` hits untouched,
/// then fire on the next `fires` hits, injecting `delay_ms` plus a
/// deterministic jitter in `[0, jitter_ms]`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultRule {
    pub skip: u64,
    pub fires: u64,
    pub delay_ms: u64,
    pub jitter_ms: u64,
}

impl FaultRule {
    /// Fire on the first `fires` hits, no delay (panic/error/reject
    /// points ignore the delay anyway).
    pub fn first(fires: u64) -> FaultRule {
        FaultRule { skip: 0, fires, delay_ms: 0, jitter_ms: 0 }
    }

    /// Fire on the first `fires` hits with a fixed delay.
    pub fn delay(fires: u64, delay_ms: u64) -> FaultRule {
        FaultRule { skip: 0, fires, delay_ms, jitter_ms: 0 }
    }

    /// Same, but skip the first `skip` hits.
    pub fn after(skip: u64, fires: u64, delay_ms: u64) -> FaultRule {
        FaultRule { skip, fires, delay_ms, jitter_ms: 0 }
    }
}

/// A seeded, deterministic set of per-point rules.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    rules: [Option<FaultRule>; N_FAULT_POINTS],
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: [None; N_FAULT_POINTS] }
    }

    /// Builder-style: attach a rule to one point.
    pub fn with(mut self, point: FaultPoint, rule: FaultRule) -> FaultPlan {
        self.rules[point as usize] = Some(rule);
        self
    }

    /// Whether hit number `n` (0-based) at `point` fires, and with what
    /// delay. Pure function of (plan, point, n) — the determinism
    /// contract.
    fn decide(&self, point: FaultPoint, n: u64) -> Option<Duration> {
        let rule = self.rules[point as usize]?;
        if n < rule.skip || n >= rule.skip + rule.fires {
            return None;
        }
        let mut ms = rule.delay_ms;
        if rule.jitter_ms > 0 {
            let mut rng = Rng::new(
                self.seed ^ (point as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ n,
            );
            ms += rng.next_u64() % (rule.jitter_ms + 1);
        }
        Some(Duration::from_millis(ms))
    }
}

#[cfg(feature = "fault-injection")]
mod active {
    use super::{FaultPlan, FaultPoint, N_FAULT_POINTS};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard};
    use std::time::Duration;

    struct State {
        plan: Option<FaultPlan>,
        hits: [u64; N_FAULT_POINTS],
    }

    static STATE: Mutex<State> = Mutex::new(State { plan: None, hits: [0; N_FAULT_POINTS] });
    /// Serializes tests that install plans (cargo runs `#[test]`s
    /// concurrently; a process-global plan must be exclusive).
    static SERIAL: Mutex<()> = Mutex::new(());
    /// Fast path: probes skip the STATE lock entirely while no plan is
    /// installed, so fault-enabled builds don't serialize hot scans.
    static INSTALLED: AtomicU64 = AtomicU64::new(0);

    /// Uninstalls the plan (and releases the test-serialization lock)
    /// on drop.
    pub struct FaultGuard {
        _serial: MutexGuard<'static, ()>,
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            INSTALLED.store(0, Ordering::SeqCst);
            let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
            st.plan = None;
            st.hits = [0; N_FAULT_POINTS];
        }
    }

    /// Install a plan process-wide until the guard drops. Hit counters
    /// start at zero.
    pub fn install(plan: FaultPlan) -> FaultGuard {
        // an earlier test that panicked mid-plan poisons SERIAL; the
        // guard's Drop still cleared the plan, so recovery is sound
        let serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
            st.plan = Some(plan);
            st.hits = [0; N_FAULT_POINTS];
        }
        INSTALLED.store(1, Ordering::SeqCst);
        FaultGuard { _serial: serial }
    }

    /// Probe: does the installed plan fire at this point, this hit?
    /// Returns the injected delay when it does (`ZERO` for points that
    /// don't sleep). Counts the hit either way.
    pub fn fire(point: FaultPoint) -> Option<Duration> {
        if INSTALLED.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
        let n = st.hits[point as usize];
        st.hits[point as usize] = n + 1;
        st.plan.as_ref().and_then(|p| p.decide(point, n))
    }
}

#[cfg(feature = "fault-injection")]
pub use active::{fire, install, FaultGuard};

/// Probe stub: without the `fault-injection` feature every injection
/// point compiles to an inlined `None` — zero cost in production builds.
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn fire(_point: FaultPoint) -> Option<Duration> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_window_is_half_open() {
        let plan = FaultPlan::new(7).with(FaultPoint::SlowScan, FaultRule::after(2, 3, 10));
        // hits 0,1 skipped; 2,3,4 fire; 5+ pass
        for n in 0..2 {
            assert_eq!(plan.decide(FaultPoint::SlowScan, n), None);
        }
        for n in 2..5 {
            assert_eq!(plan.decide(FaultPoint::SlowScan, n), Some(Duration::from_millis(10)));
        }
        assert_eq!(plan.decide(FaultPoint::SlowScan, 5), None);
        // other points have no rule
        assert_eq!(plan.decide(FaultPoint::WorkerPanic, 0), None);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let plan = FaultPlan::new(42).with(
            FaultPoint::BatcherDelay,
            FaultRule { skip: 0, fires: 100, delay_ms: 5, jitter_ms: 7 },
        );
        for n in 0..100 {
            let a = plan.decide(FaultPoint::BatcherDelay, n).unwrap();
            let b = plan.decide(FaultPoint::BatcherDelay, n).unwrap();
            assert_eq!(a, b, "same (plan, point, hit) must decide identically");
            assert!(a >= Duration::from_millis(5) && a <= Duration::from_millis(12));
        }
        // a different seed moves the jitter (with overwhelming odds over
        // 100 draws)
        let other = FaultPlan::new(43).with(
            FaultPoint::BatcherDelay,
            FaultRule { skip: 0, fires: 100, delay_ms: 5, jitter_ms: 7 },
        );
        assert!(
            (0..100).any(|n| {
                plan.decide(FaultPoint::BatcherDelay, n)
                    != other.decide(FaultPoint::BatcherDelay, n)
            }),
            "seed must influence jitter"
        );
    }

    #[cfg(not(feature = "fault-injection"))]
    #[test]
    fn probe_is_inert_without_the_feature() {
        assert_eq!(fire(FaultPoint::WorkerPanic), None);
        assert_eq!(fire(FaultPoint::SlowScan), None);
    }

    #[cfg(feature = "fault-injection")]
    #[test]
    fn install_fire_uninstall_cycle() {
        // nothing installed → inert
        assert_eq!(fire(FaultPoint::QueueFull), None);
        {
            let _g = install(FaultPlan::new(1).with(FaultPoint::QueueFull, FaultRule::first(2)));
            assert_eq!(fire(FaultPoint::QueueFull), Some(Duration::ZERO));
            assert_eq!(fire(FaultPoint::QueueFull), Some(Duration::ZERO));
            assert_eq!(fire(FaultPoint::QueueFull), None, "rule exhausted after `fires` hits");
            // un-ruled points count hits but never fire
            assert_eq!(fire(FaultPoint::SlowScan), None);
        }
        // guard dropped → inert again, counters reset
        assert_eq!(fire(FaultPoint::QueueFull), None);
    }
}
