//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Full JSON grammar minus `\u` surrogate pairs beyond the BMP; numbers
//! are f64. Used for the AOT artifact manifest, index metadata and bench
//! CSV/JSON outputs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` on non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let n = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err("bad escape".into()),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => esc(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"models": {"m": {"cfg": {"d": 32}}}}"#).unwrap();
        let d = v.get("models").unwrap().get("m").unwrap().get("cfg").unwrap().get("d");
        assert_eq!(d.unwrap().as_usize(), Some(32));
    }
}
