//! Timing helpers for the bench harnesses (criterion is unavailable
//! offline). Median-of-runs wall-clock timing with warmup.

use std::time::Instant;

/// Time `f()` with `warmup` discarded runs and `runs` measured runs;
/// returns (median_secs, min_secs).
pub fn time_median<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let med = samples[samples.len() / 2];
    (med, min)
}

/// Simple scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Pretty time formatting for logs (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_is_ordered() {
        let (med, min) = time_median(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= med);
        assert!(med >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-10).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
