//! Load generator for the TCP front-end (`bench-net` on the CLI):
//! N connections × (closed-loop | fixed-rate), reporting wire-level
//! QPS/p50/p99 plus typed outcome counts (shed / deadline-exceeded /
//! degraded / worker-died), so overload behavior is visible — not just
//! the happy path.
//!
//! - **Closed loop** (`rate == 0`): each connection keeps `pipeline`
//!   requests in flight and issues its share of `requests` as fast as
//!   replies come back — measures capacity.
//! - **Fixed rate** (`rate > 0` QPS, split across connections): each
//!   connection fires on its own clock for `duration`, pumping replies
//!   between ticks — measures latency at an offered load, and keeps
//!   submitting while the server sheds (the typed counters make the
//!   shed visible).
//!
//! Latency is measured client-side, submit → reply, so it includes the
//! wire. Percentiles are nearest-rank over the merged per-connection
//! samples — the same estimator the router's own [`Stats`] uses, so the
//! two views are comparable.
//!
//! Every successful reply is validated: the `(score, id)` list must be
//! sorted under the engine's total order (ascending score, id as the
//! tie-break). A violation fails the run loudly — the load generator
//! doubles as a wire-level conformance check.
//!
//! [`Stats`]: crate::server::Stats

use super::client::NetClient;
use super::frame::NetSearchReply;
use crate::index::SearchParams;
use crate::server::{percentile, RouterError};
use crate::tensor::Matrix;
use std::time::{Duration, Instant};

/// One load run's shape.
#[derive(Clone, Debug)]
pub struct LoadCfg {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent connections.
    pub conns: usize,
    /// Total requests (closed-loop mode; split across connections).
    pub requests: usize,
    /// Per-connection in-flight window (closed-loop mode).
    pub pipeline: usize,
    /// Target offered load in QPS across all connections; `0` selects
    /// closed-loop mode.
    pub rate: f64,
    /// Wall-clock run time (fixed-rate mode).
    pub duration: Duration,
    /// Search knobs carried on every request.
    pub sp: SearchParams,
    /// Per-request deadline (ms; 0 = none).
    pub deadline_ms: u64,
    /// Query pool; connections walk it round-robin from staggered
    /// offsets.
    pub queries: Matrix,
}

/// Aggregated outcome of a load run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    /// replies received (every outcome)
    pub completed: u64,
    pub ok: u64,
    /// subset of `ok` flagged degraded
    pub degraded: u64,
    /// `Overloaded` + `Saturated` replies
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub worker_died: u64,
    pub stopped: u64,
    pub wall: Duration,
    /// completed replies per second of wall time
    pub qps: f64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

/// Per-connection accumulator, merged into the [`LoadReport`].
#[derive(Default)]
struct PerConn {
    sent: u64,
    completed: u64,
    ok: u64,
    degraded: u64,
    shed: u64,
    deadline_exceeded: u64,
    worker_died: u64,
    stopped: u64,
    latencies_ns: Vec<u64>,
}

impl PerConn {
    fn record(
        &mut self,
        latency: Duration,
        outcome: &Result<NetSearchReply, RouterError>,
    ) -> anyhow::Result<()> {
        self.completed += 1;
        self.latencies_ns.push(latency.as_nanos() as u64);
        match outcome {
            Ok(reply) => {
                for w in reply.results.windows(2) {
                    let ordered =
                        w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[0].1 <= w[1].1);
                    if !ordered {
                        anyhow::bail!(
                            "reply violates the (score, id) total order: {:?} before {:?}",
                            w[0],
                            w[1]
                        );
                    }
                }
                self.ok += 1;
                if reply.degraded {
                    self.degraded += 1;
                }
            }
            Err(RouterError::Overloaded { .. } | RouterError::Saturated) => self.shed += 1,
            Err(RouterError::DeadlineExceeded) => self.deadline_exceeded += 1,
            Err(RouterError::WorkerDied) => self.worker_died += 1,
            Err(RouterError::Stopped) => self.stopped += 1,
        }
        Ok(())
    }
}

/// Pop the submit timestamp for `id` out of the in-flight window.
fn take_inflight(inflight: &mut Vec<(u64, Instant)>, id: u64) -> anyhow::Result<Instant> {
    match inflight.iter().position(|(i, _)| *i == id) {
        Some(pos) => Ok(inflight.swap_remove(pos).1),
        None => anyhow::bail!("reply for unknown request id {id}"),
    }
}

fn closed_loop(
    addr: &str,
    quota: usize,
    pipeline: usize,
    sp: SearchParams,
    deadline_ms: u64,
    queries: &Matrix,
    offset: usize,
) -> anyhow::Result<PerConn> {
    let mut client = NetClient::connect(addr)?;
    let mut acc = PerConn::default();
    let mut inflight: Vec<(u64, Instant)> = Vec::new();
    let mut sent = 0usize;
    let mut done = 0usize;
    while done < quota {
        while sent < quota && inflight.len() < pipeline {
            let row = (offset + sent) % queries.rows;
            let id = client.submit_search(queries.row(row), &sp, deadline_ms)?;
            inflight.push((id, Instant::now()));
            sent += 1;
            acc.sent += 1;
        }
        if let Some((id, outcome)) = client.recv_any_search(None)? {
            let t0 = take_inflight(&mut inflight, id)?;
            acc.record(t0.elapsed(), &outcome)?;
            done += 1;
        }
    }
    Ok(acc)
}

fn rate_loop(
    addr: &str,
    rate_per_conn: f64,
    duration: Duration,
    sp: SearchParams,
    deadline_ms: u64,
    queries: &Matrix,
    offset: usize,
) -> anyhow::Result<PerConn> {
    let mut client = NetClient::connect(addr)?;
    let mut acc = PerConn::default();
    let mut inflight: Vec<(u64, Instant)> = Vec::new();
    let interval = Duration::from_secs_f64(1.0 / rate_per_conn);
    let start = Instant::now();
    let mut next_fire = start;
    let mut sent = 0usize;
    while start.elapsed() < duration {
        let now = Instant::now();
        if now >= next_fire {
            let row = (offset + sent) % queries.rows;
            let id = client.submit_search(queries.row(row), &sp, deadline_ms)?;
            inflight.push((id, Instant::now()));
            sent += 1;
            acc.sent += 1;
            next_fire += interval;
            if next_fire < now {
                // fell behind (slow replies): re-anchor instead of
                // bursting an unbounded backlog of catch-up sends
                next_fire = now;
            }
            continue;
        }
        // pump replies until the next scheduled send (set_read_timeout
        // rejects a zero duration, hence the 1 ms floor)
        let wait = (next_fire - now).max(Duration::from_millis(1));
        if let Some((id, outcome)) = client.recv_any_search(Some(wait))? {
            let t0 = take_inflight(&mut inflight, id)?;
            acc.record(t0.elapsed(), &outcome)?;
        }
    }
    // the offered-load window is over; collect every outstanding reply
    while !inflight.is_empty() {
        match client.recv_any_search(Some(Duration::from_secs(30)))? {
            Some((id, outcome)) => {
                let t0 = take_inflight(&mut inflight, id)?;
                acc.record(t0.elapsed(), &outcome)?;
            }
            None => anyhow::bail!(
                "timed out draining {} in-flight replies after the run",
                inflight.len()
            ),
        }
    }
    Ok(acc)
}

/// Run the configured load and aggregate. Any connection-level failure
/// (transport error, malformed reply, order violation) fails the whole
/// run with that error.
pub fn run(cfg: &LoadCfg) -> anyhow::Result<LoadReport> {
    if cfg.conns == 0 {
        anyhow::bail!("LoadCfg::conns must be >= 1");
    }
    if cfg.queries.rows == 0 {
        anyhow::bail!("LoadCfg::queries must have at least one row");
    }
    if cfg.rate == 0.0 && cfg.requests == 0 {
        anyhow::bail!("closed-loop mode needs LoadCfg::requests >= 1");
    }
    let pipeline = cfg.pipeline.max(1);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(cfg.conns);
    for c in 0..cfg.conns {
        // per-connection share: requests split evenly, remainder to the
        // first threads; query offsets staggered so connections don't
        // all replay the same rows in lockstep
        let quota = cfg.requests / cfg.conns + usize::from(c < cfg.requests % cfg.conns);
        let addr = cfg.addr.clone();
        let sp = cfg.sp;
        let deadline_ms = cfg.deadline_ms;
        let queries = cfg.queries.clone();
        let rate_per_conn = cfg.rate / cfg.conns as f64;
        let duration = cfg.duration;
        let offset = c * queries.rows / cfg.conns.max(1);
        handles.push(std::thread::spawn(move || {
            if rate_per_conn > 0.0 {
                rate_loop(&addr, rate_per_conn, duration, sp, deadline_ms, &queries, offset)
            } else if quota > 0 {
                closed_loop(&addr, quota, pipeline, sp, deadline_ms, &queries, offset)
            } else {
                Ok(PerConn::default())
            }
        }));
    }
    let mut merged = PerConn::default();
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(pc)) => {
                merged.sent += pc.sent;
                merged.completed += pc.completed;
                merged.ok += pc.ok;
                merged.degraded += pc.degraded;
                merged.shed += pc.shed;
                merged.deadline_exceeded += pc.deadline_exceeded;
                merged.worker_died += pc.worker_died;
                merged.stopped += pc.stopped;
                merged.latencies_ns.extend(pc.latencies_ns);
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err =
                    first_err.or_else(|| Some(anyhow::Error::msg("a load thread panicked")))
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall = t0.elapsed();
    merged.latencies_ns.sort_unstable();
    let mean_ns = if merged.latencies_ns.is_empty() {
        0
    } else {
        merged.latencies_ns.iter().sum::<u64>() / merged.latencies_ns.len() as u64
    };
    Ok(LoadReport {
        sent: merged.sent,
        completed: merged.completed,
        ok: merged.ok,
        degraded: merged.degraded,
        shed: merged.shed,
        deadline_exceeded: merged.deadline_exceeded,
        worker_died: merged.worker_died,
        stopped: merged.stopped,
        wall,
        qps: merged.completed as f64 / wall.as_secs_f64().max(1e-9),
        mean: Duration::from_nanos(mean_ns),
        p50: percentile(&merged.latencies_ns, 0.50),
        p99: percentile(&merged.latencies_ns, 0.99),
    })
}
