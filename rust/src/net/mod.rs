//! Network serving tier: the wire protocol in front of the
//! [`Router`](crate::server::Router).
//!
//! This subsystem turns in-process serving into a socket boundary
//! without changing its semantics: frames map 1:1 onto the router
//! contract, and loopback replies are **bit-identical** to in-process
//! ones — results, `degraded` flag, and every typed
//! [`RouterError`](crate::server::RouterError) included (pinned by
//! `tests/net_equivalence.rs`).
//!
//! | piece | file | role |
//! |---|---|---|
//! | [`frame`] | codec | frame layout + typed payload bodies (pure, no I/O) |
//! | [`NetServer`] | server | accept loop + per-connection reader/writer pairs |
//! | [`NetClient`] | client | blocking client, pipelining + reply stash |
//! | [`loadgen`] | load | `bench-net`: N conns × closed-loop / fixed-rate |
//!
//! # Wire protocol v1
//!
//! Every message is one **frame**: a fixed 20-byte header followed by
//! `payload_len` payload bytes. All integers are little-endian; `f32`
//! values travel as IEEE-754 bit patterns, so scores cross the wire
//! bit-identically.
//!
//! ```text
//! offset  size  field        notes
//!      0     4  magic        "QNC2"
//!      4     1  version      1 (strict: anything else is rejected)
//!      5     1  op           Search=1 Write=2 Stats=3 Ping=4 Drain=5
//!      6     1  status       requests: 0; replies: table below
//!      7     1  reserved     must be 0
//!      8     8  request_id   client-chosen; echoed on the reply.
//!                            0 is reserved for connection notices
//!     16     4  payload_len  bytes that follow (≤ frame-max-bytes)
//!     20     …  payload      op/status-specific body (frame.rs)
//! ```
//!
//! Requests on one connection may be **pipelined**; replies are tagged
//! with the originating `request_id` and may interleave in any order —
//! clients must key on the id, not on arrival order.
//!
//! ## Status codes ↔ `RouterError`
//!
//! Every router outcome is a distinct wire status, so the client can
//! reconstruct the exact in-process result:
//!
//! | code | status | in-process equivalent | payload |
//! |---|---|---|---|
//! | 0 | `Ok` | `Ok(Response { degraded: false, .. })` | reply body |
//! | 1 | `OkDegraded` | `Ok(Response { degraded: true, .. })` | reply body |
//! | 2 | `Stopped` | `Err(RouterError::Stopped)` | empty |
//! | 3 | `Saturated` | `Err(RouterError::Saturated)` | empty |
//! | 4 | `WorkerDied` | `Err(RouterError::WorkerDied)` | empty |
//! | 5 | `DeadlineExceeded` | `Err(RouterError::DeadlineExceeded)` | empty |
//! | 6 | `Overloaded` | `Err(RouterError::Overloaded { .. })` | `retry_after_hint` ns (u64) |
//! | 7 | `BadRequest` | — (semantic rejection; connection stays open) | UTF-8 message |
//! | 8 | `Protocol` | — (framing violation; connection closes) | UTF-8 message |
//!
//! ## Protocol errors
//!
//! Malformed input — bad magic/version, unknown op or status, a
//! declared length over `frame-max-bytes`, a stream ending mid-frame,
//! or a payload that does not decode — is a typed
//! [`ProtocolError`](frame::ProtocolError). The server counts it,
//! sends a best-effort status-8 notice (request id 0 for framing-level
//! violations, the offending id for payload-level ones), and closes
//! **only that connection**. Never a panic, never a hang, never
//! another connection.
//!
//! ## Backpressure
//!
//! Three nested limits: `--max-conns` (further connects get a typed
//! `Overloaded` notice and close), the per-connection in-flight cap
//! (the reader stops pulling frames when the cap is reached, so TCP
//! flow control pushes back on the sender), and the router's own
//! admission/queue gates (`Overloaded`/`Saturated`, surfaced as wire
//! statuses per request). `--frame-max-bytes` bounds per-frame memory
//! before any allocation happens.
//!
//! ## Drain semantics
//!
//! Triggered by a `Drain` frame, [`NetServer::drain`], or dropping the
//! server:
//!
//! 1. the listener closes — new connections are refused from that
//!    instant;
//! 2. each reader stops pulling new frames at its next frame boundary
//!    (a partially-received frame gets a bounded grace to complete);
//!    requests already buffered in the socket are answered with a
//!    typed `Stopped` status (pings/stats still answered for real);
//! 3. each writer drains its queue: every accepted in-flight request
//!    gets its reply — a result or a typed status — **exactly once**;
//! 4. sockets close, threads join. The router is left running:
//!    draining the network tier never tears down in-process serving.

pub mod client;
pub mod frame;
pub mod loadgen;
pub mod server;

pub use client::NetClient;
pub use frame::{
    Frame, FrameReader, NetSearchReply, NetStats, NetWriteReply, Op, ProtocolError, WireStatus,
};
pub use loadgen::{LoadCfg, LoadReport};
pub use server::{NetCfg, NetServer};
