//! The TCP front-end: a multi-threaded accept loop feeding the
//! in-process [`Router`] through per-connection reader/writer pairs.
//!
//! ## Per-connection architecture
//!
//! Each accepted connection gets **two** threads:
//!
//! - the **reader** owns the receive half: it pumps a [`FrameReader`]
//!   (50 ms read timeout so drain is noticed promptly; partial reads
//!   lose nothing), validates each frame, and submits through
//!   [`Router::try_submit_within`] / [`Router::try_submit_write_within`]
//!   — *never* the blocking submit, so a saturated router answers with
//!   a typed wire status instead of stalling the connection;
//! - the **writer** owns the send half: it consumes a **bounded**
//!   channel of either finished frames or pending router reply
//!   receivers, waits for each reply with a bounded `recv_timeout`
//!   (deadline + grace, or a backstop — mirroring the router's own
//!   discipline, so a wedged worker becomes a typed `WorkerDied` frame,
//!   never a hung connection), and streams the encoded replies out.
//!
//! The bounded channel **is** the per-connection in-flight cap
//! ([`NetCfg::conn_inflight`]): when a client pipelines more requests
//! than the cap, the reader blocks handing the next one to the writer,
//! stops pulling frames, and TCP backpressure propagates to the sender
//! — per-connection flow control with no extra bookkeeping. Replies are
//! written in submission order per connection (the protocol permits
//! interleaving and clients key on `request_id`, so FIFO is merely the
//! simplest legal schedule).
//!
//! ## Failure containment
//!
//! A framing violation ([`ProtocolError`]) increments
//! `protocol_errors`, sends a best-effort [`WireStatus::Protocol`]
//! notice, and closes **only the offending connection** — the accept
//! loop and every other connection keep serving. Transport errors
//! (reset, broken pipe, write timeout) close the connection silently;
//! pending router replies are still drained so the router's reply
//! guards resolve, they are just not written.
//!
//! ## Drain
//!
//! [`NetServer::drain`] (also triggered by dropping the server or by a
//! wire [`Op::Drain`] frame) stops the accept loop (the listener socket
//! closes, so new connections are refused by the OS), then every reader
//! stops pulling new frames at its next frame boundary — requests
//! already buffered in the socket are answered with a typed
//! [`RouterError::Stopped`] status (pings/stats still answered for
//! real), a partially-received frame gets a bounded grace to complete —
//! and the writers drain every in-flight reply exactly once before the
//! sockets close. The router itself stays alive: draining the network
//! tier does not tear down in-process serving.

use super::frame::{
    bad_request_frame, encode_search_ok, encode_stats, encode_write_ok, error_frame,
    protocol_notice, Frame, FrameIoError, FrameReader, NetStats, Op, Poll, ProtocolError,
    SearchBody, WireStatus, WriteBody, CONN_NOTICE_ID, DEFAULT_FRAME_MAX, MIN_FRAME_MAX,
};
use crate::server::{Reply, Router, RouterError, WriteOp, WriteReply};
use crate::util::deadline::Deadline;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Reader poll tick: how quickly a connection notices drain.
const POLL_TICK: Duration = Duration::from_millis(50);
/// Read tick for the post-drain sweep over already-buffered frames.
const SWEEP_TICK: Duration = Duration::from_millis(10);
/// How long a partially-received frame may complete after drain begins.
const DRAIN_MIDFRAME_GRACE: Duration = Duration::from_secs(2);
/// Writer-side socket timeout: a peer that stops reading cannot wedge
/// drain — the write fails and the connection is marked dead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);
/// Grace added to a request's deadline when the writer waits for its
/// router reply (covers the batching window, like the router's own
/// bounded recv).
const RECV_GRACE: Duration = Duration::from_secs(1);
/// Reply-wait backstop for deadline-less requests.
const RECV_BACKSTOP: Duration = Duration::from_secs(60);
/// `retry_after_hint` sent when a connection is refused at the
/// `max_conns` cap (the router was never consulted, so no live
/// estimate exists).
const REFUSAL_HINT: Duration = Duration::from_millis(50);

/// Network-tier knobs (the CLI's `--max-conns`/`--frame-max-bytes`/
/// `--conn-inflight`; `0` on the CLI selects these defaults).
#[derive(Clone, Debug)]
pub struct NetCfg {
    /// Accepted connections served concurrently; further connects get a
    /// best-effort [`WireStatus::Overloaded`] notice and are closed.
    pub max_conns: usize,
    /// Per-frame payload ceiling; an oversized declared length is a
    /// protocol error rejected from the header alone.
    pub frame_max_bytes: usize,
    /// Per-connection in-flight request cap (the bounded reader→writer
    /// channel's capacity — see the module docs).
    pub conn_inflight: usize,
}

impl Default for NetCfg {
    fn default() -> NetCfg {
        NetCfg { max_conns: 64, frame_max_bytes: DEFAULT_FRAME_MAX, conn_inflight: 32 }
    }
}

/// Network-tier counters, surfaced through [`Stats`](crate::server::Stats)
/// by the stats frame op and by `cmd_serve`.
#[derive(Default)]
struct NetCounters {
    connections: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    protocol_errors: AtomicU64,
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    router: Arc<Router>,
    cfg: NetCfg,
    counters: NetCounters,
    draining: AtomicBool,
}

/// The TCP front-end. Binds, accepts, serves; dropping it (or calling
/// [`drain`](Self::drain)) runs the graceful-drain protocol described
/// in the module docs.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting. The router is shared — in-process callers keep
    /// working, and it survives the server's drain.
    pub fn bind(addr: &str, router: Arc<Router>, cfg: NetCfg) -> anyhow::Result<NetServer> {
        if cfg.max_conns == 0 {
            anyhow::bail!("NetCfg::max_conns must be >= 1");
        }
        if cfg.frame_max_bytes < MIN_FRAME_MAX {
            anyhow::bail!(
                "NetCfg::frame_max_bytes must be >= {MIN_FRAME_MAX}, got {}",
                cfg.frame_max_bytes
            );
        }
        if cfg.conn_inflight == 0 {
            anyhow::bail!("NetCfg::conn_inflight must be >= 1");
        }
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| anyhow::anyhow!("cannot read bound address: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("cannot set the listener non-blocking: {e}"))?;
        let shared = Arc::new(Shared {
            router,
            cfg,
            counters: NetCounters::default(),
            draining: AtomicBool::new(false),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        Ok(NetServer { shared, local_addr, accept: Some(accept) })
    }

    /// The bound address — the ephemeral port when bound to `:0`.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Signal drain without waiting: stop accepting, let connections
    /// finish their in-flight work (see the module docs).
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Snapshot the router's stats with the net counters filled in,
    /// plus the index dim / live-row facts clients need.
    pub fn stats(&self) -> NetStats {
        stats_of(&self.shared)
    }

    /// Graceful shutdown: refuse new connections, answer every
    /// in-flight frame exactly once, close every socket, join every
    /// thread. Returns the final stats snapshot. The router is left
    /// running.
    pub fn drain(mut self) -> NetStats {
        self.begin_drain();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        stats_of(&self.shared)
    }
}

/// Dropping the server IS graceful drain (mirror of `Router`'s drop
/// contract) — pinned by the shutdown-drain-over-the-wire test.
impl Drop for NetServer {
    fn drop(&mut self) {
        self.begin_drain();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn stats_of(shared: &Shared) -> NetStats {
    let mut stats = shared.router.stats();
    stats.connections = shared.counters.connections.load(Ordering::Relaxed);
    stats.frames_in = shared.counters.frames_in.load(Ordering::Relaxed);
    stats.frames_out = shared.counters.frames_out.load(Ordering::Relaxed);
    stats.protocol_errors = shared.counters.protocol_errors.load(Ordering::Relaxed);
    let index = shared.router.index();
    NetStats { stats, dim: index.params.cfg.d as u32, live_rows: index.live_len() as u64 }
}

/// Accept until drain: non-blocking accepts on a short tick (so drain
/// is noticed within ~5 ms), per-connection threads, and a typed
/// refusal at the connection cap. On drain the listener drops first —
/// the OS refuses new connects from that instant — then every live
/// connection thread is joined.
fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                conns.retain(|h| !h.is_finished());
                if conns.len() >= shared.cfg.max_conns {
                    refuse(stream);
                    continue;
                }
                shared.counters.connections.fetch_add(1, Ordering::Relaxed);
                let shared = shared.clone();
                conns.push(std::thread::spawn(move || conn_loop(&shared, stream)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // transient accept failure (EMFILE, aborted handshake…):
                // back off briefly, keep serving existing connections
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    // refuse-new-connections must hold before in-flight draining starts
    drop(listener);
    for h in conns {
        let _ = h.join();
    }
}

/// Best-effort typed refusal at the connection cap: one `Overloaded`
/// notice frame (op `Ping`, the connection-notice id), then close.
fn refuse(mut stream: TcpStream) {
    let f = error_frame(
        Op::Ping,
        CONN_NOTICE_ID,
        &RouterError::Overloaded { retry_after_hint: REFUSAL_HINT },
    );
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let _ = stream.write_all(&f.encode());
    let _ = stream.shutdown(Shutdown::Both);
}

/// What the reader hands the writer: either a frame ready to send, or a
/// pending router reply to wait on (bounded) and encode.
enum ConnMsg {
    Immediate(Frame),
    Search { id: u64, rx: Receiver<Reply>, deadline: Deadline },
    Write { id: u64, rx: Receiver<WriteReply>, deadline: Deadline },
}

/// One connection's reader side (runs on the connection thread; spawns
/// and joins its writer).
fn conn_loop(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = sync_channel::<ConnMsg>(shared.cfg.conn_inflight);
    let writer = {
        let shared = shared.clone();
        std::thread::spawn(move || writer_loop(write_half, &rx, &shared))
    };
    let mut stream = stream;
    let _ = stream.set_read_timeout(Some(POLL_TICK));
    let mut reader = FrameReader::new(shared.cfg.frame_max_bytes);
    let mut drain_mark: Option<Instant> = None;
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            if reader.is_idle() {
                final_sweep(shared, &mut stream, &mut reader, &tx);
                break;
            }
            // mid-frame: a bounded grace for the frame to complete, so a
            // slow sender is not cut mid-request the instant drain starts
            let mark = *drain_mark.get_or_insert_with(Instant::now);
            if mark.elapsed() > DRAIN_MIDFRAME_GRACE {
                break;
            }
        }
        match reader.poll(&mut stream) {
            Ok(Poll::Frame(f)) => {
                shared.counters.frames_in.fetch_add(1, Ordering::Relaxed);
                if handle_frame(shared, &tx, f).is_err() {
                    break;
                }
            }
            Ok(Poll::Pending) => {}
            Ok(Poll::Eof) => break,
            Err(FrameIoError::Protocol(pe)) => {
                shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(ConnMsg::Immediate(protocol_notice(&pe.to_string())));
                break;
            }
            Err(FrameIoError::Io(_)) => break,
        }
    }
    // closing the channel lets the writer drain its queue and exit;
    // every accepted in-flight request still gets its reply written
    drop(tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// After drain: requests the client already pushed into the socket get
/// a typed `Stopped` status (pings/stats/drain still answered for
/// real) instead of a silent close. Best-effort — the sweep stops at
/// the first quiet tick.
fn final_sweep(
    shared: &Arc<Shared>,
    stream: &mut TcpStream,
    reader: &mut FrameReader,
    tx: &SyncSender<ConnMsg>,
) {
    let _ = stream.set_read_timeout(Some(SWEEP_TICK));
    loop {
        match reader.poll(stream) {
            Ok(Poll::Frame(f)) => {
                shared.counters.frames_in.fetch_add(1, Ordering::Relaxed);
                let reply = match f.op {
                    Op::Ping => Frame::reply(Op::Ping, WireStatus::Ok, f.request_id, f.payload),
                    Op::Drain => Frame::reply(Op::Drain, WireStatus::Ok, f.request_id, Vec::new()),
                    Op::Stats => Frame::reply(
                        Op::Stats,
                        WireStatus::Ok,
                        f.request_id,
                        encode_stats(&stats_of(shared)),
                    ),
                    Op::Search | Op::Write => {
                        error_frame(f.op, f.request_id, &RouterError::Stopped)
                    }
                };
                if tx.send(ConnMsg::Immediate(reply)).is_err() {
                    return;
                }
            }
            _ => return,
        }
    }
}

/// Decode one request frame and route it. `Err(())` closes the
/// connection (payload-level protocol violation, or the writer died).
fn handle_frame(shared: &Arc<Shared>, tx: &SyncSender<ConnMsg>, f: Frame) -> Result<(), ()> {
    let send = |msg: ConnMsg| tx.send(msg).map_err(|_| ());
    match f.op {
        Op::Ping => send(ConnMsg::Immediate(Frame::reply(
            Op::Ping,
            WireStatus::Ok,
            f.request_id,
            f.payload,
        ))),
        Op::Drain => {
            // ack first, then flip the flag: the ack is already queued,
            // so it is flushed before this connection's writer exits
            let out = send(ConnMsg::Immediate(Frame::reply(
                Op::Drain,
                WireStatus::Ok,
                f.request_id,
                Vec::new(),
            )));
            shared.draining.store(true, Ordering::SeqCst);
            out
        }
        Op::Stats => send(ConnMsg::Immediate(Frame::reply(
            Op::Stats,
            WireStatus::Ok,
            f.request_id,
            encode_stats(&stats_of(shared)),
        ))),
        Op::Search => {
            let body = match SearchBody::decode(&f.payload) {
                Ok(b) => b,
                Err(pe) => return payload_violation(shared, &send, f.op, f.request_id, &pe),
            };
            let dim = shared.router.index().params.cfg.d;
            if body.query.len() != dim {
                return send(ConnMsg::Immediate(bad_request_frame(
                    Op::Search,
                    f.request_id,
                    &format!("query has {} dims, the index expects {dim}", body.query.len()),
                )));
            }
            let deadline = Deadline::from_ms(body.deadline_ms);
            match shared.router.try_submit_within(body.query, body.sp, deadline) {
                Ok(rx) => send(ConnMsg::Search { id: f.request_id, rx, deadline }),
                Err(e) => send(ConnMsg::Immediate(error_frame(Op::Search, f.request_id, &e))),
            }
        }
        Op::Write => {
            let body = match WriteBody::decode(&f.payload) {
                Ok(b) => b,
                Err(pe) => return payload_violation(shared, &send, f.op, f.request_id, &pe),
            };
            if let WriteOp::Insert { vectors, .. } = &body.op {
                let dim = shared.router.index().params.cfg.d;
                if vectors.cols != dim {
                    return send(ConnMsg::Immediate(bad_request_frame(
                        Op::Write,
                        f.request_id,
                        &format!("insert rows have {} dims, the index expects {dim}", vectors.cols),
                    )));
                }
            }
            let deadline = Deadline::from_ms(body.deadline_ms);
            match shared.router.try_submit_write_within(body.op, deadline) {
                Ok(rx) => send(ConnMsg::Write { id: f.request_id, rx, deadline }),
                Err(e) => send(ConnMsg::Immediate(error_frame(Op::Write, f.request_id, &e))),
            }
        }
    }
}

/// A well-framed request whose payload does not decode is a protocol
/// violation like any other: count it, tell the peer (tagged with the
/// offending request id so a pipelined client can attribute it), close.
fn payload_violation(
    shared: &Arc<Shared>,
    send: &dyn Fn(ConnMsg) -> Result<(), ()>,
    op: Op,
    request_id: u64,
    pe: &ProtocolError,
) -> Result<(), ()> {
    shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
    let _ = send(ConnMsg::Immediate(Frame::reply(
        op,
        WireStatus::Protocol,
        request_id,
        pe.to_string().into_bytes(),
    )));
    Err(())
}

/// Bounded reply wait, mirroring `Router`'s own recv discipline: the
/// guard protocol delivers *something* for every accepted request, so a
/// timeout here means a wedged serving thread — typed `WorkerDied`,
/// never a hung connection.
fn bounded_recv<T>(
    rx: &Receiver<Result<T, RouterError>>,
    deadline: Deadline,
) -> Result<T, RouterError> {
    let timeout = match deadline.remaining() {
        Some(rem) => rem + RECV_GRACE,
        None => RECV_BACKSTOP,
    };
    match rx.recv_timeout(timeout) {
        Ok(reply) => reply,
        Err(_) => Err(RouterError::WorkerDied),
    }
}

/// One connection's writer side: encode and send replies in queue
/// order. A failed/timed-out socket write marks the connection dead;
/// pending router replies are still consumed (their guards resolve) but
/// no longer written.
fn writer_loop(mut stream: TcpStream, rx: &Receiver<ConnMsg>, shared: &Arc<Shared>) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut dead = false;
    while let Ok(msg) = rx.recv() {
        let frame = match msg {
            ConnMsg::Immediate(f) => f,
            ConnMsg::Search { id, rx, deadline } => match bounded_recv(&rx, deadline) {
                Ok(resp) => {
                    let (status, payload) = encode_search_ok(&resp);
                    Frame::reply(Op::Search, status, id, payload)
                }
                Err(e) => error_frame(Op::Search, id, &e),
            },
            ConnMsg::Write { id, rx, deadline } => match bounded_recv(&rx, deadline) {
                Ok(resp) => Frame::reply(Op::Write, WireStatus::Ok, id, encode_write_ok(&resp)),
                Err(e) => error_frame(Op::Write, id, &e),
            },
        };
        if !dead {
            if stream.write_all(&frame.encode()).is_err() {
                dead = true;
            } else {
                shared.counters.frames_out.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    let _ = stream.flush();
}
