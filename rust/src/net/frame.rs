//! The wire format: a versioned, length-prefixed binary frame codec.
//!
//! This layer is pure — no sockets, no threads, nothing beyond
//! `std::io::Read` — so every byte-level rule is testable against plain
//! buffers (`tests/net_protocol.rs` drives it through chunked readers,
//! truncation, and corruption). The frame layout and the status-code ↔
//! [`RouterError`] mapping are specified in the [`crate::net`] module
//! docs; this file is their single implementation.
//!
//! Two layers live here:
//!
//! 1. **Framing** — [`Frame`] (header + opaque payload), its encoder,
//!    and the incremental [`FrameReader`] decoder. The reader owns an
//!    accumulation buffer so partial reads (short `read()`s, read
//!    timeouts used for drain polling) never lose bytes: a `WouldBlock`
//!    or `TimedOut` between frames — or mid-frame — simply returns
//!    [`Poll::Pending`] and the next call resumes where it left off.
//! 2. **Payload codecs** — typed encode/decode for each op's request
//!    and reply body ([`SearchBody`], [`WriteBody`], [`NetStats`], …),
//!    mapping 1:1 onto the in-process [`Router`](crate::server::Router)
//!    contract so loopback replies can be compared bit-for-bit against
//!    in-process ones.
//!
//! All integers are little-endian; `f32` scores travel as their IEEE-754
//! bit pattern (`to_bits`/`from_bits`), so scores survive the wire
//! bit-identically — the equivalence suite depends on this.

use crate::index::{EncodeParams, ScanLayout, SearchParams};
use crate::server::{Response, RouterError, Stats, WriteOp, WriteOutcome, WriteResponse};
use crate::tensor::Matrix;
use std::time::Duration;

/// Frame magic: first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"QNC2";
/// Protocol version this build speaks (strict: any other is rejected).
pub const VERSION: u8 = 1;
/// Fixed header size: magic(4) + version(1) + op(1) + status(1) +
/// reserved(1) + request_id(8) + payload_len(4).
pub const HEADER_LEN: usize = 20;
/// Default payload-size ceiling (8 MiB) — `--frame-max-bytes 0` maps here.
pub const DEFAULT_FRAME_MAX: usize = 8 << 20;
/// Smallest accepted `--frame-max-bytes`: below this, even a modest
/// search request (dim-1536 query + params) could not be framed.
pub const MIN_FRAME_MAX: usize = 4096;
/// `request_id` reserved for connection-level notices (protocol errors,
/// connection refusal) — never assigned to a request by any client.
pub const CONN_NOTICE_ID: u64 = 0;

// ---------------------------------------------------------------------
// ops + statuses
// ---------------------------------------------------------------------

/// Frame operation — what the payload means.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Request: [`SearchBody`]. Reply: search results / router error.
    Search = 1,
    /// Request: [`WriteBody`]. Reply: write outcome / router error.
    Write = 2,
    /// Request: empty. Reply: [`NetStats`] snapshot.
    Stats = 3,
    /// Request: arbitrary bytes. Reply: the same bytes (liveness probe;
    /// also the op carried by connection-level notices).
    Ping = 4,
    /// Request: empty. Reply: empty `Ok`, then the server drains.
    Drain = 5,
}

impl Op {
    /// Every defined op, for exhaustive roundtrip tests.
    pub const ALL: [Op; 5] = [Op::Search, Op::Write, Op::Stats, Op::Ping, Op::Drain];

    pub fn from_u8(v: u8) -> Option<Op> {
        match v {
            1 => Some(Op::Search),
            2 => Some(Op::Write),
            3 => Some(Op::Stats),
            4 => Some(Op::Ping),
            5 => Some(Op::Drain),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }
}

/// Reply status — request frames always carry [`WireStatus::Ok`]; reply
/// frames encode the outcome, mapping every [`RouterError`] variant and
/// the `degraded` flag to a distinct code (see the [`crate::net`] table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireStatus {
    /// Success; payload is the op's reply body.
    Ok = 0,
    /// Success under deadline pressure: the reply is the flagged
    /// stage-1/2 shortlist (`Response::degraded == true`).
    OkDegraded = 1,
    /// [`RouterError::Stopped`] — the router refused the request.
    Stopped = 2,
    /// [`RouterError::Saturated`] — the bounded ingress queue was full.
    Saturated = 3,
    /// [`RouterError::WorkerDied`] — the serving thread died first.
    WorkerDied = 4,
    /// [`RouterError::DeadlineExceeded`] — expired before serving began.
    DeadlineExceeded = 5,
    /// [`RouterError::Overloaded`] — admission shed; payload carries the
    /// `retry_after_hint` in nanoseconds (u64).
    Overloaded = 6,
    /// The request was well-framed but semantically invalid (wrong query
    /// dimension, …); payload is a UTF-8 message. The connection stays
    /// open — this is the caller's bug, not a framing violation.
    BadRequest = 7,
    /// Framing/codec violation notice; payload is a UTF-8 message. Sent
    /// best-effort with [`CONN_NOTICE_ID`] just before the server closes
    /// the offending connection.
    Protocol = 8,
}

impl WireStatus {
    /// Every defined status, for exhaustive roundtrip tests.
    pub const ALL: [WireStatus; 9] = [
        WireStatus::Ok,
        WireStatus::OkDegraded,
        WireStatus::Stopped,
        WireStatus::Saturated,
        WireStatus::WorkerDied,
        WireStatus::DeadlineExceeded,
        WireStatus::Overloaded,
        WireStatus::BadRequest,
        WireStatus::Protocol,
    ];

    pub fn from_u8(v: u8) -> Option<WireStatus> {
        match v {
            0 => Some(WireStatus::Ok),
            1 => Some(WireStatus::OkDegraded),
            2 => Some(WireStatus::Stopped),
            3 => Some(WireStatus::Saturated),
            4 => Some(WireStatus::WorkerDied),
            5 => Some(WireStatus::DeadlineExceeded),
            6 => Some(WireStatus::Overloaded),
            7 => Some(WireStatus::BadRequest),
            8 => Some(WireStatus::Protocol),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// The status a [`RouterError`] travels as.
    pub fn of_router_error(e: &RouterError) -> WireStatus {
        match e {
            RouterError::Stopped => WireStatus::Stopped,
            RouterError::Saturated => WireStatus::Saturated,
            RouterError::WorkerDied => WireStatus::WorkerDied,
            RouterError::DeadlineExceeded => WireStatus::DeadlineExceeded,
            RouterError::Overloaded { .. } => WireStatus::Overloaded,
        }
    }
}

// ---------------------------------------------------------------------
// protocol errors
// ---------------------------------------------------------------------

/// A framing/codec violation — typed so the server can count it, notify
/// the peer, and close exactly the offending connection (never a panic,
/// never a hang, never another connection).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtocolError {
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version byte differs from [`VERSION`] (the protocol is strict-v1).
    BadVersion(u8),
    /// Reserved header byte was non-zero.
    BadReserved(u8),
    /// Op byte maps to no [`Op`].
    UnknownOp(u8),
    /// Status byte maps to no [`WireStatus`].
    UnknownStatus(u8),
    /// Declared payload length exceeds the connection's frame-max.
    Oversized { len: usize, max: usize },
    /// The stream ended mid-frame (`got` of `need` bytes buffered).
    Truncated { got: usize, need: usize },
    /// The frame was well-formed but its payload did not decode as the
    /// op's declared body.
    BadPayload(String),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            ProtocolError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            ProtocolError::BadReserved(v) => write!(f, "non-zero reserved header byte {v:#04x}"),
            ProtocolError::UnknownOp(v) => write!(f, "unknown op byte {v:#04x}"),
            ProtocolError::UnknownStatus(v) => write!(f, "unknown status byte {v:#04x}"),
            ProtocolError::Oversized { len, max } => {
                write!(f, "declared payload of {len} bytes exceeds frame-max-bytes {max}")
            }
            ProtocolError::Truncated { got, need } => {
                write!(f, "stream ended mid-frame ({got} of {need} bytes)")
            }
            ProtocolError::BadPayload(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

/// What [`FrameReader::poll`] can fail with: a transport error or a
/// protocol violation. Both are fatal to the connection; only the latter
/// is the peer's fault (and counted as such).
#[derive(Debug)]
pub enum FrameIoError {
    Io(std::io::Error),
    Protocol(ProtocolError),
}

impl std::fmt::Display for FrameIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameIoError::Io(e) => write!(f, "transport error: {e}"),
            FrameIoError::Protocol(e) => write!(f, "protocol error: {e}"),
        }
    }
}

impl std::error::Error for FrameIoError {}

// ---------------------------------------------------------------------
// frame + incremental reader
// ---------------------------------------------------------------------

/// One wire frame: fixed header + opaque payload. The payload's meaning
/// is `(op, status)`-dependent — see the payload codecs below.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub status: WireStatus,
    pub request_id: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    /// A request frame (requests always carry status `Ok`).
    pub fn request(op: Op, request_id: u64, payload: Vec<u8>) -> Frame {
        Frame { op, status: WireStatus::Ok, request_id, payload }
    }

    /// A reply frame echoing the request's op and id.
    pub fn reply(op: Op, status: WireStatus, request_id: u64, payload: Vec<u8>) -> Frame {
        Frame { op, status, request_id, payload }
    }

    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.encoded_len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.op.as_u8());
        out.push(self.status.as_u8());
        out.push(0); // reserved
        out.extend_from_slice(&self.request_id.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }
}

/// One step of incremental decoding.
#[derive(Debug)]
pub enum Poll {
    /// A complete frame was decoded (more may be buffered — poll again).
    Frame(Frame),
    /// No complete frame yet and the source would block / timed out;
    /// call again later, no bytes were lost.
    Pending,
    /// Clean end-of-stream at a frame boundary.
    Eof,
}

/// Incremental frame decoder: accumulates bytes from any `Read` source
/// and yields complete frames. Header fields are validated eagerly — a
/// bad magic or version is reported as soon as those bytes arrive, an
/// oversized declared length as soon as the header completes — so a
/// hostile peer cannot make the server buffer unbounded garbage.
pub struct FrameReader {
    buf: Vec<u8>,
    max_payload: usize,
}

impl FrameReader {
    pub fn new(max_payload: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_payload }
    }

    /// `true` when no partial frame is buffered — the stream sits at a
    /// frame boundary (the server's drain logic keys off this).
    pub fn is_idle(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total bytes the currently-buffered frame needs (header estimate
    /// until the header is complete).
    fn expected_total(&self) -> usize {
        if self.buf.len() < HEADER_LEN {
            HEADER_LEN
        } else {
            let len =
                u32::from_le_bytes(self.buf[16..HEADER_LEN].try_into().expect("4-byte slice"));
            HEADER_LEN + len as usize
        }
    }

    /// Try to cut one complete frame off the front of the buffer,
    /// validating header fields as far as the buffered bytes reach.
    fn try_parse(&mut self) -> Result<Option<Frame>, ProtocolError> {
        let buf = &self.buf;
        if buf.len() >= 4 && buf[..4] != MAGIC {
            return Err(ProtocolError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
        }
        if buf.len() >= 5 && buf[4] != VERSION {
            return Err(ProtocolError::BadVersion(buf[4]));
        }
        if buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let op = Op::from_u8(buf[5]).ok_or(ProtocolError::UnknownOp(buf[5]))?;
        let status = WireStatus::from_u8(buf[6]).ok_or(ProtocolError::UnknownStatus(buf[6]))?;
        if buf[7] != 0 {
            return Err(ProtocolError::BadReserved(buf[7]));
        }
        let request_id = u64::from_le_bytes(buf[8..16].try_into().expect("8-byte slice"));
        let len = u32::from_le_bytes(buf[16..HEADER_LEN].try_into().expect("4-byte slice")) as usize;
        if len > self.max_payload {
            return Err(ProtocolError::Oversized { len, max: self.max_payload });
        }
        let total = HEADER_LEN + len;
        if buf.len() < total {
            return Ok(None);
        }
        let payload = buf[HEADER_LEN..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(Frame { op, status, request_id, payload }))
    }

    /// Advance the decoder: drain buffered frames first, then read more
    /// bytes. A `WouldBlock`/`TimedOut`/`Interrupted` read maps to
    /// [`Poll::Pending`] with all buffered bytes intact; a clean EOF at a
    /// frame boundary is [`Poll::Eof`]; an EOF mid-frame is a
    /// [`ProtocolError::Truncated`].
    pub fn poll<R: std::io::Read>(&mut self, src: &mut R) -> Result<Poll, FrameIoError> {
        use std::io::ErrorKind;
        loop {
            if let Some(f) = self.try_parse().map_err(FrameIoError::Protocol)? {
                return Ok(Poll::Frame(f));
            }
            let mut scratch = [0u8; 16 * 1024];
            match src.read(&mut scratch) {
                Ok(0) => {
                    return if self.buf.is_empty() {
                        Ok(Poll::Eof)
                    } else {
                        Err(FrameIoError::Protocol(ProtocolError::Truncated {
                            got: self.buf.len(),
                            need: self.expected_total(),
                        }))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&scratch[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) =>
                {
                    return Ok(Poll::Pending);
                }
                Err(e) => return Err(FrameIoError::Io(e)),
            }
        }
    }
}

/// Decode a complete byte buffer into its frames (test/diagnostic
/// helper). Trailing partial bytes are a [`ProtocolError::Truncated`].
pub fn decode_all(bytes: &[u8], max_payload: usize) -> Result<Vec<Frame>, ProtocolError> {
    let mut reader = FrameReader::new(max_payload);
    let mut src = bytes;
    let mut out = Vec::new();
    loop {
        match reader.poll(&mut src) {
            Ok(Poll::Frame(f)) => out.push(f),
            Ok(Poll::Eof) => return Ok(out),
            // a slice source never blocks; Pending is unreachable but
            // harmless to loop on
            Ok(Poll::Pending) => {}
            Err(FrameIoError::Protocol(e)) => return Err(e),
            Err(FrameIoError::Io(e)) => {
                return Err(ProtocolError::BadPayload(format!("slice read failed: {e}")))
            }
        }
    }
}

// ---------------------------------------------------------------------
// payload primitives
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Cursor over a reply/request payload; every read is bounds-checked
/// into a typed [`ProtocolError::BadPayload`], and [`finish`] enforces
/// exact consumption (strict v1: trailing bytes are a violation).
///
/// [`finish`]: PayloadReader::finish
struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn new(buf: &'a [u8]) -> PayloadReader<'a> {
        PayloadReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.buf.len() - self.pos < n {
            return Err(ProtocolError::BadPayload(format!(
                "need {n} bytes at offset {}, payload has {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn f32(&mut self) -> Result<f32, ProtocolError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn string(&mut self) -> Result<String, ProtocolError> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| ProtocolError::BadPayload("string is not valid UTF-8".into()))
    }

    /// Read `n` f32s (bounds-checked as one slice before allocating).
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, ProtocolError> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| ProtocolError::BadPayload("f32 count overflows".into()))?;
        let s = self.take(bytes)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().expect("4-byte chunk"))))
            .collect())
    }

    fn finish(self) -> Result<(), ProtocolError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(ProtocolError::BadPayload(format!(
                "{} trailing bytes after the declared body",
                self.buf.len() - self.pos
            )))
        }
    }
}

// ---------------------------------------------------------------------
// search bodies
// ---------------------------------------------------------------------

/// A search request's payload: the full [`SearchParams`] knob set, the
/// request deadline (milliseconds from server receipt; 0 = none, same
/// convention as [`Deadline::from_ms`](crate::util::deadline::Deadline::from_ms)),
/// and the query vector.
#[derive(Clone, Debug, PartialEq)]
pub struct SearchBody {
    pub sp: SearchParams,
    pub deadline_ms: u64,
    pub query: Vec<f32>,
}

impl SearchBody {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(7 * 4 + 8 + 4 + 4 * self.query.len());
        for v in [
            self.sp.nprobe,
            self.sp.ef_search,
            self.sp.n_aq,
            self.sp.n_pairs,
            self.sp.n_final,
            self.sp.batch_threads,
        ] {
            put_u32(&mut out, v as u32);
        }
        put_u32(&mut out, self.sp.scan_layout.wire_code());
        put_u64(&mut out, self.deadline_ms);
        put_u32(&mut out, self.query.len() as u32);
        for &x in &self.query {
            put_f32(&mut out, x);
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<SearchBody, ProtocolError> {
        let mut r = PayloadReader::new(payload);
        let nprobe = r.u32()? as usize;
        let ef_search = r.u32()? as usize;
        let n_aq = r.u32()? as usize;
        let n_pairs = r.u32()? as usize;
        let n_final = r.u32()? as usize;
        let batch_threads = r.u32()? as usize;
        // Strict v1: an unrecognised scan-layout code is a typed protocol
        // error, never a silent fall-back to flat — a newer client asking
        // for a layout this build lacks must hear "no", not get different
        // scores.
        let layout_code = r.u32()?;
        let scan_layout = ScanLayout::from_wire(layout_code).ok_or_else(|| {
            ProtocolError::BadPayload(format!("unknown scan-layout code {layout_code}"))
        })?;
        let sp = SearchParams {
            nprobe,
            ef_search,
            n_aq,
            n_pairs,
            n_final,
            batch_threads,
            scan_layout,
        };
        let deadline_ms = r.u64()?;
        let n = r.u32()? as usize;
        let query = r.f32s(n)?;
        r.finish()?;
        Ok(SearchBody { sp, deadline_ms, query })
    }
}

/// A successful search reply as decoded by the client: the same
/// `(score, id)` list, `degraded` flag, and server-side latency an
/// in-process caller gets from [`Response`].
#[derive(Clone, Debug, PartialEq)]
pub struct NetSearchReply {
    pub results: Vec<(f32, u32)>,
    pub degraded: bool,
    pub server_latency: Duration,
}

/// Encode a router [`Response`] as a reply body; the status carries the
/// `degraded` flag ([`WireStatus::OkDegraded`] vs [`WireStatus::Ok`]).
pub fn encode_search_ok(resp: &Response) -> (WireStatus, Vec<u8>) {
    let status = if resp.degraded { WireStatus::OkDegraded } else { WireStatus::Ok };
    let mut out = Vec::with_capacity(8 + 4 + 8 * resp.results.len());
    put_u64(&mut out, resp.latency.as_nanos() as u64);
    put_u32(&mut out, resp.results.len() as u32);
    for &(score, id) in &resp.results {
        put_f32(&mut out, score);
        put_u32(&mut out, id);
    }
    (status, out)
}

pub fn decode_search_ok(
    status: WireStatus,
    payload: &[u8],
) -> Result<NetSearchReply, ProtocolError> {
    let degraded = match status {
        WireStatus::Ok => false,
        WireStatus::OkDegraded => true,
        other => {
            return Err(ProtocolError::BadPayload(format!(
                "status {other:?} is not a successful search reply"
            )))
        }
    };
    let mut r = PayloadReader::new(payload);
    let server_latency = Duration::from_nanos(r.u64()?);
    let n = r.u32()? as usize;
    let mut results = Vec::with_capacity(n.min(payload.len() / 8 + 1));
    for _ in 0..n {
        let score = r.f32()?;
        let id = r.u32()?;
        results.push((score, id));
    }
    r.finish()?;
    Ok(NetSearchReply { results, degraded, server_latency })
}

// ---------------------------------------------------------------------
// router errors on the wire
// ---------------------------------------------------------------------

/// The error-status payload: empty for every variant except
/// [`WireStatus::Overloaded`], which carries `retry_after_hint` in ns.
pub fn error_payload(e: &RouterError) -> Vec<u8> {
    match e {
        RouterError::Overloaded { retry_after_hint } => {
            let mut out = Vec::with_capacity(8);
            put_u64(&mut out, retry_after_hint.as_nanos() as u64);
            out
        }
        _ => Vec::new(),
    }
}

/// Build the reply frame a [`RouterError`] travels as.
pub fn error_frame(op: Op, request_id: u64, e: &RouterError) -> Frame {
    Frame::reply(op, WireStatus::of_router_error(e), request_id, error_payload(e))
}

/// Reconstruct the exact [`RouterError`] from an error-status reply —
/// the inverse of [`error_frame`], pinned by the equivalence suite.
pub fn decode_router_error(
    status: WireStatus,
    payload: &[u8],
) -> Result<RouterError, ProtocolError> {
    let mut r = PayloadReader::new(payload);
    let e = match status {
        WireStatus::Stopped => RouterError::Stopped,
        WireStatus::Saturated => RouterError::Saturated,
        WireStatus::WorkerDied => RouterError::WorkerDied,
        WireStatus::DeadlineExceeded => RouterError::DeadlineExceeded,
        WireStatus::Overloaded => {
            RouterError::Overloaded { retry_after_hint: Duration::from_nanos(r.u64()?) }
        }
        other => {
            return Err(ProtocolError::BadPayload(format!(
                "status {other:?} is not a router error"
            )))
        }
    };
    r.finish()?;
    Ok(e)
}

/// A connection-level protocol notice: sent best-effort (op `Ping`,
/// request id [`CONN_NOTICE_ID`]) just before closing the connection.
pub fn protocol_notice(msg: &str) -> Frame {
    Frame::reply(Op::Ping, WireStatus::Protocol, CONN_NOTICE_ID, msg.as_bytes().to_vec())
}

/// A per-request rejection (semantic, not framing): connection stays up.
pub fn bad_request_frame(op: Op, request_id: u64, msg: &str) -> Frame {
    Frame::reply(op, WireStatus::BadRequest, request_id, msg.as_bytes().to_vec())
}

// ---------------------------------------------------------------------
// write bodies
// ---------------------------------------------------------------------

/// A write request's payload: the [`WriteOp`] plus a deadline (same
/// 0-disables convention as [`SearchBody::deadline_ms`]).
#[derive(Clone, Debug)]
pub struct WriteBody {
    pub op: WriteOp,
    pub deadline_ms: u64,
}

const WRITE_INSERT: u8 = 0;
const WRITE_DELETE: u8 = 1;
const WRITE_COMPACT: u8 = 2;

impl WriteBody {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.deadline_ms);
        match &self.op {
            WriteOp::Insert { vectors, ep } => {
                out.push(WRITE_INSERT);
                put_u32(&mut out, ep.a as u32);
                put_u32(&mut out, ep.b as u32);
                put_u32(&mut out, vectors.rows as u32);
                put_u32(&mut out, vectors.cols as u32);
                for &x in &vectors.data {
                    put_f32(&mut out, x);
                }
            }
            WriteOp::Delete { ids } => {
                out.push(WRITE_DELETE);
                put_u32(&mut out, ids.len() as u32);
                for &id in ids {
                    put_u32(&mut out, id);
                }
            }
            WriteOp::Compact => out.push(WRITE_COMPACT),
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<WriteBody, ProtocolError> {
        let mut r = PayloadReader::new(payload);
        let deadline_ms = r.u64()?;
        let op = match r.u8()? {
            WRITE_INSERT => {
                let ep = EncodeParams { a: r.u32()? as usize, b: r.u32()? as usize };
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let n = rows.checked_mul(cols).ok_or_else(|| {
                    ProtocolError::BadPayload("insert matrix shape overflows".into())
                })?;
                let data = r.f32s(n)?;
                WriteOp::Insert { vectors: Matrix::from_vec(rows, cols, data), ep }
            }
            WRITE_DELETE => {
                let n = r.u32()? as usize;
                let bytes = r.take(n.checked_mul(4).ok_or_else(|| {
                    ProtocolError::BadPayload("delete id count overflows".into())
                })?)?;
                let ids = bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect();
                WriteOp::Delete { ids }
            }
            WRITE_COMPACT => WriteOp::Compact,
            other => {
                return Err(ProtocolError::BadPayload(format!("unknown write kind {other:#04x}")))
            }
        };
        r.finish()?;
        Ok(WriteBody { op, deadline_ms })
    }
}

/// A write reply as decoded by the client — mirror of [`WriteResponse`].
#[derive(Clone, Debug)]
pub struct NetWriteReply {
    /// The op's outcome, or the index's validation error as a string —
    /// exactly [`WriteResponse::outcome`].
    pub outcome: Result<WriteOutcome, String>,
    pub server_latency: Duration,
}

const OUTCOME_INSERTED: u8 = 0;
const OUTCOME_DELETED: u8 = 1;
const OUTCOME_COMPACTED: u8 = 2;
const OUTCOME_REJECTED: u8 = 3;

pub fn encode_write_ok(resp: &WriteResponse) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, resp.latency.as_nanos() as u64);
    match &resp.outcome {
        Ok(WriteOutcome::Inserted(ids)) => {
            out.push(OUTCOME_INSERTED);
            put_u32(&mut out, ids.len() as u32);
            for &id in ids {
                put_u32(&mut out, id);
            }
        }
        Ok(WriteOutcome::Deleted(n)) => {
            out.push(OUTCOME_DELETED);
            put_u64(&mut out, *n as u64);
        }
        Ok(WriteOutcome::Compacted(n)) => {
            out.push(OUTCOME_COMPACTED);
            put_u64(&mut out, *n as u64);
        }
        Err(msg) => {
            out.push(OUTCOME_REJECTED);
            put_str(&mut out, msg);
        }
    }
    out
}

pub fn decode_write_ok(payload: &[u8]) -> Result<NetWriteReply, ProtocolError> {
    let mut r = PayloadReader::new(payload);
    let server_latency = Duration::from_nanos(r.u64()?);
    let outcome = match r.u8()? {
        OUTCOME_INSERTED => {
            let n = r.u32()? as usize;
            let bytes = r.take(n.checked_mul(4).ok_or_else(|| {
                ProtocolError::BadPayload("inserted id count overflows".into())
            })?)?;
            Ok(WriteOutcome::Inserted(
                bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect(),
            ))
        }
        OUTCOME_DELETED => Ok(WriteOutcome::Deleted(r.u64()? as usize)),
        OUTCOME_COMPACTED => Ok(WriteOutcome::Compacted(r.u64()? as usize)),
        OUTCOME_REJECTED => Err(r.string()?),
        other => {
            return Err(ProtocolError::BadPayload(format!(
                "unknown write outcome tag {other:#04x}"
            )))
        }
    };
    r.finish()?;
    Ok(NetWriteReply { outcome, server_latency })
}

// ---------------------------------------------------------------------
// stats body
// ---------------------------------------------------------------------

/// The stats-op reply: the router's full [`Stats`] snapshot (net
/// counters filled in by the [`NetServer`](crate::net::NetServer)) plus
/// the two index facts a client needs to shape requests — the vector
/// dimension and the live row count.
#[derive(Clone, Debug)]
pub struct NetStats {
    pub stats: Stats,
    pub dim: u32,
    pub live_rows: u64,
}

pub fn encode_stats(ns: &NetStats) -> Vec<u8> {
    let s = &ns.stats;
    let mut out = Vec::with_capacity(16 * 8 + 4 + 8 * s.shard_scans.len() + 12);
    for v in [
        s.served,
        s.mean_latency.as_nanos() as u64,
        s.p50.as_nanos() as u64,
        s.p99.as_nanos() as u64,
        s.inserted,
        s.deleted,
        s.epoch,
        s.panics,
        s.respawns,
        s.shed,
        s.deadline_exceeded,
        s.degraded,
        s.connections,
        s.frames_in,
        s.frames_out,
        s.protocol_errors,
    ] {
        put_u64(&mut out, v);
    }
    put_u32(&mut out, s.shard_scans.len() as u32);
    for &v in &s.shard_scans {
        put_u64(&mut out, v);
    }
    put_u32(&mut out, ns.dim);
    put_u64(&mut out, ns.live_rows);
    out
}

pub fn decode_stats(payload: &[u8]) -> Result<NetStats, ProtocolError> {
    let mut r = PayloadReader::new(payload);
    let served = r.u64()?;
    let mean_latency = Duration::from_nanos(r.u64()?);
    let p50 = Duration::from_nanos(r.u64()?);
    let p99 = Duration::from_nanos(r.u64()?);
    let inserted = r.u64()?;
    let deleted = r.u64()?;
    let epoch = r.u64()?;
    let panics = r.u64()?;
    let respawns = r.u64()?;
    let shed = r.u64()?;
    let deadline_exceeded = r.u64()?;
    let degraded = r.u64()?;
    let connections = r.u64()?;
    let frames_in = r.u64()?;
    let frames_out = r.u64()?;
    let protocol_errors = r.u64()?;
    let n_shards = r.u32()? as usize;
    let mut shard_scans = Vec::with_capacity(n_shards.min(payload.len() / 8 + 1));
    for _ in 0..n_shards {
        shard_scans.push(r.u64()?);
    }
    let dim = r.u32()?;
    let live_rows = r.u64()?;
    r.finish()?;
    Ok(NetStats {
        stats: Stats {
            served,
            mean_latency,
            p50,
            p99,
            shard_scans,
            inserted,
            deleted,
            epoch,
            panics,
            respawns,
            shed,
            deadline_exceeded,
            degraded,
            connections,
            frames_in,
            frames_out,
            protocol_errors,
        },
        dim,
        live_rows,
    })
}

// ---------------------------------------------------------------------
// unit tests (property/hardening coverage lives in tests/net_protocol.rs)
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips_through_decode_all() {
        let frames = vec![
            Frame::request(Op::Ping, 7, b"hello".to_vec()),
            Frame::reply(Op::Search, WireStatus::OkDegraded, u64::MAX, vec![1, 2, 3]),
            Frame::request(Op::Drain, 9, Vec::new()),
        ];
        let mut bytes = Vec::new();
        for f in &frames {
            f.encode_into(&mut bytes);
        }
        assert_eq!(decode_all(&bytes, DEFAULT_FRAME_MAX).unwrap(), frames);
    }

    #[test]
    fn truncated_stream_is_a_typed_error() {
        let bytes = Frame::request(Op::Search, 1, vec![0; 64]).encode();
        for cut in 1..bytes.len() {
            match decode_all(&bytes[..cut], DEFAULT_FRAME_MAX) {
                Err(
                    ProtocolError::Truncated { .. }
                    | ProtocolError::BadMagic(_)
                    | ProtocolError::BadVersion(_),
                ) => {}
                other => panic!("cut at {cut}: expected a typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_declared_length_is_rejected_from_the_header() {
        let f = Frame::request(Op::Ping, 3, vec![0; 100]);
        let bytes = f.encode();
        // header-only prefix already carries the oversized declaration
        let err = decode_all(&bytes[..HEADER_LEN], 64).unwrap_err();
        assert_eq!(err, ProtocolError::Oversized { len: 100, max: 64 });
    }

    #[test]
    fn search_body_roundtrips() {
        for scan_layout in [ScanLayout::Flat, ScanLayout::Transposed, ScanLayout::Packed4] {
            let body = SearchBody {
                sp: SearchParams {
                    nprobe: 4,
                    ef_search: 32,
                    n_aq: 64,
                    n_pairs: 8,
                    n_final: 5,
                    batch_threads: 2,
                    scan_layout,
                },
                deadline_ms: 1234,
                query: vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0],
            };
            let back = SearchBody::decode(&body.encode()).unwrap();
            assert_eq!(back, body);
        }
    }

    #[test]
    fn unknown_scan_layout_code_is_a_typed_error() {
        let mut bytes = SearchBody {
            sp: SearchParams::default(),
            deadline_ms: 0,
            query: vec![1.0],
        }
        .encode();
        // the scan-layout word is the 7th u32 of the params block
        bytes[24..28].copy_from_slice(&99u32.to_le_bytes());
        match SearchBody::decode(&bytes) {
            Err(ProtocolError::BadPayload(msg)) => {
                assert!(msg.contains("scan-layout"), "msg: {msg}")
            }
            other => panic!("expected BadPayload, got {other:?}"),
        }
    }

    #[test]
    fn router_errors_roundtrip_exactly() {
        let errors = [
            RouterError::Stopped,
            RouterError::Saturated,
            RouterError::WorkerDied,
            RouterError::DeadlineExceeded,
            RouterError::Overloaded { retry_after_hint: Duration::from_micros(250) },
        ];
        for e in errors {
            let f = error_frame(Op::Search, 42, &e);
            assert_eq!(decode_router_error(f.status, &f.payload).unwrap(), e);
        }
    }

    #[test]
    fn payload_reader_rejects_trailing_bytes() {
        let mut body = SearchBody {
            sp: SearchParams::default(),
            deadline_ms: 0,
            query: vec![1.0],
        }
        .encode();
        body.push(0xFF);
        assert!(matches!(
            SearchBody::decode(&body),
            Err(ProtocolError::BadPayload(_))
        ));
    }
}
