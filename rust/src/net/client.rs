//! The matching client: a blocking, single-connection [`NetClient`]
//! that speaks the frame protocol and hands back exactly the types an
//! in-process caller would see.
//!
//! ## Two-level results
//!
//! Every request method returns `anyhow::Result<Result<_, RouterError>>`:
//!
//! - the **outer** `Result` is the transport/protocol level — the
//!   connection broke, the server sent malformed bytes, or the server
//!   rejected the request as semantically invalid
//!   ([`WireStatus::BadRequest`]);
//! - the **inner** `Result` is the in-process router contract,
//!   reconstructed bit-for-bit: a successful reply (results + `degraded`
//!   flag) or the exact [`RouterError`] the router produced — including
//!   `Overloaded`'s `retry_after_hint`, which travels as nanoseconds.
//!
//! This split is what lets the equivalence suite compare a loopback
//! call against `Router::search_blocking` with `assert_eq!`.
//!
//! ## Pipelining
//!
//! [`submit_search`] / [`recv_search`] split submission from receipt,
//! so one connection can keep many requests in flight. Replies may
//! arrive in any order; the client stashes frames for other request ids
//! and hands each reply to the call that asked for it.
//!
//! [`submit_search`]: NetClient::submit_search
//! [`recv_search`]: NetClient::recv_search

use super::frame::{
    decode_router_error, decode_search_ok, decode_stats, decode_write_ok, Frame, FrameIoError,
    FrameReader, NetSearchReply, NetStats, NetWriteReply, Op, Poll, SearchBody, WireStatus,
    WriteBody, CONN_NOTICE_ID, DEFAULT_FRAME_MAX,
};
use crate::index::SearchParams;
use crate::server::{RouterError, WriteOp};
use std::io::Write;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A blocking protocol client over one TCP connection.
pub struct NetClient {
    stream: TcpStream,
    reader: FrameReader,
    next_id: u64,
    /// replies that arrived while waiting for a different request id
    stash: Vec<Frame>,
}

impl NetClient {
    /// Connect and prepare to speak protocol v1.
    pub fn connect<A: ToSocketAddrs + std::fmt::Display>(addr: A) -> anyhow::Result<NetClient> {
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient {
            stream,
            reader: FrameReader::new(DEFAULT_FRAME_MAX),
            next_id: 1, // 0 is CONN_NOTICE_ID, never a request id
            stash: Vec::new(),
        })
    }

    pub fn peer_addr(&self) -> std::io::Result<SocketAddr> {
        self.stream.peer_addr()
    }

    fn send(&mut self, frame: &Frame) -> anyhow::Result<()> {
        self.stream
            .write_all(&frame.encode())
            .map_err(|e| anyhow::anyhow!("send failed: {e}"))
    }

    /// Read the next frame, blocking (bounded only by `timeout` if set
    /// via [`set_recv_timeout`](Self::set_recv_timeout)). A clean EOF is
    /// an error here — the caller was owed a reply.
    fn next_frame(&mut self) -> anyhow::Result<Frame> {
        loop {
            match self.reader.poll(&mut self.stream) {
                Ok(Poll::Frame(f)) => return Ok(f),
                Ok(Poll::Pending) => {
                    anyhow::bail!("timed out waiting for a reply frame")
                }
                Ok(Poll::Eof) => anyhow::bail!("server closed the connection"),
                Err(FrameIoError::Protocol(pe)) => {
                    anyhow::bail!("server sent a malformed frame: {pe}")
                }
                Err(FrameIoError::Io(e)) => anyhow::bail!("receive failed: {e}"),
            }
        }
    }

    /// Bound every subsequent reply wait (maps to a "timed out" outer
    /// error instead of blocking forever). `None` restores blocking
    /// reads — the default.
    pub fn set_recv_timeout(&mut self, timeout: Option<Duration>) -> anyhow::Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .map_err(|e| anyhow::anyhow!("cannot set the receive timeout: {e}"))
    }

    /// Get the reply for `id`, stashing any interleaved replies for
    /// other in-flight requests. A connection-level notice (request id
    /// [`CONN_NOTICE_ID`]) aborts the wait with its message.
    fn recv_for(&mut self, id: u64) -> anyhow::Result<Frame> {
        if let Some(pos) = self.stash.iter().position(|f| f.request_id == id) {
            return Ok(self.stash.swap_remove(pos));
        }
        loop {
            let f = self.next_frame()?;
            if f.request_id == id {
                return Ok(f);
            }
            if f.request_id == CONN_NOTICE_ID {
                anyhow::bail!(
                    "connection notice from the server: {}",
                    String::from_utf8_lossy(&f.payload)
                );
            }
            self.stash.push(f);
        }
    }

    /// Decode a search/write reply's status into the inner router
    /// result, or an outer error for rejection/protocol statuses.
    fn inner_error(f: &Frame) -> anyhow::Result<RouterError> {
        match f.status {
            WireStatus::BadRequest => anyhow::bail!(
                "server rejected the request: {}",
                String::from_utf8_lossy(&f.payload)
            ),
            WireStatus::Protocol => anyhow::bail!(
                "server reported a protocol violation: {}",
                String::from_utf8_lossy(&f.payload)
            ),
            s => decode_router_error(s, &f.payload)
                .map_err(|pe| anyhow::anyhow!("malformed error reply: {pe}")),
        }
    }

    /// Fire a search without waiting; returns the request id to pass to
    /// [`recv_search`](Self::recv_search). `deadline_ms` follows the
    /// CLI convention: 0 = no deadline.
    pub fn submit_search(
        &mut self,
        query: &[f32],
        sp: &SearchParams,
        deadline_ms: u64,
    ) -> anyhow::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let body = SearchBody { sp: *sp, deadline_ms, query: query.to_vec() };
        self.send(&Frame::request(Op::Search, id, body.encode()))?;
        Ok(id)
    }

    /// Wait for the reply to a submitted search.
    pub fn recv_search(
        &mut self,
        id: u64,
    ) -> anyhow::Result<Result<NetSearchReply, RouterError>> {
        let f = self.recv_for(id)?;
        match f.status {
            WireStatus::Ok | WireStatus::OkDegraded => Ok(Ok(decode_search_ok(
                f.status,
                &f.payload,
            )
            .map_err(|pe| anyhow::anyhow!("malformed search reply: {pe}"))?)),
            _ => Ok(Err(Self::inner_error(&f)?)),
        }
    }

    /// Blocking search: submit + receive.
    pub fn search(
        &mut self,
        query: &[f32],
        sp: &SearchParams,
        deadline_ms: u64,
    ) -> anyhow::Result<Result<NetSearchReply, RouterError>> {
        let id = self.submit_search(query, sp, deadline_ms)?;
        self.recv_search(id)
    }

    /// Receive whichever in-flight search reply arrives next (stash
    /// first, then the wire) — the load generator's completion pump.
    /// `Ok(None)` means `timeout` elapsed with no complete frame; bytes
    /// already received are kept for the next call.
    #[allow(clippy::type_complexity)]
    pub fn recv_any_search(
        &mut self,
        timeout: Option<Duration>,
    ) -> anyhow::Result<Option<(u64, Result<NetSearchReply, RouterError>)>> {
        let f = match self.stash.pop() {
            Some(f) => f,
            None => {
                self.set_recv_timeout(timeout)?;
                let polled = self.reader.poll(&mut self.stream);
                self.set_recv_timeout(None)?;
                match polled {
                    Ok(Poll::Frame(f)) => f,
                    Ok(Poll::Pending) => return Ok(None),
                    Ok(Poll::Eof) => anyhow::bail!("server closed the connection"),
                    Err(FrameIoError::Protocol(pe)) => {
                        anyhow::bail!("server sent a malformed frame: {pe}")
                    }
                    Err(FrameIoError::Io(e)) => anyhow::bail!("receive failed: {e}"),
                }
            }
        };
        if f.request_id == CONN_NOTICE_ID {
            anyhow::bail!(
                "connection notice from the server: {}",
                String::from_utf8_lossy(&f.payload)
            );
        }
        let id = f.request_id;
        let outcome = match f.status {
            WireStatus::Ok | WireStatus::OkDegraded => Ok(decode_search_ok(f.status, &f.payload)
                .map_err(|pe| anyhow::anyhow!("malformed search reply: {pe}"))?),
            _ => Err(Self::inner_error(&f)?),
        };
        Ok(Some((id, outcome)))
    }

    /// Blocking write (insert / delete / compact).
    pub fn write(
        &mut self,
        op: WriteOp,
        deadline_ms: u64,
    ) -> anyhow::Result<Result<NetWriteReply, RouterError>> {
        let id = self.next_id;
        self.next_id += 1;
        let body = WriteBody { op, deadline_ms };
        self.send(&Frame::request(Op::Write, id, body.encode()))?;
        let f = self.recv_for(id)?;
        match f.status {
            WireStatus::Ok => Ok(Ok(decode_write_ok(&f.payload)
                .map_err(|pe| anyhow::anyhow!("malformed write reply: {pe}"))?)),
            _ => Ok(Err(Self::inner_error(&f)?)),
        }
    }

    /// Fetch the server's stats snapshot (router stats + net counters +
    /// index dim / live rows).
    pub fn stats(&mut self) -> anyhow::Result<NetStats> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Frame::request(Op::Stats, id, Vec::new()))?;
        let f = self.recv_for(id)?;
        if f.status != WireStatus::Ok {
            anyhow::bail!("stats request failed with status {:?}", f.status);
        }
        decode_stats(&f.payload).map_err(|pe| anyhow::anyhow!("malformed stats reply: {pe}"))
    }

    /// Liveness probe: the payload is echoed back.
    pub fn ping(&mut self, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Frame::request(Op::Ping, id, payload.to_vec()))?;
        let f = self.recv_for(id)?;
        if f.status != WireStatus::Ok {
            anyhow::bail!("ping failed with status {:?}", f.status);
        }
        Ok(f.payload)
    }

    /// Ask the server to drain: it acks, stops accepting connections,
    /// answers everything in flight, and closes.
    pub fn drain_server(&mut self) -> anyhow::Result<()> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Frame::request(Op::Drain, id, Vec::new()))?;
        let f = self.recv_for(id)?;
        if f.status != WireStatus::Ok {
            anyhow::bail!("drain request failed with status {:?}", f.status);
        }
        Ok(())
    }
}
