//! `hlotest` — verify that HLO text artifacts parse under the pinned
//! xla_extension (0.5.1) text parser. Useful when touching the L2
//! lowering: newer jax emits ops (e.g. `topk(..., largest=true)`) that
//! the old parser rejects; this surfaces the exact line.
//!
//! Usage: `cargo run --release --bin hlotest artifacts/*.hlo.txt`

fn main() {
    let mut bad = 0;
    for f in std::env::args().skip(1) {
        match xla::HloModuleProto::from_text_file(&f) {
            Ok(_) => println!("OK   {f}"),
            Err(e) => {
                bad += 1;
                let msg: String = format!("{e}").chars().take(400).collect();
                println!("FAIL {f}: {msg}");
            }
        }
    }
    if bad > 0 {
        std::process::exit(1);
    }
}
