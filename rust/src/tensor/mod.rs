//! Dense row-major f32 matrices and the distance kernels every quantizer
//! shares. Deliberately minimal: the heavy math runs inside XLA; this is
//! the substrate for k-means, codebook fitting and LUT scans.

use crate::util::pool;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Select a subset of rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// C = A @ B (naive blocked; fine for the small codebook solves —
    /// model matmuls happen inside XLA).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, m);
        for i in 0..n {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * m..(i + 1) * m];
            for (p, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * m..(p + 1) * m];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Per-row squared L2 norms.
    pub fn row_sqnorms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| sqnorm(self.row(i))).collect()
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f32> {
        let mut mu = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (m, &v) in mu.iter_mut().zip(self.row(i)) {
                *m += v as f64;
            }
        }
        mu.iter().map(|&s| (s / self.rows.max(1) as f64) as f32).collect()
    }
}

/// ||a - b||^2 for equal-length slices.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

#[inline]
pub fn sqnorm(a: &[f32]) -> f32 {
    a.iter().map(|x| x * x).sum()
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// out += a (elementwise).
#[inline]
pub fn add_assign(out: &mut [f32], a: &[f32]) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o += x;
    }
}

/// out -= a (elementwise).
#[inline]
pub fn sub_assign(out: &mut [f32], a: &[f32]) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o -= x;
    }
}

/// Index + distance of the nearest centroid (squared L2), linear scan.
#[inline]
pub fn argmin_l2(x: &[f32], centroids: &Matrix) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    for c in 0..centroids.rows {
        let d = l2_sq(x, centroids.row(c));
        if d < best.1 {
            best = (c, d);
        }
    }
    best
}

/// Top-`k` smallest distances to rows of `centroids` (index, dist),
/// ascending. Uses a bounded max-heap via sorted insertion (k is small).
pub fn topk_l2(x: &[f32], centroids: &Matrix, k: usize) -> Vec<(usize, f32)> {
    let k = k.min(centroids.rows);
    let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for c in 0..centroids.rows {
        let d = l2_sq(x, centroids.row(c));
        if best.len() < k || d < best[best.len() - 1].1 {
            let pos = best.partition_point(|&(_, bd)| bd <= d);
            best.insert(pos, (c, d));
            if best.len() > k {
                best.pop();
            }
        }
    }
    best
}

/// Assign every row of `xs` to its nearest centroid, in parallel.
pub fn assign_all(xs: &Matrix, centroids: &Matrix, nthreads: usize) -> Vec<u32> {
    let mut out = vec![0u32; xs.rows];
    pool::par_map_into(&mut out, nthreads, |i, slot| {
        *slot = argmin_l2(xs.row(i), centroids).0 as u32;
    });
    out
}

/// Mean squared reconstruction error sum ||x - x_hat||^2 averaged over rows.
pub fn mse(xs: &Matrix, xhat: &Matrix) -> f64 {
    assert_eq!(xs.rows, xhat.rows);
    assert_eq!(xs.cols, xhat.cols);
    let mut acc = 0.0f64;
    for i in 0..xs.rows {
        acc += l2_sq(xs.row(i), xhat.row(i)) as f64;
    }
    acc / xs.rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn argmin_matches_topk1() {
        prop::check("argmin-topk", 50, 40, |g| {
            let d = g.usize_in(1, 8);
            let k = g.usize_in(1, 16);
            let cents = Matrix::from_vec(k, d, g.vec_f32(k * d, -1.0, 1.0));
            let x = g.vec_f32(d, -1.0, 1.0);
            let (i1, d1) = argmin_l2(&x, &cents);
            let tk = topk_l2(&x, &cents, 1);
            if tk[0].0 == i1 && (tk[0].1 - d1).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("{:?} vs {:?}", (i1, d1), tk[0]))
            }
        });
    }

    #[test]
    fn topk_sorted_and_distinct() {
        prop::check("topk-sorted", 50, 40, |g| {
            let d = g.usize_in(1, 6);
            let n = g.usize_in(1, 32);
            let k = g.usize_in(1, n);
            let cents = Matrix::from_vec(n, d, g.vec_f32(n * d, -1.0, 1.0));
            let x = g.vec_f32(d, -1.0, 1.0);
            let tk = topk_l2(&x, &cents, k);
            if tk.len() != k {
                return Err(format!("len {} != {}", tk.len(), k));
            }
            for w in tk.windows(2) {
                if w[0].1 > w[1].1 {
                    return Err("not sorted".into());
                }
            }
            let mut idx: Vec<usize> = tk.iter().map(|t| t.0).collect();
            idx.sort_unstable();
            idx.dedup();
            if idx.len() != k {
                return Err("duplicate indices".into());
            }
            Ok(())
        });
    }

    #[test]
    fn assign_all_parallel_matches_serial() {
        let mut g = prop::Gen { rng: crate::util::prng::Rng::new(9), size: 0 };
        let xs = Matrix::from_vec(100, 4, g.vec_f32(400, -1.0, 1.0));
        let cents = Matrix::from_vec(7, 4, g.vec_f32(28, -1.0, 1.0));
        let a1 = assign_all(&xs, &cents, 1);
        let a8 = assign_all(&xs, &cents, 8);
        assert_eq!(a1, a8);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(mse(&a, &a), 0.0);
    }

    #[test]
    fn gather_rows_picks_rows() {
        let a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn col_means_correct() {
        let a = Matrix::from_vec(2, 2, vec![1., 10., 3., 30.]);
        assert_eq!(a.col_means(), vec![2.0, 20.0]);
    }
}
