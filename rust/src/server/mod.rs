//! Serving coordinator: request router + dynamic batcher + worker pool
//! over a shared [`SearchIndex`] (tokio is unavailable offline; this uses
//! std threads + mpsc channels, the same architecture as a vLLM-style
//! router: ingress queue → batch former → worker fan-out → reply
//! channels).
//!
//! Workers dispatch whole batches through the batched execution engine
//! ([`BatchSearcher`]): requests in a batch are grouped by identical
//! [`SearchParams`] and each group is planned + executed together, so
//! co-probed inverted lists are scanned once per group and stage 3 runs
//! one union decode — not one `search` call per request.
//!
//! # Failure model
//!
//! Every accepted request gets **exactly one typed reply** — a
//! [`Reply`] (`Result<Response, RouterError>`) on the read lane, a
//! [`WriteReply`] on the write lane — and every refused request gets a
//! typed [`RouterError`]. No path hangs, drops a reply silently, or
//! poisons shared state. The variants:
//!
//! - [`RouterError::Stopped`] — the router has shut down; submission is
//!   refused. In-flight requests at shutdown still drain (see below).
//! - [`RouterError::Saturated`] — [`Router::try_submit`] /
//!   [`Router::try_submit_write`] found the bounded ingress full. This
//!   is backpressure, not shedding: the blocking `submit` variants wait
//!   instead.
//! - [`RouterError::Overloaded`] — admission control refused the
//!   request: the lane's in-flight count crossed its high-water mark
//!   ([`ServerCfg::shed_watermark`] /
//!   [`ServerCfg::write_shed_watermark`]). Carries a
//!   `retry_after_hint` estimated from the current mean latency and
//!   queue pressure. Shedding at the door is deliberate: a request we
//!   cannot serve within its deadline is cheapest to reject before it
//!   consumes queue space and scan work.
//! - [`RouterError::DeadlineExceeded`] — the request's
//!   [`Deadline`] passed before a worker *started* it (in the ingress
//!   queue, in the batcher, or in the dispatch queue). Expired requests
//!   are dropped at dispatch time with this typed reply instead of
//!   being served late.
//! - [`RouterError::WorkerDied`] — the thread serving this request
//!   panicked or its decoder failed before a reply was produced. Reply
//!   delivery is guard-based ([`ReplyGuard`]): the guard's `Drop` runs
//!   during unwind, so even a panicking worker answers its callers with
//!   this typed error rather than a dropped channel. The blocking
//!   helpers additionally bound their wait with `recv_timeout` (derived
//!   from the request deadline, or
//!   [`ServerCfg::blocking_recv_timeout`]) and map a timeout to this
//!   variant — no caller can hang on a dead worker.
//!
//! **Degraded replies.** A request that reaches a worker but cannot
//! afford the full three-stage pipeline within its deadline is answered
//! with the stage-1/2 shortlist ranking and `degraded: true` on
//! [`Response`] — the QINCo2 pipeline's cheap approximate decoders are
//! an explicit operating point, not a failure. Stage 3 is skipped
//! whole, never half-run, so a degraded reply is exactly the stage-1/2
//! ranking. The invariant: **degraded results are never emitted without
//! the flag** — `degraded: false` always means the configured pipeline
//! ran to completion (enforced in
//! [`BatchSearcher::execute_within`](crate::index::BatchSearcher::execute_within),
//! which only ever weakens the pipeline at the same points it sets the
//! flag). Requests in one dispatch group execute under the tightest
//! member's deadline and degrade together — the flag applies to every
//! member of the group.
//!
//! **Supervision.** Read workers and the writer run under
//! `catch_unwind`: a panic answers the offending batch's callers with
//! `WorkerDied` (via the reply guards), bumps [`Stats::panics`] /
//! [`Stats::respawns`], and re-enters the serve loop with a freshly
//! constructed decoder — the pool never shrinks. This is safe on the
//! write lane because mutations publish a complete epoch snapshot
//! *atomically at the end*: a panicked mutation published nothing
//! (see [`crate::index::pipeline`]). All shared metrics locks are
//! poison-recovering ([`lock_ignore_poison`]): a panicked worker can
//! never take down [`Router::stats`].
//!
//! **Fault injection.** With the `fault-injection` cargo feature the
//! named probes of [`crate::util::fault`] come alive inside this module
//! and the engine (batcher delay, worker panic, decoder error,
//! queue-full, slow scan); `tests/fault_injection.rs` drives them with
//! deterministic seeded plans to prove each one surfaces as a typed
//! error or a flagged degraded reply.
//!
//! # Engine-per-worker stage-3 decoding
//!
//! Every worker thread constructs its own stage-3 [`StageDecoder`] by
//! calling [`DecoderFactory::make`] **once at thread startup** (and
//! again on respawn after a panic). The factory defaults to the
//! reference decoder ([`ReferenceDecoderFactory`]); configuring
//! [`ServerCfg::decoder_factory`] with a
//! [`RustDecoderFactory`](crate::qinco::RustDecoderFactory) shares the
//! native nn-kernel decoder's weights per worker (`--stage3 rust`),
//! while a
//! [`RuntimeDecoderFactory`](crate::qinco::RuntimeDecoderFactory) gives
//! each worker a thread-local artifact-runtime engine + codec — engines
//! are thread-confined (PJRT clients are `Rc`-based, not `Send`), so
//! per-thread construction is the only sound way to decode through one
//! under concurrent load. If a worker's factory or decoder fails (e.g.
//! a missing artifact manifest), that worker degrades to the index's
//! own infallible decoder; no request is ever dropped.
//!
//! # Reads share the index lock-free; writes get their own lane
//!
//! Workers share the index via `Arc` with no locking on the hot path —
//! including a sharded index ([`crate::index::ShardSet`]): each
//! dispatched batch pins one epoch snapshot and scatters its probed
//! buckets to the owning shards inside the engine, so heterogeneous
//! per-shard pipelines serve behind this one router unchanged. The index
//! is **live-mutable** underneath: [`Router::submit_write`] feeds a
//! dedicated write lane — its own bounded ingress channel
//! ([`ServerCfg::write_queue_cap`], backpressure independent of the
//! query queue) drained by a single writer thread that applies
//! [`WriteOp`]s through `SearchIndex::insert` / `delete` / `compact`.
//! One writer thread means write operations apply in submission order
//! and never contend with each other; readers keep serving their pinned
//! epochs throughout and pick up the new epoch on their next batch.
//! Latency and throughput metrics are collected per request into
//! per-worker rings and merged at [`Router::stats`] time (see [`Stats`]
//! for the aggregation semantics; [`Stats::shard_scans`] surfaces the
//! per-shard scan counters, [`Stats::inserted`] / [`Stats::deleted`] the
//! ingest counters). The §B latency experiment and Fig. 6 QPS numbers
//! come from here.
//!
//! Lifecycle: dropping the [`Router`] (or calling [`Router::shutdown`])
//! closes both ingresses; the batcher flushes whatever it buffered and
//! exits when the ingress disconnects, workers exit only when the batch
//! channel is *both* disconnected and drained, and the writer thread
//! drains every queued write — every accepted request gets its reply
//! (possibly a typed error) before the threads are joined. Submission
//! after shutdown fails with [`RouterError::Stopped`] instead of
//! panicking.

use crate::index::{BatchSearcher, EncodeParams, QueryPlan, SearchIndex, SearchParams};
use crate::qinco::ReferenceDecoderFactory;
use crate::quantizers::{DecoderFactory, StageDecoder};
use crate::tensor::Matrix;
use crate::util::deadline::Deadline;
use crate::util::fault::{self, FaultPoint};
use crate::util::prng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct ServerCfg {
    pub workers: usize,
    /// max queries grouped into one dispatch unit
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub batch_timeout: Duration,
    /// ingress queue capacity (backpressure: submit blocks when full)
    pub queue_cap: usize,
    /// write-lane queue capacity — its own backpressure, independent of
    /// the query ingress: a burst of ingest can never starve reads of
    /// queue space, and vice versa
    pub write_queue_cap: usize,
    /// read-lane admission high-water mark: when this many read requests
    /// are in flight (queued + serving), further submits are shed with
    /// [`RouterError::Overloaded`]. `0` disables shedding (the bounded
    /// ingress still applies backpressure).
    pub shed_watermark: usize,
    /// same, for the write lane
    pub write_shed_watermark: usize,
    /// how many times the blocking helpers retry an
    /// `Overloaded`/`Saturated` submission (with exponential, jittered
    /// backoff) before returning the error. `0` disables retry.
    pub blocking_retries: usize,
    /// base backoff between blocking-helper retries (doubles per
    /// attempt, plus a deterministic jitter of up to half the step)
    pub retry_backoff: Duration,
    /// how long the blocking helpers wait for a reply when the request
    /// carries **no** deadline, before concluding the serving thread
    /// died ([`RouterError::WorkerDied`]). Deadline-carrying requests
    /// wait `deadline + batch_timeout + grace` instead. Generous by
    /// default — this is a liveness backstop, not a latency control.
    pub blocking_recv_timeout: Duration,
    /// per-worker stage-3 decoder factory; `None` defaults to the
    /// reference decoder. Each worker thread calls `make()` once at
    /// startup (engine-per-worker — see the module docs).
    pub decoder_factory: Option<Arc<dyn DecoderFactory>>,
}

impl std::fmt::Debug for ServerCfg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerCfg")
            .field("workers", &self.workers)
            .field("max_batch", &self.max_batch)
            .field("batch_timeout", &self.batch_timeout)
            .field("queue_cap", &self.queue_cap)
            .field("write_queue_cap", &self.write_queue_cap)
            .field("shed_watermark", &self.shed_watermark)
            .field("write_shed_watermark", &self.write_shed_watermark)
            .field("blocking_retries", &self.blocking_retries)
            .field("retry_backoff", &self.retry_backoff)
            .field("blocking_recv_timeout", &self.blocking_recv_timeout)
            .field("decoder_factory", &self.decoder_factory.as_ref().map(|_| "custom"))
            .finish()
    }
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            workers: crate::util::pool::default_threads(),
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            queue_cap: 1024,
            write_queue_cap: 64,
            shed_watermark: 0,
            write_shed_watermark: 0,
            blocking_retries: 0,
            retry_backoff: Duration::from_millis(1),
            blocking_recv_timeout: Duration::from_secs(30),
            decoder_factory: None,
        }
    }
}

/// Why a router operation could not complete. See the module-level
/// "Failure model" section for when each variant is produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterError {
    /// The router has been shut down; no new requests are accepted.
    Stopped,
    /// The ingress queue is full (backpressure) — retry or shed load.
    Saturated,
    /// The serving thread handling this request died (or its decoder
    /// failed) before replying.
    WorkerDied,
    /// The request's deadline passed before a worker started it.
    DeadlineExceeded,
    /// Admission control shed this request: the lane's in-flight
    /// high-water mark is crossed. `retry_after_hint` estimates when
    /// capacity should free up (mean latency × queue pressure, clamped).
    Overloaded { retry_after_hint: Duration },
}

impl std::fmt::Display for RouterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouterError::Stopped => write!(f, "router stopped"),
            RouterError::Saturated => write!(f, "ingress queue saturated"),
            RouterError::WorkerDied => write!(f, "worker died before replying"),
            RouterError::DeadlineExceeded => {
                write!(f, "deadline exceeded before the request was served")
            }
            RouterError::Overloaded { retry_after_hint } => {
                write!(f, "overloaded; retry after ~{retry_after_hint:?}")
            }
        }
    }
}

impl std::error::Error for RouterError {}

/// What a read caller receives on its reply channel: the response, or a
/// typed router error. Exactly one is delivered per accepted request.
pub type Reply = Result<Response, RouterError>;

/// The write lane's reply payload.
pub type WriteReply = Result<WriteResponse, RouterError>;

/// Which lane a reply guard accounts against.
#[derive(Clone, Copy, Debug)]
enum Lane {
    Read,
    Write,
}

/// Guard-based reply delivery: wraps a request's reply sender so that
/// **some** reply always goes out — [`fulfill`](Self::fulfill) sends the
/// real one; if the guard is instead dropped (worker panic → unwind,
/// decoder failure path, router teardown with the request still queued)
/// its `Drop` sends a typed [`RouterError::WorkerDied`]. Either way the
/// lane's in-flight counter is decremented exactly once. This is what
/// turns "a worker died" from a hung `recv()` into a typed error.
pub struct ReplyGuard<T> {
    tx: Option<SyncSender<Result<T, RouterError>>>,
    metrics: Arc<MetricsInner>,
    lane: Lane,
}

impl<T> ReplyGuard<T> {
    fn new(tx: SyncSender<Result<T, RouterError>>, metrics: Arc<MetricsInner>, lane: Lane) -> Self {
        ReplyGuard { tx: Some(tx), metrics, lane }
    }

    /// Deliver the reply. A dropped receiver (caller gave up) is not an
    /// error.
    pub fn fulfill(mut self, reply: Result<T, RouterError>) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(reply);
        }
        // Drop still runs and decrements the in-flight counter; it sees
        // `tx == None` and sends nothing.
    }
}

impl<T> Drop for ReplyGuard<T> {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Err(RouterError::WorkerDied));
        }
        let ctr = match self.lane {
            Lane::Read => &self.metrics.read_inflight,
            Lane::Write => &self.metrics.write_inflight,
        };
        ctr.fetch_sub(1, Ordering::Relaxed);
    }
}

pub struct Request {
    pub query: Vec<f32>,
    pub sp: SearchParams,
    /// when this request must complete ([`Deadline::none()`] = never) —
    /// checked by the batcher, the dispatch path, and the engine's scan
    /// loops
    pub deadline: Deadline,
    pub reply: ReplyGuard<Response>,
    pub t_submit: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub results: Vec<(f32, u32)>,
    pub latency: Duration,
    /// `true` when deadline pressure cut the pipeline short: `results`
    /// is the stage-1/2 shortlist ranking (stage 3 skipped whole, or the
    /// scan aborted early). `false` **guarantees** the configured
    /// pipeline ran to completion — degraded results are never emitted
    /// without this flag.
    pub degraded: bool,
}

/// One mutation for the write lane, applied by the single writer thread
/// in submission order.
#[derive(Clone, Debug)]
pub enum WriteOp {
    /// Encode + ingest vectors (`ep` carries the `--a`/`--b` beam knobs).
    Insert { vectors: Matrix, ep: EncodeParams },
    /// Tombstone-delete rows by global id.
    Delete { ids: Vec<u32> },
    /// Reclaim every shard's tombstoned rows.
    Compact,
}

/// What a [`WriteOp`] produced.
#[derive(Clone, Debug)]
pub enum WriteOutcome {
    /// The global ids allocated to the inserted vectors.
    Inserted(Vec<u32>),
    /// Rows newly tombstoned.
    Deleted(usize),
    /// Rows reclaimed by compaction.
    Compacted(usize),
}

pub struct WriteRequest {
    pub op: WriteOp,
    /// writes carry deadlines too: an op whose deadline passed before
    /// the writer picked it up is answered `DeadlineExceeded` and never
    /// applied (atomic: an op either fully publishes or does nothing)
    pub deadline: Deadline,
    pub reply: ReplyGuard<WriteResponse>,
    pub t_submit: Instant,
}

#[derive(Clone, Debug)]
pub struct WriteResponse {
    /// The op's outcome, or the index's validation error (bad encode
    /// params, out-of-range delete id, …) as a string.
    pub outcome: Result<WriteOutcome, String>,
    pub latency: Duration,
}

/// Lock a mutex, recovering from poisoning. Every shared-metrics lock in
/// this module goes through here: a worker that panics while holding a
/// latency-ring lock marks it poisoned, but the data inside is a plain
/// `Vec<u64>` that is valid after any partial update (at worst one
/// sample is missing), so recovery is always sound — and
/// [`Router::stats`] must keep working precisely when workers are
/// crashing. Same reasoning for the shared batch-channel mutex.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct MetricsInner {
    served: AtomicU64,
    /// nanoseconds, summed
    total_latency: AtomicU64,
    /// rows ingested through the write lane
    inserted: AtomicU64,
    /// rows tombstoned through the write lane
    deleted: AtomicU64,
    /// worker panics caught by the supervisors
    panics: AtomicU64,
    /// worker loops re-entered after a panic (== panics today; kept
    /// separate so a future restart-budget policy can diverge)
    respawns: AtomicU64,
    /// requests refused by admission control (both lanes)
    shed: AtomicU64,
    /// requests answered `DeadlineExceeded` before serving started
    deadline_exceeded: AtomicU64,
    /// replies delivered with `degraded: true`
    degraded: AtomicU64,
    /// read requests accepted and not yet replied to
    read_inflight: AtomicU64,
    /// write requests accepted and not yet replied to
    write_inflight: AtomicU64,
    /// per-worker recent-latency rings (ns). Each worker pushes only
    /// into its own ring (capped at RECENT_CAP, oldest half evicted), so
    /// a chatty worker can never evict a quiet worker's samples;
    /// [`Router::stats`] merges every ring before ranking, which keeps
    /// the percentiles consistent under any worker/shard interleaving.
    recent: Vec<Mutex<Vec<u64>>>,
}

/// Per-worker recent-latency ring capacity.
const RECENT_CAP: usize = 4096;

/// Extra wait the blocking helpers grant past a request's deadline
/// before declaring the worker dead: the reply for a deadline-expired
/// request (typed `DeadlineExceeded`, or a degraded result) is produced
/// *at* dispatch/scan-abort time, which can trail the deadline by a
/// batching window.
const RECV_GRACE: Duration = Duration::from_millis(100);

impl MetricsInner {
    fn new(workers: usize) -> MetricsInner {
        MetricsInner {
            served: AtomicU64::new(0),
            total_latency: AtomicU64::new(0),
            inserted: AtomicU64::new(0),
            deleted: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            read_inflight: AtomicU64::new(0),
            write_inflight: AtomicU64::new(0),
            recent: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }
}

/// Merge the per-worker latency rings into one ascending-sorted vector —
/// the sample set the nearest-rank percentiles are computed over.
/// Poison-recovering: a worker that panicked mid-record must not take
/// down `stats()` (satellite regression: `fault_injection.rs` panics a
/// worker while it holds its ring lock, then asserts this still works).
fn merged_sorted(rings: &[Mutex<Vec<u64>>]) -> Vec<u64> {
    let mut merged = Vec::new();
    for ring in rings {
        merged.extend(lock_ignore_poison(ring).iter().copied());
    }
    merged.sort_unstable();
    merged
}

/// Snapshot of server health.
///
/// Latency percentiles are **nearest-rank** — the smallest sample with
/// at least `p·n` samples at or below it — computed
/// over the **union of every worker's recent ring** (the newest ≤4096
/// samples per worker), merged and sorted at snapshot time. Aggregating
/// before ranking (rather than averaging per-worker percentiles, or
/// letting workers share one eviction-contended ring) keeps the
/// percentiles consistent across workers and shards: every worker's
/// traffic is represented, and a chatty worker cannot evict a quiet
/// worker's samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub served: u64,
    pub mean_latency: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// per-shard stage-1 scan counters: (query, candidate) pairs scored
    /// by each [`IndexShard`](crate::index::IndexShard) **since this
    /// router started**, in shard order — the scatter/gather layer's
    /// load view (uneven counts reveal skewed bucket ownership). The
    /// underlying index counters are lifetime totals shared by every
    /// execution path; the router snapshots them at startup and reports
    /// the delta, so these stay consistent with the router-scoped
    /// `served`/latency fields even when the index served other
    /// routers or direct searches before.
    pub shard_scans: Vec<u64>,
    /// rows ingested through this router's write lane
    pub inserted: u64,
    /// rows tombstone-deleted through this router's write lane
    pub deleted: u64,
    /// the index's current publication epoch at snapshot time
    pub epoch: u64,
    /// worker/writer panics caught by the supervisors
    pub panics: u64,
    /// serve loops re-entered after a caught panic
    pub respawns: u64,
    /// requests shed by admission control (both lanes)
    pub shed: u64,
    /// requests answered `DeadlineExceeded` before serving started
    pub deadline_exceeded: u64,
    /// replies delivered with `degraded: true`
    pub degraded: u64,
    /// connections accepted by the network tier
    /// ([`NetServer`](crate::net::NetServer)); zero when this snapshot
    /// came straight from [`Router::stats`] — the router itself has no
    /// sockets. The four net counters are filled in by
    /// `NetServer::stats` and travel on the stats frame op.
    pub connections: u64,
    /// frames decoded off accepted connections (requests + notices)
    pub frames_in: u64,
    /// reply frames successfully written back
    pub frames_out: u64,
    /// framing/codec violations (each one closed its connection)
    pub protocol_errors: u64,
}

/// Nearest-rank percentile of an ascending-sorted latency vector: the
/// smallest element with at least `p·len` samples at or below it. Unlike
/// the floored `((len-1)·p)` index, this is never biased low — with
/// fewer than 100 samples p99 is the maximum, as it should be.
/// `pub(crate)` so the network load generator ranks its wire-level
/// samples with the same estimator.
pub(crate) fn percentile(sorted: &[u64], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    Duration::from_nanos(sorted[rank.clamp(1, sorted.len()) - 1])
}

pub struct Router {
    /// `Option` so `Drop` can close the lane and then join (shutdown
    /// drain); always `Some` while the router is live
    ingress: Option<SyncSender<Request>>,
    /// the write lane's own bounded ingress (see the module docs)
    write_ingress: Option<SyncSender<WriteRequest>>,
    cfg: ServerCfg,
    metrics: Arc<MetricsInner>,
    /// shared with the workers; [`Self::stats`] reads the per-shard scan
    /// counters off it
    index: Arc<SearchIndex>,
    /// per-shard scan counts at router startup — subtracted in
    /// [`Self::stats`] so `shard_scans` covers only this router's traffic
    scan_base: Vec<u64>,
    /// feeds the deterministic retry-backoff jitter (each retry draws a
    /// fresh SplitMix64 stream keyed by this sequence)
    jitter_seq: AtomicU64,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn the batcher and worker threads over a shared index.
    pub fn start(index: Arc<SearchIndex>, cfg: ServerCfg) -> Router {
        let workers = cfg.workers.max(1);
        let (in_tx, in_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) = sync_channel::<Vec<Request>>(workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics = Arc::new(MetricsInner::new(workers));
        let mut handles = Vec::new();

        // --- batcher: groups ingress into dispatch units, drops expired
        // requests with a typed DeadlineExceeded reply ---
        {
            let max_batch = cfg.max_batch;
            let timeout = cfg.batch_timeout;
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                batcher_loop(in_rx, batch_tx, max_batch, timeout, &metrics)
            }));
        }
        // --- workers: each dispatches whole batches through the engine,
        // with a stage-3 decoder built once per (re)spawn by the
        // factory. Supervised: a panic is caught, counted, and the loop
        // re-entered — the offending batch's callers got WorkerDied
        // through their reply guards during the unwind ---
        let factory: Arc<dyn DecoderFactory> = cfg.decoder_factory.clone().unwrap_or_else(|| {
            Arc::new(ReferenceDecoderFactory { params: index.params.clone() })
        });
        for w in 0..workers {
            let rx = batch_rx.clone();
            let idx = index.clone();
            let metrics = metrics.clone();
            let factory = factory.clone();
            handles.push(std::thread::spawn(move || loop {
                let run = catch_unwind(AssertUnwindSafe(|| {
                    worker_loop(&idx, &metrics, w, &rx, factory.as_ref())
                }));
                match run {
                    // batch channel disconnected + drained: clean exit
                    Ok(()) => return,
                    Err(_) => {
                        metrics.panics.fetch_add(1, Ordering::Relaxed);
                        metrics.respawns.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[server] worker {w} panicked; respawning \
                             (its in-flight callers were answered WorkerDied)"
                        );
                    }
                }
            }));
        }
        // --- write lane: one bounded channel, one supervised writer
        // thread. A single drainer keeps ops in submission order and
        // means the index's writer mutex is never contended from here.
        // Respawn-after-panic is safe here because every mutation
        // publishes its epoch snapshot atomically at the end — a
        // panicked mutation published nothing ---
        let (write_tx, write_rx) = sync_channel::<WriteRequest>(cfg.write_queue_cap.max(1));
        {
            let idx = index.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || loop {
                let run =
                    catch_unwind(AssertUnwindSafe(|| writer_loop(&idx, &metrics, &write_rx)));
                match run {
                    Ok(()) => return,
                    Err(_) => {
                        metrics.panics.fetch_add(1, Ordering::Relaxed);
                        metrics.respawns.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[server] writer panicked; respawning \
                             (the offending op's caller was answered WorkerDied)"
                        );
                    }
                }
            }));
        }
        let scan_base = index.snapshot().scan_counts();
        Router {
            ingress: Some(in_tx),
            write_ingress: Some(write_tx),
            cfg,
            metrics,
            index,
            scan_base,
            jitter_seq: AtomicU64::new(0),
            handles,
        }
    }

    fn ingress(&self) -> &SyncSender<Request> {
        self.ingress.as_ref().expect("ingress is Some until Drop")
    }

    fn write_ingress(&self) -> &SyncSender<WriteRequest> {
        self.write_ingress.as_ref().expect("write ingress is Some until Drop")
    }

    /// Estimated wait before a shed caller should retry: mean request
    /// latency scaled by queue pressure (in-flight per worker), clamped
    /// to [100µs, 1s]. Cheap and advisory — the point is giving shed
    /// clients *something* better than blind hammering.
    fn retry_after_hint(&self) -> Duration {
        let served = self.metrics.served.load(Ordering::Relaxed);
        let mean_ns = if served > 0 {
            self.metrics.total_latency.load(Ordering::Relaxed) / served
        } else {
            self.cfg.batch_timeout.as_nanos() as u64
        };
        let queued = self.metrics.read_inflight.load(Ordering::Relaxed);
        let per_worker = queued / self.cfg.workers.max(1) as u64 + 1;
        Duration::from_nanos(mean_ns.saturating_mul(per_worker))
            .clamp(Duration::from_micros(100), Duration::from_secs(1))
    }

    /// Admission gate for the read lane (and the `QueueFull` fault
    /// probe): shed with `Overloaded` when the in-flight high-water mark
    /// is crossed.
    fn admit_read(&self) -> Result<(), RouterError> {
        let tripped = fault::fire(FaultPoint::QueueFull).is_some()
            || (self.cfg.shed_watermark > 0
                && self.metrics.read_inflight.load(Ordering::Relaxed)
                    >= self.cfg.shed_watermark as u64);
        if tripped {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(RouterError::Overloaded { retry_after_hint: self.retry_after_hint() });
        }
        Ok(())
    }

    fn admit_write(&self) -> Result<(), RouterError> {
        let tripped = self.cfg.write_shed_watermark > 0
            && self.metrics.write_inflight.load(Ordering::Relaxed)
                >= self.cfg.write_shed_watermark as u64;
        if tripped {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(RouterError::Overloaded { retry_after_hint: self.retry_after_hint() });
        }
        Ok(())
    }

    /// Submit a query with no deadline; returns the channel the
    /// [`Reply`] arrives on. Blocks when the ingress queue is full
    /// (backpressure); sheds with [`RouterError::Overloaded`] when the
    /// admission watermark is crossed.
    pub fn submit(&self, query: Vec<f32>, sp: SearchParams) -> Result<Receiver<Reply>, RouterError> {
        self.submit_within(query, sp, Deadline::none())
    }

    /// [`Self::submit`] with a deadline carried on the request.
    pub fn submit_within(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
        deadline: Deadline,
    ) -> Result<Receiver<Reply>, RouterError> {
        self.admit_read()?;
        let (tx, rx) = sync_channel(1);
        self.metrics.read_inflight.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            query,
            sp,
            deadline,
            reply: ReplyGuard::new(tx, self.metrics.clone(), Lane::Read),
            t_submit: Instant::now(),
        };
        // a failed send drops `req`, whose guard decrements the
        // in-flight count again — accounting stays exact
        self.ingress().send(req).map_err(|_| RouterError::Stopped)?;
        Ok(rx)
    }

    /// Non-blocking submit: fails fast with [`RouterError::Saturated`]
    /// when the bounded queue is full (admission shedding still applies
    /// first).
    pub fn try_submit(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
    ) -> Result<Receiver<Reply>, RouterError> {
        self.try_submit_within(query, sp, Deadline::none())
    }

    /// [`Self::try_submit`] with a deadline carried on the request.
    pub fn try_submit_within(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
        deadline: Deadline,
    ) -> Result<Receiver<Reply>, RouterError> {
        self.admit_read()?;
        let (tx, rx) = sync_channel(1);
        self.metrics.read_inflight.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            query,
            sp,
            deadline,
            reply: ReplyGuard::new(tx, self.metrics.clone(), Lane::Read),
            t_submit: Instant::now(),
        };
        match self.ingress().try_send(req) {
            Ok(()) => Ok(rx),
            // the rejected request (inside the error) drops here, which
            // reverses its in-flight increment via the guard
            Err(TrySendError::Full(_)) => Err(RouterError::Saturated),
            Err(TrySendError::Disconnected(_)) => Err(RouterError::Stopped),
        }
    }

    /// Synchronous convenience wrapper (no deadline; the
    /// [`ServerCfg::blocking_recv_timeout`] backstop still applies).
    pub fn search_blocking(
        &self,
        query: &[f32],
        sp: SearchParams,
    ) -> Result<Response, RouterError> {
        self.search_within(query, sp, Deadline::none())
    }

    /// Synchronous search under a deadline. Retries
    /// `Overloaded`/`Saturated` submissions up to
    /// [`ServerCfg::blocking_retries`] times with exponential,
    /// deterministically-jittered backoff, then waits for the reply with
    /// `recv_timeout` (deadline + grace, or the configured backstop) —
    /// a timeout maps to [`RouterError::WorkerDied`], so this can never
    /// hang on a dead worker.
    pub fn search_within(
        &self,
        query: &[f32],
        sp: SearchParams,
        deadline: Deadline,
    ) -> Result<Response, RouterError> {
        let mut attempt = 0usize;
        loop {
            match self.submit_within(query.to_vec(), sp, deadline) {
                Ok(rx) => return self.bounded_recv(&rx, deadline),
                Err(e @ (RouterError::Overloaded { .. } | RouterError::Saturated)) => {
                    if attempt >= self.cfg.blocking_retries || deadline.expired() {
                        return Err(e);
                    }
                    attempt += 1;
                    self.backoff(attempt, deadline);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Wait for a reply, bounded: never longer than the request deadline
    /// plus a batching-window grace, never unbounded even without a
    /// deadline.
    fn bounded_recv<T>(
        &self,
        rx: &Receiver<Result<T, RouterError>>,
        deadline: Deadline,
    ) -> Result<T, RouterError> {
        let timeout = match deadline.remaining() {
            Some(rem) => rem + self.cfg.batch_timeout + RECV_GRACE,
            None => self.cfg.blocking_recv_timeout,
        };
        match rx.recv_timeout(timeout) {
            Ok(reply) => reply,
            // Timeout: the serving thread is wedged (the guard protocol
            // would have delivered *something* by now). Disconnected:
            // sender vanished without the guard firing — only possible
            // on abnormal teardown. Both are a dead worker to the caller.
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                Err(RouterError::WorkerDied)
            }
        }
    }

    /// Exponential backoff with deterministic jitter (SplitMix64 over a
    /// submission sequence number — reproducible, no shared RNG state),
    /// capped by the remaining deadline.
    fn backoff(&self, attempt: usize, deadline: Deadline) {
        let base = self.cfg.retry_backoff.max(Duration::from_micros(50));
        let step = base.saturating_mul(1u32 << (attempt - 1).min(6));
        let seq = self.jitter_seq.fetch_add(1, Ordering::Relaxed);
        let jitter_ns =
            Rng::new(0x9E37_79B9_7F4A_7C15 ^ seq).next_u64() % (step.as_nanos() as u64 / 2 + 1);
        let mut wait = step + Duration::from_nanos(jitter_ns);
        if let Some(rem) = deadline.remaining() {
            wait = wait.min(rem);
        }
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
    }

    /// Submit a mutation to the write lane (no deadline); returns the
    /// channel the [`WriteReply`] arrives on. Blocks when the write
    /// queue is full (backpressure, independent of the query ingress).
    pub fn submit_write(&self, op: WriteOp) -> Result<Receiver<WriteReply>, RouterError> {
        self.submit_write_within(op, Deadline::none())
    }

    /// [`Self::submit_write`] with a deadline: the writer answers
    /// `DeadlineExceeded` (and does not apply the op) if it picks the op
    /// up too late.
    pub fn submit_write_within(
        &self,
        op: WriteOp,
        deadline: Deadline,
    ) -> Result<Receiver<WriteReply>, RouterError> {
        self.admit_write()?;
        let (tx, rx) = sync_channel(1);
        self.metrics.write_inflight.fetch_add(1, Ordering::Relaxed);
        let req = WriteRequest {
            op,
            deadline,
            reply: ReplyGuard::new(tx, self.metrics.clone(), Lane::Write),
            t_submit: Instant::now(),
        };
        self.write_ingress().send(req).map_err(|_| RouterError::Stopped)?;
        Ok(rx)
    }

    /// Non-blocking write submit: fails fast when the write queue is
    /// saturated.
    pub fn try_submit_write(&self, op: WriteOp) -> Result<Receiver<WriteReply>, RouterError> {
        self.try_submit_write_within(op, Deadline::none())
    }

    /// [`Self::try_submit_write`] with a deadline carried on the op —
    /// the write-lane mirror of [`Self::try_submit_within`] (the
    /// network tier submits exclusively through the two `try_*_within`
    /// entry points so a saturated lane becomes a typed wire status,
    /// never a blocked connection).
    pub fn try_submit_write_within(
        &self,
        op: WriteOp,
        deadline: Deadline,
    ) -> Result<Receiver<WriteReply>, RouterError> {
        self.admit_write()?;
        let (tx, rx) = sync_channel(1);
        self.metrics.write_inflight.fetch_add(1, Ordering::Relaxed);
        let req = WriteRequest {
            op,
            deadline,
            reply: ReplyGuard::new(tx, self.metrics.clone(), Lane::Write),
            t_submit: Instant::now(),
        };
        match self.write_ingress().try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(RouterError::Saturated),
            Err(TrySendError::Disconnected(_)) => Err(RouterError::Stopped),
        }
    }

    /// Synchronous write convenience wrapper (bounded wait — see
    /// [`Self::write_within`]).
    pub fn write_blocking(&self, op: WriteOp) -> Result<WriteResponse, RouterError> {
        self.write_within(op, Deadline::none())
    }

    /// Synchronous write under a deadline, with the same bounded
    /// retry/backoff/`recv_timeout` discipline as [`Self::search_within`].
    pub fn write_within(
        &self,
        op: WriteOp,
        deadline: Deadline,
    ) -> Result<WriteResponse, RouterError> {
        let mut attempt = 0usize;
        loop {
            // WriteOp is Clone; retries are rare and bounded, so a clone
            // per attempt beats threading ownership back out of a
            // refused submit
            match self.submit_write_within(op.clone(), deadline) {
                Ok(rx) => return self.bounded_recv(&rx, deadline),
                Err(e @ (RouterError::Overloaded { .. } | RouterError::Saturated)) => {
                    if attempt >= self.cfg.blocking_retries || deadline.expired() {
                        return Err(e);
                    }
                    attempt += 1;
                    self.backoff(attempt, deadline);
                }
                Err(e) => return Err(e),
            }
        }
    }

    pub fn stats(&self) -> Stats {
        let served = self.metrics.served.load(Ordering::Relaxed);
        let total = self.metrics.total_latency.load(Ordering::Relaxed);
        // union of every worker's ring, merged before ranking (see the
        // Stats docs for the aggregation semantics)
        let recent = merged_sorted(&self.metrics.recent);
        Stats {
            served,
            mean_latency: Duration::from_nanos(if served > 0 { total / served } else { 0 }),
            p50: percentile(&recent, 0.5),
            p99: percentile(&recent, 0.99),
            shard_scans: self
                .index
                .snapshot()
                .scan_counts()
                .iter()
                .zip(&self.scan_base)
                .map(|(now, base)| now.saturating_sub(*base))
                .collect(),
            inserted: self.metrics.inserted.load(Ordering::Relaxed),
            deleted: self.metrics.deleted.load(Ordering::Relaxed),
            epoch: self.index.epoch(),
            panics: self.metrics.panics.load(Ordering::Relaxed),
            respawns: self.metrics.respawns.load(Ordering::Relaxed),
            shed: self.metrics.shed.load(Ordering::Relaxed),
            deadline_exceeded: self.metrics.deadline_exceeded.load(Ordering::Relaxed),
            degraded: self.metrics.degraded.load(Ordering::Relaxed),
            // the router has no sockets; the network tier overlays its
            // own counters onto this snapshot (NetServer::stats)
            connections: 0,
            frames_in: 0,
            frames_out: 0,
            protocol_errors: 0,
        }
    }

    /// The shared index this router serves — the network tier reads the
    /// vector dimension and live row count off it to validate requests
    /// and answer the stats op.
    pub fn index(&self) -> &Arc<SearchIndex> {
        &self.index
    }

    /// Graceful shutdown: equivalent to dropping the router. Close both
    /// ingresses, let the batcher flush its buffer, let workers drain
    /// and answer every queued batch, let the writer apply every queued
    /// write, then join all threads. Every accepted request receives its
    /// reply (a result or a typed error) — no silently lost senders.
    pub fn shutdown(self) {
        // Drop does the work; see `impl Drop for Router`.
    }
}

/// Dropping the router IS graceful shutdown — the drain property holds
/// even when the router goes out of scope with reads in flight and
/// writes queued (pinned by the shutdown-under-load property test in
/// `tests/coordinator_props.rs`).
impl Drop for Router {
    fn drop(&mut self) {
        self.ingress.take();
        self.write_ingress.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The write lane's single drainer: apply each op, count rows, reply.
/// Exits when the write ingress disconnects and every queued op has been
/// applied. Deadline-expired ops are answered `DeadlineExceeded` and
/// **not** applied — an op either fully publishes or does nothing.
fn writer_loop(idx: &SearchIndex, metrics: &MetricsInner, rx: &Receiver<WriteRequest>) {
    while let Ok(req) = rx.recv() {
        let WriteRequest { op, deadline, reply, t_submit } = req;
        if deadline.expired() {
            metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            reply.fulfill(Err(RouterError::DeadlineExceeded));
            continue;
        }
        let outcome = match &op {
            WriteOp::Insert { vectors, ep } => idx
                .insert(vectors, ep)
                .map(|gids| {
                    metrics.inserted.fetch_add(gids.len() as u64, Ordering::Relaxed);
                    WriteOutcome::Inserted(gids)
                })
                .map_err(|e| e.to_string()),
            WriteOp::Delete { ids } => idx
                .delete(ids)
                .map(|n| {
                    metrics.deleted.fetch_add(n as u64, Ordering::Relaxed);
                    WriteOutcome::Deleted(n)
                })
                .map_err(|e| e.to_string()),
            WriteOp::Compact => Ok(WriteOutcome::Compacted(idx.compact())),
        };
        reply.fulfill(Ok(WriteResponse { outcome, latency: t_submit.elapsed() }));
    }
}

/// One read worker's serve loop: pull dispatch units off the shared
/// batch channel and serve them. Runs under the supervisor's
/// `catch_unwind`; a fresh decoder is constructed per entry (so a
/// respawned worker gets a clean one). Returns when the batch channel is
/// disconnected **and** drained — nothing in flight can be lost.
fn worker_loop(
    idx: &Arc<SearchIndex>,
    metrics: &Arc<MetricsInner>,
    w: usize,
    rx: &Arc<Mutex<Receiver<Vec<Request>>>>,
    factory: &dyn DecoderFactory,
) {
    // engine-per-worker: PJRT clients are Rc-based and not Send, so
    // each thread constructs its own decoder. A failed factory (stub
    // runtime, missing artifacts) degrades this worker to the index's
    // shared decoder.
    let mut local: Option<Box<dyn StageDecoder>> = match factory.make() {
        Ok(d) => Some(d),
        Err(e) => {
            eprintln!(
                "[server] worker {w}: decoder factory failed ({e}); \
                 falling back to the index's stage-3 decoder"
            );
            None
        }
    };
    loop {
        // poison-recovering: another worker panicking between recv and
        // guard-drop would poison this mutex for the whole pool
        let batch = {
            let guard = lock_ignore_poison(rx);
            guard.recv()
        };
        match batch {
            Ok(batch) => serve_batch(idx, metrics, w, batch, &mut local),
            // the batcher exited and every queued batch has been
            // drained — nothing in flight can be lost
            Err(_) => return,
        }
    }
}

/// Serve one dispatch unit: group requests by identical [`SearchParams`]
/// and run each group through the batched engine in a single execute —
/// one scattered shard-group scan and one union decode per group
/// (heterogeneous per-shard pipelines, when configured on the index,
/// are resolved inside the engine). Each group executes under the
/// **earliest** deadline among its members (the group degrades
/// together; every member gets the same `degraded` flag). Requests
/// already expired at dispatch are answered `DeadlineExceeded` without
/// being planned. `worker` indexes this thread's own latency ring in
/// `metrics`. `decoder` is this worker's thread-local stage-3 decoder
/// (engine-per-worker); when it is absent the index's own decoder runs.
/// A decode failure re-executes the group with the index decoder (every
/// request still gets a reply unless that decoder *also* fails — then
/// the members' reply guards deliver typed `WorkerDied`) and then
/// *drops* the local decoder — decoder failures are configuration
/// errors (missing artifact, stubbed runtime), not transient, so the
/// worker must not pay a doubled execute on every subsequent batch.
fn serve_batch(
    idx: &SearchIndex,
    metrics: &MetricsInner,
    worker: usize,
    batch: Vec<Request>,
    decoder: &mut Option<Box<dyn StageDecoder>>,
) {
    let searcher = BatchSearcher::new(idx);
    // group by identical SearchParams, preserving arrival order;
    // deadline-expired requests are answered here, before any planning
    let mut groups: Vec<(SearchParams, Deadline, Vec<Request>)> = Vec::new();
    for req in batch {
        if req.deadline.expired() {
            metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            req.reply.fulfill(Err(RouterError::DeadlineExceeded));
            continue;
        }
        match groups.iter_mut().find(|(sp, _, _)| *sp == req.sp) {
            Some((_, dl, members)) => {
                *dl = dl.earliest(req.deadline);
                members.push(req);
            }
            None => groups.push((req.sp, req.deadline, vec![req])),
        }
    }
    for (sp, dl, members) in groups {
        let plans: Vec<QueryPlan> =
            members.iter().map(|r| searcher.plan(&r.query, &sp)).collect();
        // fault probe: one decision per group; an injected decoder
        // error fails BOTH decode paths (thread-local and index-held),
        // modeling a corrupted artifact rather than a per-engine blip
        let injected = fault::fire(FaultPoint::DecoderError).is_some();
        let mut output = None;
        if !injected {
            if let Some(d) = decoder.as_deref() {
                match searcher.execute_within(&plans, &sp, Some(d), dl) {
                    Ok(out) => output = Some(out),
                    Err(e) => {
                        eprintln!(
                            "[server] stage-3 decoder '{}' failed ({e}); this worker \
                             serves with the index decoder from now on",
                            d.name()
                        );
                        *decoder = None;
                    }
                }
            }
        }
        let output = match output {
            Some(out) => out,
            None => {
                let fallback = if injected {
                    Err(anyhow::anyhow!("injected stage-3 decoder failure"))
                } else {
                    searcher.execute_within(&plans, &sp, None, dl)
                };
                match fallback {
                    Ok(out) => out,
                    Err(e) => {
                        eprintln!(
                            "[server] stage-3 decode failed with no fallback ({e}); \
                             {} callers get WorkerDied",
                            members.len()
                        );
                        // dropping the members runs their reply guards:
                        // every caller receives typed WorkerDied
                        continue;
                    }
                }
            }
        };
        if output.degraded {
            metrics.degraded.fetch_add(members.len() as u64, Ordering::Relaxed);
        }
        for (req, results_j) in members.into_iter().zip(output.results) {
            let latency = req.t_submit.elapsed();
            metrics.served.fetch_add(1, Ordering::Relaxed);
            metrics
                .total_latency
                .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
            {
                // this worker's own ring: eviction here can never drop
                // another worker's samples (see the Stats docs)
                let mut recent = lock_ignore_poison(&metrics.recent[worker]);
                // fault probe: panic while the ring lock is held — the
                // worst case for stats() (lock poisoned mid-record) and
                // for this request's caller (reply not yet sent; the
                // guard delivers WorkerDied during unwind)
                if fault::fire(FaultPoint::WorkerPanic).is_some() {
                    panic!("injected worker panic (latency-ring lock held)");
                }
                if recent.len() >= RECENT_CAP {
                    let n = recent.len();
                    recent.copy_within(n / 2.., 0);
                    recent.truncate(n / 2);
                }
                recent.push(latency.as_nanos() as u64);
            }
            req.reply.fulfill(Ok(Response {
                results: results_j,
                latency,
                degraded: output.degraded,
            }));
        }
    }
}

fn batcher_loop(
    in_rx: Receiver<Request>,
    batch_tx: SyncSender<Vec<Request>>,
    max_batch: usize,
    timeout: Duration,
    metrics: &MetricsInner,
) {
    loop {
        // block for the first request of a batch; a disconnect here means
        // shutdown with nothing buffered
        let first = match in_rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let window = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= window {
                break;
            }
            match in_rx.recv_timeout(window - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                // ingress closed mid-batch: flush what we have, then the
                // next blocking recv observes the disconnect and exits
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // fault probe: a stalled dispatch thread
        if let Some(delay) = fault::fire(FaultPoint::BatcherDelay) {
            std::thread::sleep(delay);
        }
        // drop requests whose deadline passed while queued/batched, with
        // a typed reply — serving them late helps no one and steals scan
        // time from live requests
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            if req.deadline.expired() {
                metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                req.reply.fulfill(Err(RouterError::DeadlineExceeded));
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            continue;
        }
        if batch_tx.send(live).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        // 1..=100 ns: p50 is the 50th smallest, p99 the 99th
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), Duration::from_nanos(50));
        assert_eq!(percentile(&v, 0.99), Duration::from_nanos(99));
        assert_eq!(percentile(&v, 1.00), Duration::from_nanos(100));
    }

    #[test]
    fn percentile_small_samples_reach_the_max() {
        // the old floored index could never return the max with < 100
        // samples; nearest-rank p99 of a small vector IS the max
        let v = vec![10, 20, 30, 40];
        assert_eq!(percentile(&v, 0.99), Duration::from_nanos(40));
        assert_eq!(percentile(&v, 0.50), Duration::from_nanos(20));
        assert_eq!(percentile(&v, 0.25), Duration::from_nanos(10));
        // degenerate inputs
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
        assert_eq!(percentile(&[7], 0.5), Duration::from_nanos(7));
        assert_eq!(percentile(&[7], 0.0), Duration::from_nanos(7));
    }

    #[test]
    fn percentile_monotone_in_p() {
        let v = vec![1, 1, 2, 3, 5, 8, 13, 21, 34];
        let mut last = Duration::ZERO;
        for i in 0..=20 {
            let p = i as f64 / 20.0;
            let cur = percentile(&v, p);
            assert!(cur >= last, "p={p}: {cur:?} < {last:?}");
            last = cur;
        }
    }

    #[test]
    fn percentiles_merge_across_worker_rings() {
        // regression for the multi-worker merge: percentiles must be
        // computed over the *union* of the per-worker rings — identical
        // to ranking the flat concatenation — not any single ring's view
        let rings = vec![
            Mutex::new(vec![5, 1, 3]),
            Mutex::new(vec![2]),
            Mutex::new(Vec::new()),
            Mutex::new(vec![4, 6]),
        ];
        let merged = merged_sorted(&rings);
        assert_eq!(merged, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(percentile(&merged, 0.50), Duration::from_nanos(3));
        assert_eq!(percentile(&merged, 0.99), Duration::from_nanos(6));
        // uneven load: a chatty worker's full ring must not displace a
        // quiet worker's lone sample (the old shared-ring design let it)
        let rings = vec![
            Mutex::new((0..RECENT_CAP as u64).map(|i| 10 + i).collect::<Vec<_>>()),
            Mutex::new(vec![1]),
        ];
        let merged = merged_sorted(&rings);
        assert_eq!(merged.len(), RECENT_CAP + 1);
        assert_eq!(merged[0], 1, "quiet worker's sample must survive the merge");
        assert_eq!(percentile(&merged, 0.0), Duration::from_nanos(1));
        // no workers / empty rings degrade to zero, matching a fresh router
        assert!(merged_sorted(&[]).is_empty());
        assert_eq!(percentile(&merged_sorted(&[Mutex::new(Vec::new())]), 0.99), Duration::ZERO);
    }

    #[test]
    fn merged_sorted_recovers_from_a_poisoned_ring() {
        // satellite regression (unit-level): a worker that panicked
        // while holding its ring lock must not take down the stats path.
        // The full router-level version lives in tests/fault_injection.rs
        let rings = vec![Mutex::new(vec![3u64, 1]), Mutex::new(vec![2u64])];
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = rings[0].lock().unwrap();
            panic!("simulated mid-record panic");
        }));
        assert!(rings[0].is_poisoned(), "the panic must actually poison the lock");
        assert_eq!(merged_sorted(&rings), vec![1, 2, 3]);
    }

    #[test]
    fn router_error_formats() {
        assert_eq!(RouterError::Stopped.to_string(), "router stopped");
        assert!(RouterError::Saturated.to_string().contains("saturated"));
        assert!(RouterError::WorkerDied.to_string().contains("died"));
        assert!(RouterError::DeadlineExceeded.to_string().contains("deadline"));
        let e = RouterError::Overloaded { retry_after_hint: Duration::from_millis(3) };
        assert!(e.to_string().contains("overloaded"));
        assert!(e.to_string().contains("retry"));
    }

    #[test]
    fn reply_guard_drop_delivers_typed_worker_died() {
        let metrics = Arc::new(MetricsInner::new(0));
        metrics.read_inflight.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel::<Reply>(1);
        let guard: ReplyGuard<Response> = ReplyGuard::new(tx, metrics.clone(), Lane::Read);
        drop(guard); // simulates an unwinding worker
        assert_eq!(rx.recv().unwrap().unwrap_err(), RouterError::WorkerDied);
        assert_eq!(metrics.read_inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn reply_guard_fulfill_sends_once_and_decrements_once() {
        let metrics = Arc::new(MetricsInner::new(0));
        metrics.write_inflight.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel::<WriteReply>(1);
        let guard: ReplyGuard<WriteResponse> = ReplyGuard::new(tx, metrics.clone(), Lane::Write);
        guard.fulfill(Err(RouterError::DeadlineExceeded));
        assert_eq!(rx.recv().unwrap().unwrap_err(), RouterError::DeadlineExceeded);
        // exactly one reply: the channel is now disconnected, not holding
        // a second (guard-drop) message
        assert!(rx.recv().is_err());
        assert_eq!(metrics.write_inflight.load(Ordering::Relaxed), 0);
    }
}
