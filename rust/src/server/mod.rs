//! Serving coordinator: request router + dynamic batcher + worker pool
//! over a shared [`SearchIndex`] (tokio is unavailable offline; this uses
//! std threads + mpsc channels, the same architecture as a vLLM-style
//! router: ingress queue → batch former → worker fan-out → reply
//! channels).
//!
//! Workers dispatch whole batches through the batched execution engine
//! ([`BatchSearcher`]): requests in a batch are grouped by identical
//! [`SearchParams`] and each group is planned + executed together, so
//! co-probed inverted lists are scanned once per group and stage 3 runs
//! one union decode — not one `search` call per request.
//!
//! # Engine-per-worker stage-3 decoding
//!
//! Every worker thread constructs its own stage-3 [`StageDecoder`] by
//! calling [`DecoderFactory::make`] **once at thread startup**. The
//! factory defaults to the reference decoder
//! ([`ReferenceDecoderFactory`]); configuring
//! [`ServerCfg::decoder_factory`] with a
//! [`RuntimeDecoderFactory`](crate::qinco::RuntimeDecoderFactory) gives
//! each worker a thread-local PJRT engine + codec — PJRT clients are
//! `Rc`-based (not `Send`), so this per-thread construction is the only
//! sound way to decode through XLA under concurrent load. If a worker's
//! factory or decoder fails (e.g. the vendored stub `xla` crate), that
//! worker degrades to the index's own infallible decoder; no request is
//! ever dropped.
//!
//! # Reads share the index lock-free; writes get their own lane
//!
//! Workers share the index via `Arc` with no locking on the hot path —
//! including a sharded index ([`crate::index::ShardSet`]): each
//! dispatched batch pins one epoch snapshot and scatters its probed
//! buckets to the owning shards inside the engine, so heterogeneous
//! per-shard pipelines serve behind this one router unchanged. The index
//! is **live-mutable** underneath: [`Router::submit_write`] feeds a
//! dedicated write lane — its own bounded ingress channel
//! ([`ServerCfg::write_queue_cap`], backpressure independent of the
//! query queue) drained by a single writer thread that applies
//! [`WriteOp`]s through `SearchIndex::insert` / `delete` / `compact`.
//! One writer thread means write operations apply in submission order
//! and never contend with each other; readers keep serving their pinned
//! epochs throughout and pick up the new epoch on their next batch.
//! Latency and throughput metrics are collected per request into
//! per-worker rings and merged at [`Router::stats`] time (see [`Stats`]
//! for the aggregation semantics; [`Stats::shard_scans`] surfaces the
//! per-shard scan counters, [`Stats::inserted`] / [`Stats::deleted`] the
//! ingest counters). The §B latency experiment and Fig. 6 QPS numbers
//! come from here.
//!
//! Lifecycle: [`Router::shutdown`] closes both ingresses; the batcher
//! flushes whatever it buffered and exits when the ingress disconnects,
//! workers exit only when the batch channel is *both* disconnected and
//! drained, and the writer thread drains every queued write — every
//! accepted request gets its reply before the threads are joined.
//! Submission after shutdown fails with [`RouterError::Stopped`] instead
//! of panicking.

use crate::index::{BatchSearcher, EncodeParams, QueryPlan, SearchIndex, SearchParams};
use crate::qinco::ReferenceDecoderFactory;
use crate::quantizers::{DecoderFactory, StageDecoder};
use crate::tensor::Matrix;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct ServerCfg {
    pub workers: usize,
    /// max queries grouped into one dispatch unit
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub batch_timeout: Duration,
    /// ingress queue capacity (backpressure: submit blocks when full)
    pub queue_cap: usize,
    /// write-lane queue capacity — its own backpressure, independent of
    /// the query ingress: a burst of ingest can never starve reads of
    /// queue space, and vice versa
    pub write_queue_cap: usize,
    /// per-worker stage-3 decoder factory; `None` defaults to the
    /// reference decoder. Each worker thread calls `make()` once at
    /// startup (engine-per-worker — see the module docs).
    pub decoder_factory: Option<Arc<dyn DecoderFactory>>,
}

impl std::fmt::Debug for ServerCfg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerCfg")
            .field("workers", &self.workers)
            .field("max_batch", &self.max_batch)
            .field("batch_timeout", &self.batch_timeout)
            .field("queue_cap", &self.queue_cap)
            .field("write_queue_cap", &self.write_queue_cap)
            .field("decoder_factory", &self.decoder_factory.as_ref().map(|_| "custom"))
            .finish()
    }
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            workers: crate::util::pool::default_threads(),
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            queue_cap: 1024,
            write_queue_cap: 64,
            decoder_factory: None,
        }
    }
}

/// Why a router operation could not complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterError {
    /// The router has been shut down; no new requests are accepted.
    Stopped,
    /// The ingress queue is full (backpressure) — retry or shed load.
    Saturated,
    /// The serving thread handling this request died before replying.
    WorkerDied,
}

impl std::fmt::Display for RouterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouterError::Stopped => write!(f, "router stopped"),
            RouterError::Saturated => write!(f, "ingress queue saturated"),
            RouterError::WorkerDied => write!(f, "worker died before replying"),
        }
    }
}

impl std::error::Error for RouterError {}

pub struct Request {
    pub query: Vec<f32>,
    pub sp: SearchParams,
    pub reply: SyncSender<Response>,
    pub t_submit: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub results: Vec<(f32, u32)>,
    pub latency: Duration,
}

/// One mutation for the write lane, applied by the single writer thread
/// in submission order.
#[derive(Clone, Debug)]
pub enum WriteOp {
    /// Encode + ingest vectors (`ep` carries the `--a`/`--b` beam knobs).
    Insert { vectors: Matrix, ep: EncodeParams },
    /// Tombstone-delete rows by global id.
    Delete { ids: Vec<u32> },
    /// Reclaim every shard's tombstoned rows.
    Compact,
}

/// What a [`WriteOp`] produced.
#[derive(Clone, Debug)]
pub enum WriteOutcome {
    /// The global ids allocated to the inserted vectors.
    Inserted(Vec<u32>),
    /// Rows newly tombstoned.
    Deleted(usize),
    /// Rows reclaimed by compaction.
    Compacted(usize),
}

pub struct WriteRequest {
    pub op: WriteOp,
    pub reply: SyncSender<WriteResponse>,
    pub t_submit: Instant,
}

#[derive(Clone, Debug)]
pub struct WriteResponse {
    /// The op's outcome, or the index's validation error (bad encode
    /// params, out-of-range delete id, …) as a string.
    pub outcome: Result<WriteOutcome, String>,
    pub latency: Duration,
}

struct MetricsInner {
    served: AtomicU64,
    /// nanoseconds, summed
    total_latency: AtomicU64,
    /// rows ingested through the write lane
    inserted: AtomicU64,
    /// rows tombstoned through the write lane
    deleted: AtomicU64,
    /// per-worker recent-latency rings (ns). Each worker pushes only
    /// into its own ring (capped at RECENT_CAP, oldest half evicted), so
    /// a chatty worker can never evict a quiet worker's samples;
    /// [`Router::stats`] merges every ring before ranking, which keeps
    /// the percentiles consistent under any worker/shard interleaving.
    recent: Vec<Mutex<Vec<u64>>>,
}

/// Per-worker recent-latency ring capacity.
const RECENT_CAP: usize = 4096;

impl MetricsInner {
    fn new(workers: usize) -> MetricsInner {
        MetricsInner {
            served: AtomicU64::new(0),
            total_latency: AtomicU64::new(0),
            inserted: AtomicU64::new(0),
            deleted: AtomicU64::new(0),
            recent: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }
}

/// Merge the per-worker latency rings into one ascending-sorted vector —
/// the sample set the nearest-rank percentiles are computed over.
fn merged_sorted(rings: &[Mutex<Vec<u64>>]) -> Vec<u64> {
    let mut merged = Vec::new();
    for ring in rings {
        merged.extend(ring.lock().unwrap().iter().copied());
    }
    merged.sort_unstable();
    merged
}

/// Snapshot of server health.
///
/// Latency percentiles are **nearest-rank** — the smallest sample with
/// at least `p·n` samples at or below it — computed
/// over the **union of every worker's recent ring** (the newest ≤4096
/// samples per worker), merged and sorted at snapshot time. Aggregating
/// before ranking (rather than averaging per-worker percentiles, or
/// letting workers share one eviction-contended ring) keeps the
/// percentiles consistent across workers and shards: every worker's
/// traffic is represented, and a chatty worker cannot evict a quiet
/// worker's samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub served: u64,
    pub mean_latency: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// per-shard stage-1 scan counters: (query, candidate) pairs scored
    /// by each [`IndexShard`](crate::index::IndexShard) **since this
    /// router started**, in shard order — the scatter/gather layer's
    /// load view (uneven counts reveal skewed bucket ownership). The
    /// underlying index counters are lifetime totals shared by every
    /// execution path; the router snapshots them at startup and reports
    /// the delta, so these stay consistent with the router-scoped
    /// `served`/latency fields even when the index served other
    /// routers or direct searches before.
    pub shard_scans: Vec<u64>,
    /// rows ingested through this router's write lane
    pub inserted: u64,
    /// rows tombstone-deleted through this router's write lane
    pub deleted: u64,
    /// the index's current publication epoch at snapshot time
    pub epoch: u64,
}

/// Nearest-rank percentile of an ascending-sorted latency vector: the
/// smallest element with at least `p·len` samples at or below it. Unlike
/// the floored `((len-1)·p)` index, this is never biased low — with
/// fewer than 100 samples p99 is the maximum, as it should be.
fn percentile(sorted: &[u64], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    Duration::from_nanos(sorted[rank.clamp(1, sorted.len()) - 1])
}

pub struct Router {
    ingress: SyncSender<Request>,
    /// the write lane's own bounded ingress (see the module docs)
    write_ingress: SyncSender<WriteRequest>,
    metrics: Arc<MetricsInner>,
    /// shared with the workers; [`Self::stats`] reads the per-shard scan
    /// counters off it
    index: Arc<SearchIndex>,
    /// per-shard scan counts at router startup — subtracted in
    /// [`Self::stats`] so `shard_scans` covers only this router's traffic
    scan_base: Vec<u64>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn the batcher and worker threads over a shared index.
    pub fn start(index: Arc<SearchIndex>, cfg: ServerCfg) -> Router {
        let workers = cfg.workers.max(1);
        let (in_tx, in_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) = sync_channel::<Vec<Request>>(workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics = Arc::new(MetricsInner::new(workers));
        let mut handles = Vec::new();

        // --- batcher: groups ingress into dispatch units ---
        {
            let max_batch = cfg.max_batch;
            let timeout = cfg.batch_timeout;
            handles.push(std::thread::spawn(move || {
                batcher_loop(in_rx, batch_tx, max_batch, timeout)
            }));
        }
        // --- workers: each dispatches whole batches through the engine,
        // with a stage-3 decoder built once per thread by the factory ---
        let factory: Arc<dyn DecoderFactory> = cfg.decoder_factory.clone().unwrap_or_else(|| {
            Arc::new(ReferenceDecoderFactory { params: index.params.clone() })
        });
        for w in 0..workers {
            let rx = batch_rx.clone();
            let idx = index.clone();
            let metrics = metrics.clone();
            let factory = factory.clone();
            handles.push(std::thread::spawn(move || {
                // engine-per-worker: PJRT clients are Rc-based and not
                // Send, so each thread constructs its own decoder. A
                // failed factory (stub runtime, missing artifacts)
                // degrades this worker to the index's shared decoder.
                let mut local: Option<Box<dyn StageDecoder>> = match factory.make() {
                    Ok(d) => Some(d),
                    Err(e) => {
                        eprintln!(
                            "[server] worker {w}: decoder factory failed ({e}); \
                             falling back to the index's stage-3 decoder"
                        );
                        None
                    }
                };
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match batch {
                        Ok(batch) => serve_batch(&idx, &metrics, w, batch, &mut local),
                        // the batcher exited and every queued batch has
                        // been drained — nothing in flight can be lost
                        Err(_) => return,
                    }
                }
            }));
        }
        // --- write lane: one bounded channel, one writer thread. A
        // single drainer keeps ops in submission order and means the
        // index's writer mutex is never contended from here ---
        let (write_tx, write_rx) = sync_channel::<WriteRequest>(cfg.write_queue_cap.max(1));
        {
            let idx = index.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || writer_loop(&idx, &metrics, write_rx)));
        }
        let scan_base = index.snapshot().scan_counts();
        Router { ingress: in_tx, write_ingress: write_tx, metrics, index, scan_base, handles }
    }

    /// Submit a query; returns the channel the response arrives on.
    /// Blocks when the ingress queue is full (backpressure).
    pub fn submit(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
    ) -> Result<Receiver<Response>, RouterError> {
        let (tx, rx) = sync_channel(1);
        let req = Request { query, sp, reply: tx, t_submit: Instant::now() };
        self.ingress.send(req).map_err(|_| RouterError::Stopped)?;
        Ok(rx)
    }

    /// Non-blocking submit: fails fast when the queue is saturated.
    pub fn try_submit(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
    ) -> Result<Receiver<Response>, RouterError> {
        let (tx, rx) = sync_channel(1);
        let req = Request { query, sp, reply: tx, t_submit: Instant::now() };
        match self.ingress.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(RouterError::Saturated),
            Err(TrySendError::Disconnected(_)) => Err(RouterError::Stopped),
        }
    }

    /// Synchronous convenience wrapper.
    pub fn search_blocking(
        &self,
        query: &[f32],
        sp: SearchParams,
    ) -> Result<Response, RouterError> {
        self.submit(query.to_vec(), sp)?
            .recv()
            .map_err(|_| RouterError::WorkerDied)
    }

    /// Submit a mutation to the write lane; returns the channel the
    /// [`WriteResponse`] arrives on. Blocks when the write queue is full
    /// (backpressure, independent of the query ingress).
    pub fn submit_write(&self, op: WriteOp) -> Result<Receiver<WriteResponse>, RouterError> {
        let (tx, rx) = sync_channel(1);
        let req = WriteRequest { op, reply: tx, t_submit: Instant::now() };
        self.write_ingress.send(req).map_err(|_| RouterError::Stopped)?;
        Ok(rx)
    }

    /// Non-blocking write submit: fails fast when the write queue is
    /// saturated.
    pub fn try_submit_write(&self, op: WriteOp) -> Result<Receiver<WriteResponse>, RouterError> {
        let (tx, rx) = sync_channel(1);
        let req = WriteRequest { op, reply: tx, t_submit: Instant::now() };
        match self.write_ingress.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(RouterError::Saturated),
            Err(TrySendError::Disconnected(_)) => Err(RouterError::Stopped),
        }
    }

    /// Synchronous write convenience wrapper.
    pub fn write_blocking(&self, op: WriteOp) -> Result<WriteResponse, RouterError> {
        self.submit_write(op)?.recv().map_err(|_| RouterError::WorkerDied)
    }

    pub fn stats(&self) -> Stats {
        let served = self.metrics.served.load(Ordering::Relaxed);
        let total = self.metrics.total_latency.load(Ordering::Relaxed);
        // union of every worker's ring, merged before ranking (see the
        // Stats docs for the aggregation semantics)
        let recent = merged_sorted(&self.metrics.recent);
        Stats {
            served,
            mean_latency: Duration::from_nanos(if served > 0 { total / served } else { 0 }),
            p50: percentile(&recent, 0.5),
            p99: percentile(&recent, 0.99),
            shard_scans: self
                .index
                .snapshot()
                .scan_counts()
                .iter()
                .zip(&self.scan_base)
                .map(|(now, base)| now.saturating_sub(*base))
                .collect(),
            inserted: self.metrics.inserted.load(Ordering::Relaxed),
            deleted: self.metrics.deleted.load(Ordering::Relaxed),
            epoch: self.index.epoch(),
        }
    }

    /// Graceful shutdown: close both ingresses, let the batcher flush
    /// its buffer, let workers drain and answer every queued batch, let
    /// the writer apply every queued write, then join all threads. No
    /// accepted request is dropped.
    pub fn shutdown(mut self) {
        drop(self.ingress);
        drop(self.write_ingress);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The write lane's single drainer: apply each op, count rows, reply.
/// Exits when the write ingress disconnects and every queued op has been
/// applied.
fn writer_loop(idx: &SearchIndex, metrics: &MetricsInner, rx: Receiver<WriteRequest>) {
    while let Ok(req) = rx.recv() {
        let outcome = match &req.op {
            WriteOp::Insert { vectors, ep } => idx
                .insert(vectors, ep)
                .map(|gids| {
                    metrics.inserted.fetch_add(gids.len() as u64, Ordering::Relaxed);
                    WriteOutcome::Inserted(gids)
                })
                .map_err(|e| e.to_string()),
            WriteOp::Delete { ids } => idx
                .delete(ids)
                .map(|n| {
                    metrics.deleted.fetch_add(n as u64, Ordering::Relaxed);
                    WriteOutcome::Deleted(n)
                })
                .map_err(|e| e.to_string()),
            WriteOp::Compact => Ok(WriteOutcome::Compacted(idx.compact())),
        };
        // a dropped receiver (caller gave up) is not an error
        let _ = req
            .reply
            .send(WriteResponse { outcome, latency: req.t_submit.elapsed() });
    }
}

/// Serve one dispatch unit: group requests by identical [`SearchParams`]
/// and run each group through the batched engine in a single execute —
/// one scattered shard-group scan and one union decode per group
/// (heterogeneous per-shard pipelines, when configured on the index,
/// are resolved inside the engine). `worker` indexes this thread's own
/// latency ring in `metrics`. `decoder` is
/// this worker's thread-local stage-3 decoder (engine-per-worker); when
/// it is absent the index's own decoder runs. A decode failure
/// re-executes the group with the index decoder (every request still
/// gets a reply unless that decoder *also* fails — then the replies
/// drop and callers see `WorkerDied`) and then *drops* the local
/// decoder — decoder failures are configuration errors (missing
/// artifact, stubbed runtime), not transient, so the worker must not
/// pay a doubled execute on every subsequent batch.
fn serve_batch(
    idx: &SearchIndex,
    metrics: &MetricsInner,
    worker: usize,
    batch: Vec<Request>,
    decoder: &mut Option<Box<dyn StageDecoder>>,
) {
    let searcher = BatchSearcher::new(idx);
    let mut done = vec![false; batch.len()];
    for s in 0..batch.len() {
        if done[s] {
            continue;
        }
        let sp = batch[s].sp;
        let members: Vec<usize> =
            (s..batch.len()).filter(|&j| !done[j] && batch[j].sp == sp).collect();
        for &j in &members {
            done[j] = true;
        }
        let plans: Vec<QueryPlan> =
            members.iter().map(|&j| searcher.plan(&batch[j].query, &sp)).collect();
        let mut results = None;
        let mut decoder_failed = false;
        if let Some(d) = decoder.as_deref() {
            match searcher.execute_with_decoder(&plans, &sp, d) {
                Ok(r) => results = Some(r),
                Err(e) => {
                    decoder_failed = true;
                    eprintln!(
                        "[server] stage-3 decoder '{}' failed ({e}); this worker \
                         serves with the index decoder from now on",
                        d.name()
                    );
                }
            }
        }
        if decoder_failed {
            *decoder = None;
        }
        let results = match results {
            Some(r) => r,
            // the index-held decoders are infallible in practice; if one
            // ever fails the affected requests' reply channels drop so
            // callers observe WorkerDied instead of hanging — the engine
            // no longer panics the worker thread from inside
            None => match searcher.execute(&plans, &sp) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!(
                        "[server] index stage-3 decoder failed ({e}); \
                         dropping {} replies",
                        members.len()
                    );
                    continue;
                }
            },
        };
        for (&j, results_j) in members.iter().zip(results) {
            let req = &batch[j];
            let latency = req.t_submit.elapsed();
            metrics.served.fetch_add(1, Ordering::Relaxed);
            metrics
                .total_latency
                .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
            {
                // this worker's own ring: eviction here can never drop
                // another worker's samples (see the Stats docs)
                let mut recent = metrics.recent[worker].lock().unwrap();
                if recent.len() >= RECENT_CAP {
                    let n = recent.len();
                    recent.copy_within(n / 2.., 0);
                    recent.truncate(n / 2);
                }
                recent.push(latency.as_nanos() as u64);
            }
            // a dropped receiver (caller gave up) is not an error
            let _ = req.reply.send(Response { results: results_j, latency });
        }
    }
}

fn batcher_loop(
    in_rx: Receiver<Request>,
    batch_tx: SyncSender<Vec<Request>>,
    max_batch: usize,
    timeout: Duration,
) {
    loop {
        // block for the first request of a batch; a disconnect here means
        // shutdown with nothing buffered
        let first = match in_rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match in_rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                // ingress closed mid-batch: flush what we have, then the
                // next blocking recv observes the disconnect and exits
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if batch_tx.send(batch).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        // 1..=100 ns: p50 is the 50th smallest, p99 the 99th
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), Duration::from_nanos(50));
        assert_eq!(percentile(&v, 0.99), Duration::from_nanos(99));
        assert_eq!(percentile(&v, 1.00), Duration::from_nanos(100));
    }

    #[test]
    fn percentile_small_samples_reach_the_max() {
        // the old floored index could never return the max with < 100
        // samples; nearest-rank p99 of a small vector IS the max
        let v = vec![10, 20, 30, 40];
        assert_eq!(percentile(&v, 0.99), Duration::from_nanos(40));
        assert_eq!(percentile(&v, 0.50), Duration::from_nanos(20));
        assert_eq!(percentile(&v, 0.25), Duration::from_nanos(10));
        // degenerate inputs
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
        assert_eq!(percentile(&[7], 0.5), Duration::from_nanos(7));
        assert_eq!(percentile(&[7], 0.0), Duration::from_nanos(7));
    }

    #[test]
    fn percentile_monotone_in_p() {
        let v = vec![1, 1, 2, 3, 5, 8, 13, 21, 34];
        let mut last = Duration::ZERO;
        for i in 0..=20 {
            let p = i as f64 / 20.0;
            let cur = percentile(&v, p);
            assert!(cur >= last, "p={p}: {cur:?} < {last:?}");
            last = cur;
        }
    }

    #[test]
    fn percentiles_merge_across_worker_rings() {
        // regression for the multi-worker merge: percentiles must be
        // computed over the *union* of the per-worker rings — identical
        // to ranking the flat concatenation — not any single ring's view
        let rings = vec![
            Mutex::new(vec![5, 1, 3]),
            Mutex::new(vec![2]),
            Mutex::new(Vec::new()),
            Mutex::new(vec![4, 6]),
        ];
        let merged = merged_sorted(&rings);
        assert_eq!(merged, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(percentile(&merged, 0.50), Duration::from_nanos(3));
        assert_eq!(percentile(&merged, 0.99), Duration::from_nanos(6));
        // uneven load: a chatty worker's full ring must not displace a
        // quiet worker's lone sample (the old shared-ring design let it)
        let rings = vec![
            Mutex::new((0..RECENT_CAP as u64).map(|i| 10 + i).collect::<Vec<_>>()),
            Mutex::new(vec![1]),
        ];
        let merged = merged_sorted(&rings);
        assert_eq!(merged.len(), RECENT_CAP + 1);
        assert_eq!(merged[0], 1, "quiet worker's sample must survive the merge");
        assert_eq!(percentile(&merged, 0.0), Duration::from_nanos(1));
        // no workers / empty rings degrade to zero, matching a fresh router
        assert!(merged_sorted(&[]).is_empty());
        assert_eq!(percentile(&merged_sorted(&[Mutex::new(Vec::new())]), 0.99), Duration::ZERO);
    }

    #[test]
    fn router_error_formats() {
        assert_eq!(RouterError::Stopped.to_string(), "router stopped");
        assert!(RouterError::Saturated.to_string().contains("saturated"));
        assert!(RouterError::WorkerDied.to_string().contains("died"));
    }
}
