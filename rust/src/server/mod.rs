//! Serving coordinator: request router + dynamic batcher + worker pool
//! over a shared [`SearchIndex`] (tokio is unavailable offline; this uses
//! std threads + mpsc channels, the same architecture as a vLLM-style
//! router: ingress queue → batch former → worker fan-out → reply
//! channels).
//!
//! The index is immutable after build, so workers share it via `Arc`
//! with no locking on the hot path. Latency and throughput metrics are
//! collected per request (the §B latency experiment and Fig. 6 QPS
//! numbers come from here).

use crate::index::{SearchIndex, SearchParams};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServerCfg {
    pub workers: usize,
    /// max queries grouped into one dispatch unit
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub batch_timeout: Duration,
    /// ingress queue capacity (backpressure: submit blocks when full)
    pub queue_cap: usize,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            workers: crate::util::pool::default_threads(),
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            queue_cap: 1024,
        }
    }
}

pub struct Request {
    pub query: Vec<f32>,
    pub sp: SearchParams,
    pub reply: SyncSender<Response>,
    pub t_submit: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub results: Vec<(f32, u32)>,
    pub latency: Duration,
}

#[derive(Default)]
struct MetricsInner {
    served: AtomicU64,
    /// nanoseconds, summed
    total_latency: AtomicU64,
    /// most recent latencies (ring, for percentiles)
    recent: Mutex<Vec<u64>>,
}

/// Snapshot of server health.
#[derive(Clone, Debug)]
pub struct Stats {
    pub served: u64,
    pub mean_latency: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

pub struct Router {
    ingress: SyncSender<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<MetricsInner>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn the batcher and worker threads over a shared index.
    pub fn start(index: Arc<SearchIndex>, cfg: ServerCfg) -> Router {
        let (in_tx, in_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) = sync_channel::<Vec<Request>>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(MetricsInner::default());
        let mut handles = Vec::new();

        // --- batcher: groups ingress into dispatch units ---
        {
            let stop = stop.clone();
            let max_batch = cfg.max_batch;
            let timeout = cfg.batch_timeout;
            handles.push(std::thread::spawn(move || {
                batcher_loop(in_rx, batch_tx, max_batch, timeout, stop)
            }));
        }
        // --- workers ---
        for _w in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let idx = index.clone();
            let stop = stop.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || loop {
                let batch = {
                    let guard = rx.lock().unwrap();
                    guard.recv_timeout(Duration::from_millis(20))
                };
                match batch {
                    Ok(batch) => {
                        for req in batch {
                            let results = idx.search(&req.query, &req.sp);
                            let latency = req.t_submit.elapsed();
                            metrics.served.fetch_add(1, Ordering::Relaxed);
                            metrics
                                .total_latency
                                .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
                            {
                                let mut recent = metrics.recent.lock().unwrap();
                                if recent.len() >= 4096 {
                                    let n = recent.len();
                                    recent.copy_within(n / 2.., 0);
                                    recent.truncate(n / 2);
                                }
                                recent.push(latency.as_nanos() as u64);
                            }
                            let _ = req.reply.send(Response { results, latency });
                        }
                    }
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                }
            }));
        }
        Router { ingress: in_tx, stop, metrics, handles }
    }

    /// Submit a query; returns the channel the response arrives on.
    /// Blocks when the ingress queue is full (backpressure).
    pub fn submit(&self, query: Vec<f32>, sp: SearchParams) -> Receiver<Response> {
        let (tx, rx) = sync_channel(1);
        let req = Request { query, sp, reply: tx, t_submit: Instant::now() };
        self.ingress.send(req).expect("router stopped");
        rx
    }

    /// Non-blocking submit: Err when the queue is saturated.
    pub fn try_submit(
        &self,
        query: Vec<f32>,
        sp: SearchParams,
    ) -> Result<Receiver<Response>, ()> {
        let (tx, rx) = sync_channel(1);
        let req = Request { query, sp, reply: tx, t_submit: Instant::now() };
        match self.ingress.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => Err(()),
        }
    }

    /// Synchronous convenience wrapper.
    pub fn search_blocking(&self, query: &[f32], sp: SearchParams) -> Response {
        self.submit(query.to_vec(), sp).recv().expect("worker died")
    }

    pub fn stats(&self) -> Stats {
        let served = self.metrics.served.load(Ordering::Relaxed);
        let total = self.metrics.total_latency.load(Ordering::Relaxed);
        let mut recent = self.metrics.recent.lock().unwrap().clone();
        recent.sort_unstable();
        let pct = |p: f64| -> Duration {
            if recent.is_empty() {
                return Duration::ZERO;
            }
            let i = ((recent.len() - 1) as f64 * p) as usize;
            Duration::from_nanos(recent[i])
        };
        Stats {
            served,
            mean_latency: Duration::from_nanos(if served > 0 { total / served } else { 0 }),
            p50: pct(0.5),
            p99: pct(0.99),
        }
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.ingress);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    in_rx: Receiver<Request>,
    batch_tx: SyncSender<Vec<Request>>,
    max_batch: usize,
    timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    loop {
        // block for the first request of a batch
        let first = match in_rx.recv_timeout(Duration::from_millis(20)) {
            Ok(r) => r,
            Err(_) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match in_rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        if batch_tx.send(batch).is_err() {
            return;
        }
    }
}
