//! PJRT backend (feature `pjrt`): load AOT-compiled HLO text artifacts
//! and run them through the `xla` bindings.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! jax ≥ 0.5 emits 64-bit instruction ids in serialized protos, which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Off by default: the workspace vendors a stub `xla` crate whose
//! constructors error at runtime, so this backend only does real work
//! when the path dependency is swapped for the actual bindings. The
//! native backend ([`super::native`]) covers every non-training artifact
//! without any of this.

use super::manifest::ArtifactSpec;
use crate::util::qnpz::{Dtype, Tensor};
use anyhow::{bail, Result};
use std::path::Path;

/// Convert a host tensor into an XLA literal (zero-copy is not exposed by
/// the C API wrapper; one memcpy per transfer).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::I32 => xla::ElementType::S32,
    };
    // storage is bit-exact for both dtypes (i32 stored as f32 bit patterns)
    let bytes: Vec<u8> = t.data_f32.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &bytes)?)
}

/// Convert an XLA literal back into a host tensor.
pub fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = l.to_vec::<f32>()?;
            Ok(Tensor::f32(dims, data))
        }
        xla::ElementType::S32 => {
            let data = l.to_vec::<i32>()?;
            Ok(Tensor::i32(dims, &data))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// Compile one HLO text artifact for a client.
pub(super) fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    use anyhow::Context;
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parse HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Execute a compiled artifact with positional inputs.
pub(super) fn run(
    spec: &ArtifactSpec,
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[&Tensor],
) -> Result<Vec<Tensor>> {
    let literals: Vec<xla::Literal> =
        inputs.iter().map(|t| to_literal(t)).collect::<Result<_>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: output is always a tuple
    let parts = result.to_tuple()?;
    if parts.len() != spec.outputs.len() {
        bail!(
            "{}: got {} outputs, manifest says {}",
            spec.name,
            parts.len(),
            spec.outputs.len()
        );
    }
    parts.iter().map(from_literal).collect()
}
