//! PJRT runtime: load AOT-compiled HLO text artifacts and run them as
//! plain Rust functions.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! jax ≥ 0.5 emits 64-bit instruction ids in serialized protos, which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Thread model: `PjRtClient` is `Rc`-based (not `Send`), so an [`Engine`]
//! is pinned to one thread. The serving coordinator ([`crate::server`])
//! runs each Engine on a dedicated model thread behind an mpsc channel;
//! XLA itself parallelizes the compute internally.

pub mod manifest;

use crate::util::qnpz::{Dtype, Tensor};
use anyhow::{bail, Context, Result};
use manifest::{ArtifactSpec, Manifest};
use std::collections::HashMap;
use std::path::PathBuf;

/// Convert a host tensor into an XLA literal (zero-copy is not exposed by
/// the C API wrapper; one memcpy per transfer).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::I32 => xla::ElementType::S32,
    };
    // storage is bit-exact for both dtypes (i32 stored as f32 bit patterns)
    let bytes: Vec<u8> = t.data_f32.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &bytes)?)
}

/// Convert an XLA literal back into a host tensor.
pub fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = l.to_vec::<f32>()?;
            Ok(Tensor::f32(dims, data))
        }
        xla::ElementType::S32 => {
            let data = l.to_vec::<i32>()?;
            Ok(Tensor::i32(dims, &data))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional inputs (manifest order). Shapes are
    /// validated against the manifest before the FFI call.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.spec.name,
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| to_literal(t)).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts.iter().map(from_literal).collect()
    }
}

/// Loads, compiles and caches HLO artifacts for one PJRT CPU client.
pub struct Engine {
    pub manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Engine {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { manifest, dir, client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling and caching on first use) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = std::rc::Rc::new(Executable { spec, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// One-shot convenience: load + run.
    pub fn run(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run(inputs)
    }
}
