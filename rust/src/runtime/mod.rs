//! Artifact runtime: execute the manifest's model artifacts as plain
//! Rust functions, behind a backend seam.
//!
//! An [`Engine`] binds an artifact directory (`manifest.json` + model
//! specs) to one of two backends:
//!
//! * **native** (the default, [`Engine::open`]) — every non-training
//!   artifact kind (`f_step`, `decode`, `decode_partial`, `encode`) is
//!   executed by the in-crate [`crate::nn`] kernels over the same
//!   positional tensor ABI the HLO versions declare. No HLO files, no
//!   PJRT runtime, no FFI: CI and the serving tier run a true neural
//!   decode out of the box. Training kinds error with a message naming
//!   the `pjrt` feature.
//! * **pjrt** (feature `pjrt`, [`Engine::open_pjrt`]) — AOT-compiled HLO
//!   text artifacts through the `xla` PJRT bindings ([`pjrt`] module).
//!   The workspace vendors a stub `xla` crate that errors at runtime;
//!   swap the path dependency for the real xla_extension bindings to
//!   execute HLO (training included).
//!
//! Both backends validate inputs against the manifest and return the
//! manifest-declared outputs, so [`Executable::run`] callers (the codec,
//! the trainer, the benches) are backend-agnostic. The round-trip suite
//! (`tests/runtime_roundtrip.rs`) pins native results to the scalar
//! reference oracle.
//!
//! Thread model: an [`Engine`] is cheap and thread-confined (the PJRT
//! client is `Rc`-based; the native backend simply has no shared state
//! worth locking). The serving coordinator gives each worker its own
//! engine-backed decoder via `DecoderFactory` when one is configured.

pub mod manifest;
mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::util::qnpz::Tensor;
use anyhow::{bail, Context, Result};
use manifest::{ArtifactSpec, Manifest, ModelCfg};
use std::collections::HashMap;
use std::path::PathBuf;

enum ExeImpl {
    /// Dispatch to [`native::run`] at call time.
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
}

/// A loaded artifact plus its manifest spec and model configuration.
pub struct Executable {
    pub spec: ArtifactSpec,
    cfg: ModelCfg,
    exe: ExeImpl,
}

impl Executable {
    /// Execute with positional inputs (manifest order). Shapes are
    /// validated against the manifest before dispatching to the backend.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.spec.name,
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        match &self.exe {
            ExeImpl::Native => native::run(&self.spec, &self.cfg, inputs),
            #[cfg(feature = "pjrt")]
            ExeImpl::Pjrt(exe) => pjrt::run(&self.spec, exe, inputs),
        }
    }
}

enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtClient),
}

/// Loads and caches artifacts for one backend.
pub struct Engine {
    pub manifest: Manifest,
    dir: PathBuf,
    backend: Backend,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Engine {
    /// Open an artifact directory (must contain `manifest.json`) on the
    /// native backend — the default everywhere; needs no HLO files.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(Engine { manifest, dir, backend: Backend::Native, cache: HashMap::new() })
    }

    /// Open an artifact directory on the PJRT backend: artifacts load
    /// from their `.hlo.txt` files and compile through the `xla` crate.
    #[cfg(feature = "pjrt")]
    pub fn open_pjrt(dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { manifest, dir, backend: Backend::Pjrt(client), cache: HashMap::new() })
    }

    /// Backend/platform name: `"native"` for the in-crate kernels,
    /// otherwise whatever the PJRT client reports (`"cpu"` for real
    /// xla_extension, `"stub"` for the vendored placeholder).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native => "native".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(client) => client.platform_name(),
        }
    }

    /// Fetch (loading and caching on first use) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        let cfg = self
            .manifest
            .model(&spec.model)
            .with_context(|| format!("artifact {name:?} references model {:?}", spec.model))?
            .cfg
            .clone();
        let exe = match &self.backend {
            Backend::Native => {
                // artifact files are irrelevant natively; keep `dir` so
                // the pjrt arm below can read them under the feature
                let _ = &self.dir;
                ExeImpl::Native
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(client) => {
                ExeImpl::Pjrt(pjrt::compile(client, &self.dir.join(&spec.file))?)
            }
        };
        let e = std::rc::Rc::new(Executable { spec, cfg, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// One-shot convenience: load + run.
    pub fn run(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run(inputs)
    }
}
