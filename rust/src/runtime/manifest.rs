//! The AOT manifest — the ABI between `python/compile/aot.py` and the
//! Rust runtime. Lists every model (architecture + parameter inventory)
//! and every artifact (kind, file, positional input/output tensor specs).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Static architecture of a QINCo2 model (mirror of python ModelCfg).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub d: usize,
    pub m: usize,
    pub k: usize,
    pub l: usize,
    pub de: usize,
    pub dh: usize,
    pub ls: usize,
    pub dhg: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32"
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub cfg: ModelCfg,
    /// parameter inventory, in ABI order
    pub params: Vec<TensorSpec>,
    pub num_params: usize,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// encode | decode | decode_partial | train_adamw | train_adam | f_step
    pub kind: String,
    pub model: String,
    pub a: usize,
    pub b: usize,
    pub n: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Default)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .context("spec list not an array")?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                name: s.get("name").and_then(Json::as_str).context("spec name")?.to_string(),
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("spec shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: s
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read manifest {path:?} (run `make artifacts`)"))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut manifest = Manifest::default();

        let models = root.get("models").and_then(Json::as_obj).context("manifest.models")?;
        for (name, m) in models {
            let c = m.get("cfg").context("model cfg")?;
            let grab = |k: &str| -> Result<usize> {
                c.get(k).and_then(Json::as_usize).with_context(|| format!("cfg.{k}"))
            };
            let cfg = ModelCfg {
                d: grab("d")?,
                m: grab("M")?,
                k: grab("K")?,
                l: grab("L")?,
                de: grab("de")?,
                dh: grab("dh")?,
                ls: grab("Ls").unwrap_or(0),
                dhg: grab("dhg").unwrap_or(128),
            };
            manifest.models.insert(
                name.clone(),
                ModelSpec {
                    cfg,
                    params: parse_specs(m.get("params").context("model params")?)?,
                    num_params: m.get("num_params").and_then(Json::as_usize).unwrap_or(0),
                },
            );
        }

        let arts = root.get("artifacts").and_then(Json::as_arr).context("manifest.artifacts")?;
        for a in arts {
            let name =
                a.get("name").and_then(Json::as_str).context("artifact name")?.to_string();
            let spec = ArtifactSpec {
                name: name.clone(),
                file: a.get("file").and_then(Json::as_str).context("file")?.to_string(),
                kind: a.get("kind").and_then(Json::as_str).context("kind")?.to_string(),
                model: a.get("model").and_then(Json::as_str).context("model")?.to_string(),
                a: a.get("A").and_then(Json::as_usize).unwrap_or(0),
                b: a.get("B").and_then(Json::as_usize).unwrap_or(0),
                n: a.get("N").and_then(Json::as_usize).unwrap_or(0),
                inputs: parse_specs(a.get("inputs").context("inputs")?)?,
                outputs: parse_specs(a.get("outputs").context("outputs")?)?,
            };
            if !manifest.models.contains_key(&spec.model) {
                bail!("artifact {name} references unknown model {}", spec.model);
            }
            manifest.artifacts.insert(name, spec);
        }
        Ok(manifest)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| format!("model {name:?} not in manifest"))
    }

    /// Find an encode artifact for (model, A, B), any batch size.
    pub fn find_encode(&self, model: &str, a: usize, b: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|s| s.kind == "encode" && s.model == model && s.a == a && s.b == b)
            .max_by_key(|s| s.n)
    }

    /// All encode (A, B) settings available for a model.
    pub fn encode_settings(&self, model: &str) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<(usize, usize, usize)> = self
            .artifacts
            .values()
            .filter(|s| s.kind == "encode" && s.model == model)
            .map(|s| (s.a, s.b, s.n))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
        assert!(m.models.contains_key("test"), "test model missing");
        let spec = m.model("test").unwrap();
        assert_eq!(spec.cfg.d, 8);
        assert_eq!(spec.cfg.m, 3);
        assert_eq!(spec.params[0].name, "codebooks");
        assert_eq!(spec.params[0].shape, vec![3, 8, 8]);
        let enc = m.find_encode("test", 4, 4).expect("enc_test_A4_B4 missing");
        assert_eq!(enc.n, 16);
        // last encode input is x
        assert_eq!(enc.inputs.last().unwrap().name, "x");
        assert_eq!(enc.outputs[0].dtype, "i32");
    }

    #[test]
    fn unknown_names_error() {
        let m = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
        assert!(m.artifact("nope").is_none());
        assert!(m.model("nope").is_err());
    }
}
