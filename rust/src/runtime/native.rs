//! Native artifact executor: run the manifest's decode/encode artifacts
//! through the in-crate [`crate::nn`] kernels instead of PJRT.
//!
//! The manifest describes each artifact's ABI — kind, model, positional
//! inputs/outputs with shapes — and every non-training kind maps onto a
//! pure-Rust computation over the *same* positional tensors the HLO
//! version consumes, so `Engine::run` behaves identically with either
//! backend (the round-trip tests pin the semantics):
//!
//! * `f_step` — one fused [`crate::nn::qinco_step`] over per-step
//!   weights passed as inputs.
//! * `decode` / `decode_partial` — the Eq. 4 accumulation
//!   `x̂ ← x̂ + f_theta(c_step | x̂)` over the full `[M, ...]` parameter
//!   tensors.
//! * `encode` — beam-search encode via
//!   [`crate::qinco::reference::encode_beam`] with the artifact's
//!   `(A, B)` setting, reconstructions from the native decode, and
//!   per-row squared errors. One documented deviation from the lowered
//!   model: codeword pre-selection uses the cheap RQ proxy over the base
//!   codebooks, so the `presel`/`g_*` inputs are accepted (the ABI is
//!   unchanged) but unused — the learned pre-selection networks remain a
//!   `pjrt` feature concern.
//! * `train_*` — not implemented natively (the AdamW/Adam steps are only
//!   lowered to HLO); these error with a message naming the `pjrt`
//!   feature.
//!
//! Artifact batch sizes are honored exactly like the HLO versions:
//! inputs were already shape-checked against the manifest by
//! [`super::Executable::run`], and row-independence of the kernels makes
//! the codec's pad-and-strip batching transparent.

use super::manifest::{ArtifactSpec, ModelCfg};
use crate::nn::{self, StepWeights};
use crate::qinco::params::ParamStore;
use crate::qinco::reference;
use crate::tensor::Matrix;
use crate::util::qnpz::{Store, Tensor};
use anyhow::{bail, Context, Result};

/// Positional input lookup by manifest name.
fn input<'a>(spec: &ArtifactSpec, inputs: &[&'a Tensor], name: &str) -> Result<&'a Tensor> {
    spec.inputs
        .iter()
        .position(|t| t.name == name)
        .map(|i| inputs[i])
        .with_context(|| format!("{}: no input named {name:?} in the manifest ABI", spec.name))
}

/// Step-`m` weight slices out of full `[M, ...]` parameter tensors.
fn step_weights_of<'a>(
    cfg: &ModelCfg,
    step: usize,
    in_w: &'a [f32],
    cond_w: &'a [f32],
    cond_b: &'a [f32],
    up_w: &'a [f32],
    down_w: &'a [f32],
    out_w: &'a [f32],
) -> StepWeights<'a> {
    let (d, de, dh, l) = (cfg.d, cfg.de, cfg.dh, cfg.l);
    StepWeights {
        d,
        de,
        dh,
        l,
        in_w: &in_w[step * d * de..(step + 1) * d * de],
        cond_w: &cond_w[step * (de + d) * de..(step + 1) * (de + d) * de],
        cond_b: &cond_b[step * de..(step + 1) * de],
        up_w: &up_w[step * l * de * dh..(step + 1) * l * de * dh],
        down_w: &down_w[step * l * dh * de..(step + 1) * l * dh * de],
        out_w: &out_w[step * de * d..(step + 1) * de * d],
    }
}

/// Eq. 4 decode over raw parameter tensors; optionally records the
/// reconstruction after every step (`decode_partial` layout `[M, n, d]`).
#[allow(clippy::too_many_arguments)]
fn decode_codes(
    cfg: &ModelCfg,
    codes: &[i32],
    n: usize,
    cb: &[f32],
    in_w: &[f32],
    cond_w: &[f32],
    cond_b: &[f32],
    up_w: &[f32],
    down_w: &[f32],
    out_w: &[f32],
    mut partial: Option<&mut Vec<f32>>,
) -> Result<Vec<f32>> {
    let (d, k, m) = (cfg.d, cfg.k, cfg.m);
    let mut xhat = vec![0.0f32; n * d];
    let mut c = vec![0.0f32; n * d];
    for step in 0..m {
        for i in 0..n {
            let code = codes[i * m + step];
            if code < 0 || code as usize >= k {
                bail!("decode: code {code} at row {i} step {step} outside 0..{k}");
            }
            let src = (step * k + code as usize) * d;
            c[i * d..(i + 1) * d].copy_from_slice(&cb[src..src + d]);
        }
        let sw = step_weights_of(cfg, step, in_w, cond_w, cond_b, up_w, down_w, out_w);
        let f = nn::qinco_step(&sw, &c, &xhat, n);
        for (x, &fv) in xhat.iter_mut().zip(&f) {
            *x += fv;
        }
        if let Some(acc) = partial.as_deref_mut() {
            acc.extend_from_slice(&xhat);
        }
    }
    Ok(xhat)
}

/// Execute one artifact natively. `inputs` are positional and already
/// shape-validated against the manifest by the caller.
pub(super) fn run(spec: &ArtifactSpec, cfg: &ModelCfg, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    match spec.kind.as_str() {
        "f_step" => {
            let (c, xhat) = (input(spec, inputs, "c")?, input(spec, inputs, "xhat")?);
            let n = spec.n;
            // per-step weights arrive pre-sliced, so l is recovered from
            // the up_w input shape ([l, de, dh]) rather than cfg
            let up_w = input(spec, inputs, "up_w")?;
            let l = up_w.shape.first().copied().unwrap_or(0);
            let sw = StepWeights {
                d: cfg.d,
                de: cfg.de,
                dh: cfg.dh,
                l,
                in_w: &input(spec, inputs, "in_w")?.data_f32,
                cond_w: &input(spec, inputs, "cond_w")?.data_f32,
                cond_b: &input(spec, inputs, "cond_b")?.data_f32,
                up_w: &up_w.data_f32,
                down_w: &input(spec, inputs, "down_w")?.data_f32,
                out_w: &input(spec, inputs, "out_w")?.data_f32,
            };
            let f = nn::qinco_step(&sw, &c.data_f32, &xhat.data_f32, n);
            Ok(vec![Tensor::f32(vec![n, cfg.d], f)])
        }
        "decode" | "decode_partial" => {
            let codes = input(spec, inputs, "codes")?.as_i32();
            let n = spec.n;
            let mut partial =
                (spec.kind == "decode_partial").then(|| Vec::with_capacity(cfg.m * n * cfg.d));
            let xhat = decode_codes(
                cfg,
                &codes,
                n,
                &input(spec, inputs, "codebooks")?.data_f32,
                &input(spec, inputs, "in_w")?.data_f32,
                &input(spec, inputs, "cond_w")?.data_f32,
                &input(spec, inputs, "cond_b")?.data_f32,
                &input(spec, inputs, "up_w")?.data_f32,
                &input(spec, inputs, "down_w")?.data_f32,
                &input(spec, inputs, "out_w")?.data_f32,
                partial.as_mut(),
            )?;
            Ok(match partial {
                Some(steps) => vec![Tensor::f32(vec![cfg.m, n, cfg.d], steps)],
                None => vec![Tensor::f32(vec![n, cfg.d], xhat)],
            })
        }
        "encode" => {
            // rebuild a ParamStore from the positional param inputs so the
            // shared beam encoder runs unmodified — bit-identical to the
            // in-crate reference encode by construction
            let mut store = Store::new();
            let mut names = Vec::new();
            let mut x: Option<&Tensor> = None;
            for (ts, t) in spec.inputs.iter().zip(inputs) {
                if ts.name == "x" {
                    x = Some(t);
                } else {
                    store.insert(&ts.name, (*t).clone());
                    names.push(ts.name.clone());
                }
            }
            let x = x.with_context(|| format!("{}: encode artifact has no x input", spec.name))?;
            let params = ParamStore {
                model: spec.model.clone(),
                cfg: cfg.clone(),
                names,
                store,
            };
            let n = spec.n;
            let xs = Matrix::from_vec(n, cfg.d, x.data_f32.clone());
            let codes = reference::encode_beam(&params, &xs, spec.a, spec.b);
            // reconstructions re-derive through the same nn decode the
            // beam used incrementally — identical accumulation sequence
            let codes_i32: Vec<i32> = codes.data.iter().map(|&c| c as i32).collect();
            let xhat = decode_codes(
                cfg,
                &codes_i32,
                n,
                &params.get("codebooks").data_f32,
                &params.get("in_w").data_f32,
                &params.get("cond_w").data_f32,
                &params.get("cond_b").data_f32,
                &params.get("up_w").data_f32,
                &params.get("down_w").data_f32,
                &params.get("out_w").data_f32,
                None,
            )?;
            let errs: Vec<f32> = (0..n)
                .map(|i| {
                    let (xr, hr) = (&x.data_f32[i * cfg.d..(i + 1) * cfg.d], &xhat[i * cfg.d..(i + 1) * cfg.d]);
                    xr.iter().zip(hr).map(|(a, b)| (a - b) * (a - b)).sum()
                })
                .collect();
            Ok(vec![
                Tensor::i32(vec![n, cfg.m], &codes_i32),
                Tensor::f32(vec![n, cfg.d], xhat),
                Tensor::f32(vec![n], errs),
            ])
        }
        other => bail!(
            "artifact {:?} (kind {other:?}) has no native implementation: training steps \
             are only lowered to HLO — build with `--features pjrt` against a real \
             xla_extension runtime to execute it",
            spec.name
        ),
    }
}
