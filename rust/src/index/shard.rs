//! Bucket-owned index shards and the scatter/gather layer — now with
//! epoch-snapshotted mutation.
//!
//! A [`crate::index::SearchIndex`] no longer holds one monolithic set of
//! per-vector tables: the per-bucket state — inverted lists, stage-1/2
//! code tables, cached terms/norms — lives in [`IndexShard`]s, each
//! owning a **contiguous range of IVF buckets**, collected in a
//! [`ShardSet`]. The shared read-only parts (the coarse quantizer, the
//! [`PipelineSpec`] scorers, the model parameters) stay on the index and
//! are referenced by every shard.
//!
//! # Epochs and snapshots
//!
//! A [`ShardSet`] is an **immutable snapshot** of the whole per-bucket
//! state at one epoch: it holds its shards behind [`Arc`]s and is itself
//! published behind `RwLock<Arc<ShardSet>>` on the index. Readers pin
//! the current snapshot once (at `plan` time — `SearchIndex::search`
//! per query, [`crate::index::BatchSearcher::new`] per batch) and run
//! entirely against it, so a reader never observes a partial write.
//! Writers never mutate a published shard in place: the ingest, delete
//! and compaction paths copy-on-write the affected shards, rebuild the
//! routing maps, bump [`ShardSet::epoch`] and publish the new snapshot
//! atomically (see `SearchIndex::insert` / `delete` / `compact`).
//! Untouched shards are shared by `Arc` between consecutive epochs, so
//! a write costs O(rows of the mutated shards), not O(database).
//!
//! ```text
//!   writer (insert/delete/compact, serialized by SearchIndex::writer)
//!      │  copy-on-write mutated shards, epoch += 1
//!      ▼
//!   RwLock<Arc<ShardSet>>  ── pin ──► BatchSearcher / search snapshot
//!                                        (epoch frozen for the batch)
//! ```
//!
//! # Tombstones and compaction
//!
//! A delete marks [`IndexShard::tombstones`] in a copy-on-write of the
//! owning shard; the row's codes stay in place and
//! [`IndexShard::scan_group`] skips it, so deleted ids stop appearing in
//! results at the next epoch without touching the tables. Compaction
//! ([`IndexShard::compacted`]) reclaims the space: it rewrites the
//! shard's local rows bucket-major (the canonical fresh-build layout),
//! drops tombstoned rows, and the caller rewrites `local_of` — a
//! reclaimed global id keeps its `owner_of` entry but gets the
//! [`DEAD_LOCAL`] sentinel in `local_of`. Global ids are never reused.
//!
//! # Scatter / gather
//!
//! [`ShardSet::plan`] routes a batch's probed buckets to their owning
//! shards as [`ShardGroup`]s, in ascending bucket order — which, because
//! shards own contiguous ranges, is also shard-major order.
//! [`IndexShard::scan_group`] then runs the request's scan-layout
//! kernel over the shard's *local* rows, pushing
//! `(score, global id)` pairs into the per-query shortlists. Per-shard
//! shortlists merge under the total (score, id) order of
//! [`Shortlist`] (see [`Shortlist::merge_from`]), so the merged stage-1
//! shortlist — and therefore the whole pipeline — is **bit-identical to
//! the unsharded index for every shard count**: each (query, candidate)
//! pair is scored with identical floats wherever its row is stored, and
//! the order is total.
//!
//! # Scan layouts
//!
//! [`IndexShard::scan_group`] dispatches on the batch engine's per-slot
//! [`ScanPack`]:
//!
//! * [`ScanPack::Flat`] — the seed kernel: per-member strided gathers
//!   from the flat LUT pack (`luts[qi·stride + off]`), bit-exact scalar
//!   and block paths.
//! * [`ScanPack::Transposed`] — per ≤8-member chunk the flat LUT slices
//!   are transposed once (`tlut[off·8 + lane]`,
//!   [`LutPack::fill_transposed`]) so the inner loop of every scored
//!   row becomes unit-stride 8-wide loads; **bit-identical** to `Flat`
//!   because each lane accumulates the same offsets in the same order
//!   (see [`crate::quantizers::ScanLayout`]).
//! * [`ScanPack::Packed4`] — u8-quantized LUT chunks
//!   ([`QuantLutPack`]) scored against the shard's nibble-packed
//!   [`IndexShard::stage1_packed`] table: bounded-error quantized
//!   scoring, explicitly versioned by
//!   [`crate::quantizers::PACKED4_SCORING_VERSION`], never bit-exact.
//!
//! Every layout runs the same tombstone skip and the same
//! [`DEADLINE_CHECK_ROWS`] abort granularity (one deadline tick per
//! scored code row), so the degraded-ladder semantics of deadline
//! requests are layout-independent.
//!
//! # The global-id remap invariant
//!
//! Each shard stores its rows contiguously in *local* row order and
//! carries [`IndexShard::global_ids`] mapping local row → global
//! database id. The invariant (pinned by `tests/batch_equivalence.rs`
//! and `tests/mutation_invariants.rs`):
//!
//! * in the canonical layout (fresh build, or any shard right after
//!   compaction) `shards[s].global_ids[local]` enumerates, in ascending
//!   owned-bucket order (and inverted-list order within a bucket),
//!   exactly the live database rows whose IVF bucket falls in
//!   `[bucket_lo, bucket_hi)`; between mutations, ingested rows append
//!   at the tail in insertion order instead, but **within each bucket's
//!   inverted list local rows always map to ascending global ids** —
//!   appended rows get strictly larger gids — which is the property the
//!   mutation bit-identity rests on;
//! * `ShardSet::owner_of[gid]` / `ShardSet::local_of[gid]` invert the
//!   map for every non-reclaimed id:
//!   `shards[owner_of[gid]].global_ids[local_of[gid]] == gid`; reclaimed
//!   ids hold [`DEAD_LOCAL`];
//! * `shards[s].lists[b - bucket_lo]` holds *local* rows, all of which
//!   decode back (via `global_ids`) to rows assigned to bucket `b`.
//!
//! All scoring state (`codes`, `stage1_side_codes`, `stage1_terms`,
//! `stage2_codes`, `stage2_norms`) is indexed by local row; only
//! shortlist entries carry global ids.
//!
//! # Heterogeneous shards
//!
//! A shard may carry its own [`PipelineSpec`] override
//! ([`IndexShard::pipeline`]) with stage-1/2 tables fit for its rows —
//! the ROADMAP's design intent of heterogeneous stage configurations
//! behind one router. Shards without an override share the index-level
//! spec (and, at execution time, one LUT per query — see
//! [`ShardSet::lut_slot`]).

use super::batch::QueryPlan;
use super::pipeline::{gather_codes, PipelineSpec};
use crate::quantizers::{
    score_packed4_lanes, ApproxScorer, Codes, LutPack, PackedCodes, QuantLutPack, ScanPack,
    SCORE_BLOCK,
};
use crate::util::deadline::Deadline;
use crate::util::topk::Shortlist;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// How many scanned code rows a deadline-carrying scan scores between
/// `Deadline::expired()` checks inside one bucket group. Coarse enough
/// that the `Instant::now()` syscall never shows up in profiles, fine
/// enough that a single huge inverted list cannot blow past a deadline
/// unchecked. Requests without a deadline never check at all.
pub const DEADLINE_CHECK_ROWS: usize = 1024;

/// `local_of` sentinel for a global id whose row was reclaimed by
/// compaction: the id stays allocated (never reused) but maps to no row.
pub const DEAD_LOCAL: u32 = u32::MAX;

/// The one deadline-abort policy shared by every scan-layout path: one
/// tick per scored code row, an `Instant::now()` probe every
/// [`DEADLINE_CHECK_ROWS`] ticks, a dead branch when the request
/// carries no deadline. Factoring the counter out keeps the abort
/// granularity provably identical across layouts.
struct DeadlineTicker {
    deadline: Deadline,
    check: bool,
    rows_since_check: usize,
}

impl DeadlineTicker {
    #[inline]
    fn new(deadline: Deadline) -> DeadlineTicker {
        DeadlineTicker { check: !deadline.is_none(), rows_since_check: 0, deadline }
    }

    /// Tick once for the row about to be scored; `true` means the
    /// deadline expired and the scan must abort before scoring it.
    #[inline]
    fn expired(&mut self) -> bool {
        if !self.check {
            return false;
        }
        self.rows_since_check += 1;
        if self.rows_since_check >= DEADLINE_CHECK_ROWS {
            self.rows_since_check = 0;
            self.deadline.expired()
        } else {
            false
        }
    }
}

/// One scatter unit produced by [`ShardSet::plan`]: a probed bucket, its
/// owning shard, and the batch members interested in it.
pub struct ShardGroup {
    /// owning shard index in [`ShardSet::shards`]
    pub shard: u32,
    /// global bucket id
    pub bucket: u32,
    /// (query index within the batch, coarse probe distance)
    pub members: Vec<(u32, f32)>,
}

/// Everything a shard must append for one ingested database row: the
/// ingest encoder (`SearchIndex::insert`) produces one of these per
/// vector, fully consistent across stages, *before* any shard is
/// rebuilt — so a published shard is never mid-update.
pub struct RowPayload {
    /// the row's freshly allocated global id
    pub gid: u32,
    /// destination IVF bucket (must be owned by the receiving shard)
    pub bucket: u32,
    /// QINCo2 code row (stage-3 decode source)
    pub code: Vec<u32>,
    /// stage-1 side code row, iff the shard scans a side table
    pub side_code: Option<Vec<u32>>,
    /// cached stage-1 term ‖x̂‖² + 2⟨cent, x̂⟩
    pub term: f32,
    /// extended stage-2 code row (empty iff stage 2 is off)
    pub stage2_code: Vec<u32>,
    /// cached stage-2 reconstruction norm (unused when stage 2 is off)
    pub stage2_norm: f32,
}

/// Per-bucket-range slice of the index: inverted lists, code tables and
/// cached terms for the database rows whose IVF bucket falls in
/// `[bucket_lo, bucket_hi)`. See the module docs for the global-id remap
/// invariant and the tombstone semantics.
pub struct IndexShard {
    /// first owned bucket (inclusive)
    pub bucket_lo: u32,
    /// one past the last owned bucket (exclusive)
    pub bucket_hi: u32,
    /// inverted lists of the owned buckets, indexed by
    /// `bucket - bucket_lo`; values are **shard-local** rows
    pub lists: Vec<Vec<u32>>,
    /// local row → global database id (the remap invariant)
    pub global_ids: Vec<u32>,
    /// QINCo2 codes of the shard's rows — the stage-3 decode source
    pub codes: Codes,
    /// side code table scanned by stage 1 when the scorer owns one
    /// (PQ/OPQ/LSQ/RQ); `None` means stage 1 scans [`Self::codes`]
    pub stage1_side_codes: Option<Codes>,
    /// nibble-packed copy of the stage-1 scan table, present iff the
    /// index was assembled for [`crate::quantizers::ScanLayout::Packed4`]
    /// (see [`ShardSet::build_packed_tables`]); kept in sync by every
    /// mutation path so the packed scan sees exactly the rows the flat
    /// scan would
    pub stage1_packed: Option<PackedCodes>,
    /// cached stage-1 terms: ||x̂_r||² + 2⟨cent, x̂_r⟩ per local row
    pub stage1_terms: Vec<f32>,
    /// extended code table scored by stage 2 (empty when stage 2 is off)
    pub stage2_codes: Codes,
    /// cached ||x̂_pw||² per local row (empty when stage 2 is off)
    pub stage2_norms: Vec<f32>,
    /// per-local-row delete marks; a tombstoned row keeps its tables but
    /// is skipped by every scan until compaction reclaims it
    pub tombstones: Vec<bool>,
    /// number of `true` entries in [`Self::tombstones`]
    pub n_dead: usize,
    /// per-shard pipeline override (heterogeneous shards). `None` —
    /// the common case — means the shard runs the index-level
    /// [`PipelineSpec`]. Stage 3 is always index-level: the QINCo2
    /// codes are uniform across shards. `Arc` so copy-on-write shard
    /// rebuilds share the (immutable, `Send + Sync`) spec.
    pub pipeline: Option<Arc<PipelineSpec>>,
    /// lifetime count of (query, candidate) pairs this shard's stage-1
    /// scan has scored — surfaced per shard in
    /// [`crate::server::Stats::shard_scans`]. Shared (`Arc`) across the
    /// shard's copy-on-write generations: the counter belongs to the
    /// bucket range, not to one epoch's rebuild of it.
    pub scanned: Arc<AtomicU64>,
}

impl IndexShard {
    /// Number of database rows this shard stores (tombstoned included).
    #[inline]
    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    /// Number of live (non-tombstoned) rows.
    #[inline]
    pub fn live_len(&self) -> usize {
        self.global_ids.len() - self.n_dead
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }

    /// Does this shard own `bucket`?
    #[inline]
    pub fn owns(&self, bucket: u32) -> bool {
        (self.bucket_lo..self.bucket_hi).contains(&bucket)
    }

    /// The shard-local inverted list of an owned bucket.
    #[inline]
    pub fn list(&self, bucket: u32) -> &[u32] {
        debug_assert!(self.owns(bucket));
        &self.lists[(bucket - self.bucket_lo) as usize]
    }

    /// The pipeline this shard executes: its override, or the shared one.
    #[inline]
    pub fn spec<'a>(&'a self, shared: &'a PipelineSpec) -> &'a PipelineSpec {
        self.pipeline.as_deref().unwrap_or(shared)
    }

    /// The code table stage 1 scans: the side table when the shard's
    /// scorer owns one, the QINCo2 codes otherwise.
    #[inline]
    pub fn stage1_codes(&self) -> &Codes {
        self.stage1_side_codes.as_ref().unwrap_or(&self.codes)
    }

    /// Scan one owned bucket group with the given stage-1 scorer and
    /// scan-layout pack, pushing `(score, global id)` into each member's
    /// shortlist. Dispatches on the [`ScanPack`] variant (see the module
    /// docs' layout section); `block` selects the multi-query block
    /// kernel vs the scalar per-member loop on the flat-pack layouts
    /// (both bit-identical by the trait contract — the scalar path
    /// serves `Flat` and `Transposed` alike since both carry the flat
    /// pack), while `Packed4` always runs its packed kernel: the
    /// quantized layout *is* the scoring mode, there is no scalar twin.
    ///
    /// Tombstoned rows are skipped (and not counted in
    /// [`Self::scanned`]) in every layout.
    ///
    /// `deadline` bounds the scan: every [`DEADLINE_CHECK_ROWS`] scored
    /// rows the deadline is re-checked (one `DeadlineTicker` tick per
    /// row in every layout), and on expiry the scan returns `false` with
    /// the shortlists ranking whatever was scored so far (the caller
    /// marks the batch degraded). With [`Deadline::none()`] the check is
    /// a dead branch and the return is always `true` — bit-identity
    /// preserved. [`Self::scanned`] counts pairs *actually scored*, so
    /// an aborted scan does not over-report.
    pub(crate) fn scan_group(
        &self,
        scorer: &dyn ApproxScorer,
        pack: &ScanPack,
        group: &ShardGroup,
        block: bool,
        deadline: Deadline,
        shortlists: &mut [Shortlist],
    ) -> bool {
        match pack {
            ScanPack::Flat(p) => self.scan_group_flat(scorer, p, group, block, deadline, shortlists),
            ScanPack::Transposed(p) => {
                if block {
                    self.scan_group_transposed(scorer, p, group, deadline, shortlists)
                } else {
                    self.scan_group_flat(scorer, p, group, false, deadline, shortlists)
                }
            }
            ScanPack::Packed4(q) => self.scan_group_packed4(q, group, deadline, shortlists),
        }
    }

    /// The seed scan: per-member strided gathers from the flat LUT pack.
    fn scan_group_flat(
        &self,
        scorer: &dyn ApproxScorer,
        pack: &LutPack,
        group: &ShardGroup,
        block: bool,
        deadline: Deadline,
        shortlists: &mut [Shortlist],
    ) -> bool {
        // the once-per-group bounds proof behind the unchecked kernels
        pack.check_members(scorer.lut_len(), group.members.iter().map(|&(qi, _)| qi));
        let (luts, stride) = (pack.luts(), pack.stride());
        let list = self.list(group.bucket);
        let codes = self.stage1_codes();
        let any_dead = self.n_dead > 0;
        let mut ticker = DeadlineTicker::new(deadline);
        let mut scored: u64 = 0;
        let mut complete = true;
        if block {
            // block fast path: one score_block call scores a code row
            // for up to SCORE_BLOCK co-probed queries
            let mut mq = [0u32; SCORE_BLOCK];
            let mut scores = [0.0f32; SCORE_BLOCK];
            'chunks: for chunk in group.members.chunks(SCORE_BLOCK) {
                for (l, &(qi, _)) in chunk.iter().enumerate() {
                    mq[l] = qi;
                }
                for &local in list {
                    let i = local as usize;
                    if any_dead && self.tombstones[i] {
                        continue;
                    }
                    if ticker.expired() {
                        complete = false;
                        break 'chunks;
                    }
                    scorer.score_block(
                        luts,
                        stride,
                        &mq[..chunk.len()],
                        codes.row(i),
                        self.stage1_terms[i],
                        &mut scores[..chunk.len()],
                    );
                    for (l, &(qi, probe_d)) in chunk.iter().enumerate() {
                        shortlists[qi as usize].push(probe_d + scores[l], self.global_ids[i]);
                    }
                    scored += chunk.len() as u64;
                }
            }
        } else {
            // scalar reference path (bench comparisons only)
            'rows: for &local in list {
                let i = local as usize;
                if any_dead && self.tombstones[i] {
                    continue;
                }
                if ticker.expired() {
                    complete = false;
                    break 'rows;
                }
                let code = codes.row(i);
                let term = self.stage1_terms[i];
                for &(qi, probe_d) in &group.members {
                    let lut = &luts[qi as usize * stride..][..stride];
                    shortlists[qi as usize]
                        .push(probe_d + scorer.score(lut, code, term), self.global_ids[i]);
                }
                scored += group.members.len() as u64;
            }
        }
        self.scanned.fetch_add(scored, Ordering::Relaxed);
        complete
    }

    /// The query-major transposed scan: the chunk's ≤[`SCORE_BLOCK`]
    /// member LUT slices are transposed once per chunk
    /// ([`LutPack::fill_transposed`], amortized over the whole inverted
    /// list), then every scored row runs unit-stride 8-wide loads
    /// through [`ApproxScorer::score_block_transposed`]. Bit-identical
    /// to the flat paths: each lane accumulates the same offsets in the
    /// same order and finishes with the same expression.
    fn scan_group_transposed(
        &self,
        scorer: &dyn ApproxScorer,
        pack: &LutPack,
        group: &ShardGroup,
        deadline: Deadline,
        shortlists: &mut [Shortlist],
    ) -> bool {
        pack.check_members(scorer.lut_len(), group.members.iter().map(|&(qi, _)| qi));
        let list = self.list(group.bucket);
        let codes = self.stage1_codes();
        let any_dead = self.n_dead > 0;
        let mut ticker = DeadlineTicker::new(deadline);
        let mut scored: u64 = 0;
        let mut complete = true;
        let mut tlut = vec![0.0f32; pack.stride() * SCORE_BLOCK];
        let mut mq = [0u32; SCORE_BLOCK];
        let mut scores = [0.0f32; SCORE_BLOCK];
        'chunks: for chunk in group.members.chunks(SCORE_BLOCK) {
            for (l, &(qi, _)) in chunk.iter().enumerate() {
                mq[l] = qi;
            }
            pack.fill_transposed(&mq[..chunk.len()], &mut tlut);
            for &local in list {
                let i = local as usize;
                if any_dead && self.tombstones[i] {
                    continue;
                }
                if ticker.expired() {
                    complete = false;
                    break 'chunks;
                }
                scorer.score_block_transposed(
                    &tlut,
                    codes.row(i),
                    self.stage1_terms[i],
                    &mut scores[..chunk.len()],
                );
                for (l, &(qi, probe_d)) in chunk.iter().enumerate() {
                    shortlists[qi as usize].push(probe_d + scores[l], self.global_ids[i]);
                }
                scored += chunk.len() as u64;
            }
        }
        self.scanned.fetch_add(scored, Ordering::Relaxed);
        complete
    }

    /// The 4-bit fast scan: u8-quantized transposed LUT chunks
    /// ([`QuantLutPack::fill_transposed`]) against the shard's
    /// nibble-packed [`Self::stage1_packed`] rows. Quantized scoring —
    /// bounded error, not bit-exact; the layout validation at build time
    /// guarantees the packed table exists and every codeword fits a
    /// nibble, so a missing table here is a logic error.
    fn scan_group_packed4(
        &self,
        qpack: &QuantLutPack,
        group: &ShardGroup,
        deadline: Deadline,
        shortlists: &mut [Shortlist],
    ) -> bool {
        let packed = self
            .stage1_packed
            .as_ref()
            .expect("Packed4 scan on a shard without a packed stage-1 table (build-time validation missed?)");
        qpack.check_members(packed.m(), group.members.iter().map(|&(qi, _)| qi));
        let m = packed.m();
        let list = self.list(group.bucket);
        let any_dead = self.n_dead > 0;
        let mut ticker = DeadlineTicker::new(deadline);
        let mut scored: u64 = 0;
        let mut complete = true;
        let mut t8 = vec![0u8; m * 16 * SCORE_BLOCK];
        let mut lo8 = [0.0f32; SCORE_BLOCK];
        let mut delta8 = [0.0f32; SCORE_BLOCK];
        let mut mq = [0u32; SCORE_BLOCK];
        let mut scores = [0.0f32; SCORE_BLOCK];
        'chunks: for chunk in group.members.chunks(SCORE_BLOCK) {
            for (l, &(qi, _)) in chunk.iter().enumerate() {
                mq[l] = qi;
            }
            qpack.fill_transposed(&mq[..chunk.len()], &mut t8, &mut lo8, &mut delta8);
            for &local in list {
                let i = local as usize;
                if any_dead && self.tombstones[i] {
                    continue;
                }
                if ticker.expired() {
                    complete = false;
                    break 'chunks;
                }
                score_packed4_lanes(
                    &t8,
                    packed.row(i),
                    m,
                    &lo8,
                    &delta8,
                    self.stage1_terms[i],
                    &mut scores[..chunk.len()],
                );
                for (l, &(qi, probe_d)) in chunk.iter().enumerate() {
                    shortlists[qi as usize].push(probe_d + scores[l], self.global_ids[i]);
                }
                scored += chunk.len() as u64;
            }
        }
        self.scanned.fetch_add(scored, Ordering::Relaxed);
        complete
    }

    /// Copy-on-write append: a new shard generation with `rows` added at
    /// the local tail, each linked into its bucket's inverted list. The
    /// receiving shard's tables and the payloads must agree on side /
    /// stage-2 presence — the ingest encoder produced the payloads from
    /// this shard's own spec, so a mismatch is a logic error.
    pub(crate) fn with_rows_appended(&self, rows: &[RowPayload]) -> IndexShard {
        let has_side = self.stage1_side_codes.is_some();
        let has_s2 = self.stage2_codes.m > 0;
        let mut lists = self.lists.clone();
        let mut global_ids = self.global_ids.clone();
        let mut codes = self.codes.clone();
        let mut side = self.stage1_side_codes.clone();
        let mut packed = self.stage1_packed.clone();
        let mut terms = self.stage1_terms.clone();
        let mut s2_codes = self.stage2_codes.clone();
        let mut s2_norms = self.stage2_norms.clone();
        let mut tombstones = self.tombstones.clone();
        for row in rows {
            assert!(self.owns(row.bucket), "row routed to a non-owning shard");
            assert_eq!(row.side_code.is_some(), has_side, "side-table presence mismatch");
            assert_eq!(!row.stage2_code.is_empty(), has_s2, "stage-2 presence mismatch");
            let local = global_ids.len() as u32;
            lists[(row.bucket - self.bucket_lo) as usize].push(local);
            global_ids.push(row.gid);
            assert_eq!(row.code.len(), codes.m, "code width mismatch");
            codes.data.extend_from_slice(&row.code);
            codes.n += 1;
            if let (Some(tbl), Some(sc)) = (side.as_mut(), row.side_code.as_ref()) {
                assert_eq!(sc.len(), tbl.m, "side code width mismatch");
                tbl.data.extend_from_slice(sc);
                tbl.n += 1;
            }
            if let Some(pk) = packed.as_mut() {
                // mirror whatever table stage 1 scans so the packed scan
                // sees the ingested row at the same epoch the flat one does
                pk.push_row(row.side_code.as_deref().unwrap_or(&row.code));
            }
            terms.push(row.term);
            if has_s2 {
                assert_eq!(row.stage2_code.len(), s2_codes.m, "stage-2 width mismatch");
                s2_codes.data.extend_from_slice(&row.stage2_code);
                s2_codes.n += 1;
                s2_norms.push(row.stage2_norm);
            }
            tombstones.push(false);
        }
        IndexShard {
            bucket_lo: self.bucket_lo,
            bucket_hi: self.bucket_hi,
            lists,
            global_ids,
            codes,
            stage1_side_codes: side,
            stage1_packed: packed,
            stage1_terms: terms,
            stage2_codes: s2_codes,
            stage2_norms: s2_norms,
            tombstones,
            n_dead: self.n_dead,
            pipeline: self.pipeline.clone(),
            scanned: self.scanned.clone(),
        }
    }

    /// Copy-on-write delete: a new shard generation with the given local
    /// rows tombstoned. Already-dead locals are counted once.
    pub(crate) fn with_tombstones(&self, locals: &[u32]) -> IndexShard {
        let mut tombstones = self.tombstones.clone();
        let mut n_dead = self.n_dead;
        for &l in locals {
            let i = l as usize;
            if !tombstones[i] {
                tombstones[i] = true;
                n_dead += 1;
            }
        }
        IndexShard {
            bucket_lo: self.bucket_lo,
            bucket_hi: self.bucket_hi,
            lists: self.lists.clone(),
            global_ids: self.global_ids.clone(),
            codes: self.codes.clone(),
            stage1_side_codes: self.stage1_side_codes.clone(),
            stage1_packed: self.stage1_packed.clone(),
            stage1_terms: self.stage1_terms.clone(),
            stage2_codes: self.stage2_codes.clone(),
            stage2_norms: self.stage2_norms.clone(),
            tombstones,
            n_dead,
            pipeline: self.pipeline.clone(),
            scanned: self.scanned.clone(),
        }
    }

    /// Compaction: rewrite the shard into the canonical fresh-build
    /// layout — live rows only, bucket-major, inverted-list order within
    /// each bucket — exactly what [`ShardSet::partition`] would produce
    /// for the surviving rows. Returns the new shard; the caller
    /// rewrites `local_of` from the new shard's `global_ids` and marks
    /// reclaimed gids [`DEAD_LOCAL`].
    pub(crate) fn compacted(&self) -> IndexShard {
        let mut lists = Vec::with_capacity(self.lists.len());
        let mut keep: Vec<usize> = Vec::with_capacity(self.live_len());
        for old_list in &self.lists {
            let mut new_list = Vec::new();
            for &local in old_list {
                let i = local as usize;
                if self.tombstones[i] {
                    continue;
                }
                new_list.push(keep.len() as u32);
                keep.push(i);
            }
            lists.push(new_list);
        }
        IndexShard {
            bucket_lo: self.bucket_lo,
            bucket_hi: self.bucket_hi,
            lists,
            global_ids: keep.iter().map(|&i| self.global_ids[i]).collect(),
            codes: gather_codes(&self.codes, &keep),
            stage1_side_codes: self.stage1_side_codes.as_ref().map(|c| gather_codes(c, &keep)),
            stage1_packed: self.stage1_packed.as_ref().map(|p| p.gather(&keep)),
            stage1_terms: keep.iter().map(|&i| self.stage1_terms[i]).collect(),
            stage2_codes: if self.stage2_codes.m > 0 {
                gather_codes(&self.stage2_codes, &keep)
            } else {
                Codes::zeros(0, 0)
            },
            stage2_norms: if self.stage2_codes.m > 0 {
                keep.iter().map(|&i| self.stage2_norms[i]).collect()
            } else {
                Vec::new()
            },
            tombstones: vec![false; keep.len()],
            n_dead: 0,
            pipeline: self.pipeline.clone(),
            scanned: self.scanned.clone(),
        }
    }
}

/// One epoch's immutable snapshot of the partitioned per-bucket state of
/// a [`crate::index::SearchIndex`]: every shard (behind `Arc` for
/// copy-on-write sharing across epochs) plus the routing maps. Shared
/// read-only parts (coarse quantizer, scorers, params) stay on the
/// index. See the module docs for the epoch/snapshot protocol.
pub struct ShardSet {
    pub shards: Vec<Arc<IndexShard>>,
    /// global bucket → owning shard index
    pub shard_of: Vec<u32>,
    /// global database id → owning shard index (kept for reclaimed ids)
    pub owner_of: Vec<u32>,
    /// global database id → local row within its owning shard, or
    /// [`DEAD_LOCAL`] once compaction reclaimed the row
    pub local_of: Vec<u32>,
    /// global database id → IVF bucket (drained from the coarse
    /// quantizer at assembly so ingest can extend it per snapshot)
    pub assign: Vec<u32>,
    /// per-shard LUT slot: shards running the shared [`PipelineSpec`]
    /// all map to slot `0` (one LUT / LUT pack per query serves them
    /// all); each override shard gets its own slot. `n_lut_slots` sizes
    /// per-query LUT caches and per-batch LUT packs.
    pub lut_slot: Vec<u32>,
    pub n_lut_slots: usize,
    /// monotone publication counter: bumped by every successful
    /// insert/delete/compaction publish
    pub epoch: u64,
}

impl ShardSet {
    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total id space ever allocated (live + tombstoned + reclaimed).
    #[inline]
    pub fn id_space(&self) -> usize {
        self.owner_of.len()
    }

    /// Number of live (searchable) rows across all shards.
    pub fn live_len(&self) -> usize {
        self.shards.iter().map(|sh| sh.live_len()).sum()
    }

    /// Any shard carrying a pipeline override?
    #[inline]
    pub fn heterogeneous(&self) -> bool {
        self.n_lut_slots > 1
    }

    /// Contiguous bucket ranges for an `n_shards`-way split of
    /// `n_buckets` buckets: shard `s` owns
    /// `[s·B/S, (s+1)·B/S)`. Every shard owns at least one bucket when
    /// `n_shards <= n_buckets`.
    pub fn bucket_ranges(n_buckets: usize, n_shards: usize) -> Vec<(u32, u32)> {
        (0..n_shards)
            .map(|s| {
                ((s * n_buckets / n_shards) as u32, ((s + 1) * n_buckets / n_shards) as u32)
            })
            .collect()
    }

    /// Partition the assembled per-bucket state into `n_shards`
    /// bucket-owned shards (epoch 0). `lists` are the global inverted
    /// lists (bucket → global ids) and `assign` the row → bucket map,
    /// both taken from the coarse quantizer; the code tables and caches
    /// are indexed by global id and are re-gathered into each shard's
    /// local row order.
    #[allow(clippy::too_many_arguments)]
    pub fn partition(
        lists: Vec<Vec<u32>>,
        codes: Codes,
        stage1_side_codes: Option<Codes>,
        stage1_terms: Vec<f32>,
        stage2_codes: Codes,
        stage2_norms: Vec<f32>,
        n_shards: usize,
        assign: Vec<u32>,
    ) -> ShardSet {
        let n_buckets = lists.len();
        assert!(n_shards >= 1, "shard count must be at least 1 (got {n_shards})");
        assert!(
            n_shards <= n_buckets,
            "shard count {n_shards} exceeds the bucket count {n_buckets}: \
             every shard must own at least one IVF bucket"
        );
        let db = codes.n;
        assert_eq!(assign.len(), db, "assign must cover every database row");
        let has_s2 = stage2_codes.m > 0;
        let mut shard_of = vec![0u32; n_buckets];
        let mut owner_of = vec![0u32; db];
        let mut local_of = vec![0u32; db];
        let mut shards = Vec::with_capacity(n_shards);
        for (s, &(lo, hi)) in Self::bucket_ranges(n_buckets, n_shards).iter().enumerate() {
            let (lo_u, hi_u) = (lo as usize, hi as usize);
            let mut local_lists = Vec::with_capacity(hi_u - lo_u);
            let mut global_ids: Vec<u32> = Vec::new();
            for b in lo_u..hi_u {
                shard_of[b] = s as u32;
                let mut local_list = Vec::with_capacity(lists[b].len());
                for &gid in &lists[b] {
                    let local = global_ids.len() as u32;
                    owner_of[gid as usize] = s as u32;
                    local_of[gid as usize] = local;
                    global_ids.push(gid);
                    local_list.push(local);
                }
                local_lists.push(local_list);
            }
            let rows: Vec<usize> = global_ids.iter().map(|&g| g as usize).collect();
            let (sh_s2_codes, sh_s2_norms) = if has_s2 {
                (
                    gather_codes(&stage2_codes, &rows),
                    rows.iter().map(|&i| stage2_norms[i]).collect(),
                )
            } else {
                (Codes::zeros(0, 0), Vec::new())
            };
            shards.push(Arc::new(IndexShard {
                bucket_lo: lo,
                bucket_hi: hi,
                lists: local_lists,
                codes: gather_codes(&codes, &rows),
                stage1_side_codes: stage1_side_codes.as_ref().map(|c| gather_codes(c, &rows)),
                stage1_packed: None,
                stage1_terms: rows.iter().map(|&i| stage1_terms[i]).collect(),
                stage2_codes: sh_s2_codes,
                stage2_norms: sh_s2_norms,
                tombstones: vec![false; global_ids.len()],
                n_dead: 0,
                pipeline: None,
                scanned: Arc::new(AtomicU64::new(0)),
                global_ids,
            }));
        }
        let lut_slot = vec![0u32; n_shards];
        ShardSet {
            shards,
            shard_of,
            owner_of,
            local_of,
            assign,
            lut_slot,
            n_lut_slots: 1,
            epoch: 0,
        }
    }

    /// The writer's working copy for the next epoch: shards shared by
    /// `Arc` (to be swapped out per-shard via copy-on-write), routing
    /// maps cloned for extension, epoch pre-bumped. The copy stays
    /// private to the writer until published.
    pub(crate) fn cow_clone(&self) -> ShardSet {
        ShardSet {
            shards: self.shards.clone(),
            shard_of: self.shard_of.clone(),
            owner_of: self.owner_of.clone(),
            local_of: self.local_of.clone(),
            assign: self.assign.clone(),
            lut_slot: self.lut_slot.clone(),
            n_lut_slots: self.n_lut_slots,
            epoch: self.epoch + 1,
        }
    }

    /// Install a heterogeneous pipeline override on shard `s`, replacing
    /// its stage-1/2 tables with ones fit for the override's scorers
    /// (all indexed by the shard's existing local row order), and
    /// reassign LUT slots. Assembly-time only: the shards must not yet
    /// be shared with any snapshot reader.
    pub fn install_override(
        &mut self,
        s: usize,
        spec: PipelineSpec,
        stage1_side_codes: Option<Codes>,
        stage1_terms: Vec<f32>,
        stage2_codes: Codes,
        stage2_norms: Vec<f32>,
    ) {
        let sh = Arc::get_mut(&mut self.shards[s])
            .expect("install_override requires exclusive shard ownership (assembly time)");
        assert_eq!(stage1_terms.len(), sh.len(), "override terms must cover the shard");
        if let Some(side) = &stage1_side_codes {
            assert_eq!(side.n, sh.len(), "override side table must cover the shard");
        }
        if stage2_codes.m > 0 {
            assert_eq!(stage2_codes.n, sh.len(), "override stage-2 table must cover the shard");
            assert_eq!(stage2_norms.len(), sh.len(), "override stage-2 norms must cover the shard");
        }
        sh.pipeline = Some(Arc::new(spec));
        sh.stage1_side_codes = stage1_side_codes;
        // the packed table mirrors the stage-1 scan table just replaced;
        // assembly rebuilds it (build_packed_tables) after all overrides
        sh.stage1_packed = None;
        sh.stage1_terms = stage1_terms;
        sh.stage2_codes = stage2_codes;
        sh.stage2_norms = stage2_norms;
        self.recompute_slots();
    }

    /// Build each shard's nibble-packed stage-1 table for
    /// [`crate::quantizers::ScanLayout::Packed4`]. Assembly-time only —
    /// like [`Self::install_override`], the shards must not yet be
    /// shared with any snapshot reader (and it must run *after* every
    /// override install, which resets the packed table it replaces).
    /// The caller validated `k ≤ 16` for every shard's stage-1 family
    /// first; [`PackedCodes::pack`] still panics on any codeword that
    /// does not fit a nibble.
    pub fn build_packed_tables(&mut self) {
        for sh in &mut self.shards {
            let sh = Arc::get_mut(sh)
                .expect("build_packed_tables requires exclusive shard ownership (assembly time)");
            let packed = PackedCodes::pack(sh.stage1_codes());
            sh.stage1_packed = Some(packed);
        }
    }

    /// Does every shard carry the packed stage-1 table a
    /// [`crate::quantizers::ScanLayout::Packed4`] scan needs? False for
    /// any index not assembled with the packed layout — the batch
    /// engine turns that into a typed request error instead of letting
    /// the scan hit the missing-table panic.
    pub fn packed4_ready(&self) -> bool {
        self.shards.iter().all(|sh| sh.stage1_packed.is_some())
    }

    fn recompute_slots(&mut self) {
        self.n_lut_slots = 1;
        for (si, sh) in self.shards.iter().enumerate() {
            self.lut_slot[si] = if sh.pipeline.is_some() {
                let slot = self.n_lut_slots as u32;
                self.n_lut_slots += 1;
                slot
            } else {
                0
            };
        }
    }

    /// The [`PipelineSpec`] behind a LUT slot: slot 0 is the shared
    /// spec, every other slot belongs to exactly one override shard.
    pub fn slot_spec<'a>(&'a self, slot: usize, shared: &'a PipelineSpec) -> &'a PipelineSpec {
        if slot == 0 {
            return shared;
        }
        self.shards
            .iter()
            .zip(&self.lut_slot)
            .find(|&(_, &ls)| ls as usize == slot)
            .and_then(|(sh, _)| sh.pipeline.as_deref())
            .unwrap_or(shared)
    }

    /// Locate a global database id: its owning shard and local row.
    /// Must not be called on a reclaimed id ([`DEAD_LOCAL`]).
    #[inline]
    pub fn locate(&self, id: u32) -> (&IndexShard, usize) {
        let si = self.owner_of[id as usize] as usize;
        let local = self.local_of[id as usize];
        debug_assert_ne!(local, DEAD_LOCAL, "locate() on a reclaimed id {id}");
        (&self.shards[si], local as usize)
    }

    /// Gather the stage-3 (QINCo2) code rows of `ids` — the union decode
    /// input — from their owning shards, in the given order.
    pub fn gather_stage3_codes(&self, ids: &[u32]) -> Codes {
        let m = self.shards[0].codes.m;
        let mut out = Codes::zeros(ids.len(), m);
        for (o, &id) in ids.iter().enumerate() {
            let (sh, local) = self.locate(id);
            out.row_mut(o).copy_from_slice(sh.codes.row(local));
        }
        out
    }

    /// Scatter a batch's probes to their owning shards: one
    /// [`ShardGroup`] per probed bucket, in ascending bucket order (=
    /// shard-major order, since shards own contiguous ranges — the same
    /// scan order the unsharded engine used, which keeps the group
    /// chunking of the parallel scan identical for every shard count).
    pub fn plan(&self, plans: &[QueryPlan]) -> Vec<ShardGroup> {
        let mut grouped: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        for (qi, plan) in plans.iter().enumerate() {
            for &(probe_d, bucket) in &plan.probes {
                grouped.entry(bucket).or_default().push((qi as u32, probe_d));
            }
        }
        grouped
            .into_iter()
            .map(|(bucket, members)| ShardGroup {
                shard: self.shard_of[bucket as usize],
                bucket,
                members,
            })
            .collect()
    }

    /// Snapshot of the per-shard stage-1 scan counters. Counters are
    /// shared across copy-on-write shard generations, so deltas taken
    /// across epochs stay meaningful.
    pub fn scan_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|sh| sh.scanned.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// assign map implied by global inverted lists.
    fn assign_of(lists: &[Vec<u32>], db: usize) -> Vec<u32> {
        let mut assign = vec![0u32; db];
        for (b, list) in lists.iter().enumerate() {
            for &gid in list {
                assign[gid as usize] = b as u32;
            }
        }
        assign
    }

    #[test]
    fn bucket_ranges_cover_contiguously_and_nonempty() {
        for n_buckets in [1usize, 5, 12, 64] {
            for n_shards in 1..=n_buckets.min(8) {
                let ranges = ShardSet::bucket_ranges(n_buckets, n_shards);
                assert_eq!(ranges.len(), n_shards);
                let mut next = 0u32;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, next, "ranges must be contiguous");
                    assert!(hi > lo, "every shard must own at least one bucket");
                    next = hi;
                }
                assert_eq!(next as usize, n_buckets, "ranges must cover all buckets");
            }
        }
    }

    #[test]
    fn bucket_ranges_balance_within_one() {
        // non-divisible splits differ by at most one bucket
        for (n_buckets, n_shards) in [(12usize, 5usize), (7, 3), (64, 6)] {
            let sizes: Vec<usize> = ShardSet::bucket_ranges(n_buckets, n_shards)
                .iter()
                .map(|&(lo, hi)| (hi - lo) as usize)
                .collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced split {sizes:?}");
        }
    }

    fn tiny_set() -> ShardSet {
        // 4 buckets, 6 rows, 3 shards (ranges [0,1), [1,2), [2,4))
        let lists = vec![vec![3, 0], vec![5], vec![], vec![1, 4, 2]];
        let assign = assign_of(&lists, 6);
        let codes = Codes::from_vec(6, 1, vec![10, 11, 12, 13, 14, 15]);
        let terms: Vec<f32> = (0..6).map(|i| i as f32).collect();
        ShardSet::partition(
            lists,
            codes,
            None,
            terms,
            Codes::zeros(0, 0),
            Vec::new(),
            3,
            assign,
        )
    }

    #[test]
    fn partition_remaps_lists_tables_and_ids() {
        let set = tiny_set();
        assert_eq!(set.n_shards(), 3);
        assert!(!set.heterogeneous());
        assert_eq!(set.epoch, 0);
        assert_eq!(set.live_len(), 6);
        assert_eq!(set.shards[0].global_ids, vec![3, 0]);
        assert_eq!(set.shards[1].global_ids, vec![5]);
        assert_eq!(set.shards[2].global_ids, vec![1, 4, 2]);
        // local lists reference local rows
        assert_eq!(set.shards[0].lists, vec![vec![0, 1]]);
        assert_eq!(set.shards[2].lists, vec![Vec::<u32>::new(), vec![0, 1, 2]]);
        // tables follow the remap
        assert_eq!(set.shards[2].codes.row(1), &[14]);
        assert_eq!(set.shards[2].stage1_terms, vec![1.0, 4.0, 2.0]);
        // assign drained verbatim
        assert_eq!(set.assign, vec![0, 3, 3, 0, 3, 1]);
        // inverse maps round-trip
        for (si, sh) in set.shards.iter().enumerate() {
            for (local, &gid) in sh.global_ids.iter().enumerate() {
                assert_eq!(set.owner_of[gid as usize] as usize, si);
                assert_eq!(set.local_of[gid as usize] as usize, local);
            }
        }
        // gather follows global ids across shards
        let gathered = set.gather_stage3_codes(&[2, 5, 0]);
        assert_eq!(gathered.row(0), &[12]);
        assert_eq!(gathered.row(1), &[15]);
        assert_eq!(gathered.row(2), &[10]);
    }

    #[test]
    fn append_links_new_rows_into_lists_and_tables() {
        let set = tiny_set();
        // append gid 6 to bucket 2 and gid 7 to bucket 3 (both shard 2)
        let rows = vec![
            RowPayload {
                gid: 6,
                bucket: 2,
                code: vec![16],
                side_code: None,
                term: 6.0,
                stage2_code: Vec::new(),
                stage2_norm: 0.0,
            },
            RowPayload {
                gid: 7,
                bucket: 3,
                code: vec![17],
                side_code: None,
                term: 7.0,
                stage2_code: Vec::new(),
                stage2_norm: 0.0,
            },
        ];
        let sh = set.shards[2].with_rows_appended(&rows);
        assert_eq!(sh.len(), 5);
        assert_eq!(sh.live_len(), 5);
        assert_eq!(sh.global_ids, vec![1, 4, 2, 6, 7]);
        assert_eq!(sh.lists, vec![vec![3u32], vec![0, 1, 2, 4]]);
        assert_eq!(sh.codes.row(3), &[16]);
        assert_eq!(sh.codes.row(4), &[17]);
        assert_eq!(sh.stage1_terms, vec![1.0, 4.0, 2.0, 6.0, 7.0]);
        // the original shard generation is untouched
        assert_eq!(set.shards[2].len(), 3);
    }

    #[test]
    fn tombstone_then_compact_restores_canonical_layout() {
        let set = tiny_set();
        // shard 2 rows: locals 0,1,2 = gids 1,4,2 (bucket 3)
        let dead = set.shards[2].with_tombstones(&[1]);
        assert_eq!(dead.live_len(), 2);
        assert!(dead.tombstones[1]);
        // double-tombstone is idempotent
        assert_eq!(dead.with_tombstones(&[1]).n_dead, 1);
        let compacted = dead.compacted();
        assert_eq!(compacted.len(), 2);
        assert_eq!(compacted.n_dead, 0);
        assert_eq!(compacted.global_ids, vec![1, 2]);
        assert_eq!(compacted.lists, vec![Vec::<u32>::new(), vec![0, 1]]);
        assert_eq!(compacted.codes.row(0), &[11]);
        assert_eq!(compacted.codes.row(1), &[12]);
        assert_eq!(compacted.stage1_terms, vec![1.0, 2.0]);
        // the shared scan counter survives both rebuilds
        assert!(Arc::ptr_eq(&set.shards[2].scanned, &compacted.scanned));
    }

    #[test]
    fn packed_table_follows_append_tombstone_compact() {
        // the tiny_set codes (10..=15) all fit a nibble, so the packed
        // table mirrors the stage-1 scan table through every mutation
        let mut set = tiny_set();
        assert!(!set.packed4_ready());
        set.build_packed_tables();
        assert!(set.packed4_ready());
        // shard 2 locals 0,1,2 = codes 11,14,12 (m=1 → one byte per row)
        let sh2 = &set.shards[2];
        assert_eq!(sh2.stage1_packed.as_ref().unwrap().row(1), &[14u8]);
        // append keeps packing in lockstep with the code table
        let rows = vec![RowPayload {
            gid: 6,
            bucket: 2,
            code: vec![7],
            side_code: None,
            term: 6.0,
            stage2_code: Vec::new(),
            stage2_norm: 0.0,
        }];
        let appended = sh2.with_rows_appended(&rows);
        let pk = appended.stage1_packed.as_ref().unwrap();
        assert_eq!(pk.n(), 4);
        assert_eq!(pk.row(3), &[7u8]);
        // tombstones keep the table; compaction gathers live rows in the
        // canonical bucket-major order (bucket 2's appended row first)
        let dead = appended.with_tombstones(&[1]);
        assert_eq!(dead.stage1_packed.as_ref().unwrap().n(), 4);
        let comp = dead.compacted();
        let cpk = comp.stage1_packed.as_ref().unwrap();
        assert_eq!(cpk.n(), 3);
        assert_eq!(
            (0..3).map(|i| cpk.row(i)[0]).collect::<Vec<u8>>(),
            comp.stage1_codes().data.iter().map(|&c| c as u8).collect::<Vec<u8>>()
        );
    }

    #[test]
    fn cow_clone_bumps_epoch_and_shares_shards() {
        let set = tiny_set();
        let next = set.cow_clone();
        assert_eq!(next.epoch, set.epoch + 1);
        for (a, b) in set.shards.iter().zip(&next.shards) {
            assert!(Arc::ptr_eq(a, b), "untouched shards must be shared, not copied");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds the bucket count")]
    fn partition_rejects_more_shards_than_buckets() {
        ShardSet::partition(
            vec![vec![0u32], vec![1]],
            Codes::from_vec(2, 1, vec![0, 0]),
            None,
            vec![0.0; 2],
            Codes::zeros(0, 0),
            Vec::new(),
            3,
            vec![0, 1],
        );
    }
}
