//! Bucket-owned index shards and the scatter/gather layer.
//!
//! A [`crate::index::SearchIndex`] no longer holds one monolithic set of
//! per-vector tables: the per-bucket state — inverted lists, stage-1/2
//! code tables, cached terms/norms — lives in [`IndexShard`]s, each
//! owning a **contiguous range of IVF buckets**, collected in a
//! [`ShardSet`]. The shared read-only parts (the coarse quantizer, the
//! [`PipelineSpec`] scorers, the model parameters) stay on the index and
//! are referenced by every shard.
//!
//! # Scatter / gather
//!
//! [`ShardSet::plan`] routes a batch's probed buckets to their owning
//! shards as [`ShardGroup`]s, in ascending bucket order — which, because
//! shards own contiguous ranges, is also shard-major order.
//! [`IndexShard::scan_group`] then runs the existing multi-query
//! block-scan kernel over the shard's *local* rows, pushing
//! `(score, global id)` pairs into the per-query shortlists. Per-shard
//! shortlists merge under the total (score, id) order of
//! [`Shortlist`], so the merged stage-1 shortlist — and therefore the
//! whole pipeline — is **bit-identical to the unsharded index for every
//! shard count**: each (query, candidate) pair is scored with identical
//! floats wherever its row is stored, and the order is total.
//!
//! # The global-id remap invariant
//!
//! Each shard stores its rows contiguously in *local* row order and
//! carries [`IndexShard::global_ids`] mapping local row → global
//! database id. The invariant (pinned by `tests/batch_equivalence.rs`):
//!
//! * `shards[s].global_ids[local]` enumerates, in ascending owned-bucket
//!   order (and original inverted-list order within a bucket), exactly
//!   the database rows whose IVF bucket falls in
//!   `[bucket_lo, bucket_hi)`; every database row appears in exactly one
//!   shard;
//! * `ShardSet::owner_of[gid]` / `ShardSet::local_of[gid]` invert the
//!   map: `shards[owner_of[gid]].global_ids[local_of[gid]] == gid`;
//! * `shards[s].lists[b - bucket_lo]` holds *local* rows, all of which
//!   decode back (via `global_ids`) to rows assigned to bucket `b`.
//!
//! All scoring state (`codes`, `stage1_side_codes`, `stage1_terms`,
//! `stage2_codes`, `stage2_norms`) is indexed by local row; only
//! shortlist entries carry global ids.
//!
//! # Heterogeneous shards
//!
//! A shard may carry its own [`PipelineSpec`] override
//! ([`IndexShard::pipeline`]) with stage-1/2 tables fit for its rows —
//! the ROADMAP's design intent of heterogeneous stage configurations
//! behind one router. Shards without an override share the index-level
//! spec (and, at execution time, one LUT per query — see
//! [`ShardSet::lut_slot`]).

use super::batch::QueryPlan;
use super::pipeline::{gather_codes, PipelineSpec};
use crate::quantizers::{ApproxScorer, Codes, SCORE_BLOCK};
use crate::util::topk::Shortlist;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// One scatter unit produced by [`ShardSet::plan`]: a probed bucket, its
/// owning shard, and the batch members interested in it.
pub struct ShardGroup {
    /// owning shard index in [`ShardSet::shards`]
    pub shard: u32,
    /// global bucket id
    pub bucket: u32,
    /// (query index within the batch, coarse probe distance)
    pub members: Vec<(u32, f32)>,
}

/// Per-bucket-range slice of the index: inverted lists, code tables and
/// cached terms for the database rows whose IVF bucket falls in
/// `[bucket_lo, bucket_hi)`. See the module docs for the global-id remap
/// invariant.
pub struct IndexShard {
    /// first owned bucket (inclusive)
    pub bucket_lo: u32,
    /// one past the last owned bucket (exclusive)
    pub bucket_hi: u32,
    /// inverted lists of the owned buckets, indexed by
    /// `bucket - bucket_lo`; values are **shard-local** rows
    pub lists: Vec<Vec<u32>>,
    /// local row → global database id (the remap invariant)
    pub global_ids: Vec<u32>,
    /// QINCo2 codes of the shard's rows — the stage-3 decode source
    pub codes: Codes,
    /// side code table scanned by stage 1 when the scorer owns one
    /// (PQ/OPQ/LSQ/RQ); `None` means stage 1 scans [`Self::codes`]
    pub stage1_side_codes: Option<Codes>,
    /// cached stage-1 terms: ||x̂_r||² + 2⟨cent, x̂_r⟩ per local row
    pub stage1_terms: Vec<f32>,
    /// extended code table scored by stage 2 (empty when stage 2 is off)
    pub stage2_codes: Codes,
    /// cached ||x̂_pw||² per local row (empty when stage 2 is off)
    pub stage2_norms: Vec<f32>,
    /// per-shard pipeline override (heterogeneous shards). `None` —
    /// the common case — means the shard runs the index-level
    /// [`PipelineSpec`]. Stage 3 is always index-level: the QINCo2
    /// codes are uniform across shards.
    pub pipeline: Option<PipelineSpec>,
    /// lifetime count of (query, candidate) pairs this shard's stage-1
    /// scan has scored — surfaced per shard in
    /// [`crate::server::Stats::shard_scans`]
    pub scanned: AtomicU64,
}

impl IndexShard {
    /// Number of database rows this shard owns.
    #[inline]
    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }

    /// Does this shard own `bucket`?
    #[inline]
    pub fn owns(&self, bucket: u32) -> bool {
        (self.bucket_lo..self.bucket_hi).contains(&bucket)
    }

    /// The shard-local inverted list of an owned bucket.
    #[inline]
    pub fn list(&self, bucket: u32) -> &[u32] {
        debug_assert!(self.owns(bucket));
        &self.lists[(bucket - self.bucket_lo) as usize]
    }

    /// The pipeline this shard executes: its override, or the shared one.
    #[inline]
    pub fn spec<'a>(&'a self, shared: &'a PipelineSpec) -> &'a PipelineSpec {
        self.pipeline.as_ref().unwrap_or(shared)
    }

    /// The code table stage 1 scans: the side table when the shard's
    /// scorer owns one, the QINCo2 codes otherwise.
    #[inline]
    pub fn stage1_codes(&self) -> &Codes {
        self.stage1_side_codes.as_ref().unwrap_or(&self.codes)
    }

    /// Scan one owned bucket group with the given stage-1 scorer and
    /// flat LUT pack, pushing `(score, global id)` into each member's
    /// shortlist — the existing block-scan machinery, unchanged, over
    /// shard-local rows. `block` selects the multi-query
    /// [`ApproxScorer::score_block`] kernel vs the scalar per-member
    /// loop; both are bit-identical by the trait contract.
    pub(crate) fn scan_group(
        &self,
        scorer: &dyn ApproxScorer,
        luts: &[f32],
        stride: usize,
        group: &ShardGroup,
        block: bool,
        shortlists: &mut [Shortlist],
    ) {
        let list = self.list(group.bucket);
        let codes = self.stage1_codes();
        self.scanned
            .fetch_add((list.len() * group.members.len()) as u64, Ordering::Relaxed);
        if block {
            // block fast path: one score_block call scores a code row
            // for up to SCORE_BLOCK co-probed queries
            let mut mq = [0u32; SCORE_BLOCK];
            let mut scores = [0.0f32; SCORE_BLOCK];
            for chunk in group.members.chunks(SCORE_BLOCK) {
                for (l, &(qi, _)) in chunk.iter().enumerate() {
                    mq[l] = qi;
                }
                for &local in list {
                    let i = local as usize;
                    scorer.score_block(
                        luts,
                        stride,
                        &mq[..chunk.len()],
                        codes.row(i),
                        self.stage1_terms[i],
                        &mut scores[..chunk.len()],
                    );
                    for (l, &(qi, probe_d)) in chunk.iter().enumerate() {
                        shortlists[qi as usize].push(probe_d + scores[l], self.global_ids[i]);
                    }
                }
            }
        } else {
            // scalar reference path (bench comparisons only)
            for &local in list {
                let i = local as usize;
                let code = codes.row(i);
                let term = self.stage1_terms[i];
                for &(qi, probe_d) in &group.members {
                    let lut = &luts[qi as usize * stride..][..stride];
                    shortlists[qi as usize]
                        .push(probe_d + scorer.score(lut, code, term), self.global_ids[i]);
                }
            }
        }
    }
}

/// The partitioned per-bucket state of a [`crate::index::SearchIndex`]:
/// every shard plus the routing maps. Shared read-only parts (coarse
/// quantizer, scorers, params) stay on the index.
pub struct ShardSet {
    pub shards: Vec<IndexShard>,
    /// global bucket → owning shard index
    pub shard_of: Vec<u32>,
    /// global database id → owning shard index
    pub owner_of: Vec<u32>,
    /// global database id → local row within its owning shard
    pub local_of: Vec<u32>,
    /// per-shard LUT slot: shards running the shared [`PipelineSpec`]
    /// all map to slot `0` (one LUT / LUT pack per query serves them
    /// all); each override shard gets its own slot. `n_lut_slots` sizes
    /// per-query LUT caches and per-batch LUT packs.
    pub lut_slot: Vec<u32>,
    pub n_lut_slots: usize,
}

impl ShardSet {
    #[inline]
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Any shard carrying a pipeline override?
    #[inline]
    pub fn heterogeneous(&self) -> bool {
        self.n_lut_slots > 1
    }

    /// Contiguous bucket ranges for an `n_shards`-way split of
    /// `n_buckets` buckets: shard `s` owns
    /// `[s·B/S, (s+1)·B/S)`. Every shard owns at least one bucket when
    /// `n_shards <= n_buckets`.
    pub fn bucket_ranges(n_buckets: usize, n_shards: usize) -> Vec<(u32, u32)> {
        (0..n_shards)
            .map(|s| {
                ((s * n_buckets / n_shards) as u32, ((s + 1) * n_buckets / n_shards) as u32)
            })
            .collect()
    }

    /// Partition the assembled per-bucket state into `n_shards`
    /// bucket-owned shards. `lists` are the global inverted lists
    /// (bucket → global ids) taken from the coarse quantizer; the code
    /// tables and caches are indexed by global id and are re-gathered
    /// into each shard's local row order.
    #[allow(clippy::too_many_arguments)]
    pub fn partition(
        lists: Vec<Vec<u32>>,
        codes: Codes,
        stage1_side_codes: Option<Codes>,
        stage1_terms: Vec<f32>,
        stage2_codes: Codes,
        stage2_norms: Vec<f32>,
        n_shards: usize,
    ) -> ShardSet {
        let n_buckets = lists.len();
        assert!(n_shards >= 1, "shard count must be at least 1 (got {n_shards})");
        assert!(
            n_shards <= n_buckets,
            "shard count {n_shards} exceeds the bucket count {n_buckets}: \
             every shard must own at least one IVF bucket"
        );
        let db = codes.n;
        let has_s2 = stage2_codes.m > 0;
        let mut shard_of = vec![0u32; n_buckets];
        let mut owner_of = vec![0u32; db];
        let mut local_of = vec![0u32; db];
        let mut shards = Vec::with_capacity(n_shards);
        for (s, &(lo, hi)) in Self::bucket_ranges(n_buckets, n_shards).iter().enumerate() {
            let (lo_u, hi_u) = (lo as usize, hi as usize);
            let mut local_lists = Vec::with_capacity(hi_u - lo_u);
            let mut global_ids: Vec<u32> = Vec::new();
            for b in lo_u..hi_u {
                shard_of[b] = s as u32;
                let mut local_list = Vec::with_capacity(lists[b].len());
                for &gid in &lists[b] {
                    let local = global_ids.len() as u32;
                    owner_of[gid as usize] = s as u32;
                    local_of[gid as usize] = local;
                    global_ids.push(gid);
                    local_list.push(local);
                }
                local_lists.push(local_list);
            }
            let rows: Vec<usize> = global_ids.iter().map(|&g| g as usize).collect();
            let (sh_s2_codes, sh_s2_norms) = if has_s2 {
                (
                    gather_codes(&stage2_codes, &rows),
                    rows.iter().map(|&i| stage2_norms[i]).collect(),
                )
            } else {
                (Codes::zeros(0, 0), Vec::new())
            };
            shards.push(IndexShard {
                bucket_lo: lo,
                bucket_hi: hi,
                lists: local_lists,
                codes: gather_codes(&codes, &rows),
                stage1_side_codes: stage1_side_codes.as_ref().map(|c| gather_codes(c, &rows)),
                stage1_terms: rows.iter().map(|&i| stage1_terms[i]).collect(),
                stage2_codes: sh_s2_codes,
                stage2_norms: sh_s2_norms,
                pipeline: None,
                scanned: AtomicU64::new(0),
                global_ids,
            });
        }
        let lut_slot = vec![0u32; n_shards];
        ShardSet { shards, shard_of, owner_of, local_of, lut_slot, n_lut_slots: 1 }
    }

    /// Install a heterogeneous pipeline override on shard `s`, replacing
    /// its stage-1/2 tables with ones fit for the override's scorers
    /// (all indexed by the shard's existing local row order), and
    /// reassign LUT slots.
    pub fn install_override(
        &mut self,
        s: usize,
        spec: PipelineSpec,
        stage1_side_codes: Option<Codes>,
        stage1_terms: Vec<f32>,
        stage2_codes: Codes,
        stage2_norms: Vec<f32>,
    ) {
        let sh = &mut self.shards[s];
        assert_eq!(stage1_terms.len(), sh.len(), "override terms must cover the shard");
        if let Some(side) = &stage1_side_codes {
            assert_eq!(side.n, sh.len(), "override side table must cover the shard");
        }
        if stage2_codes.m > 0 {
            assert_eq!(stage2_codes.n, sh.len(), "override stage-2 table must cover the shard");
            assert_eq!(stage2_norms.len(), sh.len(), "override stage-2 norms must cover the shard");
        }
        sh.pipeline = Some(spec);
        sh.stage1_side_codes = stage1_side_codes;
        sh.stage1_terms = stage1_terms;
        sh.stage2_codes = stage2_codes;
        sh.stage2_norms = stage2_norms;
        self.recompute_slots();
    }

    fn recompute_slots(&mut self) {
        self.n_lut_slots = 1;
        for (si, sh) in self.shards.iter().enumerate() {
            self.lut_slot[si] = if sh.pipeline.is_some() {
                let slot = self.n_lut_slots as u32;
                self.n_lut_slots += 1;
                slot
            } else {
                0
            };
        }
    }

    /// The [`PipelineSpec`] behind a LUT slot: slot 0 is the shared
    /// spec, every other slot belongs to exactly one override shard.
    pub fn slot_spec<'a>(&'a self, slot: usize, shared: &'a PipelineSpec) -> &'a PipelineSpec {
        if slot == 0 {
            return shared;
        }
        self.shards
            .iter()
            .zip(&self.lut_slot)
            .find(|&(_, &ls)| ls as usize == slot)
            .and_then(|(sh, _)| sh.pipeline.as_ref())
            .unwrap_or(shared)
    }

    /// Locate a global database id: its owning shard and local row.
    #[inline]
    pub fn locate(&self, id: u32) -> (&IndexShard, usize) {
        let si = self.owner_of[id as usize] as usize;
        (&self.shards[si], self.local_of[id as usize] as usize)
    }

    /// Gather the stage-3 (QINCo2) code rows of `ids` — the union decode
    /// input — from their owning shards, in the given order.
    pub fn gather_stage3_codes(&self, ids: &[u32]) -> Codes {
        let m = self.shards[0].codes.m;
        let mut out = Codes::zeros(ids.len(), m);
        for (o, &id) in ids.iter().enumerate() {
            let (sh, local) = self.locate(id);
            out.row_mut(o).copy_from_slice(sh.codes.row(local));
        }
        out
    }

    /// Scatter a batch's probes to their owning shards: one
    /// [`ShardGroup`] per probed bucket, in ascending bucket order (=
    /// shard-major order, since shards own contiguous ranges — the same
    /// scan order the unsharded engine used, which keeps the group
    /// chunking of the parallel scan identical for every shard count).
    pub fn plan(&self, plans: &[QueryPlan]) -> Vec<ShardGroup> {
        let mut grouped: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        for (qi, plan) in plans.iter().enumerate() {
            for &(probe_d, bucket) in &plan.probes {
                grouped.entry(bucket).or_default().push((qi as u32, probe_d));
            }
        }
        grouped
            .into_iter()
            .map(|(bucket, members)| ShardGroup {
                shard: self.shard_of[bucket as usize],
                bucket,
                members,
            })
            .collect()
    }

    /// Snapshot of the per-shard stage-1 scan counters.
    pub fn scan_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|sh| sh.scanned.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_ranges_cover_contiguously_and_nonempty() {
        for n_buckets in [1usize, 5, 12, 64] {
            for n_shards in 1..=n_buckets.min(8) {
                let ranges = ShardSet::bucket_ranges(n_buckets, n_shards);
                assert_eq!(ranges.len(), n_shards);
                let mut next = 0u32;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, next, "ranges must be contiguous");
                    assert!(hi > lo, "every shard must own at least one bucket");
                    next = hi;
                }
                assert_eq!(next as usize, n_buckets, "ranges must cover all buckets");
            }
        }
    }

    #[test]
    fn bucket_ranges_balance_within_one() {
        // non-divisible splits differ by at most one bucket
        for (n_buckets, n_shards) in [(12usize, 5usize), (7, 3), (64, 6)] {
            let sizes: Vec<usize> = ShardSet::bucket_ranges(n_buckets, n_shards)
                .iter()
                .map(|&(lo, hi)| (hi - lo) as usize)
                .collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced split {sizes:?}");
        }
    }

    #[test]
    fn partition_remaps_lists_tables_and_ids() {
        // 4 buckets, 6 rows, 3 shards (ranges [0,1), [1,2), [2,4))
        let lists = vec![vec![3, 0], vec![5], vec![], vec![1, 4, 2]];
        let codes = Codes::from_vec(6, 1, vec![10, 11, 12, 13, 14, 15]);
        let terms: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let set = ShardSet::partition(
            lists,
            codes,
            None,
            terms,
            Codes::zeros(0, 0),
            Vec::new(),
            3,
        );
        assert_eq!(set.n_shards(), 3);
        assert!(!set.heterogeneous());
        assert_eq!(set.shards[0].global_ids, vec![3, 0]);
        assert_eq!(set.shards[1].global_ids, vec![5]);
        assert_eq!(set.shards[2].global_ids, vec![1, 4, 2]);
        // local lists reference local rows
        assert_eq!(set.shards[0].lists, vec![vec![0, 1]]);
        assert_eq!(set.shards[2].lists, vec![Vec::<u32>::new(), vec![0, 1, 2]]);
        // tables follow the remap
        assert_eq!(set.shards[2].codes.row(1), &[14]);
        assert_eq!(set.shards[2].stage1_terms, vec![1.0, 4.0, 2.0]);
        // inverse maps round-trip
        for (si, sh) in set.shards.iter().enumerate() {
            for (local, &gid) in sh.global_ids.iter().enumerate() {
                assert_eq!(set.owner_of[gid as usize] as usize, si);
                assert_eq!(set.local_of[gid as usize] as usize, local);
            }
        }
        // gather follows global ids across shards
        let gathered = set.gather_stage3_codes(&[2, 5, 0]);
        assert_eq!(gathered.row(0), &[12]);
        assert_eq!(gathered.row(1), &[15]);
        assert_eq!(gathered.row(2), &[10]);
    }

    #[test]
    #[should_panic(expected = "exceeds the bucket count")]
    fn partition_rejects_more_shards_than_buckets() {
        ShardSet::partition(
            vec![vec![0u32], vec![1]],
            Codes::from_vec(2, 1, vec![0, 0]),
            None,
            vec![0.0; 2],
            Codes::zeros(0, 0),
            Vec::new(),
            3,
        );
    }
}
