//! The large-scale search stack (paper Sec. 3.3, Fig. 3): IVF coarse
//! quantization with an HNSW centroid index, QINCo2 fine codes over IVF
//! residuals, and a three-stage retrieval pipeline — approximate LUT
//! scan, re-ranking scan, exact decode — assembled from the pluggable
//! [`ApproxScorer`](crate::quantizers::ApproxScorer) /
//! [`StageDecoder`](crate::quantizers::StageDecoder) traits into a
//! [`PipelineSpec`] (see [`pipeline`] for the trait-level architecture).
//!
//! # Ownership: ShardSet epochs → IndexShard → BatchSearcher snapshot
//!
//! The index is shard-partitioned ([`shard`]): all per-bucket state
//! lives in bucket-owned shards, the shared read-only parts stay at the
//! top. The shard layer is **live-mutable** behind epoch snapshots: a
//! [`ShardSet`](shard::ShardSet) is one immutable epoch of the whole
//! per-bucket state, published behind `RwLock<Arc<ShardSet>>`.
//!
//! ```text
//! SearchIndex
//! ├── ivf: Ivf                   coarse quantizer: centroids + HNSW (its
//! │                              inverted lists and per-row assignment
//! │                              are drained into the snapshot)
//! ├── pipeline: PipelineSpec     shared stage-1/2/3 trait objects
//! ├── params: Arc<ParamStore>    QINCo2 model weights (stage 3)
//! ├── writer: Mutex<()>          serializes insert/delete/compact
//! │      │  (copy-on-write mutated shards, epoch += 1, publish)
//! │      ▼
//! └── shards: RwLock<Arc<ShardSet>>   the published epoch
//!     └── ShardSet               scatter/gather layer + routing maps
//!         │                      (bucket → shard, id → shard/local row,
//!         │                      id → bucket) + the epoch counter
//!         └── [Arc<IndexShard>; S]   one per contiguous bucket range,
//!             │                  Arc-shared across epochs when untouched:
//!             ├── lists          shard-local inverted lists
//!             ├── codes, stage1_*,   code tables + cached terms, indexed
//!             │   stage2_*       by local row (global_ids maps back)
//!             ├── tombstones     per-row delete marks, skipped by scans,
//!             │                  reclaimed by compaction
//!             └── pipeline: Option<Arc<PipelineSpec>>  heterogeneous
//!                                override
//!
//!         pin ──► SearchIndex::search / BatchSearcher (one Arc<ShardSet>
//!                 per query / batch: the epoch is frozen for its whole
//!                 plan+execute, concurrent publishes are invisible)
//! ```
//!
//! Writers ([`SearchIndex::insert`] / `delete` / `compact`) never mutate
//! a published shard: they rebuild the affected shards copy-on-write and
//! swap in a complete replacement snapshot, so a pinned reader never
//! observes a partial row, a half-linked inverted list, or a
//! tombstone-without-epoch. Deletes are tombstones (rows skipped by
//! every scan from the next epoch on); compaction rewrites a shard into
//! the canonical fresh-build layout and retires the reclaimed global ids
//! ([`shard::DEAD_LOCAL`] — ids are never reused). After any mutation
//! sequence, search over the live set is bit-identical to a fresh
//! assembly over the same surviving vectors (greedy-encode ingest;
//! pinned by `tests/mutation_invariants.rs`).
//!
//! Execution scatters and gathers over that tree:
//! [`ShardSet::plan`](shard::ShardSet::plan) routes each batch's probed
//! buckets to their owning shards; per-shard scans
//! ([`IndexShard`](shard::IndexShard) + the block kernel) run the
//! existing stage-1 machinery on local rows (in parallel across
//! [`SearchParams::batch_threads`] threads); per-shard shortlists merge
//! under the total (score, id) order *before* the single stage-3 decode,
//! so sharding never costs extra f_theta work and results are
//! bit-identical to the unsharded index for every shard count.
//!
//! Two execution paths share one set of scoring kernels: the per-query
//! [`SearchIndex::search`] and the batched [`batch::BatchSearcher`]
//! engine (per-batch LUT packs, scattered shard-group scans, union
//! stage-3 decode) that the serving router dispatches whole batches
//! through. The batched scan's physical layout is selectable per
//! request ([`SearchParams::scan_layout`], CLI `--scan-layout`): flat
//! (seed), query-major transposed (bit-identical, unit-stride loads),
//! or the 4-bit packed fast scan (bounded-error quantized mode over
//! nibble-packed code tables; requires a
//! [`BuildCfg::scan_layout`]` = `[`ScanLayout::Packed4`] build).
//!
//! Both batched entry points are deadline-aware
//! ([`BatchSearcher::execute_within`](batch::BatchSearcher::execute_within),
//! [`SearchIndex::search_batch_within`]): a
//! [`Deadline`](crate::util::deadline::Deadline) is checked between
//! bucket-group scans (and every
//! [`DEADLINE_CHECK_ROWS`](shard::DEADLINE_CHECK_ROWS) rows inside
//! one), and before stage 3 — expiry degrades the call to the stage-1/2
//! shortlist ranking, flagged on [`batch::BatchOutput`], instead of
//! running long. No deadline ⇒ bit-identical to the historical paths.

pub mod batch;
pub mod hnsw;
pub mod ivf;
pub mod pipeline;
pub mod shard;

pub use batch::{stage2_use_lut, BatchOutput, BatchSearcher, QueryPlan};
pub use pipeline::{
    packed4_support, BuildCfg, EncodeParams, PipelineConfig, PipelineSpec, ScanLayout,
    SearchIndex, SearchParams, Stage1Kind, Stage3Kind,
};
pub use shard::{IndexShard, RowPayload, ShardGroup, ShardSet, DEAD_LOCAL};
