//! The large-scale search stack (paper Sec. 3.3, Fig. 3): IVF coarse
//! quantization with an HNSW centroid index, QINCo2 fine codes over IVF
//! residuals, and a three-stage retrieval pipeline — approximate LUT
//! scan, re-ranking scan, exact decode — assembled from the pluggable
//! [`ApproxScorer`](crate::quantizers::ApproxScorer) /
//! [`StageDecoder`](crate::quantizers::StageDecoder) traits into a
//! [`PipelineSpec`] (see [`pipeline`] for the trait-level architecture).
//!
//! # Ownership: ShardSet → IndexShard → BatchSearcher
//!
//! The index is shard-partitioned ([`shard`]): all per-bucket state
//! lives in bucket-owned shards, the shared read-only parts stay at the
//! top.
//!
//! ```text
//! SearchIndex
//! ├── ivf: Ivf                   coarse quantizer: centroids + HNSW +
//! │                              per-row bucket assignment (its inverted
//! │                              lists are drained into the shards)
//! ├── pipeline: PipelineSpec     shared stage-1/2/3 trait objects
//! ├── params: Arc<ParamStore>    QINCo2 model weights (stage 3)
//! └── shards: ShardSet           scatter/gather layer + routing maps
//!     │                          (bucket → shard, id → shard/local row)
//!     └── [IndexShard; S]        one per contiguous bucket range:
//!         ├── lists              shard-local inverted lists
//!         ├── codes, stage1_*,   code tables + cached terms, indexed by
//!         │   stage2_*           local row (global_ids maps back)
//!         └── pipeline: Option<PipelineSpec>   heterogeneous override
//! ```
//!
//! Execution scatters and gathers over that tree:
//! [`ShardSet::plan`](shard::ShardSet::plan) routes each batch's probed
//! buckets to their owning shards; per-shard scans
//! ([`IndexShard`](shard::IndexShard) + the block kernel) run the
//! existing stage-1 machinery on local rows (in parallel across
//! [`SearchParams::batch_threads`] threads); per-shard shortlists merge
//! under the total (score, id) order *before* the single stage-3 decode,
//! so sharding never costs extra f_theta work and results are
//! bit-identical to the unsharded index for every shard count.
//!
//! Two execution paths share one set of scoring kernels: the per-query
//! [`SearchIndex::search`] and the batched [`batch::BatchSearcher`]
//! engine (per-batch LUT packs, scattered shard-group scans, union
//! stage-3 decode) that the serving router dispatches whole batches
//! through.

pub mod batch;
pub mod hnsw;
pub mod ivf;
pub mod pipeline;
pub mod shard;

pub use batch::{stage2_use_lut, BatchSearcher, QueryPlan};
pub use pipeline::{
    BuildCfg, PipelineConfig, PipelineSpec, SearchIndex, SearchParams, Stage1Kind, Stage3Kind,
};
pub use shard::{IndexShard, ShardGroup, ShardSet};
