//! The large-scale search stack (paper Sec. 3.3, Fig. 3): IVF coarse
//! quantization with an HNSW centroid index, QINCo2 fine codes over IVF
//! residuals, and a three-stage retrieval pipeline — approximate LUT
//! scan, re-ranking scan, exact decode — assembled from the pluggable
//! [`ApproxScorer`](crate::quantizers::ApproxScorer) /
//! [`StageDecoder`](crate::quantizers::StageDecoder) traits into a
//! [`PipelineSpec`] (see [`pipeline`] for the trait-level architecture).
//!
//! Two execution paths share one set of scoring kernels: the per-query
//! [`SearchIndex::search`] and the batched [`batch::BatchSearcher`]
//! engine (per-batch LUT packing, bucket-grouped scans, union stage-3
//! decode) that the serving router dispatches whole batches through.

pub mod batch;
pub mod hnsw;
pub mod ivf;
pub mod pipeline;

pub use batch::{stage2_use_lut, BatchSearcher, QueryPlan};
pub use pipeline::{
    BuildCfg, PipelineConfig, PipelineSpec, SearchIndex, SearchParams, Stage1Kind, Stage3Kind,
};
