//! The large-scale search stack (paper Sec. 3.3, Fig. 3): IVF coarse
//! quantization with an HNSW centroid index, QINCo2 fine codes over IVF
//! residuals, an additive-LUT first-stage scan, pairwise-decoder
//! re-ranking, and a final neural decode of the surviving shortlist.

pub mod hnsw;
pub mod ivf;
pub mod pipeline;

pub use pipeline::{BuildCfg, SearchIndex, SearchParams};
