//! The large-scale search stack (paper Sec. 3.3, Fig. 3): IVF coarse
//! quantization with an HNSW centroid index, QINCo2 fine codes over IVF
//! residuals, an additive-LUT first-stage scan, pairwise-decoder
//! re-ranking, and a final neural decode of the surviving shortlist.
//!
//! Two execution paths share one set of scoring kernels: the per-query
//! [`SearchIndex::search`] and the batched [`batch::BatchSearcher`]
//! engine (per-batch LUT packing, bucket-grouped scans, union stage-3
//! decode) that the serving router dispatches whole batches through.

pub mod batch;
pub mod hnsw;
pub mod ivf;
pub mod pipeline;

pub use batch::{stage2_use_lut, BatchSearcher, QueryPlan};
pub use pipeline::{BuildCfg, SearchIndex, SearchParams};
