//! The Fig. 3 search pipeline, generic over pluggable stage traits and
//! partitioned into bucket-owned shards.
//!
//! # Three stages, two traits
//!
//! Retrieval is staged exactly as the paper draws it: HNSW coarse probe →
//! approximate LUT scan → re-scoring → exact decode of the survivors.
//! Each stage is a trait object, assembled into a [`PipelineSpec`]:
//!
//! * **stage 1** — `Box<dyn ApproxScorer>` scanning each shard's code
//!   table ([`IndexShard::stage1_codes`](super::shard::IndexShard::stage1_codes)) with the cached additive terms
//!   ([`IndexShard::stage1_terms`](super::shard::IndexShard::stage1_terms)). The default is the unitary
//!   [`AdditiveDecoder`] re-fit on the QINCo2 codes;
//!   [`PqScorer`]/[`OpqScorer`] swap in a product quantizer with its
//!   *own* code table over the same IVF residuals.
//! * **stage 2** — `Option<Box<dyn ApproxScorer>>` re-scoring the stage-1
//!   shortlist over the extended code table
//!   ([`IndexShard::stage2_codes`](super::shard::IndexShard::stage2_codes)). The default is the paper's
//!   [`PairwiseDecoder`] (Sec. 3.3, Eqs. 8-9); `None` forwards the
//!   stage-1 shortlist unchanged.
//! * **stage 3** — `Box<dyn StageDecoder>`: one batch decode of the
//!   surviving codes, then exact distances. Three decoders share the
//!   model's `Arc<ParamStore>`: the scalar-oracle [`ReferenceDecoder`]
//!   ([`Stage3Kind::Reference`], the default), the native
//!   [`crate::qinco::RustDecoder`] over the shared [`crate::nn`] kernels
//!   ([`Stage3Kind::Rust`], `--stage3 rust`), and the engine-backed
//!   [`crate::qinco::RuntimeDecoder`] that routes the same call through
//!   the artifact ABI — native kernels by default, AOT-compiled HLO
//!   under the `pjrt` feature ([`Stage3Kind::Runtime`]; the index itself
//!   holds a `RustDecoder` since engines are thread-confined, and serve
//!   workers get per-thread runtime decoders via a `DecoderFactory`).
//!   With [`Stage3Kind::Disabled`] ("pairwise-only fast mode") the
//!   stage-2 ranking is returned directly, truncated to `n_final`.
//!
//! # Shards
//!
//! The per-bucket state — inverted lists, stage-1/2 code tables, cached
//! terms — is partitioned into [`IndexShard`](super::shard::IndexShard)s, each owning a contiguous
//! IVF bucket range, collected in [`SearchIndex::shards`]
//! (a [`ShardSet`]); the shared read-only parts (coarse quantizer,
//! [`PipelineSpec`] scorers, model params) stay here. [`BuildCfg::shards`]
//! selects the shard count, and [`BuildCfg::shard_pipelines`] may give
//! individual shards their own stage-1/2 configuration (heterogeneous
//! shards). Search results are bit-identical for every shard count by
//! construction — see [`super::shard`] for the scatter/gather argument
//! and the global-id remap invariant.
//!
//! # Distance algebra (per stage)
//!
//! ```text
//! stage 1: ||q - cent_b - x̂_r||² = probe_dist + term_i − 2⟨q, x̂_r⟩
//!          with term_i = ||x̂_r||² + 2⟨cent_b, x̂_r⟩ cached per vector —
//!          the trait score contract's additive-offset linearity is what
//!          lets the coarse term fold in for free.
//! stage 2: ||x̂_pw||² − 2⟨q, x̂_pw⟩ (the pairwise decoder targets raw x,
//!          so scores are comparable across buckets)
//! stage 3: exact ||q - (cent + decode(I¹..I^M))||²
//! ```
//!
//! # Plugging in a custom scorer or decoder
//!
//! Implement [`ApproxScorer`] (score contract: `score(lut, code, t) =
//! t − 2⟨q, decode(code)⟩`, ranked under the total `(score, id)` order of
//! [`Shortlist`]) and build the index through [`SearchIndex::assemble`]
//! with a [`PipelineConfig`], or construct a [`PipelineSpec`] directly.
//! Custom stage-3 decoders implement [`StageDecoder`]; decoders that own
//! a per-thread engine (PJRT clients are `Rc`-based, not `Send`) are
//! handed to server workers through a
//! [`DecoderFactory`](crate::quantizers::DecoderFactory) — each worker
//! calls `make()` once at startup (engine-per-worker) and passes the
//! resulting decoder to [`super::batch::BatchSearcher::execute_with_decoder`].
//!
//! # Execution paths
//!
//! * [`SearchIndex::search`] — one query at a time.
//! * [`super::batch::BatchSearcher`] — the batched engine: per-batch
//!   flat LUT packs, bucket groups scattered to their owning shards
//!   ([`ShardSet::plan`]), each scanned once per batch with the
//!   multi-query [`ApproxScorer::score_block`] kernel (groups optionally
//!   split across [`SearchParams::batch_threads`] threads), per-shard
//!   shortlists merged under the total (score, id) order, and a single
//!   union decode for stage 3. Result-identical to `search` for *every*
//!   pipeline configuration, thread count **and shard count** — both
//!   paths share the crate-private `stage2_rescore` / `exact_rerank`
//!   helpers, the [`ApproxScorer::use_lut`] cost model, and the total
//!   (score, id) shortlist order of [`Shortlist`] (pinned by
//!   `batch_equivalence.rs` across all configurations).

use super::ivf::Ivf;
use super::shard::{RowPayload, ShardSet, DEAD_LOCAL};
use crate::qinco::{reference, Codec, ParamStore, ReferenceDecoder, RustDecoder};
use crate::quantizers::aq_lut::AdditiveDecoder;
use crate::quantizers::lsq::{Lsq, LsqScorer};
use crate::quantizers::opq::{Opq, OpqScorer};
use crate::quantizers::pairwise::{append_positions, PairwiseDecoder};
use crate::quantizers::pq::{Pq, PqScorer};
use crate::quantizers::rq::{Rq, RqScorer};
use crate::quantizers::{ApproxScorer, Codes, StageDecoder, VectorQuantizer};

// the scan-layout selector lives with the kernels it names; re-exported
// here (and from `crate::index`) because it is a build/search knob
pub use crate::quantizers::ScanLayout;
use crate::runtime::Engine;
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use crate::util::topk::Shortlist;
use anyhow::{bail, Result};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, RwLock};

/// Search-time knobs (the Fig. 6 sweep axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchParams {
    pub nprobe: usize,
    pub ef_search: usize,
    /// stage-1 shortlist size |S_AQ|
    pub n_aq: usize,
    /// stage-2 shortlist size |S_pairs| (0 disables pairwise re-ranking)
    pub n_pairs: usize,
    /// final results returned after the stage-3 re-rank (0 disables the
    /// re-rank: stage-2 order is returned in full; when the index was
    /// built with stage 3 disabled, the stage-2 order is truncated to
    /// `n_final` instead)
    pub n_final: usize,
    /// intra-batch parallelism of one batched execute: the stage-1
    /// shard-group scan (and the per-query stage-2/3 loops) split
    /// across this many threads, with per-thread shortlists merged
    /// under the total (score, id) order — results stay bit-identical
    /// for every thread count (pinned by `batch_equivalence`).
    /// `1` = single-threaded per call (default: the serving router
    /// parallelizes across workers instead); `0` = inherit the index's
    /// [`BuildCfg::batch_threads`] default. CLI: `--batch-threads`.
    pub batch_threads: usize,
    /// physical layout of the batched stage-1 scan (CLI:
    /// `--scan-layout`). `Flat` (the default) and `Transposed` are
    /// bit-identical by contract; `Packed4` is the bounded-error
    /// quantized fast scan and requires an index built with
    /// [`BuildCfg::scan_layout`] `= Packed4` (a typed request error
    /// otherwise, never a silent fallback). The per-query
    /// [`SearchIndex::search`] path always scans exact flat LUTs — this
    /// knob shapes the batched engine's packs.
    pub scan_layout: ScanLayout,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            nprobe: 8,
            ef_search: 64,
            n_aq: 256,
            n_pairs: 32,
            n_final: 10,
            batch_threads: 1,
            scan_layout: ScanLayout::Flat,
        }
    }
}

/// Which [`ApproxScorer`] runs the stage-1 scan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Stage1Kind {
    /// Unitary additive decoder re-fit on the QINCo2 codes (the paper's
    /// default; scans the QINCo2 code table itself).
    Aq,
    /// Product quantizer trained on the IVF residuals, scanning its own
    /// `m`-position code table (`k` follows the model's codebook size).
    Pq { m: usize },
    /// OPQ: learned rotation + PQ.
    Opq { m: usize, iters: usize },
    /// LSQ additive quantizer trained on the IVF residuals, scanning its
    /// own ICM-encoded `m`-position table (`k` follows the model).
    Lsq { m: usize },
    /// Plain residual quantizer (greedy encode), scanning its own
    /// `m`-position table — the cheapest additive baseline.
    Rq { m: usize },
}

/// Which [`StageDecoder`] the index holds for stage 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage3Kind {
    /// Scalar-oracle reference QINCo2 decoder (infallible, thread-shared;
    /// deliberately naive — the baseline every faster path is pinned to).
    Reference,
    /// Native QINCo2 decoder over the shared [`crate::nn`] kernels
    /// ([`crate::qinco::RustDecoder`]) — the production pure-Rust path.
    Rust,
    /// Serve through the artifact runtime: the index itself holds a
    /// [`crate::qinco::RustDecoder`] (engines are thread-confined, so a
    /// thread-shared index can't carry one), and the server hands each
    /// worker its own [`crate::qinco::RuntimeDecoder`] via a
    /// [`DecoderFactory`](crate::quantizers::DecoderFactory).
    Runtime,
    /// No exact re-rank: the stage-2 ranking is final ("pairwise-only
    /// fast mode"). `n_final > 0` truncates it.
    Disabled,
}

/// Build-time pipeline selection — the configuration mirror of
/// [`PipelineSpec`]. Server workers may additionally override stage 3
/// per thread via a [`DecoderFactory`](crate::quantizers::DecoderFactory)
/// (e.g. the PJRT [`RuntimeDecoderFactory`](crate::qinco::RuntimeDecoderFactory)).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    pub stage1: Stage1Kind,
    /// fit + use the pairwise re-ranker (stage 2)
    pub stage2: bool,
    pub stage3: Stage3Kind,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { stage1: Stage1Kind::Aq, stage2: true, stage3: Stage3Kind::Reference }
    }
}

impl PipelineConfig {
    /// Parse CLI-level flags: `stage1 ∈ {aq, pq, opq, lsq, rq}`
    /// (`stage1_m` sub-quantizers/steps for everything but aq),
    /// `stage3 ∈ {reference, rust, runtime, none}`. Every stage-3 name
    /// resolves to its own [`Stage3Kind`] — an unknown name is a hard
    /// error naming the flag, never a silent fallback.
    pub fn from_flags(
        stage1: &str,
        stage1_m: usize,
        stage2: bool,
        stage3: &str,
    ) -> Result<PipelineConfig> {
        let s1 = match stage1 {
            "aq" => Stage1Kind::Aq,
            "pq" | "opq" | "lsq" | "rq" => {
                if stage1_m == 0 {
                    bail!("--stage1-m must be >= 1 for a {stage1} stage 1");
                }
                match stage1 {
                    "pq" => Stage1Kind::Pq { m: stage1_m },
                    "opq" => Stage1Kind::Opq { m: stage1_m, iters: 4 },
                    "lsq" => Stage1Kind::Lsq { m: stage1_m },
                    _ => Stage1Kind::Rq { m: stage1_m },
                }
            }
            other => bail!("unknown stage-1 scorer {other:?} (expected aq|pq|opq|lsq|rq)"),
        };
        let s3 = match stage3 {
            "reference" => Stage3Kind::Reference,
            "rust" => Stage3Kind::Rust,
            "runtime" => Stage3Kind::Runtime,
            "none" | "disabled" => Stage3Kind::Disabled,
            other => bail!(
                "--stage3: unknown stage-3 decoder {other:?} (expected reference|rust|runtime|none)"
            ),
        };
        Ok(PipelineConfig { stage1: s1, stage2, stage3: s3 })
    }
}

/// The assembled three-stage pipeline: one trait object per stage. The
/// index shares these read-only across every serving thread, so stage 1/2
/// scorers are `Send + Sync` by trait bound and the stage-3 box carries
/// the marker bounds explicitly (thread-local runtime decoders live
/// *outside* the spec, handed to workers by a `DecoderFactory`). An
/// [`IndexShard`](super::shard::IndexShard) may carry its own spec (heterogeneous shards); shards
/// without one run this shared spec.
pub struct PipelineSpec {
    pub stage1: Box<dyn ApproxScorer>,
    pub stage2: Option<Box<dyn ApproxScorer>>,
    pub stage3: Box<dyn StageDecoder + Send + Sync>,
}

/// Build-time configuration.
#[derive(Clone, Debug)]
pub struct BuildCfg {
    pub k_ivf: usize,
    /// RQ steps used to quantize the IVF centroids for the pairwise pool
    pub m_tilde: usize,
    /// number of optimized pairs (paper default: 2M)
    pub n_pairs_train: usize,
    /// training subsample for the decoders
    pub fit_sample: usize,
    pub seed: u64,
    /// which scorer/decoder runs each stage
    pub pipeline: PipelineConfig,
    /// number of bucket-owned [`IndexShard`](super::shard::IndexShard)s the per-bucket state is
    /// partitioned into (contiguous bucket ranges). Must be in
    /// `1..=k_ivf`. Search results are bit-identical for every value;
    /// the knob exists for placement/parallelism. CLI: `--shards`.
    pub shards: usize,
    /// heterogeneous shards: per-shard pipeline overrides as
    /// `(shard index, config)` pairs. Each named shard gets its own
    /// stage-1/2 scorers and tables, fit on the same decoder-fit split;
    /// stage 3 must match the shared config (the QINCo2 codes are
    /// uniform across shards). Empty — the default — means every shard
    /// runs [`Self::pipeline`]. Note: every override — including two
    /// shards given *identical* configs — fits its own stage-1 scorer
    /// and claims its own per-query LUT slot; overrides are meant to be
    /// sparse (a few special shards), not a way to re-spell a
    /// homogeneous pipeline.
    pub shard_pipelines: Vec<(usize, PipelineConfig)>,
    /// default intra-batch thread count for searches against this index,
    /// used when [`SearchParams::batch_threads`] is `0` (inherit).
    /// `0` here means "all cores" (`pool::default_threads`); the
    /// out-of-the-box default is `1` (single-threaded per execute).
    pub batch_threads: usize,
    /// scan layout the index is assembled for. `Flat` / `Transposed`
    /// need no extra build state (both scan the same tables — the
    /// layout is chosen per request); `Packed4` additionally builds the
    /// nibble-packed stage-1 tables and **validates every stage-1
    /// family** with [`packed4_support`] — an incompatible family
    /// (AQ/OPQ/LSQ, or `K > 16`) is a hard build error naming the
    /// family, never a silent fallback. CLI: `--scan-layout` on build.
    pub scan_layout: ScanLayout,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg {
            k_ivf: 64,
            m_tilde: 2,
            n_pairs_train: 0,
            fit_sample: 20_000,
            seed: 0x5EA2C4,
            pipeline: PipelineConfig::default(),
            shards: 1,
            shard_pipelines: Vec::new(),
            batch_threads: 1,
            scan_layout: ScanLayout::Flat,
        }
    }
}

pub struct SearchIndex {
    /// Coarse quantizer (centroids + HNSW). Its inverted lists **and
    /// per-row assignment are drained into the shard snapshot** at
    /// assembly — per-bucket candidate lists live in the published
    /// [`ShardSet`], and `assign` lives there too so ingest can extend
    /// it per epoch.
    pub ivf: Ivf,
    pub params: Arc<ParamStore>,
    /// the shared stage implementations (shards without an override run
    /// these)
    pub pipeline: PipelineSpec,
    /// the published epoch snapshot: inverted lists, stage-1/2 code
    /// tables and caches, one [`IndexShard`](super::shard::IndexShard)
    /// per contiguous bucket range, plus the id routing maps. Readers
    /// pin it once per search / batch via [`Self::snapshot`]; writers
    /// replace the whole `Arc` under [`Self::writer`] — see the
    /// [`super::shard`] module docs for the protocol.
    shards: RwLock<Arc<ShardSet>>,
    /// serializes insert/delete/compact; readers never take it
    writer: Mutex<()>,
    /// the fitted stage-2 machinery, retained so ingest can derive new
    /// rows' extended codes/norms (`None` iff no shard enables stage 2)
    stage2_fit: Option<Stage2Fit>,
    /// RQ steps of the bucket-level stage-2 extension (from BuildCfg)
    m_tilde: usize,
    /// whether the exact stage-3 re-rank runs at all
    /// ([`Stage3Kind::Disabled`] turns searches into stage-2-final mode)
    pub stage3_enabled: bool,
    /// per-step MSE trace of the pairwise fit (Table S3; empty when
    /// stage 2 is off)
    pub pairwise_trace: Vec<(usize, usize, f64)>,
    /// resolved [`BuildCfg::batch_threads`] — the intra-batch thread
    /// count a search with `SearchParams::batch_threads == 0` inherits
    pub default_batch_threads: usize,
}

/// Encode-time knobs of the live ingest path: codeword pre-selection
/// width `a` and beam width `b` (the paper's A and B). `0` means
/// "default": `a = K` (no pre-selection), `b = 1` — which together
/// reproduce the greedy reference encode bit-for-bit. Validated by
/// [`SearchIndex::insert`] against `1 <= b <= a <= K`; the CLI
/// surfaces these as `--a` / `--b`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodeParams {
    pub a: usize,
    pub b: usize,
}

/// The fitted stage-2 machinery, shared by every shard that enables
/// stage 2: the pairwise decoder (fit once on the decoder-fit split) and
/// the RQ bucket codes of the IVF centroids. Per-row tables are derived
/// from it by [`stage2_tables`] — fitting is independent of which rows a
/// shard owns, so one fit serves the shared spec and every override.
struct Stage2Fit {
    pairwise: PairwiseDecoder,
    bucket_codes: Codes,
}

/// Build-time eligibility of a stage-1 family for the
/// [`ScanLayout::Packed4`] fast scan: only the plain additive
/// position-major families (PQ / RQ) with `k ≤ 16` codewords per
/// position can nibble-pack their code tables. Everything else errs
/// **naming the family** — requesting packed4 with an incompatible
/// stage 1 is a hard error at build time (the CLI surfaces it before
/// assembly; [`SearchIndex::assemble`] panics with the same message),
/// never a silent fallback to another layout.
pub fn packed4_support(kind: &Stage1Kind, k: usize) -> Result<()> {
    let family = match kind {
        Stage1Kind::Pq { .. } => "pq",
        Stage1Kind::Rq { .. } => "rq",
        Stage1Kind::Aq => bail!(
            "--scan-layout packed4 does not support the \"aq\" stage-1 family (it scans \
             full-width QINCo2 codes, not nibble-sized codewords); use --stage1 pq or rq"
        ),
        Stage1Kind::Opq { .. } => bail!(
            "--scan-layout packed4 does not support the \"opq\" stage-1 family; \
             use --stage1 pq or rq"
        ),
        Stage1Kind::Lsq { .. } => bail!(
            "--scan-layout packed4 does not support the \"lsq\" stage-1 family; \
             use --stage1 pq or rq"
        ),
    };
    if k > 16 {
        bail!(
            "--scan-layout packed4 requires k <= 16 codewords per position for the \
             \"{family}\" stage-1 family, but this model has K={k} (does not fit a nibble)"
        );
    }
    Ok(())
}

/// Fit the configured stage-1 scorer on the decoder-fit split and encode
/// the given residual rows into the side table it scans (`None` for AQ,
/// which scans the QINCo2 codes directly). Shared by the global build
/// and the per-shard heterogeneous overrides — same seeds, so a full
/// override is bit-identical to the homogeneous pipeline of that kind.
fn build_stage1(
    kind: &Stage1Kind,
    fit_res: &Matrix,
    fit_codes: &Codes,
    residuals: &Matrix,
    k: usize,
    seed: u64,
) -> (Box<dyn ApproxScorer>, Option<Codes>) {
    match kind {
        Stage1Kind::Aq => {
            // unitary RQ re-fit on (residual, code) pairs; scans the
            // QINCo2 code table directly (no side table)
            let aq = AdditiveDecoder::fit_rq(fit_res, fit_codes, k);
            (Box::new(aq), None)
        }
        Stage1Kind::Pq { m: m_pq } => {
            let pq = Pq::train(fit_res, *m_pq, k, seed ^ 0x9106);
            let s1_codes = pq.encode(residuals);
            (Box::new(PqScorer(pq)), Some(s1_codes))
        }
        Stage1Kind::Opq { m: m_pq, iters } => {
            let opq = Opq::train(fit_res, *m_pq, k, *iters, seed ^ 0x0619);
            let s1_codes = opq.encode(residuals);
            (Box::new(OpqScorer::new(opq)), Some(s1_codes))
        }
        Stage1Kind::Lsq { m: m_s1 } => {
            let lsq = Lsq::train(fit_res, *m_s1, k, 2, seed ^ 0x15D1);
            let s1_codes = lsq.encode(residuals);
            (Box::new(LsqScorer(lsq)), Some(s1_codes))
        }
        Stage1Kind::Rq { m: m_s1 } => {
            let rq = Rq::train(fit_res, *m_s1, k, 1, seed ^ 0x4217);
            let s1_codes = rq.encode(residuals);
            (Box::new(RqScorer(rq)), Some(s1_codes))
        }
    }
}

/// Cached stage-1 terms for a set of rows: `||x̂||² + 2⟨cent, x̂⟩` from
/// the scorer's decode of `scan_codes`, with each row's centroid given
/// by `row_buckets`.
fn stage1_terms_of(
    scorer: &dyn ApproxScorer,
    scan_codes: &Codes,
    centroids: &Matrix,
    row_buckets: &[u32],
) -> Vec<f32> {
    debug_assert_eq!(scan_codes.n, row_buckets.len());
    let dec = scorer.decode(scan_codes);
    (0..scan_codes.n)
        .map(|i| {
            let cent = centroids.row(row_buckets[i] as usize);
            tensor::sqnorm(dec.row(i)) + 2.0 * tensor::dot(cent, dec.row(i))
        })
        .collect()
}

/// Fit the pairwise stage-2 decoder on the decoder-fit split. Runs at
/// most once per index build, regardless of how many shards enable
/// stage 2 — the fit does not depend on which rows a shard owns.
#[allow(clippy::too_many_arguments)]
fn fit_stage2(
    ivf: &Ivf,
    fit_x: &Matrix,
    fit_assign: &[u32],
    fit_codes: &Codes,
    m_tilde: usize,
    n_pairs_train: usize,
    k: usize,
    seed: u64,
) -> Stage2Fit {
    // RQ-quantize the IVF centroids into M̃ codes (bucket-level only:
    // storage independent of the database size)
    let ivf_rq = Rq::train(&ivf.centroids, m_tilde, k, 4, seed ^ 0x77);
    let bucket_codes = ivf_rq.encode(&ivf.centroids);
    let n_pairs = if n_pairs_train == 0 { 2 * fit_codes.m } else { n_pairs_train };
    let mut fit_extra = Codes::zeros(fit_x.rows, m_tilde);
    for i in 0..fit_x.rows {
        fit_extra
            .row_mut(i)
            .copy_from_slice(bucket_codes.row(fit_assign[i] as usize));
    }
    let fit_pw_codes = append_positions(fit_codes, &fit_extra);
    let pairwise = PairwiseDecoder::train(fit_x, &fit_pw_codes, k, n_pairs);
    Stage2Fit { pairwise, bucket_codes }
}

/// Derive the stage-2 extended code table and norm cache for a set of
/// rows (`row_codes` + `row_buckets`, parallel) from a fitted
/// [`Stage2Fit`]. Per-row and order-preserving, so a shard's tables are
/// exactly the corresponding rows of the global tables.
fn stage2_tables(
    fit: &Stage2Fit,
    row_codes: &Codes,
    row_buckets: &[u32],
    m_tilde: usize,
) -> (Codes, Vec<f32>) {
    let n_rows = row_codes.n;
    let mut extra = Codes::zeros(n_rows, m_tilde);
    for i in 0..n_rows {
        extra
            .row_mut(i)
            .copy_from_slice(fit.bucket_codes.row(row_buckets[i] as usize));
    }
    let pw_codes = append_positions(row_codes, &extra);
    let norms = fit.pairwise.norms(&pw_codes);
    (pw_codes, norms)
}

impl SearchIndex {
    /// Encode the database and fit all the lookup decoders.
    /// `params` must be a model trained on IVF residuals of this flavor.
    pub fn build(
        engine: &mut Engine,
        codec: &Codec,
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> Result<SearchIndex> {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let (codes, _, _) = codec.encode(engine, &params, &residuals)?;

        // ---- fit split: the lookup decoders are estimated on *training*
        // vectors + their codes (paper Sec. 3.3), never on the database,
        // so their accuracy generalizes like the paper's ----
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let (fit_codes, _, _) = codec.encode(engine, &params, &fit_res)?;

        Ok(Self::assemble(params, ivf, codes, &residuals, &fit_x, &fit_assign, &fit_codes, cfg))
    }

    /// Build an index with the pure-Rust reference encoder (greedy A=K,
    /// B=1) — no PJRT runtime or HLO artifacts required. Slower to build
    /// and slightly less accurate than the beam-search XLA encoder, but
    /// runs anywhere; the artifact-free tests (`batch_equivalence`,
    /// `scorer_conformance`, `coordinator_props`) and the
    /// `bench_batch_qps` bench use it.
    pub fn build_reference(
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let codes = reference::encode_greedy(&params, &residuals);
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let fit_codes = reference::encode_greedy(&params, &fit_res);
        Self::assemble(params, ivf, codes, &residuals, &fit_x, &fit_assign, &fit_codes, cfg)
    }

    /// Assemble an index from pre-computed codes: instantiate the
    /// pipeline stages selected by `cfg.pipeline`, fit their lookup
    /// structures and per-vector caches, then partition the per-bucket
    /// state into `cfg.shards` bucket-owned [`IndexShard`](super::shard::IndexShard)s (applying
    /// any [`BuildCfg::shard_pipelines`] overrides). Engine-free — the
    /// codes may come from [`Codec::encode`] (the XLA path, see
    /// [`Self::build`]) or from the pure-Rust reference encoder, which
    /// is how the property tests and artifact-free benches construct
    /// real indexes without a PJRT runtime.
    ///
    /// `codes` are the database residual codes (row i ↔ `ivf.assign[i]`),
    /// `residuals` the residual vectors themselves (needed when stage 1
    /// trains its own quantizer); `fit_x` / `fit_assign` / `fit_codes`
    /// are the decoder-fit split: raw training vectors, their IVF
    /// buckets, and the codes of their residuals.
    ///
    /// Panics when `cfg.shards` is outside `1..=k_ivf`, when a
    /// `shard_pipelines` entry names a shard out of range, or when an
    /// override's stage-3 kind differs from the shared one (stage 3 is
    /// global — the QINCo2 codes are uniform across shards). The CLI
    /// validates `--shards` before reaching here.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        params: ParamStore,
        mut ivf: Ivf,
        codes: Codes,
        residuals: &Matrix,
        fit_x: &Matrix,
        fit_assign: &[u32],
        fit_codes: &Codes,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        assert_eq!(ivf.assign.len(), codes.n, "codes must cover the database");
        assert_eq!(residuals.rows, codes.n, "residuals must cover the database");
        assert_eq!(fit_x.rows, fit_codes.n, "fit split size mismatch");
        assert_eq!(fit_x.rows, fit_assign.len(), "fit split size mismatch");
        let k = params.cfg.k;
        // packed4 eligibility is checked before any table is built —
        // every scanned stage-1 family (shared + overrides) must
        // nibble-pack, or the build dies here naming the family
        if cfg.scan_layout == ScanLayout::Packed4 {
            if let Err(e) = packed4_support(&cfg.pipeline.stage1, k) {
                panic!("{e}");
            }
            for (s, pcfg) in &cfg.shard_pipelines {
                if let Err(e) = packed4_support(&pcfg.stage1, k) {
                    panic!("shard {s} pipeline override: {e}");
                }
            }
        }
        // the per-row bucket assignment moves into the snapshot (like the
        // inverted lists below) so ingest can extend it per epoch
        let assign = std::mem::take(&mut ivf.assign);

        // ---- stage 1: fit the configured scorer on the fit split and
        // produce the code table it scans ----
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let (stage1, stage1_side_codes) =
            build_stage1(&cfg.pipeline.stage1, &fit_res, fit_codes, residuals, k, cfg.seed);
        // cached term_i = ||x̂_r||² + 2⟨cent, x̂_r⟩ from the stage-1 decode
        let stage1_terms = stage1_terms_of(
            stage1.as_ref(),
            stage1_side_codes.as_ref().unwrap_or(&codes),
            &ivf.centroids,
            &assign,
        );

        // ---- stage 2: pairwise decoder over extended positions, fit
        // ONCE and shared by the global spec and every override shard
        // that enables stage 2 (the fit is row-independent) ----
        let need_stage2 =
            cfg.pipeline.stage2 || cfg.shard_pipelines.iter().any(|(_, p)| p.stage2);
        let s2_fit = need_stage2.then(|| {
            fit_stage2(
                &ivf,
                fit_x,
                fit_assign,
                fit_codes,
                cfg.m_tilde,
                cfg.n_pairs_train,
                k,
                cfg.seed,
            )
        });
        let (stage2_scorer, stage2_codes, stage2_norms, pairwise_trace): (
            Option<Box<dyn ApproxScorer>>,
            Codes,
            Vec<f32>,
            Vec<(usize, usize, f64)>,
        ) = if cfg.pipeline.stage2 {
            let fit = s2_fit.as_ref().expect("stage-2 fit exists when the shared spec needs it");
            let (pw_codes, norms) = stage2_tables(fit, &codes, &assign, cfg.m_tilde);
            let trace = fit.pairwise.trace();
            (Some(Box::new(fit.pairwise.clone())), pw_codes, norms, trace)
        } else {
            // the fit may still exist (override-only stage 2) — surface
            // its trace so Table S3 consumers see the pairs that were fit
            let trace = s2_fit.as_ref().map(|f| f.pairwise.trace()).unwrap_or_default();
            (None, Codes::zeros(0, 0), Vec::new(), trace)
        };

        // ---- stage 3: the index-held decoder is infallible and
        // thread-shared — the scalar oracle for Reference, the native
        // nn-kernel RustDecoder for Rust and Runtime (engines are
        // thread-confined, so Runtime's per-worker decoders arrive at
        // serve time via DecoderFactory); Disabled keeps the oracle
        // around (the batched engine still compiles against it) but
        // never invokes it.
        let params = Arc::new(params);
        let stage3: Box<dyn StageDecoder + Send + Sync> = match cfg.pipeline.stage3 {
            Stage3Kind::Rust | Stage3Kind::Runtime => {
                Box::new(RustDecoder { params: params.clone() })
            }
            Stage3Kind::Reference | Stage3Kind::Disabled => {
                Box::new(ReferenceDecoder { params: params.clone() })
            }
        };
        let stage3_enabled = cfg.pipeline.stage3 != Stage3Kind::Disabled;

        // ---- partition the per-bucket state into bucket-owned shards:
        // the coarse quantizer keeps centroids/HNSW/assign, its inverted
        // lists move into the shards ----
        let lists = std::mem::take(&mut ivf.lists);
        let mut shards = ShardSet::partition(
            lists,
            codes,
            stage1_side_codes,
            stage1_terms,
            stage2_codes,
            stage2_norms,
            cfg.shards,
            assign,
        );

        // ---- heterogeneous overrides: named shards get their own
        // stage-1/2 scorers + tables, fit with the same seeds as a
        // homogeneous build of that kind would use ----
        for (s, pcfg) in &cfg.shard_pipelines {
            assert!(
                *s < shards.n_shards(),
                "shard_pipelines names shard {s} but the index has {} shards",
                shards.n_shards()
            );
            assert_eq!(
                pcfg.stage3, cfg.pipeline.stage3,
                "per-shard stage-3 overrides are not supported: stage 3 is \
                 global (the QINCo2 codes are uniform across shards)"
            );
            let sh = &shards.shards[*s];
            let rows: Vec<usize> = sh.global_ids.iter().map(|&g| g as usize).collect();
            let sh_res = residuals.gather_rows(&rows);
            let row_buckets: Vec<u32> = rows.iter().map(|&g| shards.assign[g]).collect();
            let (o_stage1, o_side) =
                build_stage1(&pcfg.stage1, &fit_res, fit_codes, &sh_res, k, cfg.seed);
            let o_terms = stage1_terms_of(
                o_stage1.as_ref(),
                o_side.as_ref().unwrap_or(&sh.codes),
                &ivf.centroids,
                &row_buckets,
            );
            // stage 2 for the override reuses the single fit — only the
            // per-row tables are derived for this shard's rows
            let (o_s2_scorer, o_s2_codes, o_s2_norms): (
                Option<Box<dyn ApproxScorer>>,
                Codes,
                Vec<f32>,
            ) = if pcfg.stage2 {
                let fit =
                    s2_fit.as_ref().expect("stage-2 fit exists when any override needs it");
                let (pw_codes, norms) = stage2_tables(fit, &sh.codes, &row_buckets, cfg.m_tilde);
                (Some(Box::new(fit.pairwise.clone())), pw_codes, norms)
            } else {
                (None, Codes::zeros(0, 0), Vec::new())
            };
            // the override's stage-3 slot exists only because a
            // PipelineSpec is a complete three-stage pipeline; execution
            // always decodes through the index-level stage 3 (asserted
            // equal above), never through this box
            let o_stage3: Box<dyn StageDecoder + Send + Sync> = match pcfg.stage3 {
                Stage3Kind::Rust | Stage3Kind::Runtime => {
                    Box::new(RustDecoder { params: params.clone() })
                }
                Stage3Kind::Reference | Stage3Kind::Disabled => {
                    Box::new(ReferenceDecoder { params: params.clone() })
                }
            };
            let o_spec =
                PipelineSpec { stage1: o_stage1, stage2: o_s2_scorer, stage3: o_stage3 };
            shards.install_override(*s, o_spec, o_side, o_terms, o_s2_codes, o_s2_norms);
        }

        // ---- packed4 layout: nibble-pack every shard's stage-1 scan
        // table. Runs after the override installs (which replace scan
        // tables and reset their packed mirrors); the families were
        // validated up front, so every codeword fits a nibble ----
        if cfg.scan_layout == ScanLayout::Packed4 {
            shards.build_packed_tables();
        }

        SearchIndex {
            ivf,
            params,
            pipeline: PipelineSpec { stage1, stage2: stage2_scorer, stage3 },
            shards: RwLock::new(Arc::new(shards)),
            writer: Mutex::new(()),
            stage2_fit: s2_fit,
            m_tilde: cfg.m_tilde,
            stage3_enabled,
            pairwise_trace,
            default_batch_threads: if cfg.batch_threads == 0 {
                crate::util::pool::default_threads()
            } else {
                cfg.batch_threads
            },
        }
    }

    /// Pin the current epoch snapshot. Every reader path (per-query
    /// search, the batched engine, server stats) works entirely against
    /// one pinned `Arc<ShardSet>`, so concurrent writers can never
    /// expose a partial update to it — they publish whole replacement
    /// snapshots instead.
    ///
    /// Poison-recovering: the guarded value is just an `Arc` that is
    /// swapped atomically at publish time, so even a writer thread that
    /// panicked mid-mutation left it pointing at the last *complete*
    /// snapshot — readers must keep serving while a supervisor respawns
    /// the writer (see the server failure model).
    pub fn snapshot(&self) -> Arc<ShardSet> {
        self.shards.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Current publication epoch (0 for a fresh build; +1 per
    /// insert/delete/compaction publish).
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Total id space ever allocated: live + tombstoned + reclaimed
    /// rows. Result ids are always `< db_len()`. (Formerly the `db_len`
    /// field of the immutable index.)
    pub fn db_len(&self) -> usize {
        self.snapshot().id_space()
    }

    /// Number of live (searchable) rows.
    pub fn live_len(&self) -> usize {
        self.snapshot().live_len()
    }

    /// Resolve the effective intra-batch thread count for one batched
    /// execute: `sp.batch_threads`, or the index default when `0`.
    pub fn batch_threads(&self, sp: &SearchParams) -> usize {
        let t = if sp.batch_threads == 0 { self.default_batch_threads } else { sp.batch_threads };
        t.max(1)
    }

    /// Number of QINCo2 code positions per database vector (M).
    #[inline]
    pub fn code_positions(&self) -> usize {
        self.params.cfg.m
    }

    /// Full pipeline search for one query. Returns ranked (score, id) —
    /// exact squared distances when stage 3 ran, approximate scores
    /// (missing the constant ||q||²) otherwise. Probed buckets are read
    /// from their owning shards; results are bit-identical for every
    /// shard count. The epoch snapshot is pinned once at entry, so a
    /// query sees one consistent index state even under concurrent
    /// writes.
    ///
    /// Panics if the index-held stage-3 decoder fails; the built-in
    /// decoders are infallible (fallible runtime decoders belong to
    /// server workers, which handle errors by falling back).
    pub fn search(&self, q: &[f32], sp: &SearchParams) -> Vec<(f32, u32)> {
        let set = self.snapshot();
        self.search_in(&set, q, sp)
    }

    /// [`Self::search`] against an explicitly pinned snapshot — the
    /// epoch-stable entry point (used by the batched engine's chunks so
    /// one batch never spans epochs).
    pub fn search_in(&self, set: &ShardSet, q: &[f32], sp: &SearchParams) -> Vec<(f32, u32)> {
        // ---- stage 0: coarse probe ----
        let probes = self.ivf.probe(q, sp.nprobe, sp.ef_search);
        // ---- stage 1: LUT scan over the probed lists, shard-routed.
        // One LUT per slot: all shards on the shared spec reuse slot 0,
        // override shards build their own (lazily — only if probed) ----
        let mut luts: Vec<Option<Vec<f32>>> = vec![None; set.n_lut_slots];
        // local scan tallies, flushed once per shard after the loop —
        // no per-probe atomic RMW on the (contended) shard counters
        let mut scanned = vec![0u64; set.n_shards()];
        let mut shortlist = Shortlist::new(sp.n_aq);
        for &(probe_d, bucket) in &probes {
            let si = set.shard_of[bucket as usize] as usize;
            let sh = &set.shards[si];
            let scorer = sh.spec(&self.pipeline).stage1.as_ref();
            let lut = luts[set.lut_slot[si] as usize].get_or_insert_with(|| scorer.lut(q));
            let s1_codes = sh.stage1_codes();
            let list = sh.list(bucket);
            let any_dead = sh.n_dead > 0;
            for &local in list {
                let i = local as usize;
                if any_dead && sh.tombstones[i] {
                    continue;
                }
                scanned[si] += 1;
                let s = probe_d + scorer.score(lut, s1_codes.row(i), sh.stage1_terms[i]);
                shortlist.push(s, sh.global_ids[i]);
            }
        }
        for (sh, &n) in set.shards.iter().zip(&scanned) {
            if n > 0 {
                sh.scanned.fetch_add(n, Ordering::Relaxed);
            }
        }
        // ---- stage 2: approximate re-scoring ----
        let stage2 = self.stage2_rescore(set, q, shortlist.into_sorted(), sp);
        // ---- stage 3: exact decode re-rank ----
        if sp.n_final == 0 || stage2.is_empty() {
            return stage2;
        }
        if !self.stage3_enabled {
            let mut out = stage2;
            out.truncate(sp.n_final);
            return out;
        }
        let ids: Vec<u32> = stage2.iter().map(|&(_, id)| id).collect();
        let dec = self
            .pipeline
            .stage3
            .decode(&set.gather_stage3_codes(&ids))
            .expect("index-held stage-3 decoder failed");
        let rows: Vec<usize> = (0..ids.len()).collect();
        self.exact_rerank(set, q, &stage2, &dec, &rows, sp.n_final)
    }

    /// Stage 2: re-score a stage-1 shortlist with each candidate's
    /// owning-shard stage-2 scorer and keep the best `sp.n_pairs`.
    /// Chooses between a per-query joint LUT and direct dots via the
    /// scorer's [`ApproxScorer::use_lut`] cost model. Shared by the
    /// per-query and batched paths (identical float rounding). With no
    /// effective stage 2 anywhere, forwards the shortlist as-is; with
    /// heterogeneous shards, a shard without stage 2 forwards its
    /// candidates' stage-1 scores into the merged shortlist.
    pub(crate) fn stage2_rescore(
        &self,
        set: &ShardSet,
        q: &[f32],
        stage1: Vec<(f32, u32)>,
        sp: &SearchParams,
    ) -> Vec<(f32, u32)> {
        if sp.n_pairs == 0 || stage1.is_empty() {
            return stage1;
        }
        if !set.heterogeneous() {
            // homogeneous fast path: one scorer, one LUT-vs-direct
            // choice for the whole shortlist (the historical behavior)
            let Some(scorer) = self.pipeline.stage2.as_deref() else {
                return stage1;
            };
            let mut keep = Shortlist::new(sp.n_pairs);
            if scorer.use_lut(stage1.len(), q.len()) {
                let lut = scorer.lut(q);
                for &(_, id) in &stage1 {
                    let (sh, i) = set.locate(id);
                    let s = scorer.score(&lut, sh.stage2_codes.row(i), sh.stage2_norms[i]);
                    keep.push(s, id);
                }
            } else {
                for &(_, id) in &stage1 {
                    let (sh, i) = set.locate(id);
                    let s = scorer.score_direct(q, sh.stage2_codes.row(i), sh.stage2_norms[i]);
                    keep.push(s, id);
                }
            }
            return keep.into_sorted();
        }
        // heterogeneous: score each candidate through its owning shard's
        // spec, with per-slot LUTs. The LUT-vs-direct cost model is
        // consulted with the FULL shortlist size, not the slot's share:
        // LUT and direct scores agree only to float tolerance, so using
        // per-slot counts would let the partition flip the choice and
        // break the contract that a full per-shard override is
        // bit-identical to the homogeneous pipeline of that kind (pinned
        // by `full_override_matches_the_homogeneous_pipeline`).
        if !set.shards.iter().any(|sh| sh.spec(&self.pipeline).stage2.is_some()) {
            return stage1;
        }
        let mut luts: Vec<Option<Vec<f32>>> = vec![None; set.n_lut_slots];
        // the use_lut inputs are loop-invariant per slot: decide once
        let mut slot_use_lut: Vec<Option<bool>> = vec![None; set.n_lut_slots];
        let mut keep = Shortlist::new(sp.n_pairs);
        for &(s1_score, id) in &stage1 {
            let si = set.owner_of[id as usize] as usize;
            let sh = &set.shards[si];
            let Some(scorer) = sh.spec(&self.pipeline).stage2.as_deref() else {
                // this shard runs stage-2-less: its stage-1 score stands
                keep.push(s1_score, id);
                continue;
            };
            let slot = set.lut_slot[si] as usize;
            let i = set.local_of[id as usize] as usize;
            let use_lut = *slot_use_lut[slot]
                .get_or_insert_with(|| scorer.use_lut(stage1.len(), q.len()));
            let s = if use_lut {
                let lut = luts[slot].get_or_insert_with(|| scorer.lut(q));
                scorer.score(lut, sh.stage2_codes.row(i), sh.stage2_norms[i])
            } else {
                scorer.score_direct(q, sh.stage2_codes.row(i), sh.stage2_norms[i])
            };
            keep.push(s, id);
        }
        keep.into_sorted()
    }

    /// Stage 3: exact distances for survivors whose decodes sit in `dec`
    /// (survivor j ↔ `dec.row(rows[j])`), ranked and truncated. Shared by
    /// the per-query and batched paths.
    pub(crate) fn exact_rerank(
        &self,
        set: &ShardSet,
        q: &[f32],
        survivors: &[(f32, u32)],
        dec: &Matrix,
        rows: &[usize],
        n_final: usize,
    ) -> Vec<(f32, u32)> {
        debug_assert_eq!(survivors.len(), rows.len());
        let mut exact: Vec<(f32, u32)> = survivors
            .iter()
            .zip(rows)
            .map(|(&(_, id), &row)| (self.exact_distance(set, q, id as usize, dec.row(row)), id))
            .collect();
        exact.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        exact.truncate(n_final);
        exact
    }

    /// Exact ||q − (cent_i + decode_i)||² given the decoded residual row.
    pub(crate) fn exact_distance(&self, set: &ShardSet, q: &[f32], i: usize, dec_row: &[f32]) -> f32 {
        let cent = self.ivf.centroids.row(set.assign[i] as usize);
        let mut d = 0.0f32;
        for j in 0..q.len() {
            let rec = cent[j] + dec_row[j];
            let diff = q[j] - rec;
            d += diff * diff;
        }
        d
    }

    /// Search many queries; returns ranked (score, id) lists — the same
    /// shape per query as [`Self::search`], so batched and per-query
    /// callers handle one result type. Runs the batched engine over
    /// per-thread chunks of the query set — result-identical to calling
    /// [`Self::search`] per row. With `sp.batch_threads > 1` each chunk
    /// additionally splits its shard-group scan across that many
    /// threads (the outer chunk count shrinks so total thread use stays
    /// near the core count). A failing stage-3 decoder surfaces as an
    /// `Err` instead of panicking inside the engine.
    pub fn search_batch(
        &self,
        queries: &Matrix,
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        self.search_batch_within(queries, sp, crate::util::deadline::Deadline::none())
            .map(|(results, _)| results)
    }

    /// [`Self::search_batch`] under a deadline: every per-thread chunk
    /// threads the deadline into the engine
    /// ([`BatchSearcher::execute_within`](super::batch::BatchSearcher::execute_within)),
    /// so an expiring deadline degrades the whole call to the stage-1/2
    /// shortlist ranking instead of running long. Returns the ranked
    /// lists plus whether **any** chunk degraded — the CLI's
    /// `--deadline-ms` lands here. With [`Deadline::none()`]
    /// (how `search_batch` calls it) the flag is always `false` and
    /// results are bit-identical to the historical path.
    pub fn search_batch_within(
        &self,
        queries: &Matrix,
        sp: &SearchParams,
        deadline: crate::util::deadline::Deadline,
    ) -> Result<(Vec<Vec<(f32, u32)>>, bool)> {
        let n = queries.rows;
        if n == 0 {
            return Ok((Vec::new(), false));
        }
        let inner = self.batch_threads(sp);
        let nthreads = (crate::util::pool::default_threads() / inner).max(1);
        let chunk = n.div_ceil(nthreads);
        let nchunks = n.div_ceil(chunk);
        // pin ONE snapshot for the whole batch: every chunk searches the
        // same epoch, even if a writer publishes mid-call
        let set = self.snapshot();
        let mut per_chunk: Vec<Result<super::batch::BatchOutput>> = (0..nchunks)
            .map(|_| Ok(super::batch::BatchOutput { results: Vec::new(), degraded: false }))
            .collect();
        crate::util::pool::par_map_into(&mut per_chunk, nchunks, |ci, slot| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            let searcher = super::batch::BatchSearcher::with_snapshot(self, set.clone());
            let plans: Vec<super::batch::QueryPlan> =
                (lo..hi).map(|i| searcher.plan(queries.row(i), sp)).collect();
            *slot = searcher.execute_within(&plans, sp, None, deadline);
        });
        let mut out = Vec::with_capacity(n);
        let mut degraded = false;
        for chunk_res in per_chunk {
            let o = chunk_res?;
            degraded |= o.degraded;
            out.extend(o.results);
        }
        Ok((out, degraded))
    }

    /// Bytes per database vector (codes + the per-vector f32 caches),
    /// for the bitrate accounting in EXPERIMENTS.md. Accounted at the
    /// shared configuration — read off the first shard *without* a
    /// pipeline override; if every shard is overridden, shard 0's
    /// (override) layout is reported instead.
    pub fn bytes_per_vector(&self) -> f64 {
        let bits_per_code = usize::BITS - (self.params.cfg.k - 1).leading_zeros();
        let set = self.snapshot();
        let sh = set
            .shards
            .iter()
            .find(|sh| sh.pipeline.is_none())
            .unwrap_or(&set.shards[0]);
        // QINCo2 codes + the stage-1 term cache (f32)
        let mut bytes = (sh.codes.m * bits_per_code as usize) as f64 / 8.0 + 4.0;
        // a PQ/OPQ/LSQ/RQ stage 1 scans its own side table
        if let Some(side) = &sh.stage1_side_codes {
            bytes += (side.m * bits_per_code as usize) as f64 / 8.0;
        }
        // stage-2 norm cache (f32)
        if sh.spec(&self.pipeline).stage2.is_some() {
            bytes += 4.0;
        }
        bytes
    }

    // ------------------------- live mutation -------------------------
    //
    // All three write paths follow the same protocol: serialize on
    // `writer`, pin the current snapshot, prepare every piece of derived
    // state (codes, side tables, terms, stage-2 rows, routing) away from
    // any published structure, then swap in a fully consistent
    // replacement snapshot with the epoch bumped. Readers pinned on the
    // old snapshot keep it alive through its `Arc` and never observe a
    // partial write.

    /// Ingest new vectors under live traffic: assign each to its IVF
    /// bucket, encode its residual with the paper's codeword
    /// pre-selection + beam search ([`reference::encode_beam`]), derive
    /// the owning shard's stage-1/2 rows, append, and publish a new
    /// epoch. Returns the freshly allocated global ids (dense,
    /// ascending, in input order).
    ///
    /// With the default [`EncodeParams`] (`a = K, b = 1` — greedy),
    /// search after any insert/delete/compact sequence is bit-identical
    /// to a fresh greedy build over the same surviving vectors (pinned
    /// by `tests/mutation_invariants.rs`; LSQ stage-1 pipelines are
    /// excluded — their ICM encoder is batch-layout dependent).
    pub fn insert(&self, vectors: &Matrix, ep: &EncodeParams) -> Result<Vec<u32>> {
        let d = self.params.cfg.d;
        let k = self.params.cfg.k;
        if vectors.cols != d {
            bail!("insert vectors have dimension {}, the index expects {d}", vectors.cols);
        }
        let a = if ep.a == 0 { k } else { ep.a };
        let b = if ep.b == 0 { 1 } else { ep.b };
        if !(1 <= b && b <= a && a <= k) {
            bail!("encode params must satisfy 1 <= b <= a <= K={k} (got a={a}, b={b})");
        }
        if vectors.rows == 0 {
            return Ok(Vec::new());
        }
        let _w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.snapshot();

        // ---- encode everything before touching any routing state ----
        // per-row nearest centroid (== batch assign_all, pinned by
        // ivf::tests::assignment_is_nearest_centroid) and residual
        let mut buckets = Vec::with_capacity(vectors.rows);
        let mut residuals = vectors.clone();
        for i in 0..vectors.rows {
            let (bkt, _) = tensor::argmin_l2(vectors.row(i), &self.ivf.centroids);
            buckets.push(bkt as u32);
            let crow = self.ivf.centroids.row(bkt).to_vec();
            tensor::sub_assign(residuals.row_mut(i), &crow);
        }
        let codes = reference::encode_beam(&self.params, &residuals, a, b);
        let base = cur.id_space() as u32;
        let gids: Vec<u32> = (0..vectors.rows as u32).map(|i| base + i).collect();

        // group rows per destination shard preserving input order, so
        // within-bucket inverted lists stay ascending-gid (the layout
        // property the mutation bit-identity argument needs)
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); cur.n_shards()];
        for (i, &bkt) in buckets.iter().enumerate() {
            by_shard[cur.shard_of[bkt as usize] as usize].push(i);
        }

        let mut next = cur.cow_clone();
        next.owner_of.extend(std::iter::repeat(0).take(vectors.rows));
        next.local_of.extend(std::iter::repeat(0).take(vectors.rows));
        next.assign.extend_from_slice(&buckets);
        for (si, rows) in by_shard.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let sh = &cur.shards[si];
            let spec = sh.spec(&self.pipeline);
            let row_codes = gather_codes(&codes, rows);
            let row_buckets: Vec<u32> = rows.iter().map(|&i| buckets[i]).collect();
            // stage-1 side table rows, iff this shard scans one
            let side = if sh.stage1_side_codes.is_some() {
                let rows_res = residuals.gather_rows(rows);
                match spec.stage1.encode_rows(&rows_res) {
                    Some(c) => Some(c),
                    None => bail!(
                        "shard {si} scans a stage-1 side table but its scorer \
                         cannot encode new rows; this pipeline does not support ingest"
                    ),
                }
            } else {
                None
            };
            let scan_codes = side.as_ref().unwrap_or(&row_codes);
            let terms = stage1_terms_of(
                spec.stage1.as_ref(),
                scan_codes,
                &self.ivf.centroids,
                &row_buckets,
            );
            // stage-2 extension rows, iff this shard scores a stage 2
            let has_s2 = sh.stage2_codes.m > 0;
            let (s2_codes, s2_norms) = if has_s2 {
                let fit = self
                    .stage2_fit
                    .as_ref()
                    .expect("stage-2 fit is retained whenever any shard enables stage 2");
                stage2_tables(fit, &row_codes, &row_buckets, self.m_tilde)
            } else {
                (Codes::zeros(0, 0), Vec::new())
            };
            let payloads: Vec<RowPayload> = rows
                .iter()
                .enumerate()
                .map(|(o, &i)| RowPayload {
                    gid: gids[i],
                    bucket: buckets[i],
                    code: row_codes.row(o).to_vec(),
                    side_code: side.as_ref().map(|c| c.row(o).to_vec()),
                    term: terms[o],
                    stage2_code: if has_s2 { s2_codes.row(o).to_vec() } else { Vec::new() },
                    stage2_norm: if has_s2 { s2_norms[o] } else { 0.0 },
                })
                .collect();
            for (o, &i) in rows.iter().enumerate() {
                next.owner_of[gids[i] as usize] = si as u32;
                next.local_of[gids[i] as usize] = (sh.len() + o) as u32;
            }
            next.shards[si] = Arc::new(sh.with_rows_appended(&payloads));
        }
        // publish the new epoch atomically
        *self.shards.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(next);
        Ok(gids)
    }

    /// Tombstone-delete rows by global id: the rows' tables stay in
    /// place but every scan skips them from the next epoch on (space is
    /// reclaimed by [`Self::compact`]). An out-of-range id is an error;
    /// an already-deleted (tombstoned or reclaimed) id is skipped.
    /// Returns the number of rows newly deleted — a new epoch publishes
    /// iff it is non-zero.
    pub fn delete(&self, ids: &[u32]) -> Result<usize> {
        let _w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.snapshot();
        let mut by_shard: Vec<Vec<u32>> = vec![Vec::new(); cur.n_shards()];
        for &id in ids {
            if id as usize >= cur.id_space() {
                bail!("delete id {id} out of range (the id space is {})", cur.id_space());
            }
            let local = cur.local_of[id as usize];
            if local == DEAD_LOCAL {
                continue; // reclaimed by an earlier compaction
            }
            let si = cur.owner_of[id as usize] as usize;
            if cur.shards[si].tombstones[local as usize] {
                continue; // already tombstoned
            }
            by_shard[si].push(local);
        }
        let mut next = cur.cow_clone();
        let mut newly = 0usize;
        for (si, locals) in by_shard.iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let rebuilt = cur.shards[si].with_tombstones(locals);
            newly += rebuilt.n_dead - cur.shards[si].n_dead;
            next.shards[si] = Arc::new(rebuilt);
        }
        if newly == 0 {
            return Ok(0);
        }
        *self.shards.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(next);
        Ok(newly)
    }

    /// Reclaim one shard's tombstoned rows: rewrite its local rows into
    /// the canonical bucket-major layout (exactly what a fresh
    /// [`ShardSet::partition`] over the survivors would produce) and
    /// mark the reclaimed global ids [`DEAD_LOCAL`]. Global ids are
    /// never reused. Returns the number of rows reclaimed; a new epoch
    /// publishes iff it is non-zero.
    pub fn compact_shard(&self, s: usize) -> Result<usize> {
        let _w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.snapshot();
        if s >= cur.n_shards() {
            bail!("compact_shard({s}) out of range (the index has {} shards)", cur.n_shards());
        }
        if cur.shards[s].n_dead == 0 {
            return Ok(0);
        }
        let mut next = cur.cow_clone();
        let reclaimed = Self::compact_one(&cur, &mut next, s);
        *self.shards.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(next);
        Ok(reclaimed)
    }

    /// [`Self::compact_shard`] over every shard that has tombstoned
    /// rows, in one epoch bump. Returns the total rows reclaimed.
    pub fn compact(&self) -> usize {
        let _w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.snapshot();
        if cur.shards.iter().all(|sh| sh.n_dead == 0) {
            return 0;
        }
        let mut next = cur.cow_clone();
        let mut reclaimed = 0usize;
        for s in 0..cur.n_shards() {
            if cur.shards[s].n_dead > 0 {
                reclaimed += Self::compact_one(&cur, &mut next, s);
            }
        }
        *self.shards.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(next);
        reclaimed
    }

    fn compact_one(cur: &ShardSet, next: &mut ShardSet, s: usize) -> usize {
        let old = &cur.shards[s];
        let rebuilt = old.compacted();
        for (local, &gid) in old.global_ids.iter().enumerate() {
            if old.tombstones[local] {
                next.local_of[gid as usize] = DEAD_LOCAL;
            }
        }
        for (local, &gid) in rebuilt.global_ids.iter().enumerate() {
            next.local_of[gid as usize] = local as u32;
        }
        next.shards[s] = Arc::new(rebuilt);
        old.n_dead
    }
}

/// Gather code rows by index.
pub fn gather_codes(codes: &Codes, idx: &[usize]) -> Codes {
    let mut out = Codes::zeros(idx.len(), codes.m);
    for (o, &i) in idx.iter().enumerate() {
        out.row_mut(o).copy_from_slice(codes.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage3_flag_names_resolve_to_their_own_kinds() {
        // regression: "runtime" used to silently alias Reference, so a
        // `--stage3 runtime` index decoded through the wrong path
        for (name, want) in [
            ("reference", Stage3Kind::Reference),
            ("rust", Stage3Kind::Rust),
            ("runtime", Stage3Kind::Runtime),
            ("none", Stage3Kind::Disabled),
            ("disabled", Stage3Kind::Disabled),
        ] {
            let cfg = PipelineConfig::from_flags("aq", 0, true, name).unwrap();
            assert_eq!(cfg.stage3, want, "--stage3 {name}");
        }
    }

    #[test]
    fn unknown_stage3_name_is_a_hard_error_naming_the_flag() {
        let err = PipelineConfig::from_flags("aq", 0, true, "xla").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--stage3"), "error should name the flag: {msg}");
        assert!(msg.contains("\"xla\""), "error should name the bad value: {msg}");
        assert!(msg.contains("reference|rust|runtime|none"), "error should list options: {msg}");
    }

    #[test]
    fn packed4_accepts_the_nibble_sized_additive_families() {
        assert!(packed4_support(&Stage1Kind::Pq { m: 4 }, 16).is_ok());
        assert!(packed4_support(&Stage1Kind::Rq { m: 3 }, 8).is_ok());
    }

    #[test]
    fn packed4_rejects_incompatible_families_naming_them() {
        // never a silent fallback: each excluded family errs by name
        for (kind, family) in [
            (Stage1Kind::Aq, "aq"),
            (Stage1Kind::Opq { m: 4, iters: 4 }, "opq"),
            (Stage1Kind::Lsq { m: 4 }, "lsq"),
        ] {
            let msg = packed4_support(&kind, 8).unwrap_err().to_string();
            assert!(msg.contains("packed4"), "error should name the layout: {msg}");
            assert!(
                msg.contains(&format!("\"{family}\"")),
                "error should name the family: {msg}"
            );
        }
    }

    #[test]
    fn packed4_rejects_codewords_wider_than_a_nibble() {
        let msg = packed4_support(&Stage1Kind::Pq { m: 4 }, 32).unwrap_err().to_string();
        assert!(msg.contains("K=32"), "error should report the model's K: {msg}");
        assert!(msg.contains("\"pq\""), "error should name the family: {msg}");
    }
}
