//! The Fig. 3 search pipeline, generic over pluggable stage traits.
//!
//! # Three stages, two traits
//!
//! Retrieval is staged exactly as the paper draws it: HNSW coarse probe →
//! approximate LUT scan → re-scoring → exact decode of the survivors.
//! Each stage is a trait object, assembled into a [`PipelineSpec`]:
//!
//! * **stage 1** — `Box<dyn ApproxScorer>` scanning [`SearchIndex::stage1_codes`]
//!   with the cached additive terms [`SearchIndex::stage1_terms`]. The
//!   default is the unitary [`AdditiveDecoder`] re-fit on the QINCo2
//!   codes; [`PqScorer`]/[`OpqScorer`] swap in a product quantizer with
//!   its *own* code table over the same IVF residuals.
//! * **stage 2** — `Option<Box<dyn ApproxScorer>>` re-scoring the stage-1
//!   shortlist over the extended code table ([`SearchIndex::stage2_codes`]).
//!   The default is the paper's [`PairwiseDecoder`] (Sec. 3.3, Eqs. 8-9);
//!   `None` forwards the stage-1 shortlist unchanged.
//! * **stage 3** — `Box<dyn StageDecoder>`: one batch decode of the
//!   surviving codes, then exact distances. The default is the pure-Rust
//!   [`ReferenceDecoder`]; [`crate::qinco::RuntimeDecoder`] routes the
//!   same call through one padded XLA dispatch per batch. With
//!   [`Stage3Kind::Disabled`] ("pairwise-only fast mode") the stage-2
//!   ranking is returned directly, truncated to `n_final`.
//!
//! # Distance algebra (per stage)
//!
//! ```text
//! stage 1: ||q - cent_b - x̂_r||² = probe_dist + term_i − 2⟨q, x̂_r⟩
//!          with term_i = ||x̂_r||² + 2⟨cent_b, x̂_r⟩ cached per vector —
//!          the trait score contract's additive-offset linearity is what
//!          lets the coarse term fold in for free.
//! stage 2: ||x̂_pw||² − 2⟨q, x̂_pw⟩ (the pairwise decoder targets raw x,
//!          so scores are comparable across buckets)
//! stage 3: exact ||q - (cent + decode(I¹..I^M))||²
//! ```
//!
//! # Plugging in a custom scorer or decoder
//!
//! Implement [`ApproxScorer`] (score contract: `score(lut, code, t) =
//! t − 2⟨q, decode(code)⟩`, ranked under the total `(score, id)` order of
//! [`Shortlist`]) and build the index through [`SearchIndex::assemble`]
//! with a [`PipelineConfig`], or construct a [`PipelineSpec`] directly.
//! Custom stage-3 decoders implement [`StageDecoder`]; decoders that own
//! a per-thread engine (PJRT clients are `Rc`-based, not `Send`) are
//! handed to server workers through a
//! [`DecoderFactory`](crate::quantizers::DecoderFactory) — each worker
//! calls `make()` once at startup (engine-per-worker) and passes the
//! resulting decoder to [`super::batch::BatchSearcher::execute_with_decoder`].
//!
//! # Execution paths
//!
//! * [`SearchIndex::search`] — one query at a time.
//! * [`super::batch::BatchSearcher`] — the batched engine: per-batch
//!   flat LUT packs, bucket-grouped inverted-list scans (each co-probed
//!   list is read once per batch, each code row scored against a block
//!   of co-probed queries via [`ApproxScorer::score_block`], bucket
//!   groups optionally split across [`SearchParams::batch_threads`]
//!   threads), and a single union decode for stage 3. Result-identical
//!   to `search` for *every* pipeline configuration and thread count —
//!   both paths share the crate-private `stage2_rescore` /
//!   `exact_rerank` helpers, the [`ApproxScorer::use_lut`] cost model,
//!   and the total (score, id) shortlist order of [`Shortlist`] (pinned
//!   by `batch_equivalence.rs` across all configurations).

use super::ivf::Ivf;
use crate::qinco::{reference, Codec, ParamStore, ReferenceDecoder};
use crate::quantizers::aq_lut::AdditiveDecoder;
use crate::quantizers::lsq::{Lsq, LsqScorer};
use crate::quantizers::opq::{Opq, OpqScorer};
use crate::quantizers::pairwise::{append_positions, PairwiseDecoder};
use crate::quantizers::pq::{Pq, PqScorer};
use crate::quantizers::rq::{Rq, RqScorer};
use crate::quantizers::{ApproxScorer, Codes, StageDecoder, VectorQuantizer};
use crate::runtime::Engine;
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use crate::util::topk::Shortlist;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Search-time knobs (the Fig. 6 sweep axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchParams {
    pub nprobe: usize,
    pub ef_search: usize,
    /// stage-1 shortlist size |S_AQ|
    pub n_aq: usize,
    /// stage-2 shortlist size |S_pairs| (0 disables pairwise re-ranking)
    pub n_pairs: usize,
    /// final results returned after the stage-3 re-rank (0 disables the
    /// re-rank: stage-2 order is returned in full; when the index was
    /// built with stage 3 disabled, the stage-2 order is truncated to
    /// `n_final` instead)
    pub n_final: usize,
    /// intra-batch parallelism of one batched execute: the stage-1
    /// bucket-group scan (and the per-query stage-2/3 loops) split
    /// across this many threads, with per-thread shortlists merged
    /// under the total (score, id) order — results stay bit-identical
    /// for every thread count (pinned by `batch_equivalence`).
    /// `1` = single-threaded per call (default: the serving router
    /// parallelizes across workers instead); `0` = inherit the index's
    /// [`BuildCfg::batch_threads`] default. CLI: `--batch-threads`.
    pub batch_threads: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            nprobe: 8,
            ef_search: 64,
            n_aq: 256,
            n_pairs: 32,
            n_final: 10,
            batch_threads: 1,
        }
    }
}

/// Which [`ApproxScorer`] runs the stage-1 scan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Stage1Kind {
    /// Unitary additive decoder re-fit on the QINCo2 codes (the paper's
    /// default; scans the QINCo2 code table itself).
    Aq,
    /// Product quantizer trained on the IVF residuals, scanning its own
    /// `m`-position code table (`k` follows the model's codebook size).
    Pq { m: usize },
    /// OPQ: learned rotation + PQ.
    Opq { m: usize, iters: usize },
    /// LSQ additive quantizer trained on the IVF residuals, scanning its
    /// own ICM-encoded `m`-position table (`k` follows the model).
    Lsq { m: usize },
    /// Plain residual quantizer (greedy encode), scanning its own
    /// `m`-position table — the cheapest additive baseline.
    Rq { m: usize },
}

/// Which [`StageDecoder`] the index holds for stage 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage3Kind {
    /// Pure-Rust reference QINCo2 decoder (infallible, thread-shared).
    Reference,
    /// No exact re-rank: the stage-2 ranking is final ("pairwise-only
    /// fast mode"). `n_final > 0` truncates it.
    Disabled,
}

/// Build-time pipeline selection — the configuration mirror of
/// [`PipelineSpec`]. Server workers may additionally override stage 3
/// per thread via a [`DecoderFactory`](crate::quantizers::DecoderFactory)
/// (e.g. the PJRT [`RuntimeDecoderFactory`](crate::qinco::RuntimeDecoderFactory)).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    pub stage1: Stage1Kind,
    /// fit + use the pairwise re-ranker (stage 2)
    pub stage2: bool,
    pub stage3: Stage3Kind,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { stage1: Stage1Kind::Aq, stage2: true, stage3: Stage3Kind::Reference }
    }
}

impl PipelineConfig {
    /// Parse CLI-level flags: `stage1 ∈ {aq, pq, opq, lsq, rq}`
    /// (`stage1_m` sub-quantizers/steps for everything but aq),
    /// `stage3 ∈ {reference, runtime, none}`. `"runtime"` builds a
    /// reference-decoding index — the runtime path is selected per
    /// worker thread at serve time through a `DecoderFactory`, never
    /// baked into the (thread-shared) index.
    pub fn from_flags(
        stage1: &str,
        stage1_m: usize,
        stage2: bool,
        stage3: &str,
    ) -> Result<PipelineConfig> {
        let s1 = match stage1 {
            "aq" => Stage1Kind::Aq,
            "pq" | "opq" | "lsq" | "rq" => {
                if stage1_m == 0 {
                    bail!("--stage1-m must be >= 1 for a {stage1} stage 1");
                }
                match stage1 {
                    "pq" => Stage1Kind::Pq { m: stage1_m },
                    "opq" => Stage1Kind::Opq { m: stage1_m, iters: 4 },
                    "lsq" => Stage1Kind::Lsq { m: stage1_m },
                    _ => Stage1Kind::Rq { m: stage1_m },
                }
            }
            other => bail!("unknown stage-1 scorer {other:?} (expected aq|pq|opq|lsq|rq)"),
        };
        let s3 = match stage3 {
            "reference" | "runtime" => Stage3Kind::Reference,
            "none" | "disabled" => Stage3Kind::Disabled,
            other => bail!("unknown stage-3 decoder {other:?} (expected reference|runtime|none)"),
        };
        Ok(PipelineConfig { stage1: s1, stage2, stage3: s3 })
    }
}

/// The assembled three-stage pipeline: one trait object per stage. The
/// index shares these read-only across every serving thread, so stage 1/2
/// scorers are `Send + Sync` by trait bound and the stage-3 box carries
/// the marker bounds explicitly (thread-local runtime decoders live
/// *outside* the spec, handed to workers by a `DecoderFactory`).
pub struct PipelineSpec {
    pub stage1: Box<dyn ApproxScorer>,
    pub stage2: Option<Box<dyn ApproxScorer>>,
    pub stage3: Box<dyn StageDecoder + Send + Sync>,
}

/// Build-time configuration.
#[derive(Clone, Debug)]
pub struct BuildCfg {
    pub k_ivf: usize,
    /// RQ steps used to quantize the IVF centroids for the pairwise pool
    pub m_tilde: usize,
    /// number of optimized pairs (paper default: 2M)
    pub n_pairs_train: usize,
    /// training subsample for the decoders
    pub fit_sample: usize,
    pub seed: u64,
    /// which scorer/decoder runs each stage
    pub pipeline: PipelineConfig,
    /// default intra-batch thread count for searches against this index,
    /// used when [`SearchParams::batch_threads`] is `0` (inherit).
    /// `0` here means "all cores" (`pool::default_threads`); the
    /// out-of-the-box default is `1` (single-threaded per execute).
    pub batch_threads: usize,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg {
            k_ivf: 64,
            m_tilde: 2,
            n_pairs_train: 0,
            fit_sample: 20_000,
            seed: 0x5EA2C4,
            pipeline: PipelineConfig::default(),
            batch_threads: 1,
        }
    }
}

pub struct SearchIndex {
    pub ivf: Ivf,
    /// QINCo2 codes of the database residuals [N, M] — the stage-3
    /// decode source
    pub codes: Codes,
    pub params: Arc<ParamStore>,
    /// the pluggable stage implementations
    pub pipeline: PipelineSpec,
    /// side code table scanned by the stage-1 scorer when it differs
    /// from the QINCo2 codes (PQ/OPQ stage 1); `None` means stage 1
    /// scans [`Self::codes`] directly — no duplicated table for the
    /// default AQ pipeline. Resolve with [`Self::stage1_codes`].
    pub stage1_side_codes: Option<Codes>,
    /// cached stage-1 terms: ||x̂_r||² + 2⟨cent, x̂_r⟩ per db vector
    pub stage1_terms: Vec<f32>,
    /// extended code table scored by stage 2 (empty when stage 2 is off)
    pub stage2_codes: Codes,
    /// cached ||x̂_pw||² per db vector (empty when stage 2 is off)
    pub stage2_norms: Vec<f32>,
    /// whether the exact stage-3 re-rank runs at all
    /// ([`Stage3Kind::Disabled`] turns searches into stage-2-final mode)
    pub stage3_enabled: bool,
    /// per-step MSE trace of the pairwise fit (Table S3; empty when
    /// stage 2 is off)
    pub pairwise_trace: Vec<(usize, usize, f64)>,
    /// resolved [`BuildCfg::batch_threads`] — the intra-batch thread
    /// count a search with `SearchParams::batch_threads == 0` inherits
    pub default_batch_threads: usize,
    pub db_len: usize,
}

impl SearchIndex {
    /// Encode the database and fit all the lookup decoders.
    /// `params` must be a model trained on IVF residuals of this flavor.
    pub fn build(
        engine: &mut Engine,
        codec: &Codec,
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> Result<SearchIndex> {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let (codes, _, _) = codec.encode(engine, &params, &residuals)?;

        // ---- fit split: the lookup decoders are estimated on *training*
        // vectors + their codes (paper Sec. 3.3), never on the database,
        // so their accuracy generalizes like the paper's ----
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let (fit_codes, _, _) = codec.encode(engine, &params, &fit_res)?;

        Ok(Self::assemble(params, ivf, codes, &residuals, &fit_x, &fit_assign, &fit_codes, cfg))
    }

    /// Build an index with the pure-Rust reference encoder (greedy A=K,
    /// B=1) — no PJRT runtime or HLO artifacts required. Slower to build
    /// and slightly less accurate than the beam-search XLA encoder, but
    /// runs anywhere; the artifact-free tests (`batch_equivalence`,
    /// `scorer_conformance`, `coordinator_props`) and the
    /// `bench_batch_qps` bench use it.
    pub fn build_reference(
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let codes = reference::encode_greedy(&params, &residuals);
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let fit_codes = reference::encode_greedy(&params, &fit_res);
        Self::assemble(params, ivf, codes, &residuals, &fit_x, &fit_assign, &fit_codes, cfg)
    }

    /// Assemble an index from pre-computed codes: instantiate the
    /// pipeline stages selected by `cfg.pipeline`, fit their lookup
    /// structures and per-vector caches. Engine-free — the codes may come
    /// from [`Codec::encode`] (the XLA path, see [`Self::build`]) or from
    /// the pure-Rust reference encoder, which is how the property tests
    /// and artifact-free benches construct real indexes without a PJRT
    /// runtime.
    ///
    /// `codes` are the database residual codes (row i ↔ `ivf.assign[i]`),
    /// `residuals` the residual vectors themselves (needed when stage 1
    /// trains its own quantizer); `fit_x` / `fit_assign` / `fit_codes`
    /// are the decoder-fit split: raw training vectors, their IVF
    /// buckets, and the codes of their residuals.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        params: ParamStore,
        ivf: Ivf,
        codes: Codes,
        residuals: &Matrix,
        fit_x: &Matrix,
        fit_assign: &[u32],
        fit_codes: &Codes,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        assert_eq!(ivf.assign.len(), codes.n, "codes must cover the database");
        assert_eq!(residuals.rows, codes.n, "residuals must cover the database");
        assert_eq!(fit_x.rows, fit_codes.n, "fit split size mismatch");
        assert_eq!(fit_x.rows, fit_assign.len(), "fit split size mismatch");
        let m = codes.m;
        let k = params.cfg.k;
        let db_rows = codes.n;

        // ---- stage 1: fit the configured scorer on the fit split and
        // produce the code table it scans ----
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let (stage1, stage1_side_codes): (Box<dyn ApproxScorer>, Option<Codes>) =
            match &cfg.pipeline.stage1 {
                Stage1Kind::Aq => {
                    // unitary RQ re-fit on (residual, code) pairs; scans
                    // the QINCo2 code table directly (no side table)
                    let aq = AdditiveDecoder::fit_rq(&fit_res, fit_codes, k);
                    (Box::new(aq), None)
                }
                Stage1Kind::Pq { m: m_pq } => {
                    let pq = Pq::train(&fit_res, *m_pq, k, cfg.seed ^ 0x9106);
                    let s1_codes = pq.encode(residuals);
                    (Box::new(PqScorer(pq)), Some(s1_codes))
                }
                Stage1Kind::Opq { m: m_pq, iters } => {
                    let opq = Opq::train(&fit_res, *m_pq, k, *iters, cfg.seed ^ 0x0619);
                    let s1_codes = opq.encode(residuals);
                    (Box::new(OpqScorer::new(opq)), Some(s1_codes))
                }
                Stage1Kind::Lsq { m: m_s1 } => {
                    let lsq = Lsq::train(&fit_res, *m_s1, k, 2, cfg.seed ^ 0x15D1);
                    let s1_codes = lsq.encode(residuals);
                    (Box::new(LsqScorer(lsq)), Some(s1_codes))
                }
                Stage1Kind::Rq { m: m_s1 } => {
                    let rq = Rq::train(&fit_res, *m_s1, k, 1, cfg.seed ^ 0x4217);
                    let s1_codes = rq.encode(residuals);
                    (Box::new(RqScorer(rq)), Some(s1_codes))
                }
            };
        // cached term_i = ||x̂_r||² + 2⟨cent, x̂_r⟩ from the stage-1 decode
        let s1_dec = stage1.decode(stage1_side_codes.as_ref().unwrap_or(&codes));
        let mut stage1_terms = Vec::with_capacity(db_rows);
        for i in 0..db_rows {
            let cent = ivf.centroids.row(ivf.assign[i] as usize);
            stage1_terms
                .push(tensor::sqnorm(s1_dec.row(i)) + 2.0 * tensor::dot(cent, s1_dec.row(i)));
        }

        // ---- stage 2: pairwise decoder over extended positions ----
        let (stage2, stage2_codes, stage2_norms, pairwise_trace): (
            Option<Box<dyn ApproxScorer>>,
            Codes,
            Vec<f32>,
            Vec<(usize, usize, f64)>,
        ) = if cfg.pipeline.stage2 {
            // RQ-quantize the IVF centroids into M̃ codes (bucket-level
            // only: storage independent of the database size)
            let ivf_rq = Rq::train(&ivf.centroids, cfg.m_tilde, k, 4, cfg.seed ^ 0x77);
            let bucket_codes = ivf_rq.encode(&ivf.centroids);
            let mut extra = Codes::zeros(db_rows, cfg.m_tilde);
            for i in 0..db_rows {
                extra
                    .row_mut(i)
                    .copy_from_slice(bucket_codes.row(ivf.assign[i] as usize));
            }
            let pw_codes = append_positions(&codes, &extra);
            let n_pairs = if cfg.n_pairs_train == 0 { 2 * m } else { cfg.n_pairs_train };
            let mut fit_extra = Codes::zeros(fit_x.rows, cfg.m_tilde);
            for i in 0..fit_x.rows {
                fit_extra
                    .row_mut(i)
                    .copy_from_slice(bucket_codes.row(fit_assign[i] as usize));
            }
            let fit_pw_codes = append_positions(fit_codes, &fit_extra);
            let pairwise = PairwiseDecoder::train(fit_x, &fit_pw_codes, k, n_pairs);
            let pw_norms = pairwise.norms(&pw_codes);
            let trace = pairwise.trace();
            (Some(Box::new(pairwise)), pw_codes, pw_norms, trace)
        } else {
            (None, Codes::zeros(0, 0), Vec::new(), Vec::new())
        };

        // ---- stage 3: the index-held decoder is always the infallible,
        // thread-shared reference decoder; Disabled keeps it around (the
        // batched engine still compiles against it) but never invokes it.
        // Runtime decoders are per-worker-thread, via DecoderFactory.
        let params = Arc::new(params);
        let stage3: Box<dyn StageDecoder + Send + Sync> =
            Box::new(ReferenceDecoder { params: params.clone() });
        let stage3_enabled = cfg.pipeline.stage3 != Stage3Kind::Disabled;

        SearchIndex {
            ivf,
            codes,
            params,
            pipeline: PipelineSpec { stage1, stage2, stage3 },
            stage1_side_codes,
            stage1_terms,
            stage2_codes,
            stage2_norms,
            stage3_enabled,
            pairwise_trace,
            default_batch_threads: if cfg.batch_threads == 0 {
                crate::util::pool::default_threads()
            } else {
                cfg.batch_threads
            },
            db_len: db_rows,
        }
    }

    /// Resolve the effective intra-batch thread count for one batched
    /// execute: `sp.batch_threads`, or the index default when `0`.
    pub fn batch_threads(&self, sp: &SearchParams) -> usize {
        let t = if sp.batch_threads == 0 { self.default_batch_threads } else { sp.batch_threads };
        t.max(1)
    }

    /// Full pipeline search for one query. Returns ranked (score, id) —
    /// exact squared distances when stage 3 ran, approximate scores
    /// (missing the constant ||q||²) otherwise.
    ///
    /// Panics if the index-held stage-3 decoder fails; the built-in
    /// decoders are infallible (fallible runtime decoders belong to
    /// server workers, which handle errors by falling back).
    pub fn search(&self, q: &[f32], sp: &SearchParams) -> Vec<(f32, u32)> {
        // ---- stage 0: coarse probe ----
        let probes = self.ivf.probe(q, sp.nprobe, sp.ef_search);
        // ---- stage 1: LUT scan over the probed lists ----
        let scorer = self.pipeline.stage1.as_ref();
        let s1_codes = self.stage1_codes();
        let lut = scorer.lut(q);
        let mut shortlist = Shortlist::new(sp.n_aq);
        for &(probe_d, bucket) in &probes {
            for &id in &self.ivf.lists[bucket as usize] {
                let i = id as usize;
                let s =
                    probe_d + scorer.score(&lut, s1_codes.row(i), self.stage1_terms[i]);
                shortlist.push(s, id);
            }
        }
        // ---- stage 2: approximate re-scoring ----
        let stage2 = self.stage2_rescore(q, shortlist.into_sorted(), sp);
        // ---- stage 3: exact decode re-rank ----
        if sp.n_final == 0 || stage2.is_empty() {
            return stage2;
        }
        if !self.stage3_enabled {
            let mut out = stage2;
            out.truncate(sp.n_final);
            return out;
        }
        let ids: Vec<usize> = stage2.iter().map(|&(_, id)| id as usize).collect();
        let dec = self
            .pipeline
            .stage3
            .decode(&gather_codes(&self.codes, &ids))
            .expect("index-held stage-3 decoder failed");
        let rows: Vec<usize> = (0..ids.len()).collect();
        self.exact_rerank(q, &stage2, &dec, &rows, sp.n_final)
    }

    /// Stage 2: re-score a stage-1 shortlist with the configured scorer
    /// and keep the best `sp.n_pairs`. Chooses between a per-query joint
    /// LUT and direct dots via the scorer's [`ApproxScorer::use_lut`]
    /// cost model. Shared by the per-query and batched paths (identical
    /// float rounding). A `None` stage 2 forwards the shortlist as-is.
    pub(crate) fn stage2_rescore(
        &self,
        q: &[f32],
        stage1: Vec<(f32, u32)>,
        sp: &SearchParams,
    ) -> Vec<(f32, u32)> {
        let Some(scorer) = self.pipeline.stage2.as_deref() else {
            return stage1;
        };
        if sp.n_pairs == 0 || stage1.is_empty() {
            return stage1;
        }
        let mut keep = Shortlist::new(sp.n_pairs);
        if scorer.use_lut(stage1.len(), q.len()) {
            let lut = scorer.lut(q);
            for &(_, id) in &stage1 {
                let i = id as usize;
                let s = scorer.score(&lut, self.stage2_codes.row(i), self.stage2_norms[i]);
                keep.push(s, id);
            }
        } else {
            for &(_, id) in &stage1 {
                let i = id as usize;
                let s = scorer.score_direct(q, self.stage2_codes.row(i), self.stage2_norms[i]);
                keep.push(s, id);
            }
        }
        keep.into_sorted()
    }

    /// Stage 3: exact distances for survivors whose decodes sit in `dec`
    /// (survivor j ↔ `dec.row(rows[j])`), ranked and truncated. Shared by
    /// the per-query and batched paths.
    pub(crate) fn exact_rerank(
        &self,
        q: &[f32],
        survivors: &[(f32, u32)],
        dec: &Matrix,
        rows: &[usize],
        n_final: usize,
    ) -> Vec<(f32, u32)> {
        debug_assert_eq!(survivors.len(), rows.len());
        let mut exact: Vec<(f32, u32)> = survivors
            .iter()
            .zip(rows)
            .map(|(&(_, id), &row)| (self.exact_distance(q, id as usize, dec.row(row)), id))
            .collect();
        exact.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        exact.truncate(n_final);
        exact
    }

    /// Exact ||q − (cent_i + decode_i)||² given the decoded residual row.
    pub(crate) fn exact_distance(&self, q: &[f32], i: usize, dec_row: &[f32]) -> f32 {
        let cent = self.ivf.centroids.row(self.ivf.assign[i] as usize);
        let mut d = 0.0f32;
        for j in 0..q.len() {
            let rec = cent[j] + dec_row[j];
            let diff = q[j] - rec;
            d += diff * diff;
        }
        d
    }

    /// Search many queries; returns ranked (score, id) lists — the same
    /// shape per query as [`Self::search`], so batched and per-query
    /// callers handle one result type. Runs the batched engine over
    /// per-thread chunks of the query set — result-identical to calling
    /// [`Self::search`] per row. With `sp.batch_threads > 1` each chunk
    /// additionally splits its bucket-group scan across that many
    /// threads (the outer chunk count shrinks so total thread use stays
    /// near the core count). A failing stage-3 decoder surfaces as an
    /// `Err` instead of panicking inside the engine.
    pub fn search_batch(
        &self,
        queries: &Matrix,
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let n = queries.rows;
        if n == 0 {
            return Ok(Vec::new());
        }
        let inner = self.batch_threads(sp);
        let nthreads = (crate::util::pool::default_threads() / inner).max(1);
        let chunk = n.div_ceil(nthreads);
        let nchunks = n.div_ceil(chunk);
        let mut per_chunk: Vec<Result<Vec<Vec<(f32, u32)>>>> =
            (0..nchunks).map(|_| Ok(Vec::new())).collect();
        crate::util::pool::par_map_into(&mut per_chunk, nchunks, |ci, slot| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            let searcher = super::batch::BatchSearcher::new(self);
            let plans: Vec<super::batch::QueryPlan> =
                (lo..hi).map(|i| searcher.plan(queries.row(i), sp)).collect();
            *slot = searcher.execute(&plans, sp);
        });
        let mut out = Vec::with_capacity(n);
        for chunk_res in per_chunk {
            out.extend(chunk_res?);
        }
        Ok(out)
    }

    /// The code table stage 1 scans: the side table when the scorer owns
    /// one (PQ/OPQ), the QINCo2 codes otherwise.
    #[inline]
    pub fn stage1_codes(&self) -> &Codes {
        self.stage1_side_codes.as_ref().unwrap_or(&self.codes)
    }

    /// Bytes per database vector (codes + the per-vector f32 caches),
    /// for the bitrate accounting in EXPERIMENTS.md.
    pub fn bytes_per_vector(&self) -> f64 {
        let bits_per_code = usize::BITS - (self.params.cfg.k - 1).leading_zeros();
        // QINCo2 codes + the stage-1 term cache (f32)
        let mut bytes = (self.codes.m * bits_per_code as usize) as f64 / 8.0 + 4.0;
        // a PQ/OPQ stage 1 scans its own side table
        if let Some(side) = &self.stage1_side_codes {
            bytes += (side.m * bits_per_code as usize) as f64 / 8.0;
        }
        // stage-2 norm cache (f32)
        if self.pipeline.stage2.is_some() {
            bytes += 4.0;
        }
        bytes
    }
}

/// Gather code rows by index.
pub fn gather_codes(codes: &Codes, idx: &[usize]) -> Codes {
    let mut out = Codes::zeros(idx.len(), codes.m);
    for (o, &i) in idx.iter().enumerate() {
        out.row_mut(o).copy_from_slice(codes.row(i));
    }
    out
}
