//! The Fig. 3 search pipeline.
//!
//! Database encoding (build time):
//!   x → IVF bucket I⁰ → QINCo2 codes (I¹..I^M) of the residual
//!   x - C⁰(I⁰); plus: a unitary additive decoder re-fit on the codes
//!   (stage-1 LUT scans), the IVF centroids RQ-quantized into M̃ extra
//!   positions, and a pairwise decoder trained on the extended codes
//!   (stage-2 re-ranking).
//!
//! Retrieval:
//!   HNSW → nprobe buckets → AQ LUT scan (S_IVF → S_AQ) → pairwise
//!   re-scoring (S_AQ → S_pairs) → neural decode + exact distance on the
//!   survivors. Stage distances:
//!     stage 1: ||q - cent_b - x̂_r||² = ||q - cent_b||²
//!              + (||x̂_r||² + 2⟨cent_b, x̂_r⟩) − 2⟨q, x̂_r⟩
//!              = probe_dist + term_i − 2·LUT-sum   (term_i cached)
//!     stage 2: ||x̂_pw||² − 2⟨q, x̂_pw⟩ (pairwise decoder targets raw x,
//!              so scores are comparable across buckets)
//!     stage 3: exact ||q - (cent + decode(I¹..I^M))||², Rust reference
//!              decoder (same math as the HLO artifact, pad-free).
//!
//! Execution paths:
//!   * [`SearchIndex::search`] — one query at a time.
//!   * [`super::batch::BatchSearcher`] — the batched engine: per-batch
//!     flat AQ LUTs, bucket-grouped inverted-list scans (each co-probed
//!     list is read once per batch), and a single union decode for
//!     stage 3. Result-identical to `search` — both paths share
//!     [`stage2_rescore`](SearchIndex::stage2_rescore) /
//!     [`exact_rerank`](SearchIndex::exact_rerank) and the total
//!     (score, id) shortlist order of [`Shortlist`].
//!
//! Stage-2 cost model ([`super::batch::stage2_use_lut`]): re-scoring |S|
//! candidates over P pair steps costs P·|S|·d flops with direct dots, vs
//! P·K²·d once + P·|S| lookups with a per-query joint LUT. The LUT
//! amortizes when |S| ≳ K²·d/(d−1); both paths consult the same model so
//! the choice — and the float rounding — never diverges between them.
//! Shortlists are bounded binary max-heaps ([`crate::util::topk`])
//! instead of sorted-`Vec::insert`: O(log k) per candidate, and their
//! (score, id) total order makes results independent of scan order.

use super::batch::{stage2_use_lut, BatchSearcher, QueryPlan};
use super::ivf::Ivf;
use crate::qinco::{reference, Codec, ParamStore};
use crate::quantizers::pairwise::{append_positions, PairwiseDecoder};
use crate::quantizers::rq::Rq;
use crate::quantizers::{aq_lut::AdditiveDecoder, Codes, VectorQuantizer};
use crate::runtime::Engine;
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use crate::util::topk::Shortlist;
use anyhow::Result;

/// Search-time knobs (the Fig. 6 sweep axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchParams {
    pub nprobe: usize,
    pub ef_search: usize,
    /// stage-1 shortlist size |S_AQ|
    pub n_aq: usize,
    /// stage-2 shortlist size |S_pairs| (0 disables pairwise re-ranking)
    pub n_pairs: usize,
    /// final results returned after neural re-rank (0 disables neural
    /// re-rank: stage-2 order is returned)
    pub n_final: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { nprobe: 8, ef_search: 64, n_aq: 256, n_pairs: 32, n_final: 10 }
    }
}

/// Build-time configuration.
#[derive(Clone, Debug)]
pub struct BuildCfg {
    pub k_ivf: usize,
    /// RQ steps used to quantize the IVF centroids for the pairwise pool
    pub m_tilde: usize,
    /// number of optimized pairs (paper default: 2M)
    pub n_pairs_train: usize,
    /// training subsample for the decoders
    pub fit_sample: usize,
    pub seed: u64,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg { k_ivf: 64, m_tilde: 2, n_pairs_train: 0, fit_sample: 20_000, seed: 0x5EA2C4 }
    }
}

pub struct SearchIndex {
    pub ivf: Ivf,
    /// QINCo2 codes of the database residuals [N, M]
    pub codes: Codes,
    pub params: ParamStore,
    /// stage-1 unitary decoder + cached per-vector term
    pub aq: AdditiveDecoder,
    pub(crate) aq_terms: Vec<f32>,
    /// stage-2 pairwise decoder over extended positions + cached norms
    pub pairwise: PairwiseDecoder,
    pub(crate) pw_codes: Codes,
    pub(crate) pw_norms: Vec<f32>,
    /// per-step MSE trace of the pairwise fit (Table S3)
    pub pairwise_trace: Vec<(usize, usize, f64)>,
    pub db_len: usize,
}

impl SearchIndex {
    /// Encode the database and fit all the lookup decoders.
    /// `params` must be a model trained on IVF residuals of this flavor.
    pub fn build(
        engine: &mut Engine,
        codec: &Codec,
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> Result<SearchIndex> {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let (codes, _, _) = codec.encode(engine, &params, &residuals)?;

        // ---- fit split: the lookup decoders are estimated on *training*
        // vectors + their codes (paper Sec. 3.3), never on the database,
        // so their accuracy generalizes like the paper's ----
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let (fit_codes, _, _) = codec.encode(engine, &params, &fit_res)?;

        Ok(Self::assemble(params, ivf, codes, &fit_x, &fit_assign, &fit_codes, cfg))
    }

    /// Build an index with the pure-Rust reference encoder (greedy A=K,
    /// B=1) — no PJRT runtime or HLO artifacts required. Slower to build
    /// and slightly less accurate than the beam-search XLA encoder, but
    /// runs anywhere; the artifact-free tests (`batch_equivalence`,
    /// `coordinator_props`) and the `bench_batch_qps` bench use it.
    pub fn build_reference(
        params: ParamStore,
        train: &Matrix,
        database: &Matrix,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        let mut rng = Rng::new(cfg.seed);
        let ivf = Ivf::build(train, database, cfg.k_ivf, cfg.seed);
        let residuals = ivf.residuals(database);
        let codes = reference::encode_greedy(&params, &residuals);
        let fit_idx = if train.rows > cfg.fit_sample {
            rng.sample_indices(train.rows, cfg.fit_sample)
        } else {
            (0..train.rows).collect()
        };
        let fit_x = train.gather_rows(&fit_idx);
        let fit_assign =
            tensor::assign_all(&fit_x, &ivf.centroids, crate::util::pool::default_threads());
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let fit_codes = reference::encode_greedy(&params, &fit_res);
        Self::assemble(params, ivf, codes, &fit_x, &fit_assign, &fit_codes, cfg)
    }

    /// Assemble an index from pre-computed codes: fit the stage-1/stage-2
    /// lookup decoders and their per-vector caches. Engine-free — the
    /// codes may come from [`Codec::encode`] (the XLA path, see
    /// [`Self::build`]) or from the pure-Rust reference encoder, which is
    /// how the property tests and artifact-free benches construct real
    /// indexes without a PJRT runtime.
    ///
    /// `codes` are the database residual codes (row i ↔ `ivf.assign[i]`);
    /// `fit_x` / `fit_assign` / `fit_codes` are the decoder-fit split:
    /// raw training vectors, their IVF buckets, and the codes of their
    /// residuals.
    pub fn assemble(
        params: ParamStore,
        ivf: Ivf,
        codes: Codes,
        fit_x: &Matrix,
        fit_assign: &[u32],
        fit_codes: &Codes,
        cfg: &BuildCfg,
    ) -> SearchIndex {
        assert_eq!(ivf.assign.len(), codes.n, "codes must cover the database");
        assert_eq!(fit_x.rows, fit_codes.n, "fit split size mismatch");
        assert_eq!(fit_x.rows, fit_assign.len(), "fit split size mismatch");
        let m = codes.m;
        let k = params.cfg.k;
        let db_rows = codes.n;

        // ---- stage-1 decoder: unitary RQ re-fit on (residual, code) ----
        let mut fit_res = fit_x.clone();
        for i in 0..fit_res.rows {
            let crow = ivf.centroids.row(fit_assign[i] as usize).to_vec();
            tensor::sub_assign(fit_res.row_mut(i), &crow);
        }
        let aq = AdditiveDecoder::fit_rq(&fit_res, fit_codes, k);
        // cached term_i = ||x̂_r||² + 2⟨cent, x̂_r⟩ using the AQ decode
        let aq_dec = aq.decode(&codes);
        let mut aq_terms = Vec::with_capacity(db_rows);
        for i in 0..db_rows {
            let cent = ivf.centroids.row(ivf.assign[i] as usize);
            aq_terms
                .push(tensor::sqnorm(aq_dec.row(i)) + 2.0 * tensor::dot(cent, aq_dec.row(i)));
        }

        // ---- stage-2: pairwise decoder over extended positions ----
        // RQ-quantize the IVF centroids into M̃ codes (bucket-level only:
        // storage independent of the database size)
        let ivf_rq = Rq::train(&ivf.centroids, cfg.m_tilde, k, 4, cfg.seed ^ 0x77);
        let bucket_codes = ivf_rq.encode(&ivf.centroids);
        let mut extra = Codes::zeros(db_rows, cfg.m_tilde);
        for i in 0..db_rows {
            extra
                .row_mut(i)
                .copy_from_slice(bucket_codes.row(ivf.assign[i] as usize));
        }
        let pw_codes = append_positions(&codes, &extra);
        let n_pairs = if cfg.n_pairs_train == 0 { 2 * m } else { cfg.n_pairs_train };
        let mut fit_extra = Codes::zeros(fit_x.rows, cfg.m_tilde);
        for i in 0..fit_x.rows {
            fit_extra
                .row_mut(i)
                .copy_from_slice(bucket_codes.row(fit_assign[i] as usize));
        }
        let fit_pw_codes = append_positions(fit_codes, &fit_extra);
        let pairwise = PairwiseDecoder::train(fit_x, &fit_pw_codes, k, n_pairs);
        let pw_norms = pairwise.norms(&pw_codes);
        let pairwise_trace = pairwise.trace();

        SearchIndex {
            ivf,
            codes,
            params,
            aq,
            aq_terms,
            pairwise,
            pw_codes,
            pw_norms,
            pairwise_trace,
            db_len: db_rows,
        }
    }

    /// Full pipeline search for one query. Returns ranked (dist, id).
    pub fn search(&self, q: &[f32], sp: &SearchParams) -> Vec<(f32, u32)> {
        // ---- stage 0: coarse probe ----
        let probes = self.ivf.probe(q, sp.nprobe, sp.ef_search);
        // ---- stage 1: AQ LUT scan over the probed lists ----
        let lut = self.aq.lut(q);
        let mut shortlist = Shortlist::new(sp.n_aq);
        for &(probe_d, bucket) in &probes {
            for &id in &self.ivf.lists[bucket as usize] {
                let i = id as usize;
                let s = probe_d
                    + self.aq.score(&lut, self.codes.row(i), self.aq_terms[i]);
                shortlist.push(s, id);
            }
        }
        // ---- stage 2: pairwise re-scoring ----
        let stage2 = self.stage2_rescore(q, shortlist.into_sorted(), sp);
        // ---- stage 3: neural decode re-rank ----
        if sp.n_final == 0 || stage2.is_empty() {
            return stage2;
        }
        let ids: Vec<usize> = stage2.iter().map(|&(_, id)| id as usize).collect();
        let dec = reference::decode(&self.params, &gather_codes(&self.codes, &ids));
        let rows: Vec<usize> = (0..ids.len()).collect();
        self.exact_rerank(q, &stage2, &dec, &rows, sp.n_final)
    }

    /// Stage 2: re-score a stage-1 shortlist with the pairwise decoder
    /// and keep the best `sp.n_pairs`. Chooses between a per-query joint
    /// LUT and direct dots via the [`stage2_use_lut`] cost model. Shared
    /// by the per-query and batched paths (identical float rounding).
    pub(crate) fn stage2_rescore(
        &self,
        q: &[f32],
        stage1: Vec<(f32, u32)>,
        sp: &SearchParams,
    ) -> Vec<(f32, u32)> {
        if sp.n_pairs == 0 || stage1.is_empty() {
            return stage1;
        }
        let k = self.pairwise.k;
        let mut keep = Shortlist::new(sp.n_pairs);
        if stage2_use_lut(stage1.len(), self.pairwise.steps.len(), k, q.len()) {
            let lut = self.pairwise.lut(q);
            for &(_, id) in &stage1 {
                let i = id as usize;
                let s = self.pairwise.score(&lut, self.pw_codes.row(i), self.pw_norms[i]);
                keep.push(s, id);
            }
        } else {
            for &(_, id) in &stage1 {
                let i = id as usize;
                let code = self.pw_codes.row(i);
                let mut ip = 0.0f32;
                for s in &self.pairwise.steps {
                    let joint = code[s.i] as usize * k + code[s.j] as usize;
                    ip += tensor::dot(q, s.codebook.row(joint));
                }
                keep.push(self.pw_norms[i] - 2.0 * ip, id);
            }
        }
        keep.into_sorted()
    }

    /// Stage 3: exact distances for survivors whose decodes sit in `dec`
    /// (survivor j ↔ `dec.row(rows[j])`), ranked and truncated. Shared by
    /// the per-query and batched paths.
    pub(crate) fn exact_rerank(
        &self,
        q: &[f32],
        survivors: &[(f32, u32)],
        dec: &Matrix,
        rows: &[usize],
        n_final: usize,
    ) -> Vec<(f32, u32)> {
        debug_assert_eq!(survivors.len(), rows.len());
        let mut exact: Vec<(f32, u32)> = survivors
            .iter()
            .zip(rows)
            .map(|(&(_, id), &row)| (self.exact_distance(q, id as usize, dec.row(row)), id))
            .collect();
        exact.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        exact.truncate(n_final);
        exact
    }

    /// Exact ||q − (cent_i + decode_i)||² given the decoded residual row.
    pub(crate) fn exact_distance(&self, q: &[f32], i: usize, dec_row: &[f32]) -> f32 {
        let cent = self.ivf.centroids.row(self.ivf.assign[i] as usize);
        let mut d = 0.0f32;
        for j in 0..q.len() {
            let rec = cent[j] + dec_row[j];
            let diff = q[j] - rec;
            d += diff * diff;
        }
        d
    }

    /// Search many queries; returns ranked id lists (for recall metrics).
    /// Runs the batched engine over per-thread chunks of the query set —
    /// result-identical to calling [`Self::search`] per row.
    pub fn search_batch(&self, queries: &Matrix, sp: &SearchParams) -> Vec<Vec<u32>> {
        let n = queries.rows;
        if n == 0 {
            return Vec::new();
        }
        let nthreads = crate::util::pool::default_threads().max(1);
        let chunk = n.div_ceil(nthreads);
        let nchunks = n.div_ceil(chunk);
        let mut per_chunk: Vec<Vec<Vec<u32>>> = vec![Vec::new(); nchunks];
        crate::util::pool::par_map_into(&mut per_chunk, nchunks, |ci, slot| {
            let lo = ci * chunk;
            let hi = ((ci + 1) * chunk).min(n);
            let searcher = BatchSearcher::new(self);
            let plans: Vec<QueryPlan> =
                (lo..hi).map(|i| searcher.plan(queries.row(i), sp)).collect();
            *slot = searcher
                .execute(&plans, sp)
                .into_iter()
                .map(|r| r.into_iter().map(|(_, id)| id).collect())
                .collect();
        });
        per_chunk.into_iter().flatten().collect()
    }

    /// Bytes per database vector (codes + the per-vector f32 term caches),
    /// for the bitrate accounting in EXPERIMENTS.md.
    pub fn bytes_per_vector(&self) -> f64 {
        let bits_per_code = usize::BITS - (self.params.cfg.k - 1).leading_zeros();
        let code_bits = self.codes.m * bits_per_code as usize;
        code_bits as f64 / 8.0 + 8.0 // + two f32 caches (aq term, pw norm)
    }
}

/// Gather code rows by index.
pub fn gather_codes(codes: &Codes, idx: &[usize]) -> Codes {
    let mut out = Codes::zeros(idx.len(), codes.m);
    for (o, &i) in idx.iter().enumerate() {
        out.row_mut(o).copy_from_slice(codes.row(i));
    }
    out
}
