//! Hierarchical Navigable Small World graphs (Malkov & Yashunin, 2018).
//!
//! Used exactly as in the paper's pipeline: an HNSW index over the
//! K_IVF coarse centroids finds the `nprobe` closest inverted lists for a
//! query (the `efSearch` knob swept in Fig. 6). Sized for up to ~10^5
//! nodes; plenty for coarse quantizers.

use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// (distance, id) max-heap entry (BinaryHeap is a max-heap).
#[derive(PartialEq)]
struct Far(f32, u32);

impl Eq for Far {}
impl PartialOrd for Far {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Far {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

/// Min-heap entry via reversed ordering.
#[derive(PartialEq)]
struct Near(f32, u32);

impl Eq for Near {}
impl PartialOrd for Near {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Near {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}

pub struct Hnsw {
    /// per-level adjacency: `links[level][node]` = neighbor ids
    links: Vec<Vec<Vec<u32>>>,
    /// highest level of each node
    levels: Vec<u8>,
    entry: u32,
    #[allow(dead_code)]
    max_level: usize,
    pub m: usize,
    pub ef_construction: usize,
    /// the indexed points (owned copy — centroids are small)
    pub points: Matrix,
}

impl Hnsw {
    /// Build over the rows of `points` with `m` links per node.
    pub fn build(points: &Matrix, m: usize, ef_construction: usize, seed: u64) -> Hnsw {
        let n = points.rows;
        assert!(n > 0);
        let mut rng = Rng::new(seed ^ 0x4A53);
        let ml = 1.0 / (m as f64).ln().max(0.1);
        let mut levels = Vec::with_capacity(n);
        let mut max_level = 0usize;
        for _ in 0..n {
            let lvl = ((-rng.f64().max(1e-12).ln()) * ml) as usize;
            let lvl = lvl.min(12);
            max_level = max_level.max(lvl);
            levels.push(lvl as u8);
        }
        let mut hnsw = Hnsw {
            links: (0..=max_level).map(|_| vec![Vec::new(); n]).collect(),
            levels,
            entry: 0,
            max_level,
            m,
            ef_construction,
            points: points.clone(),
        };
        // insert nodes one by one
        let mut entry_set = false;
        for node in 0..n as u32 {
            if !entry_set {
                hnsw.entry = node;
                entry_set = true;
                continue;
            }
            hnsw.insert(node);
            if hnsw.levels[node as usize] as usize
                > hnsw.levels[hnsw.entry as usize] as usize
            {
                hnsw.entry = node;
            }
        }
        hnsw
    }

    fn dist(&self, q: &[f32], node: u32) -> f32 {
        tensor::l2_sq(q, self.points.row(node as usize))
    }

    /// Greedy descent from `start` at `level` towards `q`.
    fn greedy(&self, q: &[f32], start: u32, level: usize) -> u32 {
        let mut cur = start;
        let mut cur_d = self.dist(q, cur);
        loop {
            let mut improved = false;
            for &nb in &self.links[level][cur as usize] {
                let d = self.dist(q, nb);
                if d < cur_d {
                    cur = nb;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam search at one level; returns up to `ef` (dist, id) ascending.
    fn search_level(&self, q: &[f32], entry: u32, ef: usize, level: usize) -> Vec<(f32, u32)> {
        let mut visited = vec![false; self.points.rows];
        let mut candidates = BinaryHeap::new(); // min-heap by Near
        let mut results: BinaryHeap<Far> = BinaryHeap::new(); // max-heap
        let d0 = self.dist(q, entry);
        visited[entry as usize] = true;
        candidates.push(Near(d0, entry));
        results.push(Far(d0, entry));
        while let Some(Near(d, node)) = candidates.pop() {
            let worst = results.peek().map(|f| f.0).unwrap_or(f32::INFINITY);
            if d > worst && results.len() >= ef {
                break;
            }
            for &nb in &self.links[level][node as usize] {
                if visited[nb as usize] {
                    continue;
                }
                visited[nb as usize] = true;
                let dn = self.dist(q, nb);
                let worst = results.peek().map(|f| f.0).unwrap_or(f32::INFINITY);
                if results.len() < ef || dn < worst {
                    candidates.push(Near(dn, nb));
                    results.push(Far(dn, nb));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
        let mut out: Vec<(f32, u32)> = results.into_iter().map(|f| (f.0, f.1)).collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    fn insert(&mut self, node: u32) {
        let q = self.points.row(node as usize).to_vec();
        let node_level = self.levels[node as usize] as usize;
        let mut cur = self.entry;
        let top = self.levels[self.entry as usize] as usize;
        // descend levels above the node's level greedily
        for level in (node_level + 1..=top).rev() {
            cur = self.greedy(&q, cur, level);
        }
        // connect at each level from min(node_level, top) down to 0
        for level in (0..=node_level.min(top)).rev() {
            let found = self.search_level(&q, cur, self.ef_construction, level);
            cur = found[0].1;
            let mmax = if level == 0 { 2 * self.m } else { self.m };
            let selected: Vec<u32> =
                found.iter().take(self.m).map(|&(_, id)| id).collect();
            for &nb in &selected {
                self.links[level][node as usize].push(nb);
                self.links[level][nb as usize].push(node);
                // prune neighbors over capacity: keep closest
                if self.links[level][nb as usize].len() > mmax {
                    let base = self.points.row(nb as usize).to_vec();
                    let mut with_d: Vec<(f32, u32)> = self.links[level][nb as usize]
                        .iter()
                        .map(|&x| (tensor::l2_sq(&base, self.points.row(x as usize)), x))
                        .collect();
                    with_d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    with_d.truncate(mmax);
                    self.links[level][nb as usize] = with_d.into_iter().map(|(_, x)| x).collect();
                }
            }
        }
    }

    /// Approximate k nearest nodes to `q` with beam width `ef_search`.
    pub fn search(&self, q: &[f32], k: usize, ef_search: usize) -> Vec<(f32, u32)> {
        let mut cur = self.entry;
        let top = self.levels[self.entry as usize] as usize;
        for level in (1..=top).rev() {
            cur = self.greedy(q, cur, level);
        }
        let mut out = self.search_level(q, cur, ef_search.max(k), 0);
        out.truncate(k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn exact_on_small_sets_with_large_ef() {
        let pts = generate(Flavor::Deep, 200, 8, 1);
        let hnsw = Hnsw::build(&pts, 8, 64, 2);
        let queries = generate(Flavor::Deep, 20, 8, 3);
        let mut hits = 0;
        for i in 0..queries.rows {
            let q = queries.row(i);
            let res = hnsw.search(q, 1, 200);
            let (want, _) = tensor::argmin_l2(q, &pts);
            if res[0].1 == want as u32 {
                hits += 1;
            }
        }
        assert!(hits >= 19, "recall {hits}/20 too low for exhaustive ef");
    }

    #[test]
    fn higher_ef_no_worse_recall() {
        let pts = generate(Flavor::BigAnn, 500, 12, 4);
        let hnsw = Hnsw::build(&pts, 6, 32, 5);
        let queries = generate(Flavor::BigAnn, 50, 12, 6);
        let recall = |ef: usize| -> usize {
            (0..queries.rows)
                .filter(|&i| {
                    let q = queries.row(i);
                    let res = hnsw.search(q, 1, ef);
                    let (want, _) = tensor::argmin_l2(q, &pts);
                    !res.is_empty() && res[0].1 == want as u32
                })
                .count()
        };
        let r_small = recall(4);
        let r_big = recall(128);
        assert!(r_big >= r_small, "{r_big} < {r_small}");
        assert!(r_big >= 45, "recall@ef=128 {r_big}/50");
    }

    #[test]
    fn results_sorted_and_unique() {
        let pts = generate(Flavor::Ssnpp, 300, 8, 7);
        let hnsw = Hnsw::build(&pts, 8, 48, 8);
        let q = pts.row(5);
        let res = hnsw.search(q, 10, 64);
        assert_eq!(res.len(), 10);
        for w in res.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        let mut ids: Vec<u32> = res.iter().map(|r| r.1).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        // the query point itself must be found
        assert_eq!(res[0].1, 5);
        assert!(res[0].0 < 1e-9);
    }

    #[test]
    fn single_node_graph() {
        let pts = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let hnsw = Hnsw::build(&pts, 4, 8, 9);
        let res = hnsw.search(&[1.0, 2.0, 3.0, 4.0], 5, 16);
        assert_eq!(res.len(), 1);
        assert_eq!(res[0].1, 0);
    }
}
