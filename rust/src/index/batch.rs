//! Batched search execution engine (the serving hot path).
//!
//! Per-query search re-derives everything from scratch: one stage-1 LUT
//! per call, every probed inverted list scanned per query, one tiny
//! decode per query. Under batched traffic that wastes the structure the
//! batch exposes — co-probed buckets, shared decode work — so this module
//! splits search into an explicit *plan* ([`QueryPlan`]) and a batched
//! *execute* ([`BatchSearcher`]):
//!
//!   1. **Plan**: HNSW coarse probe per query (cheap, independent).
//!   2. **Stage 1**: all per-query LUTs (whatever
//!      [`ApproxScorer`](crate::quantizers::ApproxScorer) the
//!      pipeline's stage 1 is) are packed into one flat cache-contiguous
//!      buffer; queries are grouped by probed bucket so each co-probed
//!      inverted list is scanned *once per batch* — per database vector,
//!      its code row is read once and scored against every interested
//!      query's LUT slice. Shortlists are bounded binary max-heaps with a
//!      total (score, id) order, so the scan order change does not change
//!      results.
//!   3. **Stage 2**: per-query re-scoring through the shared
//!      (crate-private) `SearchIndex::stage2_rescore` — a per-query joint
//!      LUT or direct dots, chosen by the scorer's
//!      [`use_lut`](crate::quantizers::ApproxScorer::use_lut) cost model.
//!   4. **Stage 3**: ONE decode over the union of all surviving
//!      shortlists (deduplicated across queries), then per-query exact
//!      distances. The decoder is pluggable: [`BatchSearcher::execute`]
//!      uses the index's own [`StageDecoder`] (the infallible reference
//!      decoder), while [`BatchSearcher::execute_with_decoder`] accepts
//!      any `&dyn StageDecoder` — this is how server workers route the
//!      union through their thread-local
//!      [`RuntimeDecoder`](crate::qinco::RuntimeDecoder) (one padded XLA
//!      dispatch per batch, engine-per-worker).
//!
//! The engine is deliberately single-threaded per call: the serving
//! router parallelizes across batches/workers, and
//! [`SearchIndex::search_batch`] chunks a query matrix across threads.
//! Every path is result-identical to [`SearchIndex::search`] for every
//! pipeline configuration (pinned by the `batch_equivalence` property
//! suite).

use super::pipeline::{gather_codes, SearchIndex, SearchParams};
use crate::quantizers::StageDecoder;
use crate::util::topk::Shortlist;
use anyhow::Result;
use std::collections::BTreeMap;

// the cost model moved next to the ApproxScorer trait it now serves;
// re-exported here (and from `crate::index`) for existing callers
pub use crate::quantizers::stage2_use_lut;

/// Per-query plan: the owned query vector plus its coarse-probe result.
/// Building plans is independent per query; executing them is where the
/// batch-level sharing happens.
pub struct QueryPlan {
    pub query: Vec<f32>,
    /// (probe distance, bucket) from the HNSW coarse quantizer
    pub probes: Vec<(f32, u32)>,
}

/// Batched executor over a shared [`SearchIndex`].
pub struct BatchSearcher<'a> {
    pub index: &'a SearchIndex,
}

impl<'a> BatchSearcher<'a> {
    pub fn new(index: &'a SearchIndex) -> BatchSearcher<'a> {
        BatchSearcher { index }
    }

    /// Stage 0 for one query: coarse-probe and snapshot the query.
    pub fn plan(&self, q: &[f32], sp: &SearchParams) -> QueryPlan {
        QueryPlan {
            query: q.to_vec(),
            probes: self.index.ivf.probe(q, sp.nprobe, sp.ef_search),
        }
    }

    /// Execute a batch of plans with the index's own stage-3 decoder.
    /// Returns ranked (score, id) lists, one per plan, identical to
    /// [`SearchIndex::search`] per query.
    ///
    /// Panics if the index-held decoder fails; the built-in decoders are
    /// infallible (fallible per-thread runtime decoders go through
    /// [`Self::execute_with_decoder`], whose errors the caller handles).
    pub fn execute(&self, plans: &[QueryPlan], sp: &SearchParams) -> Vec<Vec<(f32, u32)>> {
        self.execute_with_decoder(plans, sp, self.index.pipeline.stage3.as_ref())
            .expect("index-held stage-3 decoder failed")
    }

    /// Execute with a caller-supplied stage-3 decoder. The decoder is
    /// invoked at most once per batch, on the deduplicated union of every
    /// surviving shortlist — server workers pass their thread-local
    /// engine-backed decoder here to spend a single XLA dispatch per
    /// batch. When the index was built with stage 3 disabled, the decoder
    /// is never invoked and the stage-2 ranking is returned (truncated to
    /// `n_final`), exactly like the per-query path.
    pub fn execute_with_decoder(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        decoder: &dyn StageDecoder,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let idx = self.index;
        if plans.is_empty() {
            return Ok(Vec::new());
        }

        // ---- stage 1: flat LUT pack + bucket-grouped scan ----
        let scorer = idx.pipeline.stage1.as_ref();
        let stride = scorer.lut_len();
        let mut luts = vec![0.0f32; plans.len() * stride];
        for (qi, plan) in plans.iter().enumerate() {
            scorer.lut_into(&plan.query, &mut luts[qi * stride..(qi + 1) * stride]);
        }
        // bucket → [(query, probe distance)]: every co-probed inverted
        // list is scanned once for the whole batch
        let mut groups: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        for (qi, plan) in plans.iter().enumerate() {
            for &(probe_d, bucket) in &plan.probes {
                groups.entry(bucket).or_default().push((qi as u32, probe_d));
            }
        }
        let mut shortlists: Vec<Shortlist> =
            plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect();
        let s1_codes = idx.stage1_codes();
        for (&bucket, members) in &groups {
            for &id in &idx.ivf.lists[bucket as usize] {
                let i = id as usize;
                let code = s1_codes.row(i);
                let term = idx.stage1_terms[i];
                for &(qi, probe_d) in members {
                    let qi = qi as usize;
                    let lut = &luts[qi * stride..(qi + 1) * stride];
                    shortlists[qi].push(probe_d + scorer.score(lut, code, term), id);
                }
            }
        }

        // ---- stage 2: per-query re-scoring ----
        let stage2: Vec<Vec<(f32, u32)>> = shortlists
            .into_iter()
            .zip(plans)
            .map(|(sl, plan)| idx.stage2_rescore(&plan.query, sl.into_sorted(), sp))
            .collect();
        if sp.n_final == 0 {
            return Ok(stage2);
        }
        if !idx.stage3_enabled {
            // stage-2-final mode: the approximate ranking is the answer
            return Ok(stage2
                .into_iter()
                .map(|mut list| {
                    list.truncate(sp.n_final);
                    list
                })
                .collect());
        }

        // ---- stage 3: one decode over the union of all survivors ----
        let mut union: BTreeMap<u32, usize> = BTreeMap::new();
        for list in &stage2 {
            for &(_, id) in list {
                union.insert(id, 0);
            }
        }
        if union.is_empty() {
            return Ok(stage2); // every shortlist is empty
        }
        for (row, slot) in union.values_mut().enumerate() {
            *slot = row;
        }
        let ids: Vec<usize> = union.keys().map(|&id| id as usize).collect();
        let dec = decoder.decode(&gather_codes(&idx.codes, &ids))?;
        Ok(stage2
            .into_iter()
            .zip(plans)
            .map(|(list, plan)| {
                let rows: Vec<usize> = list.iter().map(|&(_, id)| union[&id]).collect();
                idx.exact_rerank(&plan.query, &list, &dec, &rows, sp.n_final)
            })
            .collect())
    }

    /// Plan + execute a whole query matrix in one batch.
    pub fn search(
        &self,
        queries: &crate::tensor::Matrix,
        sp: &SearchParams,
    ) -> Vec<Vec<(f32, u32)>> {
        let plans: Vec<QueryPlan> =
            (0..queries.rows).map(|i| self.plan(queries.row(i), sp)).collect();
        self.execute(&plans, sp)
    }
}
