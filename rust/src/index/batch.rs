//! Batched search execution engine (the serving hot path).
//!
//! Per-query search re-derives everything from scratch: one stage-1 LUT
//! per call, every probed inverted list scanned per query, one tiny
//! decode per query. Under batched traffic that wastes the structure the
//! batch exposes — co-probed buckets, shared decode work — so this module
//! splits search into an explicit *plan* ([`QueryPlan`]) and a batched
//! *execute* ([`BatchSearcher`]):
//!
//!   1. **Plan**: HNSW coarse probe per query (cheap, independent).
//!   2. **Stage 1 (scatter)**: per-query LUTs are packed into flat
//!      cache-contiguous buffers — one pack per LUT slot: every shard on
//!      the shared [`PipelineSpec`](super::pipeline::PipelineSpec) reads
//!      the same pack, each heterogeneous override shard gets its own
//!      ([`ShardSet::lut_slot`](super::shard::ShardSet::lut_slot)).
//!      [`ShardSet::plan`](super::shard::ShardSet::plan) routes the
//!      batch's probed buckets to their owning
//!      [`IndexShard`](super::shard::IndexShard)s as bucket groups, in
//!      ascending bucket order, so each co-probed inverted list is
//!      scanned *once per batch*. Each shard scans its local groups with
//!      the multi-query
//!      [`score_block`](crate::quantizers::ApproxScorer::score_block)
//!      kernel (blocks of up to
//!      [`SCORE_BLOCK`](crate::quantizers::SCORE_BLOCK) co-probed
//!      queries per code row), pushing `(score, global id)` into the
//!      per-query shortlists — bounded binary max-heaps with a total
//!      (score, id) order, so neither the scan-order change, the block
//!      kernel, nor the shard partition changes results (gather =
//!      shortlist merge under that total order).
//!   3. **Stage 2**: per-query re-scoring through the shared
//!      (crate-private) `SearchIndex::stage2_rescore` — a per-query joint
//!      LUT or direct dots, chosen by the scorer's
//!      [`use_lut`](crate::quantizers::ApproxScorer::use_lut) cost model,
//!      with each candidate scored by its owning shard's stage-2 scorer.
//!   4. **Stage 3**: ONE decode over the union of all surviving
//!      shortlists (deduplicated across queries, rows gathered from the
//!      owning shards), then per-query exact distances. The decoder is
//!      pluggable: [`BatchSearcher::execute`] uses the index's own
//!      [`StageDecoder`], while [`BatchSearcher::execute_with_decoder`]
//!      accepts any `&dyn StageDecoder` — this is how server workers
//!      route the union through their thread-local
//!      [`RuntimeDecoder`](crate::qinco::RuntimeDecoder) (one padded XLA
//!      dispatch per batch, engine-per-worker). Either way a decode
//!      failure surfaces as an `Err`, never a panic inside the engine.
//!
//! # Intra-batch parallelism
//!
//! One execute call is no longer pinned to a single thread:
//! [`SearchParams::batch_threads`] splits the scattered shard groups
//! across the scoped thread pool
//! ([`par_map_into`](crate::util::pool::par_map_into) over per-thread
//! partials; each thread scans a contiguous chunk of groups — which may
//! span shard boundaries — into its own per-query shortlists, which are
//! then merged under the total (score, id) order), and runs the
//! per-query stage-2/stage-3 loops across the same thread count. Because
//! every (query, candidate) pair is scored exactly once with identical
//! floats and the shortlist order is total, results are bit-identical
//! for **every** thread count and **every** shard count — the default
//! `batch_threads = 1` keeps the historical behavior where the serving
//! router parallelizes across batches/workers and
//! [`SearchIndex::search_batch`] chunks a query matrix across threads;
//! raise it when one large batch would otherwise execute on a single
//! worker thread (multi-shard scans then proceed in parallel across
//! shards, since the group list is shard-major).
//!
//! Every path is result-identical to [`SearchIndex::search`] for every
//! pipeline configuration, thread count and shard count (pinned by the
//! `batch_equivalence` property suite).

use super::pipeline::{SearchIndex, SearchParams};
use super::shard::ShardSet;
use crate::quantizers::StageDecoder;
use crate::util::pool;
use crate::util::topk::Shortlist;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

// the cost model moved next to the ApproxScorer trait it now serves;
// re-exported here (and from `crate::index`) for existing callers
pub use crate::quantizers::stage2_use_lut;

/// Per-query plan: the owned query vector plus its coarse-probe result.
/// Building plans is independent per query; executing them is where the
/// batch-level sharing happens.
pub struct QueryPlan {
    pub query: Vec<f32>,
    /// (probe distance, bucket) from the HNSW coarse quantizer
    pub probes: Vec<(f32, u32)>,
}

/// Batched executor over a shared [`SearchIndex`], pinned to one epoch
/// snapshot: the [`ShardSet`] is captured at construction, so a whole
/// plan+execute cycle — however long it runs — sees exactly one index
/// state even while writers publish new epochs concurrently.
pub struct BatchSearcher<'a> {
    pub index: &'a SearchIndex,
    set: Arc<ShardSet>,
}

impl<'a> BatchSearcher<'a> {
    /// Pin the index's *current* epoch for this searcher's lifetime.
    pub fn new(index: &'a SearchIndex) -> BatchSearcher<'a> {
        let set = index.snapshot();
        BatchSearcher { index, set }
    }

    /// Pin an explicitly supplied snapshot — used by
    /// [`SearchIndex::search_batch`] so every per-thread chunk of one
    /// call shares a single epoch.
    pub fn with_snapshot(index: &'a SearchIndex, set: Arc<ShardSet>) -> BatchSearcher<'a> {
        BatchSearcher { index, set }
    }

    /// The epoch snapshot this searcher is pinned to.
    pub fn snapshot(&self) -> &ShardSet {
        &self.set
    }

    /// Stage 0 for one query: coarse-probe and snapshot the query.
    pub fn plan(&self, q: &[f32], sp: &SearchParams) -> QueryPlan {
        QueryPlan {
            query: q.to_vec(),
            probes: self.index.ivf.probe(q, sp.nprobe, sp.ef_search),
        }
    }

    /// Execute a batch of plans with the index's own stage-3 decoder.
    /// Returns ranked (score, id) lists, one per plan, identical to
    /// [`SearchIndex::search`] per query. The built-in index decoders
    /// are infallible in practice, but a failure still surfaces as an
    /// `Err` for the caller to handle (the per-request serving path
    /// additionally has its own fallback).
    pub fn execute(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        self.execute_with_decoder(plans, sp, self.index.pipeline.stage3.as_ref())
    }

    /// Execute with a caller-supplied stage-3 decoder. The decoder is
    /// invoked at most once per batch, on the deduplicated union of every
    /// surviving shortlist — server workers pass their thread-local
    /// engine-backed decoder here to spend a single XLA dispatch per
    /// batch. When the index was built with stage 3 disabled, the decoder
    /// is never invoked and the stage-2 ranking is returned (truncated to
    /// `n_final`), exactly like the per-query path.
    pub fn execute_with_decoder(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        decoder: &dyn StageDecoder,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let idx = self.index;
        if plans.is_empty() {
            return Ok(Vec::new());
        }
        let threads = idx.batch_threads(sp);

        // ---- stage 1: flat LUT packs + scattered shard-group scan ----
        let shortlists = self.scan_shortlists(plans, sp, threads, true);

        // ---- stage 2: per-query re-scoring ----
        let sorted: Vec<Vec<(f32, u32)>> =
            shortlists.into_iter().map(|sl| sl.into_sorted()).collect();
        let stage2: Vec<Vec<(f32, u32)>> = if threads > 1 && plans.len() > 1 {
            let mut slots: Vec<(Vec<(f32, u32)>, Vec<(f32, u32)>)> =
                sorted.into_iter().map(|s| (s, Vec::new())).collect();
            pool::par_map_into(&mut slots, threads, |qi, slot| {
                let stage1 = std::mem::take(&mut slot.0);
                slot.1 = idx.stage2_rescore(&self.set, &plans[qi].query, stage1, sp);
            });
            slots.into_iter().map(|(_, rescored)| rescored).collect()
        } else {
            sorted
                .into_iter()
                .zip(plans)
                .map(|(sl, plan)| idx.stage2_rescore(&self.set, &plan.query, sl, sp))
                .collect()
        };
        if sp.n_final == 0 {
            return Ok(stage2);
        }
        if !idx.stage3_enabled {
            // stage-2-final mode: the approximate ranking is the answer
            return Ok(stage2
                .into_iter()
                .map(|mut list| {
                    list.truncate(sp.n_final);
                    list
                })
                .collect());
        }

        // ---- stage 3: one decode over the union of all survivors,
        // gathered from their owning shards ----
        let mut union: BTreeMap<u32, usize> = BTreeMap::new();
        for list in &stage2 {
            for &(_, id) in list {
                union.insert(id, 0);
            }
        }
        if union.is_empty() {
            return Ok(stage2); // every shortlist is empty
        }
        for (row, slot) in union.values_mut().enumerate() {
            *slot = row;
        }
        let ids: Vec<u32> = union.keys().copied().collect();
        let dec = decoder.decode(&self.set.gather_stage3_codes(&ids))?;
        let rerank_one = |qi: usize, list: &[(f32, u32)]| {
            let rows: Vec<usize> = list.iter().map(|&(_, id)| union[&id]).collect();
            idx.exact_rerank(&self.set, &plans[qi].query, list, &dec, &rows, sp.n_final)
        };
        if threads > 1 && plans.len() > 1 {
            let mut out: Vec<Vec<(f32, u32)>> = vec![Vec::new(); plans.len()];
            pool::par_map_into(&mut out, threads, |qi, slot| {
                *slot = rerank_one(qi, &stage2[qi]);
            });
            Ok(out)
        } else {
            Ok(stage2
                .iter()
                .enumerate()
                .map(|(qi, list)| rerank_one(qi, list))
                .collect())
        }
    }

    /// Stage-1 only: pack the per-query LUTs and run the scattered
    /// shard-group scan, returning each plan's stage-1 shortlist in
    /// ascending (score, id) order. `block` selects the multi-query
    /// [`score_block`](crate::quantizers::ApproxScorer::score_block)
    /// kernel vs the scalar per-member `score` loop and `threads` the
    /// group parallelism — every combination returns bit-identical
    /// lists; the knobs exist so `bench_batch_qps` can measure the
    /// kernels against each other.
    pub fn scan_stage1(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        threads: usize,
        block: bool,
    ) -> Vec<Vec<(f32, u32)>> {
        self.scan_shortlists(plans, sp, threads, block)
            .into_iter()
            .map(|sl| sl.into_sorted())
            .collect()
    }

    /// The stage-1 scan over scattered shard groups: one bounded
    /// shortlist per plan. See [`Self::scan_stage1`] for the
    /// `threads`/`block` knobs.
    fn scan_shortlists(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        threads: usize,
        block: bool,
    ) -> Vec<Shortlist> {
        let idx = self.index;
        let set = &*self.set;

        // scatter: bucket → [(query, probe distance)] groups routed to
        // their owning shards, ascending bucket order (= shard-major) —
        // every co-probed inverted list is scanned once for the batch
        let groups = set.plan(plans);

        // flat LUT packs, one per LUT slot (slot 0 = the shared spec,
        // one extra slot per heterogeneous override shard). A slot's
        // pack only fills the LUT rows of queries whose probes actually
        // reach that slot's shard(s) — a batch that rarely (or never)
        // touches an override shard pays nothing for its scorer; rows
        // left unfilled are never read by the scan
        let nslots = set.n_lut_slots;
        let mut query_uses_slot = vec![false; nslots * plans.len()];
        for group in &groups {
            let slot = set.lut_slot[group.shard as usize] as usize;
            for &(qi, _) in &group.members {
                query_uses_slot[slot * plans.len() + qi as usize] = true;
            }
        }
        let packs: Vec<(usize, Vec<f32>)> = (0..nslots)
            .map(|slot| {
                let used = &query_uses_slot[slot * plans.len()..(slot + 1) * plans.len()];
                if !used.iter().any(|&u| u) {
                    return (0, Vec::new());
                }
                let scorer = set.slot_spec(slot, &idx.pipeline).stage1.as_ref();
                let stride = scorer.lut_len();
                let mut luts = vec![0.0f32; plans.len() * stride];
                for (qi, plan) in plans.iter().enumerate() {
                    if used[qi] {
                        scorer.lut_into(&plan.query, &mut luts[qi * stride..(qi + 1) * stride]);
                    }
                }
                (stride, luts)
            })
            .collect();

        // scan groups[lo..hi] into `shortlists` (one slot per plan)
        let scan_range = |lo: usize, hi: usize, shortlists: &mut [Shortlist]| {
            for group in &groups[lo..hi] {
                let sh = &set.shards[group.shard as usize];
                let scorer = sh.spec(&idx.pipeline).stage1.as_ref();
                let (stride, luts) = &packs[set.lut_slot[group.shard as usize] as usize];
                sh.scan_group(scorer, luts, *stride, group, block, shortlists);
            }
        };

        let ngroups = groups.len();
        let mut shortlists: Vec<Shortlist> =
            plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect();
        let threads = threads.min(ngroups).max(1);
        if threads <= 1 {
            scan_range(0, ngroups, &mut shortlists);
            return shortlists;
        }
        // group-parallel scan: per-thread partial shortlists over
        // contiguous chunks of shard groups, merged afterwards. Every
        // (query, candidate) pair still scores exactly once, and the
        // merge pushes under the same total (score, id) order, so the
        // result is bit-identical to the serial scan.
        let chunk = ngroups.div_ceil(threads);
        let nchunks = ngroups.div_ceil(chunk);
        let mut partials: Vec<Vec<Shortlist>> = (0..nchunks)
            .map(|_| plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect())
            .collect();
        // one scoped thread per group chunk, each owning one partial
        // slot (disjoint &mut via par_map_into — no aliasing possible)
        pool::par_map_into(&mut partials, nchunks, |t, part| {
            scan_range(t * chunk, ((t + 1) * chunk).min(ngroups), part);
        });
        for part in partials {
            for (sl, partial) in shortlists.iter_mut().zip(part) {
                sl.merge_from(partial);
            }
        }
        shortlists
    }

    /// Plan + execute a whole query matrix in one batch.
    pub fn search(
        &self,
        queries: &crate::tensor::Matrix,
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let plans: Vec<QueryPlan> =
            (0..queries.rows).map(|i| self.plan(queries.row(i), sp)).collect();
        self.execute(&plans, sp)
    }
}
