//! Batched search execution engine (the serving hot path).
//!
//! Per-query search re-derives everything from scratch: one AQ LUT per
//! call, every probed inverted list scanned per query, one tiny neural
//! decode per query. Under batched traffic that wastes the structure the
//! batch exposes — co-probed buckets, shared decode work — so this module
//! splits search into an explicit *plan* ([`QueryPlan`]) and a batched
//! *execute* ([`BatchSearcher`]):
//!
//!   1. **Plan**: HNSW coarse probe per query (cheap, independent).
//!   2. **Stage 1**: all per-query AQ LUTs are packed into one flat
//!      cache-contiguous buffer; queries are grouped by probed bucket so
//!      each co-probed inverted list is scanned *once per batch* — per
//!      database vector, its code row is read once and scored against
//!      every interested query's LUT slice. Shortlists are bounded
//!      binary max-heaps with a total (score, id) order, so the scan
//!      order change does not change results.
//!   3. **Stage 2**: per-query pairwise re-scoring through
//!      [`SearchIndex::stage2_rescore`] — a per-query joint LUT or
//!      direct dots, chosen by the [`stage2_use_lut`] cost model.
//!   4. **Stage 3**: ONE decode over the union of all surviving
//!      shortlists (deduplicated across queries), then per-query exact
//!      distances. The decoder is pluggable: the default is the pure-Rust
//!      reference decoder; [`BatchSearcher::execute_with_decoder`] lets a
//!      caller holding an [`Engine`](crate::runtime::Engine) route the
//!      union through a single [`Codec::decode`](crate::qinco::Codec)
//!      dispatch instead (one padded XLA call per batch, not per query).
//!
//! The engine is deliberately single-threaded per call: the serving
//! router parallelizes across batches/workers, and
//! [`SearchIndex::search_batch`] chunks a query matrix across threads.
//! Every path is result-identical to [`SearchIndex::search`] (pinned by
//! the `batch_equivalence` property suite).

use super::pipeline::{gather_codes, SearchIndex, SearchParams};
use crate::qinco::reference;
use crate::quantizers::Codes;
use crate::tensor::Matrix;
use crate::util::topk::Shortlist;
use anyhow::Result;
use std::collections::BTreeMap;

/// Stage-2 cost model: should a query build a joint pairwise LUT?
///
/// LUT: `steps·K²·d` multiplies up front, then ~1 flop per (candidate,
/// step). Direct: `steps·d` multiplies per candidate. The LUT amortizes
/// when `n_cands ≳ K²·d/(d−1)`. Both the per-query and batched paths
/// consult this same function, so their float rounding never diverges.
pub fn stage2_use_lut(n_cands: usize, n_steps: usize, k: usize, d: usize) -> bool {
    if n_cands == 0 || n_steps == 0 {
        return false;
    }
    let lut_cost = n_steps
        .saturating_mul(k)
        .saturating_mul(k)
        .saturating_mul(d)
        .saturating_add(n_cands.saturating_mul(n_steps));
    let direct_cost = n_cands.saturating_mul(n_steps).saturating_mul(d);
    lut_cost < direct_cost
}

/// Per-query plan: the owned query vector plus its coarse-probe result.
/// Building plans is independent per query; executing them is where the
/// batch-level sharing happens.
pub struct QueryPlan {
    pub query: Vec<f32>,
    /// (probe distance, bucket) from the HNSW coarse quantizer
    pub probes: Vec<(f32, u32)>,
}

/// Batched executor over a shared [`SearchIndex`].
pub struct BatchSearcher<'a> {
    pub index: &'a SearchIndex,
}

impl<'a> BatchSearcher<'a> {
    pub fn new(index: &'a SearchIndex) -> BatchSearcher<'a> {
        BatchSearcher { index }
    }

    /// Stage 0 for one query: coarse-probe and snapshot the query.
    pub fn plan(&self, q: &[f32], sp: &SearchParams) -> QueryPlan {
        QueryPlan {
            query: q.to_vec(),
            probes: self.index.ivf.probe(q, sp.nprobe, sp.ef_search),
        }
    }

    /// Execute a batch of plans with the pure-Rust reference decoder for
    /// stage 3. Returns ranked (dist, id) lists, one per plan, identical
    /// to [`SearchIndex::search`] per query.
    pub fn execute(&self, plans: &[QueryPlan], sp: &SearchParams) -> Vec<Vec<(f32, u32)>> {
        let params = &self.index.params;
        self.execute_with_decoder(plans, sp, &mut |codes| Ok(reference::decode(params, codes)))
            .expect("reference decoder is infallible")
    }

    /// Execute with a caller-supplied stage-3 decoder. The decoder is
    /// invoked at most once per batch, on the deduplicated union of every
    /// surviving shortlist — pass
    /// `|codes| codec.decode(&mut engine, &params, codes)` to spend a
    /// single XLA dispatch per batch on the runtime path.
    pub fn execute_with_decoder(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        decode: &mut dyn FnMut(&Codes) -> Result<Matrix>,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let idx = self.index;
        if plans.is_empty() {
            return Ok(Vec::new());
        }

        // ---- stage 1: flat LUT pack + bucket-grouped scan ----
        let stride = idx.aq.lut_len();
        let mut luts = vec![0.0f32; plans.len() * stride];
        for (qi, plan) in plans.iter().enumerate() {
            idx.aq.lut_into(&plan.query, &mut luts[qi * stride..(qi + 1) * stride]);
        }
        // bucket → [(query, probe distance)]: every co-probed inverted
        // list is scanned once for the whole batch
        let mut groups: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
        for (qi, plan) in plans.iter().enumerate() {
            for &(probe_d, bucket) in &plan.probes {
                groups.entry(bucket).or_default().push((qi as u32, probe_d));
            }
        }
        let mut shortlists: Vec<Shortlist> =
            plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect();
        for (&bucket, members) in &groups {
            for &id in &idx.ivf.lists[bucket as usize] {
                let i = id as usize;
                let code = idx.codes.row(i);
                let term = idx.aq_terms[i];
                for &(qi, probe_d) in members {
                    let qi = qi as usize;
                    let lut = &luts[qi * stride..(qi + 1) * stride];
                    shortlists[qi].push(probe_d + idx.aq.score(lut, code, term), id);
                }
            }
        }

        // ---- stage 2: per-query pairwise re-scoring ----
        let stage2: Vec<Vec<(f32, u32)>> = shortlists
            .into_iter()
            .zip(plans)
            .map(|(sl, plan)| idx.stage2_rescore(&plan.query, sl.into_sorted(), sp))
            .collect();
        if sp.n_final == 0 {
            return Ok(stage2);
        }

        // ---- stage 3: one decode over the union of all survivors ----
        let mut union: BTreeMap<u32, usize> = BTreeMap::new();
        for list in &stage2 {
            for &(_, id) in list {
                union.insert(id, 0);
            }
        }
        if union.is_empty() {
            return Ok(stage2); // every shortlist is empty
        }
        for (row, slot) in union.values_mut().enumerate() {
            *slot = row;
        }
        let ids: Vec<usize> = union.keys().map(|&id| id as usize).collect();
        let dec = decode(&gather_codes(&idx.codes, &ids))?;
        Ok(stage2
            .into_iter()
            .zip(plans)
            .map(|(list, plan)| {
                let rows: Vec<usize> = list.iter().map(|&(_, id)| union[&id]).collect();
                idx.exact_rerank(&plan.query, &list, &dec, &rows, sp.n_final)
            })
            .collect())
    }

    /// Plan + execute a whole query matrix in one batch.
    pub fn search(&self, queries: &Matrix, sp: &SearchParams) -> Vec<Vec<(f32, u32)>> {
        let plans: Vec<QueryPlan> =
            (0..queries.rows).map(|i| self.plan(queries.row(i), sp)).collect();
        self.execute(&plans, sp)
    }
}

#[cfg(test)]
mod tests {
    use super::stage2_use_lut;

    #[test]
    fn cost_model_boundaries() {
        // degenerate inputs never pick the LUT
        assert!(!stage2_use_lut(0, 4, 8, 8));
        assert!(!stage2_use_lut(100, 0, 8, 8));
        // tiny shortlists cannot amortize K²·d LUT entries per step
        assert!(!stage2_use_lut(4, 6, 256, 32));
        // k=8, d=8, 6 steps: build 3072 flops vs 48/candidate direct —
        // breakeven near |S| ≈ 73
        assert!(!stage2_use_lut(64, 6, 8, 8));
        assert!(stage2_use_lut(128, 6, 8, 8));
        // larger codebooks push the breakeven far beyond the shortlist
        assert!(!stage2_use_lut(128, 6, 64, 8));
    }

    #[test]
    fn cost_model_monotone_in_candidates() {
        // once the LUT pays off it keeps paying off as |S| grows
        let mut prev = false;
        for n in [1usize, 8, 32, 64, 128, 512, 4096] {
            let now = stage2_use_lut(n, 6, 8, 8);
            assert!(now || !prev, "LUT choice flapped at n={n}");
            prev = now;
        }
        assert!(prev, "LUT must win for huge shortlists");
    }
}
