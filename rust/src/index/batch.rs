//! Batched search execution engine (the serving hot path).
//!
//! Per-query search re-derives everything from scratch: one stage-1 LUT
//! per call, every probed inverted list scanned per query, one tiny
//! decode per query. Under batched traffic that wastes the structure the
//! batch exposes — co-probed buckets, shared decode work — so this module
//! splits search into an explicit *plan* ([`QueryPlan`]) and a batched
//! *execute* ([`BatchSearcher`]):
//!
//!   1. **Plan**: HNSW coarse probe per query (cheap, independent).
//!   2. **Stage 1 (scatter)**: per-query LUTs are packed into flat
//!      cache-contiguous buffers — one pack per LUT slot: every shard on
//!      the shared [`PipelineSpec`](super::pipeline::PipelineSpec) reads
//!      the same pack, each heterogeneous override shard gets its own
//!      ([`ShardSet::lut_slot`](super::shard::ShardSet::lut_slot)).
//!      [`ShardSet::plan`](super::shard::ShardSet::plan) routes the
//!      batch's probed buckets to their owning
//!      [`IndexShard`](super::shard::IndexShard)s as bucket groups, in
//!      ascending bucket order, so each co-probed inverted list is
//!      scanned *once per batch*. Each shard scans its local groups with
//!      the multi-query
//!      [`score_block`](crate::quantizers::ApproxScorer::score_block)
//!      kernel (blocks of up to
//!      [`SCORE_BLOCK`](crate::quantizers::SCORE_BLOCK) co-probed
//!      queries per code row), pushing `(score, global id)` into the
//!      per-query shortlists — bounded binary max-heaps with a total
//!      (score, id) order, so neither the scan-order change, the block
//!      kernel, nor the shard partition changes results (gather =
//!      shortlist merge under that total order). The pack's physical
//!      layout follows [`SearchParams::scan_layout`]: `Flat` is the
//!      seed layout, `Transposed` re-packs each scanned chunk
//!      query-major for unit-stride loads (bit-identical to `Flat` by
//!      contract), and `Packed4` quantizes the LUTs to `u8` against the
//!      shards' nibble-packed code tables (bounded-error scoring mode —
//!      see [`ScanLayout`](crate::quantizers::ScanLayout)).
//!   3. **Stage 2**: per-query re-scoring through the shared
//!      (crate-private) `SearchIndex::stage2_rescore` — a per-query joint
//!      LUT or direct dots, chosen by the scorer's
//!      [`use_lut`](crate::quantizers::ApproxScorer::use_lut) cost model,
//!      with each candidate scored by its owning shard's stage-2 scorer.
//!   4. **Stage 3**: ONE decode over the union of all surviving
//!      shortlists (deduplicated across queries, rows gathered from the
//!      owning shards), then per-query exact distances. The decoder is
//!      pluggable: [`BatchSearcher::execute`] uses the index's own
//!      [`StageDecoder`], while [`BatchSearcher::execute_with_decoder`]
//!      accepts any `&dyn StageDecoder` — this is how server workers
//!      route the union through their thread-local
//!      [`RuntimeDecoder`](crate::qinco::RuntimeDecoder) (one engine
//!      dispatch per batch — native nn kernels by default, one padded
//!      XLA dispatch under the `pjrt` feature; engine-per-worker).
//!      Either way a decode failure surfaces as an `Err`, never a panic
//!      inside the engine.
//!
//! # Intra-batch parallelism
//!
//! One execute call is no longer pinned to a single thread:
//! [`SearchParams::batch_threads`] splits the scattered shard groups
//! across the scoped thread pool
//! ([`par_map_into`](crate::util::pool::par_map_into) over per-thread
//! partials; each thread scans a contiguous chunk of groups — which may
//! span shard boundaries — into its own per-query shortlists, which are
//! then merged under the total (score, id) order), and runs the
//! per-query stage-2/stage-3 loops across the same thread count. Because
//! every (query, candidate) pair is scored exactly once with identical
//! floats and the shortlist order is total, results are bit-identical
//! for **every** thread count and **every** shard count — the default
//! `batch_threads = 1` keeps the historical behavior where the serving
//! router parallelizes across batches/workers and
//! [`SearchIndex::search_batch`] chunks a query matrix across threads;
//! raise it when one large batch would otherwise execute on a single
//! worker thread (multi-shard scans then proceed in parallel across
//! shards, since the group list is shard-major).
//!
//! Every path is result-identical to [`SearchIndex::search`] for every
//! pipeline configuration, thread count and shard count (pinned by the
//! `batch_equivalence` property suite).

use super::pipeline::{SearchIndex, SearchParams};
use super::shard::ShardSet;
use crate::quantizers::{LutPack, QuantLutPack, ScanLayout, ScanPack, StageDecoder};
use crate::util::deadline::Deadline;
use crate::util::fault::{self, FaultPoint};
use crate::util::pool;
use crate::util::topk::Shortlist;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

// the cost model moved next to the ApproxScorer trait it now serves;
// re-exported here (and from `crate::index`) for existing callers
pub use crate::quantizers::stage2_use_lut;

/// Per-query plan: the owned query vector plus its coarse-probe result.
/// Building plans is independent per query; executing them is where the
/// batch-level sharing happens.
pub struct QueryPlan {
    pub query: Vec<f32>,
    /// (probe distance, bucket) from the HNSW coarse quantizer
    pub probes: Vec<(f32, u32)>,
}

/// What a deadline-aware execute returns: the ranked lists plus whether
/// deadline pressure cut the pipeline short.
///
/// The degraded ladder (each rung sets `degraded: true`, and `degraded`
/// is **never** false unless the full configured pipeline ran):
/// 1. the stage-1 scan aborted between (or inside) bucket groups — the
///    lists rank whatever was scanned before the deadline;
/// 2. the deadline expired after a complete scan — stage 2 is skipped
///    and the stage-1 ranking stands;
/// 3. the deadline expired after stage 2 — stage 3 is skipped **whole**
///    (never half-run) and the stage-2 ranking is returned, truncated
///    to `n_final`.
///
/// With [`Deadline::none()`] no rung can trigger and the output is
/// bit-identical to [`BatchSearcher::execute`] — which is how the
/// equivalence suites stay pinned.
#[derive(Clone, Debug)]
pub struct BatchOutput {
    /// ranked (score, id) lists, one per plan
    pub results: Vec<Vec<(f32, u32)>>,
    /// true iff the pipeline was cut short by the deadline
    pub degraded: bool,
}

/// Batched executor over a shared [`SearchIndex`], pinned to one epoch
/// snapshot: the [`ShardSet`] is captured at construction, so a whole
/// plan+execute cycle — however long it runs — sees exactly one index
/// state even while writers publish new epochs concurrently.
pub struct BatchSearcher<'a> {
    pub index: &'a SearchIndex,
    set: Arc<ShardSet>,
}

impl<'a> BatchSearcher<'a> {
    /// Pin the index's *current* epoch for this searcher's lifetime.
    pub fn new(index: &'a SearchIndex) -> BatchSearcher<'a> {
        let set = index.snapshot();
        BatchSearcher { index, set }
    }

    /// Pin an explicitly supplied snapshot — used by
    /// [`SearchIndex::search_batch`] so every per-thread chunk of one
    /// call shares a single epoch.
    pub fn with_snapshot(index: &'a SearchIndex, set: Arc<ShardSet>) -> BatchSearcher<'a> {
        BatchSearcher { index, set }
    }

    /// The epoch snapshot this searcher is pinned to.
    pub fn snapshot(&self) -> &ShardSet {
        &self.set
    }

    /// Stage 0 for one query: coarse-probe and snapshot the query.
    pub fn plan(&self, q: &[f32], sp: &SearchParams) -> QueryPlan {
        QueryPlan {
            query: q.to_vec(),
            probes: self.index.ivf.probe(q, sp.nprobe, sp.ef_search),
        }
    }

    /// Execute a batch of plans with the index's own stage-3 decoder.
    /// Returns ranked (score, id) lists, one per plan, identical to
    /// [`SearchIndex::search`] per query. The built-in index decoders
    /// are infallible in practice, but a failure still surfaces as an
    /// `Err` for the caller to handle (the per-request serving path
    /// additionally has its own fallback).
    pub fn execute(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        self.execute_within(plans, sp, None, Deadline::none()).map(|o| o.results)
    }

    /// Execute with a caller-supplied stage-3 decoder. The decoder is
    /// invoked at most once per batch, on the deduplicated union of every
    /// surviving shortlist — server workers pass their thread-local
    /// engine-backed decoder here to spend a single XLA dispatch per
    /// batch. When the index was built with stage 3 disabled, the decoder
    /// is never invoked and the stage-2 ranking is returned (truncated to
    /// `n_final`), exactly like the per-query path.
    pub fn execute_with_decoder(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        decoder: &dyn StageDecoder,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        self.execute_within(plans, sp, Some(decoder), Deadline::none()).map(|o| o.results)
    }

    /// Deadline-aware execute — the serving router's entry point.
    /// `decoder` selects the stage-3 decoder (`None` = the index's own);
    /// `deadline` is checked between bucket-group scans (and every
    /// [`DEADLINE_CHECK_ROWS`](super::shard) scanned rows inside a
    /// group), after stage 1, and **before** stage 3 — stage 3 either
    /// runs whole or is skipped whole. Under deadline pressure the
    /// result is the stage-1/2 shortlist ranking with
    /// [`BatchOutput::degraded`] set (see [`BatchOutput`] for the exact
    /// ladder); with [`Deadline::none()`] this is bit-identical to
    /// [`Self::execute`] / [`Self::execute_with_decoder`].
    pub fn execute_within(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        decoder: Option<&dyn StageDecoder>,
        deadline: Deadline,
    ) -> Result<BatchOutput> {
        let idx = self.index;
        if plans.is_empty() {
            return Ok(BatchOutput { results: Vec::new(), degraded: false });
        }
        // the packed layout needs the nibble-packed tables only a
        // packed4 assembly builds — a typed request error, not a panic
        // deep inside the scan
        if sp.scan_layout == ScanLayout::Packed4 && !self.set.packed4_ready() {
            anyhow::bail!(
                "scan layout \"packed4\" requires an index built with --scan-layout packed4 \
                 (this index has no packed stage-1 tables)"
            );
        }
        let threads = idx.batch_threads(sp);

        // ---- stage 1: per-layout LUT packs + scattered shard-group scan ----
        let (shortlists, scan_complete) =
            self.scan_shortlists_within(plans, sp, threads, true, deadline);
        let mut degraded = !scan_complete;

        // ---- stage 2: per-query re-scoring (skipped under pressure:
        // an aborted scan, or a deadline that expired during a complete
        // scan, leaves the stage-1 ranking standing) ----
        let sorted: Vec<Vec<(f32, u32)>> =
            shortlists.into_iter().map(|sl| sl.into_sorted()).collect();
        let stage2: Vec<Vec<(f32, u32)>> = if degraded || deadline.expired() {
            degraded = true;
            sorted
        } else if threads > 1 && plans.len() > 1 {
            let mut slots: Vec<(Vec<(f32, u32)>, Vec<(f32, u32)>)> =
                sorted.into_iter().map(|s| (s, Vec::new())).collect();
            pool::par_map_into(&mut slots, threads, |qi, slot| {
                let stage1 = std::mem::take(&mut slot.0);
                slot.1 = idx.stage2_rescore(&self.set, &plans[qi].query, stage1, sp);
            });
            slots.into_iter().map(|(_, rescored)| rescored).collect()
        } else {
            sorted
                .into_iter()
                .zip(plans)
                .map(|(sl, plan)| idx.stage2_rescore(&self.set, &plan.query, sl, sp))
                .collect()
        };
        if sp.n_final == 0 {
            return Ok(BatchOutput { results: stage2, degraded });
        }
        let truncated = |lists: Vec<Vec<(f32, u32)>>| {
            lists
                .into_iter()
                .map(|mut list| {
                    list.truncate(sp.n_final);
                    list
                })
                .collect()
        };
        if !idx.stage3_enabled {
            // stage-2-final mode: the approximate ranking is the answer
            return Ok(BatchOutput { results: truncated(stage2), degraded });
        }
        // the deadline gate for stage 3: skipped whole, never half-run.
        // A degraded reply is exactly the stage-1/2 ranking (truncated
        // to the requested depth), flagged as such.
        if degraded || deadline.expired() {
            return Ok(BatchOutput { results: truncated(stage2), degraded: true });
        }

        // ---- stage 3: one decode over the union of all survivors,
        // gathered from their owning shards ----
        let decoder = decoder.unwrap_or_else(|| idx.pipeline.stage3.as_ref());
        let mut union: BTreeMap<u32, usize> = BTreeMap::new();
        for list in &stage2 {
            for &(_, id) in list {
                union.insert(id, 0);
            }
        }
        if union.is_empty() {
            return Ok(BatchOutput { results: stage2, degraded: false }); // every shortlist is empty
        }
        for (row, slot) in union.values_mut().enumerate() {
            *slot = row;
        }
        let ids: Vec<u32> = union.keys().copied().collect();
        let dec = decoder.decode(&self.set.gather_stage3_codes(&ids))?;
        let rerank_one = |qi: usize, list: &[(f32, u32)]| {
            let rows: Vec<usize> = list.iter().map(|&(_, id)| union[&id]).collect();
            idx.exact_rerank(&self.set, &plans[qi].query, list, &dec, &rows, sp.n_final)
        };
        let results = if threads > 1 && plans.len() > 1 {
            let mut out: Vec<Vec<(f32, u32)>> = vec![Vec::new(); plans.len()];
            pool::par_map_into(&mut out, threads, |qi, slot| {
                *slot = rerank_one(qi, &stage2[qi]);
            });
            out
        } else {
            stage2
                .iter()
                .enumerate()
                .map(|(qi, list)| rerank_one(qi, list))
                .collect()
        };
        Ok(BatchOutput { results, degraded: false })
    }

    /// Stage-1 only: pack the per-query LUTs and run the scattered
    /// shard-group scan, returning each plan's stage-1 shortlist in
    /// ascending (score, id) order. `block` selects the multi-query
    /// [`score_block`](crate::quantizers::ApproxScorer::score_block)
    /// kernel vs the scalar per-member `score` loop, `threads` the
    /// group parallelism, and [`SearchParams::scan_layout`] the pack
    /// layout — every `threads`/`block` combination returns
    /// bit-identical lists, as do the `Flat` and `Transposed` layouts;
    /// `Packed4` scores in its bounded-error quantized mode. The knobs
    /// exist so `bench_batch_qps` can measure the kernels against each
    /// other.
    pub fn scan_stage1(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        threads: usize,
        block: bool,
    ) -> Vec<Vec<(f32, u32)>> {
        self.scan_shortlists_within(plans, sp, threads, block, Deadline::none())
            .0
            .into_iter()
            .map(|sl| sl.into_sorted())
            .collect()
    }

    /// The stage-1 scan over scattered shard groups: one bounded
    /// shortlist per plan, plus whether the scan ran to completion
    /// (`false` = the deadline expired between or inside bucket groups
    /// and the tail was abandoned — the shortlists rank whatever was
    /// scanned). With [`Deadline::none()`] the completion flag is always
    /// `true` and the scan is bit-identical to its historical behavior.
    /// See [`Self::scan_stage1`] for the `threads`/`block` knobs.
    fn scan_shortlists_within(
        &self,
        plans: &[QueryPlan],
        sp: &SearchParams,
        threads: usize,
        block: bool,
        deadline: Deadline,
    ) -> (Vec<Shortlist>, bool) {
        let idx = self.index;
        let set = &*self.set;

        // scatter: bucket → [(query, probe distance)] groups routed to
        // their owning shards, ascending bucket order (= shard-major) —
        // every co-probed inverted list is scanned once for the batch
        let groups = set.plan(plans);

        // scan packs, one per LUT slot (slot 0 = the shared spec, one
        // extra slot per heterogeneous override shard). A slot's pack
        // only fills the LUT rows of queries whose probes actually
        // reach that slot's shard(s) — a batch that rarely (or never)
        // touches an override shard pays nothing for its scorer; rows
        // left unfilled are never read by the scan. The flat pack is
        // always built first (its constructor is the bounds proof the
        // scan kernels rely on), then wrapped per the request's
        // [`ScanLayout`]: `Transposed` carries the same flat floats
        // (transposition is chunk-local at scan time), `Packed4`
        // quantizes them to `u8` with the slot scorer's packed geometry.
        // An unused slot gets the empty pack — scanning it would fail
        // `check_members` loudly instead of reading out of bounds.
        let nslots = set.n_lut_slots;
        let mut query_uses_slot = vec![false; nslots * plans.len()];
        for group in &groups {
            let slot = set.lut_slot[group.shard as usize] as usize;
            for &(qi, _) in &group.members {
                query_uses_slot[slot * plans.len() + qi as usize] = true;
            }
        }
        let packs: Vec<ScanPack> = (0..nslots)
            .map(|slot| {
                let used = &query_uses_slot[slot * plans.len()..(slot + 1) * plans.len()];
                if !used.iter().any(|&u| u) {
                    return ScanPack::Flat(LutPack::empty());
                }
                let scorer = set.slot_spec(slot, &idx.pipeline).stage1.as_ref();
                let stride = scorer.lut_len();
                let mut luts = vec![0.0f32; plans.len() * stride];
                for (qi, plan) in plans.iter().enumerate() {
                    if used[qi] {
                        scorer.lut_into(&plan.query, &mut luts[qi * stride..(qi + 1) * stride]);
                    }
                }
                let flat = LutPack::new(stride, plans.len(), luts);
                match sp.scan_layout {
                    ScanLayout::Flat => ScanPack::Flat(flat),
                    ScanLayout::Transposed => ScanPack::Transposed(flat),
                    ScanLayout::Packed4 => {
                        let (m, k) = scorer.packed4_geometry().expect(
                            "packed4 scan with a stage-1 family that has no packed \
                             geometry (build-time validation missed?)",
                        );
                        ScanPack::Packed4(QuantLutPack::quantize(&flat, m, k))
                    }
                }
            })
            .collect();

        // scan groups[lo..hi] into `shortlists` (one slot per plan);
        // returns false when the deadline cut the range short. The
        // deadline is checked before every bucket group (and every
        // DEADLINE_CHECK_ROWS rows inside scan_group) — with no
        // deadline both checks are a dead branch.
        let scan_range = |lo: usize, hi: usize, shortlists: &mut [Shortlist]| -> bool {
            for group in &groups[lo..hi] {
                // fault probe: a stalled scan (drives the mid-scan
                // deadline-degradation path in tests)
                if let Some(delay) = fault::fire(FaultPoint::SlowScan) {
                    std::thread::sleep(delay);
                }
                if deadline.expired() {
                    return false;
                }
                let sh = &set.shards[group.shard as usize];
                let scorer = sh.spec(&idx.pipeline).stage1.as_ref();
                let pack = &packs[set.lut_slot[group.shard as usize] as usize];
                if !sh.scan_group(scorer, pack, group, block, deadline, shortlists) {
                    return false;
                }
            }
            true
        };

        let ngroups = groups.len();
        let mut shortlists: Vec<Shortlist> =
            plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect();
        let threads = threads.min(ngroups).max(1);
        if threads <= 1 {
            let complete = scan_range(0, ngroups, &mut shortlists);
            return (shortlists, complete);
        }
        // group-parallel scan: per-thread partial shortlists over
        // contiguous chunks of shard groups, merged afterwards. Every
        // (query, candidate) pair still scores exactly once, and the
        // merge pushes under the same total (score, id) order, so the
        // result is bit-identical to the serial scan. Under a deadline,
        // any chunk aborting marks the whole scan incomplete.
        let chunk = ngroups.div_ceil(threads);
        let nchunks = ngroups.div_ceil(chunk);
        let mut partials: Vec<(Vec<Shortlist>, bool)> = (0..nchunks)
            .map(|_| (plans.iter().map(|_| Shortlist::new(sp.n_aq)).collect(), true))
            .collect();
        // one scoped thread per group chunk, each owning one partial
        // slot (disjoint &mut via par_map_into — no aliasing possible)
        pool::par_map_into(&mut partials, nchunks, |t, part| {
            part.1 = scan_range(t * chunk, ((t + 1) * chunk).min(ngroups), &mut part.0);
        });
        let mut complete = true;
        for (part, chunk_complete) in partials {
            complete &= chunk_complete;
            for (sl, partial) in shortlists.iter_mut().zip(part) {
                sl.merge_from(partial);
            }
        }
        (shortlists, complete)
    }

    /// Plan + execute a whole query matrix in one batch.
    pub fn search(
        &self,
        queries: &crate::tensor::Matrix,
        sp: &SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>> {
        let plans: Vec<QueryPlan> =
            (0..queries.rows).map(|i| self.plan(queries.row(i), sp)).collect();
        self.execute(&plans, sp)
    }
}
