//! Inverted file index (Jégou et al., 2010): k-means coarse quantizer +
//! HNSW over the centroids (the paper's `IVF…_HNSW32` structure) +
//! inverted lists of database ids.

use super::hnsw::Hnsw;
use crate::clustering::{kmeans, KMeansCfg};
use crate::tensor::Matrix;
use crate::util::prng::Rng;

pub struct Ivf {
    pub centroids: Matrix,
    pub hnsw: Hnsw,
    /// inverted lists: database row ids per bucket. NOTE: when this Ivf
    /// is assembled into a [`crate::index::SearchIndex`], the lists are
    /// **drained into the bucket-owned shards**
    /// ([`crate::index::ShardSet`]) — on an assembled index read the
    /// per-bucket candidates through the owning
    /// [`crate::index::IndexShard`], not here.
    pub lists: Vec<Vec<u32>>,
    /// bucket of each database row. Like [`Self::lists`], drained into
    /// the [`crate::index::ShardSet`] snapshot at assembly (ingest
    /// extends it per epoch) — on an assembled index read
    /// `snapshot().assign`, not here.
    pub assign: Vec<u32>,
}

impl Ivf {
    /// Train the coarse quantizer on (a sample of) `train`, then assign
    /// every `database` row to its bucket.
    pub fn build(train: &Matrix, database: &Matrix, k_ivf: usize, seed: u64) -> Ivf {
        let mut rng = Rng::new(seed ^ 0x1F1F);
        // k-means wants several points per centroid; sample if huge
        let sample = if train.rows > 50 * k_ivf {
            train.gather_rows(&rng.sample_indices(train.rows, 50 * k_ivf))
        } else {
            train.clone()
        };
        let km = kmeans(&sample, &KMeansCfg::new(k_ivf).iters(10).seed(seed));
        let centroids = km.centroids;
        let hnsw = Hnsw::build(&centroids, 16, 64, seed ^ 0xBEEF);
        let assign = crate::tensor::assign_all(database, &centroids, crate::util::pool::default_threads());
        let mut lists = vec![Vec::new(); centroids.rows];
        for (i, &a) in assign.iter().enumerate() {
            lists[a as usize].push(i as u32);
        }
        Ivf { centroids, hnsw, lists, assign }
    }

    pub fn k_ivf(&self) -> usize {
        self.centroids.rows
    }

    /// The `nprobe` buckets closest to `q` (HNSW with `ef_search`).
    pub fn probe(&self, q: &[f32], nprobe: usize, ef_search: usize) -> Vec<(f32, u32)> {
        self.hnsw.search(q, nprobe, ef_search)
    }

    /// Residuals of the database rows w.r.t. their centroid (the vectors
    /// the fine quantizer actually encodes).
    pub fn residuals(&self, database: &Matrix) -> Matrix {
        let mut out = database.clone();
        for i in 0..out.rows {
            let c = self.assign[i] as usize;
            let crow = self.centroids.row(c).to_vec();
            crate::tensor::sub_assign(out.row_mut(i), &crow);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn lists_partition_database() {
        let train = generate(Flavor::Deep, 400, 8, 1);
        let db = generate(Flavor::Deep, 300, 8, 2);
        let ivf = Ivf::build(&train, &db, 16, 3);
        let total: usize = ivf.lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 300);
        let mut seen = vec![false; 300];
        for l in &ivf.lists {
            for &id in l {
                assert!(!seen[id as usize], "duplicate id {id}");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let train = generate(Flavor::BigAnn, 300, 8, 4);
        let db = generate(Flavor::BigAnn, 100, 8, 5);
        let ivf = Ivf::build(&train, &db, 8, 6);
        for i in 0..db.rows {
            let (want, _) = crate::tensor::argmin_l2(db.row(i), &ivf.centroids);
            assert_eq!(ivf.assign[i], want as u32);
        }
    }

    #[test]
    fn probe_finds_own_bucket() {
        let train = generate(Flavor::Deep, 500, 8, 7);
        let db = generate(Flavor::Deep, 200, 8, 8);
        let ivf = Ivf::build(&train, &db, 16, 9);
        let mut hits = 0;
        for i in 0..50 {
            let probes = ivf.probe(db.row(i), 3, 64);
            if probes.iter().any(|&(_, b)| b == ivf.assign[i]) {
                hits += 1;
            }
        }
        assert!(hits >= 48, "probe recall {hits}/50");
    }

    #[test]
    fn residuals_subtract_centroids() {
        let train = generate(Flavor::Deep, 200, 6, 10);
        let db = generate(Flavor::Deep, 50, 6, 11);
        let ivf = Ivf::build(&train, &db, 4, 12);
        let res = ivf.residuals(&db);
        for i in 0..db.rows {
            let c = ivf.centroids.row(ivf.assign[i] as usize);
            for j in 0..6 {
                assert!((res.row(i)[j] - (db.row(i)[j] - c[j])).abs() < 1e-6);
            }
        }
    }
}
