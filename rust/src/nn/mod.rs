//! Native neural-network kernels: the QINCo2 `f_theta` forward pass as
//! plain Rust, shared by every bulk decode/encode path in the crate.
//!
//! This is the CPU twin of `python/compile/kernels/qinco_step.py` — the
//! same fused step (input projection, concat-conditioning, statically
//! unrolled residual ReLU blocks, output projection, codeword add) over
//! the same weight layout, so the [`crate::runtime`] native backend can
//! execute the manifest's decode/encode artifacts without PJRT.
//!
//! # Kernel shape
//!
//! [`matmul`] is a cache-blocked `y = x @ w`: the weight matrix is
//! walked in [`LANES`]-wide column panels (one panel is `cin × 8` floats
//! — L1-resident for every layer of the model family), and each panel is
//! swept over all rows with a fixed-width 8-lane accumulator, the same
//! unrolled-lane idiom as the scan kernel's `score_block_lanes`. The
//! trailing `cout % LANES` columns take a scalar remainder path.
//!
//! # Numerics
//!
//! Every output element accumulates its `cin` products in ascending-`i`
//! order — exactly the summation order of the scalar oracle loop in
//! [`crate::qinco::reference`] (`f_theta_scalar`). IEEE f32 addition in
//! a fixed order is deterministic, so for finite weights the blocked
//! kernel is expected to match the oracle bit for bit; the documented
//! *contract*, pinned by the `rust_decoder_matches_reference` suite, is
//! agreement within `1e-5` absolute. Greedy/beam encode both route
//! through [`qinco_step`], so `encode_beam(A=K, B=1)` stays bit-identical
//! to greedy — the invariant live-index ingest relies on.
//!
//! # Tail handling
//!
//! [`qinco_step`] mirrors the Python kernel's zero-pad tail: the batch
//! is padded with zero rows up to a whole number of [`ROW_TILE`]-row
//! tiles (`t = min(ROW_TILE, max(n, 1))`, `pad = (-n) % t`) and the pad
//! is stripped from the output. The kernels are row-independent, so the
//! pad is mathematically transparent — it exists so the blocking matches
//! the artifact semantics exactly, including `n = 0` and `n < tile`.
//! One deliberate difference: `qinco_step.py` lowers `L = 0` as a single
//! *zeroed* residual block because Pallas rejects zero-sized blocks
//! (`v + relu(v @ 0) @ 0 = v`); native code just skips the block loop,
//! which is the same function.

/// Column lanes per accumulator block of [`matmul`] — the same width as
/// the scan kernel's `SCORE_BLOCK`.
pub const LANES: usize = 8;

/// Row-tile granularity of [`qinco_step`]'s zero-pad batching, matching
/// the Pallas kernel's TPU tile. Batches are processed (and padded) in
/// tiles of `min(ROW_TILE, max(n, 1))` rows so scratch buffers stay
/// cache-resident for arbitrarily large decodes.
pub const ROW_TILE: usize = 512;

/// `y[rows, cout] = x[rows, cin] @ w[cin, cout]`, all row-major flat
/// slices. Overwrites `y[..rows * cout]`.
///
/// Blocked as described in the module docs; each `y[r, o]` is the
/// ascending-`i` sum of `x[r, i] * w[i, o]`, so results are bit-stable
/// across batch sizes and identical to a naive scalar triple loop.
pub fn matmul(x: &[f32], rows: usize, cin: usize, w: &[f32], cout: usize, y: &mut [f32]) {
    debug_assert!(x.len() >= rows * cin, "matmul: x too short");
    debug_assert!(w.len() >= cin * cout, "matmul: w too short");
    debug_assert!(y.len() >= rows * cout, "matmul: y too short");
    let full = cout - cout % LANES;
    let mut o = 0;
    while o < full {
        // one cin×LANES weight panel, swept over every row while hot
        for r in 0..rows {
            let xr = &x[r * cin..(r + 1) * cin];
            let mut acc = [0.0f32; LANES];
            for (i, &xv) in xr.iter().enumerate() {
                let wp = &w[i * cout + o..i * cout + o + LANES];
                acc[0] += xv * wp[0];
                acc[1] += xv * wp[1];
                acc[2] += xv * wp[2];
                acc[3] += xv * wp[3];
                acc[4] += xv * wp[4];
                acc[5] += xv * wp[5];
                acc[6] += xv * wp[6];
                acc[7] += xv * wp[7];
            }
            y[r * cout + o..r * cout + o + LANES].copy_from_slice(&acc);
        }
        o += LANES;
    }
    // remainder columns (cout % LANES): scalar lanes, same i-order
    for o in full..cout {
        for r in 0..rows {
            let xr = &x[r * cin..(r + 1) * cin];
            let mut a = 0.0f32;
            for (i, &xv) in xr.iter().enumerate() {
                a += xv * w[i * cout + o];
            }
            y[r * cout + o] = a;
        }
    }
}

/// One decode step's weight slices (already sliced to step `m` out of
/// the `[M, ...]` parameter tensors — see
/// `crate::qinco::native::step_weights` for the `ParamStore` adapter).
/// Layouts match the manifest ABI: `in_w [d, de]`, `cond_w [de+d, de]`,
/// `cond_b [de]`, `up_w [l, de, dh]`, `down_w [l, dh, de]`,
/// `out_w [de, d]`, all row-major flat.
pub struct StepWeights<'a> {
    pub d: usize,
    pub de: usize,
    pub dh: usize,
    pub l: usize,
    pub in_w: &'a [f32],
    pub cond_w: &'a [f32],
    pub cond_b: &'a [f32],
    pub up_w: &'a [f32],
    pub down_w: &'a [f32],
    pub out_w: &'a [f32],
}

impl StepWeights<'_> {
    fn debug_validate(&self) {
        debug_assert_eq!(self.in_w.len(), self.d * self.de);
        debug_assert_eq!(self.cond_w.len(), (self.de + self.d) * self.de);
        debug_assert_eq!(self.cond_b.len(), self.de);
        debug_assert_eq!(self.up_w.len(), self.l * self.de * self.dh);
        debug_assert_eq!(self.down_w.len(), self.l * self.dh * self.de);
        debug_assert_eq!(self.out_w.len(), self.de * self.d);
    }
}

/// Fused `f_theta(c | xhat)` for a batch: returns `[rows, d]` flat.
///
/// ```text
/// c_emb = c @ in_w
/// v     = c_emb + ([c_emb ; xhat] @ cond_w + cond_b)
/// L ×   { v += relu(v @ up_w[i]) @ down_w[i] }
/// out   = c + v @ out_w
/// ```
///
/// `c` and `xhat` are `[rows, d]` flat. Mirrors the Pallas kernel's
/// zero-pad tail handling (module docs); the pad rows are stripped
/// before returning.
pub fn qinco_step(sw: &StepWeights, c: &[f32], xhat: &[f32], rows: usize) -> Vec<f32> {
    let (d, de, dh, l) = (sw.d, sw.de, sw.dh, sw.l);
    sw.debug_validate();
    debug_assert_eq!(c.len(), rows * d, "qinco_step: c shape");
    debug_assert_eq!(xhat.len(), rows * d, "qinco_step: xhat shape");
    // t = min(tile, max(n, 1)); pad = (-n) % t  — qinco_step.py verbatim
    let t = ROW_TILE.min(rows.max(1));
    let pad = (t - rows % t) % t;
    let padded = rows + pad;
    let (c_owned, xhat_owned);
    let (c_all, xhat_all): (&[f32], &[f32]) = if pad == 0 {
        (c, xhat)
    } else {
        c_owned = {
            let mut v = c.to_vec();
            v.resize(padded * d, 0.0);
            v
        };
        xhat_owned = {
            let mut v = xhat.to_vec();
            v.resize(padded * d, 0.0);
            v
        };
        (&c_owned, &xhat_owned)
    };
    let mut out = vec![0.0f32; padded * d];
    // scratch reused across row tiles
    let mut c_emb = vec![0.0f32; t * de];
    let mut cat = vec![0.0f32; t * (de + d)];
    let mut v = vec![0.0f32; t * de];
    let mut hidden = vec![0.0f32; t * dh];
    let mut delta = vec![0.0f32; t * de];
    let mut lo = 0;
    while lo < padded {
        let ct = &c_all[lo * d..(lo + t) * d];
        let xt = &xhat_all[lo * d..(lo + t) * d];
        // c_emb = c @ in_w
        matmul(ct, t, d, sw.in_w, de, &mut c_emb);
        // v = c_emb + ([c_emb ; xhat] @ cond_w + cond_b)
        for r in 0..t {
            cat[r * (de + d)..r * (de + d) + de].copy_from_slice(&c_emb[r * de..(r + 1) * de]);
            cat[r * (de + d) + de..(r + 1) * (de + d)].copy_from_slice(&xt[r * d..(r + 1) * d]);
        }
        matmul(&cat, t, de + d, sw.cond_w, de, &mut v);
        for r in 0..t {
            for j in 0..de {
                v[r * de + j] += sw.cond_b[j] + c_emb[r * de + j];
            }
        }
        // statically-unrolled residual ReLU blocks
        for blk in 0..l {
            let up = &sw.up_w[blk * de * dh..(blk + 1) * de * dh];
            let down = &sw.down_w[blk * dh * de..(blk + 1) * dh * de];
            matmul(&v, t, de, up, dh, &mut hidden);
            for h in hidden.iter_mut() {
                if *h < 0.0 {
                    *h = 0.0;
                }
            }
            matmul(&hidden, t, dh, down, de, &mut delta);
            for (vv, &dv) in v.iter_mut().zip(&delta) {
                *vv += dv;
            }
        }
        // out = c + v @ out_w
        let ot = &mut out[lo * d..(lo + t) * d];
        matmul(&v, t, de, sw.out_w, d, ot);
        for (o, &cv) in ot.iter_mut().zip(ct) {
            *o += cv;
        }
        lo += t;
    }
    out.truncate(rows * d);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect()
    }

    /// The oracle: naive triple loop, ascending-i accumulation.
    fn matmul_naive(x: &[f32], rows: usize, cin: usize, w: &[f32], cout: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; rows * cout];
        for r in 0..rows {
            for o in 0..cout {
                let mut a = 0.0f32;
                for i in 0..cin {
                    a += x[r * cin + i] * w[i * cout + o];
                }
                y[r * cout + o] = a;
            }
        }
        y
    }

    #[test]
    fn blocked_matmul_is_bit_identical_to_naive() {
        let mut rng = Rng::new(7);
        // cover full-lane, remainder-only, and mixed column counts plus
        // row counts around the lane width
        for &(rows, cin, cout) in &[
            (1usize, 3usize, 1usize),
            (4, 8, 8),
            (7, 5, 7),
            (8, 16, 24),
            (9, 11, 17),
            (16, 13, 9),
            (33, 24, 16),
        ] {
            let x = randv(&mut rng, rows * cin);
            let w = randv(&mut rng, cin * cout);
            let mut y = vec![f32::NAN; rows * cout];
            matmul(&x, rows, cin, &w, cout, &mut y);
            assert_eq!(
                y,
                matmul_naive(&x, rows, cin, &w, cout),
                "rows={rows} cin={cin} cout={cout}"
            );
        }
    }

    fn random_weights(rng: &mut Rng, d: usize, de: usize, dh: usize, l: usize) -> Vec<Vec<f32>> {
        vec![
            randv(rng, d * de),
            randv(rng, (de + d) * de),
            randv(rng, de),
            randv(rng, l * de * dh),
            randv(rng, l * dh * de),
            randv(rng, de * d),
        ]
    }

    fn weights_of(buf: &[Vec<f32>], d: usize, de: usize, dh: usize, l: usize) -> StepWeights<'_> {
        StepWeights {
            d,
            de,
            dh,
            l,
            in_w: &buf[0],
            cond_w: &buf[1],
            cond_b: &buf[2],
            up_w: &buf[3],
            down_w: &buf[4],
            out_w: &buf[5],
        }
    }

    #[test]
    fn qinco_step_batch_is_row_independent_and_pad_transparent() {
        // non-multiple-of-LANES dims exercise the remainder columns; the
        // batch result must equal per-row evaluation exactly (row
        // independence), which also proves the zero-pad tail transparent
        let (d, de, dh, l) = (5usize, 7usize, 11usize, 2usize);
        let mut rng = Rng::new(23);
        let buf = random_weights(&mut rng, d, de, dh, l);
        let sw = weights_of(&buf, d, de, dh, l);
        let rows = 13;
        let c = randv(&mut rng, rows * d);
        let xhat = randv(&mut rng, rows * d);
        let batch = qinco_step(&sw, &c, &xhat, rows);
        assert_eq!(batch.len(), rows * d);
        assert!(batch.iter().all(|v| v.is_finite()));
        for r in 0..rows {
            let one = qinco_step(&sw, &c[r * d..(r + 1) * d], &xhat[r * d..(r + 1) * d], 1);
            assert_eq!(&batch[r * d..(r + 1) * d], &one[..], "row {r}");
        }
    }

    #[test]
    fn qinco_step_zero_network_is_pure_codeword_passthrough() {
        // all-zero weights: v = 0, every block adds 0, out = c + 0 = c
        let (d, de, dh, l) = (6usize, 9usize, 4usize, 1usize);
        let buf = vec![
            vec![0.0; d * de],
            vec![0.0; (de + d) * de],
            vec![0.0; de],
            vec![0.0; l * de * dh],
            vec![0.0; l * dh * de],
            vec![0.0; de * d],
        ];
        let sw = weights_of(&buf, d, de, dh, l);
        let mut rng = Rng::new(3);
        let c = randv(&mut rng, 4 * d);
        let xhat = randv(&mut rng, 4 * d);
        assert_eq!(qinco_step(&sw, &c, &xhat, 4), c);
    }

    #[test]
    fn qinco_step_l_zero_skips_residual_blocks() {
        // L = 0 must behave as the identity on v (the Pallas kernel's
        // zeroed-block workaround computes the same function)
        let (d, de, dh) = (5usize, 7usize, 11usize);
        let mut rng = Rng::new(41);
        let mut buf = random_weights(&mut rng, d, de, dh, 1);
        buf[3] = Vec::new(); // up_w: [0, de, dh]
        buf[4] = Vec::new(); // down_w
        let sw = weights_of(&buf, d, de, dh, 0);
        let c = randv(&mut rng, 3 * d);
        let xhat = randv(&mut rng, 3 * d);
        let got = qinco_step(&sw, &c, &xhat, 3);
        // oracle without blocks: out = c + (c_emb + cat @ cond_w + b) @ out_w
        for r in 0..3 {
            let cr = &c[r * d..(r + 1) * d];
            let xr = &xhat[r * d..(r + 1) * d];
            let c_emb = matmul_naive(cr, 1, d, &buf[0], de);
            let mut cat = c_emb.clone();
            cat.extend_from_slice(xr);
            let mut v = matmul_naive(&cat, 1, de + d, &buf[1], de);
            for j in 0..de {
                v[j] += buf[2][j] + c_emb[j];
            }
            let mut want = matmul_naive(&v, 1, de, &buf[5], d);
            for j in 0..d {
                want[j] += cr[j];
            }
            for j in 0..d {
                assert!(
                    (got[r * d + j] - want[j]).abs() <= 1e-5,
                    "row {r} col {j}: {} vs {}",
                    got[r * d + j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn qinco_step_empty_batch_is_empty() {
        let (d, de, dh, l) = (4usize, 4usize, 4usize, 1usize);
        let mut rng = Rng::new(9);
        let buf = random_weights(&mut rng, d, de, dh, l);
        let sw = weights_of(&buf, d, de, dh, l);
        assert!(qinco_step(&sw, &[], &[], 0).is_empty());
    }
}
