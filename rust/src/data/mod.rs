//! Datasets: synthetic generators standing in for the paper's corpora
//! (DESIGN.md §Substitutions), fvecs/ivecs I/O for real data, and
//! brute-force ground truth.
//!
//! The four generators mimic the *structure* that drives multi-codebook
//! quantization behaviour on the paper's four benchmarks: cluster
//! anisotropy, heavy tails, non-negativity/sparsity and low intrinsic
//! dimension. All methods are compared on identical draws, so orderings
//! and ratios are meaningful even though absolute MSE differs from the
//! paper's corpora.

pub mod io;

use crate::tensor::{self, Matrix};
use crate::util::{pool, prng::Rng};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    /// Deep1B-like: CNN embeddings — L2-normalized anisotropic Gaussian
    /// mixture with shared low-rank structure.
    Deep,
    /// BigANN-like: SIFT descriptors — non-negative, clipped, integer-ish
    /// histogram bins with cluster structure.
    BigAnn,
    /// FB-ssnpp-like: SSCD copy-detection embeddings — heavy-tailed,
    /// weak cluster structure (the paper's hardest dataset).
    Ssnpp,
    /// Contriever-like: text embeddings — strong low-rank component and
    /// larger variance spread across directions.
    Contriever,
}

impl Flavor {
    pub fn parse(s: &str) -> Option<Flavor> {
        match s.to_ascii_lowercase().as_str() {
            "deep" | "deep1m" | "deep1b" => Some(Flavor::Deep),
            "bigann" | "bigann1m" | "sift" => Some(Flavor::BigAnn),
            "ssnpp" | "fb-ssnpp" | "fbssnpp" => Some(Flavor::Ssnpp),
            "contriever" => Some(Flavor::Contriever),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Flavor::Deep => "deep",
            Flavor::BigAnn => "bigann",
            Flavor::Ssnpp => "ssnpp",
            Flavor::Contriever => "contriever",
        }
    }

    pub fn all() -> [Flavor; 4] {
        [Flavor::BigAnn, Flavor::Deep, Flavor::Contriever, Flavor::Ssnpp]
    }
}

/// A train/database/query split with brute-force ground truth.
pub struct Dataset {
    pub flavor: Flavor,
    pub train: Matrix,
    pub database: Matrix,
    pub queries: Matrix,
    /// index into `database` of each query's exact nearest neighbor
    pub ground_truth: Vec<u32>,
    /// normalization applied to all splits (train statistics)
    pub norm_means: Vec<f32>,
    pub norm_std: f32,
    seed: u64,
}

impl Dataset {
    /// Draw extra vectors from the same distribution, normalized with the
    /// dataset's train statistics (e.g. large decoder-fitting splits).
    pub fn extra_split(&self, n: usize, tag: u64) -> Matrix {
        let mut xs = generate(self.flavor, n, self.train.cols,
                              self.seed.wrapping_add(100 + tag));
        normalize_with(&mut xs, &self.norm_means, self.norm_std);
        xs
    }
}

/// Mixture model shared by all flavors; flavor-specific post-processing
/// shapes the marginals.
struct Mixture {
    centers: Matrix,
    /// per-component, per-dimension scales (anisotropy)
    scales: Matrix,
    weights: Vec<f32>,
    /// shared low-rank basis mixed into every sample
    basis: Matrix,
    rank: usize,
}

fn build_mixture(flavor: Flavor, d: usize, rng: &mut Rng) -> Mixture {
    let n_comp = match flavor {
        Flavor::Ssnpp => 8, // weak structure
        _ => 64,
    };
    let rank = match flavor {
        Flavor::Contriever => d / 4,
        Flavor::Deep => d / 2,
        _ => d,
    }
    .max(1);
    let mut centers = Matrix::zeros(n_comp, d);
    let spread = match flavor {
        Flavor::Ssnpp => 0.3,
        _ => 1.0,
    };
    rng.fill_normal(&mut centers.data, 0.0, spread);
    let mut scales = Matrix::zeros(n_comp, d);
    for v in scales.data.iter_mut() {
        // log-normal anisotropy
        *v = (0.5 * rng.normal_f32()).exp()
            * match flavor {
                Flavor::Contriever => (2.0 * rng.f32()).exp() * 0.3,
                _ => 0.45,
            };
    }
    let mut weights: Vec<f32> = (0..n_comp).map(|_| rng.f32() + 0.05).collect();
    let total: f32 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= total;
    }
    let mut basis = Matrix::zeros(rank, d);
    rng.fill_normal(&mut basis.data, 0.0, 1.0 / (rank as f32).sqrt());
    Mixture { centers, scales, weights, basis, rank }
}

fn sample_into(mix: &Mixture, flavor: Flavor, out: &mut [f32], d: usize, rng: &mut Rng) {
    // pick component
    let mut t = rng.f32();
    let mut comp = mix.weights.len() - 1;
    for (i, &w) in mix.weights.iter().enumerate() {
        t -= w;
        if t <= 0.0 {
            comp = i;
            break;
        }
    }
    let c = mix.centers.row(comp);
    let s = mix.scales.row(comp);
    // low-rank latent
    let mut latent = vec![0.0f32; mix.rank];
    rng.fill_normal(&mut latent, 0.0, 1.0);
    for j in 0..d {
        let mut lowrank = 0.0f32;
        for (r, &lv) in latent.iter().enumerate() {
            lowrank += lv * mix.basis.data[r * d + j];
        }
        out[j] = c[j] + s[j] * rng.normal_f32() + lowrank;
    }
    match flavor {
        Flavor::BigAnn => {
            // SIFT-like: shift positive, clip, quantize to integer grid
            for v in out.iter_mut() {
                *v = (v.abs() * 40.0).min(218.0).floor() / 128.0;
            }
        }
        Flavor::Deep => {
            // L2-normalize like CNN embeddings
            let n = tensor::sqnorm(out).sqrt().max(1e-9);
            for v in out.iter_mut() {
                *v /= n;
            }
        }
        Flavor::Ssnpp => {
            // heavy tails: cube a fraction of the mass
            for v in out.iter_mut() {
                *v += 0.15 * *v * *v * *v;
            }
        }
        Flavor::Contriever => {}
    }
}

/// Generate `n` vectors of dimension `d` from the flavor's mixture.
pub fn generate(flavor: Flavor, n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let mix = build_mixture(flavor, d, &mut rng);
    let out = Matrix::zeros(n, d);
    // per-row RNG forked deterministically so generation order is stable
    let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let nthreads = pool::default_threads();
    pool::scope_chunks(n, nthreads, |lo, hi| {
        // SAFETY-free parallel write: each chunk writes disjoint rows via
        // raw pointer arithmetic is avoided — instead recompute slice.
        // We use interior chunking through an unsafe-free trick: cast to
        // atomic is overkill; chunk rows are disjoint so we use a local
        // buffer then copy through a raw pointer.
        let base = out.data.as_ptr() as usize;
        for i in lo..hi {
            let mut r = Rng::new(seeds[i]);
            let mut buf = vec![0.0f32; d];
            sample_into(&mix, flavor, &mut buf, d, &mut r);
            unsafe {
                let dst = (base as *mut f32).add(i * d);
                std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, d);
            }
        }
    });
    out
}

/// Normalize columns to zero mean / unit global std, in place — the
/// QINCo2 training normalization (App. A.2). Returns (means, std).
pub fn normalize(xs: &mut Matrix) -> (Vec<f32>, f32) {
    let means = xs.col_means();
    let mut var = 0.0f64;
    for i in 0..xs.rows {
        let row = xs.row_mut(i);
        for (v, &m) in row.iter_mut().zip(&means) {
            *v -= m;
            var += (*v as f64) * (*v as f64);
        }
    }
    let std = ((var / (xs.rows * xs.cols).max(1) as f64).sqrt() as f32).max(1e-9);
    for v in xs.data.iter_mut() {
        *v /= std;
    }
    (means, std)
}

/// Apply a previously computed normalization to another split.
pub fn normalize_with(xs: &mut Matrix, means: &[f32], std: f32) {
    for i in 0..xs.rows {
        for (v, &m) in xs.row_mut(i).iter_mut().zip(means) {
            *v = (*v - m) / std;
        }
    }
}

/// Exact nearest neighbor (squared L2) of each query, multi-threaded.
pub fn brute_force_gt(database: &Matrix, queries: &Matrix) -> Vec<u32> {
    let mut out = vec![0u32; queries.rows];
    pool::par_map_into(&mut out, pool::default_threads(), |qi, slot| {
        *slot = tensor::argmin_l2(queries.row(qi), database).0 as u32;
    });
    out
}

/// Exact top-k nearest neighbors of each query (for recall@k baselines).
pub fn brute_force_gt_k(database: &Matrix, queries: &Matrix, k: usize) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); queries.rows];
    pool::par_map_into(&mut out, pool::default_threads(), |qi, slot| {
        *slot = tensor::topk_l2(queries.row(qi), database, k)
            .into_iter()
            .map(|(i, _)| i as u32)
            .collect();
    });
    out
}

/// Build a full train/db/query dataset with ground truth, normalized by
/// train statistics (the paper's protocol).
pub fn load(flavor: Flavor, n_train: usize, n_db: usize, n_query: usize, d: usize,
            seed: u64) -> Dataset {
    let mut train = generate(flavor, n_train, d, seed);
    let mut database = generate(flavor, n_db, d, seed.wrapping_add(1));
    let mut queries = generate(flavor, n_query, d, seed.wrapping_add(2));
    let (means, std) = normalize(&mut train);
    normalize_with(&mut database, &means, std);
    normalize_with(&mut queries, &means, std);
    let ground_truth = brute_force_gt(&database, &queries);
    Dataset {
        flavor,
        train,
        database,
        queries,
        ground_truth,
        norm_means: means,
        norm_std: std,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_determinism() {
        for f in Flavor::all() {
            let a = generate(f, 50, 16, 7);
            let b = generate(f, 50, 16, 7);
            assert_eq!(a.rows, 50);
            assert_eq!(a.cols, 16);
            assert_eq!(a.data, b.data, "{f:?} not deterministic");
            assert!(a.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(Flavor::Deep, 10, 8, 1);
        let b = generate(Flavor::Deep, 10, 8, 2);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn flavors_have_expected_marginals() {
        let big = generate(Flavor::BigAnn, 500, 16, 3);
        assert!(big.data.iter().all(|&v| v >= 0.0), "bigann must be non-negative");
        let deep = generate(Flavor::Deep, 200, 16, 3);
        for i in 0..deep.rows {
            let n = tensor::sqnorm(deep.row(i)).sqrt();
            assert!((n - 1.0).abs() < 1e-4, "deep rows must be unit norm, got {n}");
        }
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut xs = generate(Flavor::Contriever, 400, 8, 4);
        let (_, _) = normalize(&mut xs);
        let means = xs.col_means();
        assert!(means.iter().all(|m| m.abs() < 1e-3), "{means:?}");
        let var: f64 = xs.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / xs.data.len() as f64;
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
    }

    #[test]
    fn ground_truth_is_exact() {
        let db = generate(Flavor::Deep, 200, 8, 5);
        let q = generate(Flavor::Deep, 20, 8, 6);
        let gt = brute_force_gt(&db, &q);
        for (qi, &g) in gt.iter().enumerate() {
            let dg = tensor::l2_sq(q.row(qi), db.row(g as usize));
            for i in 0..db.rows {
                assert!(dg <= tensor::l2_sq(q.row(qi), db.row(i)) + 1e-6);
            }
        }
    }

    #[test]
    fn gt_k_first_equals_gt1() {
        let db = generate(Flavor::BigAnn, 100, 8, 8);
        let q = generate(Flavor::BigAnn, 10, 8, 9);
        let g1 = brute_force_gt(&db, &q);
        let gk = brute_force_gt_k(&db, &q, 5);
        for (a, b) in g1.iter().zip(&gk) {
            assert_eq!(*a, b[0]);
            assert_eq!(b.len(), 5);
        }
    }

    #[test]
    fn load_builds_consistent_dataset() {
        let ds = load(Flavor::Deep, 100, 80, 10, 8, 42);
        assert_eq!(ds.train.rows, 100);
        assert_eq!(ds.database.rows, 80);
        assert_eq!(ds.queries.rows, 10);
        assert_eq!(ds.ground_truth.len(), 10);
        assert!(ds.ground_truth.iter().all(|&g| (g as usize) < 80));
    }
}
