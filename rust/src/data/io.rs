//! fvecs/ivecs/bvecs readers and writers — the interchange formats of the
//! BigANN/Deep1B benchmark ecosystem — so the library also runs on the
//! real corpora when they are available on disk.
//!
//! fvecs layout per vector: `u32 d` (little-endian) then `d` f32 values;
//! ivecs is the same with i32 payloads, bvecs with u8.

use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

fn read_all(path: &Path) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    )
    .read_to_end(&mut buf)?;
    Ok(buf)
}

/// Read an .fvecs file, optionally capping the number of vectors.
pub fn read_fvecs(path: &Path, limit: Option<usize>) -> Result<Matrix> {
    let buf = read_all(path)?;
    let mut rows: Vec<f32> = Vec::new();
    let mut d0: Option<usize> = None;
    let mut i = 0usize;
    let mut n = 0usize;
    while i + 4 <= buf.len() {
        if let Some(l) = limit {
            if n >= l {
                break;
            }
        }
        let d = u32::from_le_bytes(buf[i..i + 4].try_into().unwrap()) as usize;
        i += 4;
        if d == 0 || d > 1 << 20 {
            bail!("implausible dimension {d} at byte {i} of {path:?}");
        }
        match d0 {
            None => d0 = Some(d),
            Some(dd) if dd != d => bail!("ragged fvecs: {dd} vs {d}"),
            _ => {}
        }
        if i + 4 * d > buf.len() {
            bail!("truncated fvecs {path:?}");
        }
        for j in 0..d {
            rows.push(f32::from_le_bytes(buf[i + 4 * j..i + 4 * j + 4].try_into().unwrap()));
        }
        i += 4 * d;
        n += 1;
    }
    let d = d0.unwrap_or(0);
    Ok(Matrix::from_vec(n, d, rows))
}

/// Read a .bvecs file (u8 payload) into f32.
pub fn read_bvecs(path: &Path, limit: Option<usize>) -> Result<Matrix> {
    let buf = read_all(path)?;
    let mut rows: Vec<f32> = Vec::new();
    let mut d0: Option<usize> = None;
    let mut i = 0usize;
    let mut n = 0usize;
    while i + 4 <= buf.len() {
        if let Some(l) = limit {
            if n >= l {
                break;
            }
        }
        let d = u32::from_le_bytes(buf[i..i + 4].try_into().unwrap()) as usize;
        i += 4;
        if d == 0 || d > 1 << 20 {
            bail!("implausible dimension {d} in {path:?}");
        }
        match d0 {
            None => d0 = Some(d),
            Some(dd) if dd != d => bail!("ragged bvecs"),
            _ => {}
        }
        if i + d > buf.len() {
            bail!("truncated bvecs {path:?}");
        }
        rows.extend(buf[i..i + d].iter().map(|&b| b as f32));
        i += d;
        n += 1;
    }
    Ok(Matrix::from_vec(n, d0.unwrap_or(0), rows))
}

/// Read an .ivecs file (ground-truth index lists).
pub fn read_ivecs(path: &Path, limit: Option<usize>) -> Result<Vec<Vec<i32>>> {
    let buf = read_all(path)?;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 4 <= buf.len() {
        if let Some(l) = limit {
            if out.len() >= l {
                break;
            }
        }
        let d = u32::from_le_bytes(buf[i..i + 4].try_into().unwrap()) as usize;
        i += 4;
        if i + 4 * d > buf.len() {
            bail!("truncated ivecs {path:?}");
        }
        let mut row = Vec::with_capacity(d);
        for j in 0..d {
            row.push(i32::from_le_bytes(buf[i + 4 * j..i + 4 * j + 4].try_into().unwrap()));
        }
        i += 4 * d;
        out.push(row);
    }
    Ok(out)
}

pub fn write_fvecs(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for i in 0..m.rows {
        w.write_all(&(m.cols as u32).to_le_bytes())?;
        for &v in m.row(i) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("qinco_io_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fvecs_roundtrip() {
        let dir = tmpdir();
        let p = dir.join("a.fvecs");
        let m = Matrix::from_vec(3, 4, (0..12).map(|i| i as f32 * 0.5).collect());
        write_fvecs(&p, &m).unwrap();
        let m2 = read_fvecs(&p, None).unwrap();
        assert_eq!(m, m2);
        let m1 = read_fvecs(&p, Some(2)).unwrap();
        assert_eq!(m1.rows, 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fvecs_rejects_truncation() {
        let dir = tmpdir();
        let p = dir.join("bad.fvecs");
        std::fs::write(&p, 4u32.to_le_bytes()).unwrap(); // header only
        assert!(read_fvecs(&p, None).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_parse() {
        let dir = tmpdir();
        let p = dir.join("g.ivecs");
        let mut bytes = Vec::new();
        for row in [[1i32, 2], [3, 4]] {
            bytes.extend(2u32.to_le_bytes());
            for v in row {
                bytes.extend(v.to_le_bytes());
            }
        }
        std::fs::write(&p, &bytes).unwrap();
        let rows = read_ivecs(&p, None).unwrap();
        assert_eq!(rows, vec![vec![1, 2], vec![3, 4]]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bvecs_parse() {
        let dir = tmpdir();
        let p = dir.join("b.bvecs");
        let mut bytes = Vec::new();
        bytes.extend(3u32.to_le_bytes());
        bytes.extend([10u8, 20, 30]);
        std::fs::write(&p, &bytes).unwrap();
        let m = read_bvecs(&p, None).unwrap();
        assert_eq!(m.data, vec![10.0, 20.0, 30.0]);
        std::fs::remove_file(&p).ok();
    }
}
