//! k-means (Lloyd) with k-means++ seeding — the substrate under RQ/PQ
//! codebook training, IVF coarse quantizers and the QINCo2 codebook
//! initialization (App. A.2: "10 k-means iterations per codebook").

use crate::tensor::{self, Matrix};
use crate::util::{pool, prng::Rng};

#[derive(Clone, Debug)]
pub struct KMeansCfg {
    pub k: usize,
    pub iters: usize,
    pub seed: u64,
    pub nthreads: usize,
}

impl KMeansCfg {
    pub fn new(k: usize) -> Self {
        KMeansCfg { k, iters: 10, seed: 0x5EED, nthreads: pool::default_threads() }
    }

    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[derive(Clone, Debug)]
pub struct KMeans {
    pub centroids: Matrix,
    /// final assignment of the training rows
    pub assign: Vec<u32>,
    /// mean squared distance at the last iteration
    pub inertia: f64,
}

/// k-means++ seeding: D^2-weighted sampling of initial centroids.
fn seed_pp(xs: &Matrix, k: usize, rng: &mut Rng) -> Matrix {
    let n = xs.rows;
    let mut cents = Matrix::zeros(k, xs.cols);
    let first = rng.below(n);
    cents.row_mut(0).copy_from_slice(xs.row(first));
    let mut d2: Vec<f32> = (0..n)
        .map(|i| tensor::l2_sq(xs.row(i), cents.row(0)))
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        cents.row_mut(c).copy_from_slice(xs.row(pick));
        for i in 0..n {
            let d = tensor::l2_sq(xs.row(i), cents.row(c));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    cents
}

/// Lloyd iterations with empty-cluster splitting (an empty cluster takes
/// a random point from the largest cluster — same policy as Faiss).
pub fn kmeans(xs: &Matrix, cfg: &KMeansCfg) -> KMeans {
    assert!(xs.rows > 0, "kmeans on empty data");
    let k = cfg.k.min(xs.rows);
    let mut rng = Rng::new(cfg.seed);
    let mut cents = seed_pp(xs, k, &mut rng);
    let mut assign = vec![0u32; xs.rows];
    let mut inertia = f64::INFINITY;

    for _ in 0..cfg.iters.max(1) {
        assign = tensor::assign_all(xs, &cents, cfg.nthreads);
        // recompute centroids
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, xs.cols);
        for (i, &a) in assign.iter().enumerate() {
            counts[a as usize] += 1;
            tensor::add_assign(sums.row_mut(a as usize), xs.row(i));
        }
        for c in 0..k {
            if counts[c] == 0 {
                // split: steal a random member of the biggest cluster
                let big = (0..k).max_by_key(|&j| counts[j]).unwrap();
                let members: Vec<usize> = assign
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a as usize == big)
                    .map(|(i, _)| i)
                    .collect();
                let pick = members[rng.below(members.len())];
                let mut row = xs.row(pick).to_vec();
                for v in row.iter_mut() {
                    *v += 1e-4 * rng.normal_f32();
                }
                cents.row_mut(c).copy_from_slice(&row);
            } else {
                let inv = 1.0 / counts[c] as f32;
                let sum_row = sums.row(c).to_vec();
                for (o, s) in cents.row_mut(c).iter_mut().zip(sum_row) {
                    *o = s * inv;
                }
            }
        }
        // inertia for convergence reporting
        let mut acc = 0.0f64;
        for (i, &a) in assign.iter().enumerate() {
            acc += tensor::l2_sq(xs.row(i), cents.row(a as usize)) as f64;
        }
        inertia = acc / xs.rows as f64;
    }
    // final assignment must be consistent with the *final* centroids
    assign = tensor::assign_all(xs, &cents, cfg.nthreads);
    let mut acc = 0.0f64;
    for (i, &a) in assign.iter().enumerate() {
        acc += tensor::l2_sq(xs.row(i), cents.row(a as usize)) as f64;
    }
    inertia = inertia.min(acc / xs.rows as f64);
    KMeans { centroids: cents, assign, inertia }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, centers: &[[f32; 2]], spread: f32, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for c in centers {
            for _ in 0..n_per {
                data.push(c[0] + spread * rng.normal_f32());
                data.push(c[1] + spread * rng.normal_f32());
            }
        }
        Matrix::from_vec(n_per * centers.len(), 2, data)
    }

    #[test]
    fn finds_well_separated_blobs() {
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let xs = blobs(100, &centers, 0.3, 1);
        let km = kmeans(&xs, &KMeansCfg::new(3).iters(15));
        assert!(km.inertia < 0.5, "inertia {}", km.inertia);
        // every true center must be close to some learned centroid
        for c in &centers {
            let (_, d) = tensor::argmin_l2(c, &km.centroids);
            assert!(d < 0.5, "center {c:?} unmatched (d={d})");
        }
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let xs = blobs(2, &[[0.0, 0.0]], 0.1, 2);
        let km = kmeans(&xs, &KMeansCfg::new(16).iters(3));
        assert_eq!(km.centroids.rows, 2);
    }

    #[test]
    fn more_iters_no_worse() {
        let xs = blobs(200, &[[0.0, 0.0], [5.0, 5.0]], 1.0, 3);
        let i1 = kmeans(&xs, &KMeansCfg::new(8).iters(1).seed(42)).inertia;
        let i10 = kmeans(&xs, &KMeansCfg::new(8).iters(12).seed(42)).inertia;
        assert!(i10 <= i1 + 1e-6, "{i10} > {i1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let xs = blobs(50, &[[0.0, 0.0], [3.0, 3.0]], 0.5, 4);
        let a = kmeans(&xs, &KMeansCfg::new(4).seed(9));
        let b = kmeans(&xs, &KMeansCfg::new(4).seed(9));
        assert_eq!(a.centroids.data, b.centroids.data);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn assignments_are_nearest() {
        let xs = blobs(100, &[[0.0, 0.0], [4.0, 4.0]], 0.8, 5);
        let km = kmeans(&xs, &KMeansCfg::new(5).iters(8));
        for i in 0..xs.rows {
            let (best, _) = tensor::argmin_l2(xs.row(i), &km.centroids);
            assert_eq!(best as u32, km.assign[i]);
        }
    }
}
