//! QINCo2: vector compression and large-scale nearest-neighbor search with
//! improved implicit neural codebooks.
//!
//! Rust + JAX + Pallas reproduction of *"Qinco2: Vector Compression and
//! Search with Improved Implicit Neural Codebooks"* (Vallaeys, Muckley,
//! Verbeek, Douze — ICLR 2025).
//!
//! Three-layer architecture (see `DESIGN.md`):
//! - **L3 (this crate)**: the search/serving coordinator — IVF + HNSW +
//!   LUT distance scans + pairwise-decoder re-ranking + batched neural
//!   decode, plus the full training driver. Pure Rust, no Python at
//!   runtime.
//! - **L2 (`python/compile/model.py`)**: the QINCo2 model (beam-search
//!   encoder, decoder, AdamW train step) AOT-lowered to HLO text.
//! - **L1 (`python/compile/kernels/`)**: Pallas kernels for the
//!   f_theta candidate evaluator and pre-selection scoring.
//!
//! The [`runtime`] module loads the HLO artifacts through the PJRT C API
//! (`xla` crate — vendored as a stub when the real bindings are absent;
//! see `rust/vendor/xla`) and exposes them as plain Rust functions;
//! [`qinco`] wraps them into a trainer and codec; [`index`] and
//! [`server`] build the billion-scale-search pipeline of the paper's
//! Figure 3; [`quantizers`] holds the classical baselines (PQ, OPQ, RQ,
//! LSQ) and the paper's pairwise additive decoder.
//!
//! Search executes through one of two result-identical paths:
//! - per-query [`index::SearchIndex::search`] (Fig. 3, one request at a
//!   time), and
//! - the batched engine [`index::batch`] — per-batch flat AQ-LUT packs,
//!   bucket-grouped inverted-list scans (each co-probed list is read
//!   once per batch), per-query stage-2 joint LUTs chosen by the
//!   [`index::stage2_use_lut`] cost model, and a single union decode for
//!   stage 3. The [`server`] router forms dynamic batches and dispatches
//!   them whole through this engine.

pub mod cli;
pub mod clustering;
pub mod data;
pub mod experiments;
pub mod index;
pub mod linalg;
pub mod metrics;
pub mod qinco;
pub mod quantizers;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

pub use tensor::Matrix;
