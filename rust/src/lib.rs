//! QINCo2: vector compression and large-scale nearest-neighbor search with
//! improved implicit neural codebooks.
//!
//! Rust + JAX + Pallas reproduction of *"Qinco2: Vector Compression and
//! Search with Improved Implicit Neural Codebooks"* (Vallaeys, Muckley,
//! Verbeek, Douze — ICLR 2025).
//!
//! Three-layer architecture (see `DESIGN.md`):
//! - **L3 (this crate)**: the search/serving coordinator — IVF + HNSW +
//!   LUT distance scans + pairwise-decoder re-ranking + batched neural
//!   decode, plus the full training driver. Pure Rust, no Python at
//!   runtime.
//! - **L2 (`python/compile/model.py`)**: the QINCo2 model (beam-search
//!   encoder, decoder, AdamW train step) AOT-lowered to HLO text.
//! - **L1 (`python/compile/kernels/`)**: Pallas kernels for the
//!   f_theta candidate evaluator and pre-selection scoring.
//!
//! The [`runtime`] module executes the manifest's model artifacts as
//! plain Rust functions behind a backend seam: the default **native**
//! backend dispatches every inference artifact to the in-crate [`nn`]
//! kernels (blocked matmul + fused QINCo2 step — no HLO files, no FFI),
//! while the off-by-default `pjrt` cargo feature swaps in the HLO
//! artifacts through the PJRT C API (`xla` crate — vendored as a stub
//! when the real bindings are absent; see `rust/vendor/xla`; training
//! artifacts only execute there). [`qinco`] wraps the runtime into a
//! trainer and codec; [`index`] and
//! [`server`] build the billion-scale-search pipeline of the paper's
//! Figure 3; [`quantizers`] holds the classical baselines (PQ, OPQ, RQ,
//! LSQ) and the paper's pairwise additive decoder.
//!
//! # The pluggable three-stage pipeline
//!
//! Retrieval is assembled from two object-safe traits
//! ([`quantizers::ApproxScorer`] for the approximate scan stages,
//! [`quantizers::StageDecoder`] for the exact decode stage) into an
//! [`index::PipelineSpec`] — stage 1 defaults to the unitary additive
//! decoder, stage 2 to the paper's pairwise decoder, stage 3 to the
//! scalar-oracle reference QINCo2 decoder, and each slot accepts any
//! conforming implementation (PQ/OPQ flat-LUT adapters for stage 1,
//! stage-2-final "pairwise-only" mode, the native [`nn`]-kernel
//! [`qinco::RustDecoder`] or the engine-backed [`qinco::RuntimeDecoder`]
//! for stage 3). [`index::PipelineConfig`] selects stages by
//! configuration from the CLI, the benches, and the tests; the
//! [`quantizers::DecoderFactory`] trait hands every server worker its
//! own thread-local stage-3 decoder (engine-per-worker — engines are
//! thread-confined and cannot cross threads). See [`index::pipeline`]
//! for the trait contracts and extension points.
//!
//! # Sharded index: scatter/gather over bucket-owned shards
//!
//! The per-bucket state — inverted lists, stage-1/2 code tables, cached
//! terms — is partitioned into [`index::IndexShard`]s, each owning a
//! contiguous range of IVF buckets plus a global-id remap, collected in
//! an [`index::ShardSet`] (ownership diagram in [`index`]); the shared
//! read-only parts (coarse quantizer, [`index::PipelineSpec`] scorers,
//! model params) stay on the [`index::SearchIndex`]. Searches scatter
//! each query's probed buckets to their owning shards, scan them with
//! the existing block kernels, and gather-merge the per-shard shortlists
//! under the total (score, id) order *before* the single stage-3 decode
//! — so sharding costs no extra neural-decode work and results are
//! bit-identical to the unsharded index for every shard count
//! (`BuildCfg::shards`, CLI `--shards`). Individual shards may run their
//! own stage-1/2 configuration (`BuildCfg::shard_pipelines`) behind the
//! same router.
//!
//! The shard layer is **live-mutable** behind epoch snapshots:
//! [`index::SearchIndex::insert`] encodes fresh vectors (codeword
//! pre-selection + beam search over the QINCo2 model), assigns IVF
//! buckets, and appends to the owning shards copy-on-write;
//! [`index::SearchIndex::delete`] tombstones rows (skipped by every
//! scan) and [`index::SearchIndex::compact`] rewrites shards into the
//! canonical fresh-build layout. Each mutation publishes a complete
//! replacement [`index::ShardSet`] snapshot, so concurrent readers pin
//! an epoch and never observe partial writes; after any mutation
//! sequence, greedy-ingested state answers bit-identically to a fresh
//! build over the surviving vectors (`tests/mutation_invariants.rs`).
//! The [`server`] router gives writes their own bounded lane
//! (`server::WriteOp`) so ingest never steals a read worker.
//!
//! Search executes through one of two result-identical paths:
//! - per-query [`index::SearchIndex::search`] (Fig. 3, one request at a
//!   time), and
//! - the batched engine [`index::batch`] — per-batch flat LUT packs,
//!   shard-scattered bucket-group scans (each co-probed list is read
//!   once per batch, each code row scored against up to 8 co-probed
//!   queries in one multi-query
//!   [`quantizers::ApproxScorer::score_block`] kernel call, with the
//!   shard groups optionally split across threads —
//!   `SearchParams::batch_threads`), per-query stage-2 joint LUTs chosen
//!   by the [`index::stage2_use_lut`] cost model, and a single union
//!   decode for stage 3 gathered from the owning shards. The [`server`]
//!   router forms dynamic batches and dispatches
//!   them whole through this engine; [`index::SearchIndex::search_batch`]
//!   and `search` return the same `Vec<(score, id)>` shape per query,
//!   ranked under the total (score, id) order of [`util::topk`].
//!
//! # Scan layouts
//!
//! The batched scan's *physical* layout is selectable per request
//! ([`index::SearchParams::scan_layout`], CLI `--scan-layout`):
//! - **flat** (the default): per-query LUT slices from the batch pack,
//!   scored lane by lane;
//! - **transposed**: each ≤8-member bucket-group chunk repacks the
//!   co-probed queries' LUTs query-major
//!   ([`quantizers::LutPack::fill_transposed`]) so entry `off` of all
//!   lanes is one contiguous 8-wide load — contractually
//!   **bit-identical** to flat, pinned by `tests/scorer_conformance.rs`
//!   and `tests/batch_equivalence.rs`;
//! - **packed4**: additive stage-1 families with `k ≤ 16` (PQ/RQ) scan
//!   nibble-packed code tables ([`quantizers::PackedCodes`]) against
//!   u8-quantized LUTs ([`quantizers::QuantLutPack`]) — an explicitly
//!   versioned ([`quantizers::PACKED4_SCORING_VERSION`]) bounded-error
//!   scoring mode (`|quantized − exact| ≤ m·delta`, rank agreement
//!   pinned by `tests/layout_equivalence.rs`). Requires an index
//!   assembled with [`index::BuildCfg::scan_layout`]` = Packed4`;
//!   requesting it against any other index is a typed error, never a
//!   silent fallback.
//!
//! Deadline checks and the degraded ladder below are layout-independent:
//! all three scan paths share the same per-row ticker granularity.
//!
//! # Failure model: deadlines, shedding, supervision
//!
//! The serving layer carries an explicit end-to-end failure model (the
//! full contract lives in the [`server`] module docs): every request
//! can carry a [`util::deadline::Deadline`], checked by the batcher, at
//! dispatch, between bucket-group scans (and every
//! [`index::shard::DEADLINE_CHECK_ROWS`] rows inside one), and before
//! stage 3 — expiry surfaces as a typed
//! `RouterError::DeadlineExceeded` or as a reply explicitly flagged
//! `degraded: true` carrying the stage-1/2 shortlist ranking (stage 3
//! is skipped whole, never half-run, and degraded results are **never**
//! emitted unflagged). Admission control sheds past a configurable
//! in-flight watermark with `RouterError::Overloaded` plus a
//! retry-after hint; the blocking helpers bound every wait with
//! `recv_timeout` and bounded, jittered retries, so no caller hangs on
//! a dead worker. Worker and writer threads run under `catch_unwind`
//! supervision — a panicking batch answers its callers
//! `RouterError::WorkerDied` while the thread respawns — and all shared
//! metrics locks recover from poisoning. A deterministic, seeded fault
//! injector ([`util::fault`], behind the `fault-injection` feature)
//! drives `tests/fault_injection.rs`, which proves each named fault
//! point resolves to a typed error or a flagged degraded reply — never
//! a hang, a poisoned lock, or an abort.
//!
//! # Serving over the network
//!
//! The [`net`] module puts a socket boundary in front of the router
//! without changing its semantics: a versioned length-prefixed binary
//! frame protocol (layout and status-code table in the [`net`] module
//! docs) carries search/write/stats/ping/drain ops over TCP, a
//! [`net::NetServer`] accept loop feeds per-connection reader/writer
//! thread pairs into `Router::try_submit_within` /
//! `try_submit_write_within`, and the matching [`net::NetClient`]
//! reconstructs exactly the in-process types — results and the
//! `degraded` flag bit-identical, every `RouterError` variant (hint
//! included) a distinct wire status (`tests/net_equivalence.rs` pins
//! loopback == in-process across all of them). Backpressure is layered:
//! a connection cap with typed refusal, a per-connection in-flight cap
//! that falls back on TCP flow control, per-frame size limits, and the
//! router's own admission gates per request. Graceful drain mirrors the
//! router's: stop accepting, answer everything in flight exactly once,
//! close. The CLI serves with `serve --listen ADDR` and load-tests with
//! `bench-net` (closed-loop or fixed-rate, wire-level QPS/p50/p99 plus
//! typed shed/deadline/degraded counts).

pub mod cli;
pub mod clustering;
pub mod data;
pub mod experiments;
pub mod index;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod qinco;
pub mod quantizers;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

pub use tensor::Matrix;
