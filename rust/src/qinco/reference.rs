//! Reference implementation of the QINCo2 model (Eqs. 10-13): the
//! scalar oracle plus the shared greedy/beam encoders.
//!
//! Two numerically distinct `f_theta` paths live here on purpose:
//!
//! * [`f_theta_scalar`] / [`decode_scalar`] — the plain scalar loop, the
//!   crate's *oracle*. [`ReferenceDecoder`] decodes through it, so the
//!   default stage 3 stays an implementation-independent cross-check of
//!   every other path (the `rust_decoder_matches_reference` suite and
//!   the runtime round-trips compare against it).
//! * [`f_theta`] / [`decode`] — the bulk path, routed through the shared
//!   [`crate::nn`] kernels (blocked matmul + fused step). The encoders
//!   ([`encode_greedy`], [`encode_beam`]) and the native runtime backend
//!   use this; it accumulates in the oracle's summation order, so the
//!   two agree within the documented `1e-5` tolerance (bit-identical for
//!   finite weights in practice).

use super::native;
use super::params::ParamStore;
use crate::quantizers::{Codes, DecoderFactory, StageDecoder};
use crate::tensor::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// y[rows, cols_out] = x[rows, cols_in] @ w[cols_in, cols_out], with w
/// given as a flat slice. Oracle-side scalar matmul (ascending-i
/// accumulation per output element — the order the nn kernels replicate).
fn matmul_into(x: &[f32], rows: usize, cin: usize, w: &[f32], cout: usize, y: &mut [f32]) {
    y[..rows * cout].fill(0.0);
    for r in 0..rows {
        let xr = &x[r * cin..(r + 1) * cin];
        let yr = &mut y[r * cout..(r + 1) * cout];
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * cout..(i + 1) * cout];
            for (o, &wv) in yr.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// f_theta(c | xhat) for a batch of rows through the shared [`crate::nn`]
/// kernels — the bulk path every encoder and the native runtime use.
/// `c` and `xhat` are [rows, d] flattened; result is [rows, d].
pub fn f_theta(params: &ParamStore, step: usize, c: &[f32], xhat: &[f32], rows: usize) -> Vec<f32> {
    crate::nn::qinco_step(&native::step_weights(params, step), c, xhat, rows)
}

/// f_theta(c | xhat) as the scalar oracle loop: no blocking, no padding,
/// no shared kernels — the independent cross-check the nn path is
/// validated against. Same signature and weight slicing as [`f_theta`].
pub fn f_theta_scalar(
    params: &ParamStore,
    step: usize,
    c: &[f32],
    xhat: &[f32],
    rows: usize,
) -> Vec<f32> {
    let cfg = &params.cfg;
    let (d, de, dh, l) = (cfg.d, cfg.de, cfg.dh, cfg.l);
    let in_w = &params.get("in_w").data_f32[step * d * de..(step + 1) * d * de];
    let cond_w =
        &params.get("cond_w").data_f32[step * (de + d) * de..(step + 1) * (de + d) * de];
    let cond_b = &params.get("cond_b").data_f32[step * de..(step + 1) * de];
    let up_w = &params.get("up_w").data_f32[step * l * de * dh..(step + 1) * l * de * dh];
    let down_w = &params.get("down_w").data_f32[step * l * dh * de..(step + 1) * l * dh * de];
    let out_w = &params.get("out_w").data_f32[step * de * d..(step + 1) * de * d];

    // c_emb = c @ in_w
    let mut c_emb = vec![0.0f32; rows * de];
    matmul_into(c, rows, d, in_w, de, &mut c_emb);
    // concat [c_emb; xhat] @ cond_w + cond_b
    let mut cat = vec![0.0f32; rows * (de + d)];
    for r in 0..rows {
        cat[r * (de + d)..r * (de + d) + de].copy_from_slice(&c_emb[r * de..(r + 1) * de]);
        cat[r * (de + d) + de..(r + 1) * (de + d)].copy_from_slice(&xhat[r * d..(r + 1) * d]);
    }
    let mut v = vec![0.0f32; rows * de];
    matmul_into(&cat, rows, de + d, cond_w, de, &mut v);
    for r in 0..rows {
        for j in 0..de {
            v[r * de + j] += cond_b[j] + c_emb[r * de + j];
        }
    }
    // residual blocks
    let mut hidden = vec![0.0f32; rows * dh];
    let mut delta = vec![0.0f32; rows * de];
    for blk in 0..l {
        let up = &up_w[blk * de * dh..(blk + 1) * de * dh];
        let down = &down_w[blk * dh * de..(blk + 1) * dh * de];
        matmul_into(&v, rows, de, up, dh, &mut hidden);
        for h in hidden.iter_mut() {
            if *h < 0.0 {
                *h = 0.0;
            }
        }
        matmul_into(&hidden, rows, dh, down, de, &mut delta);
        for (vv, &dv) in v.iter_mut().zip(&delta) {
            *vv += dv;
        }
    }
    // out = c + v @ out_w
    let mut out = vec![0.0f32; rows * d];
    matmul_into(&v, rows, de, out_w, d, &mut out);
    for (o, &cv) in out.iter_mut().zip(c) {
        *o += cv;
    }
    out
}

/// Full decode of a code table (Eq. 4): xhat^m = xhat^{m-1} + f_theta(c^m),
/// with `f_step` evaluating each step's batch.
fn decode_with(
    params: &ParamStore,
    codes: &Codes,
    f_step: impl Fn(&ParamStore, usize, &[f32], &[f32], usize) -> Vec<f32>,
) -> Matrix {
    let cfg = &params.cfg;
    let (n, d, k, m) = (codes.n, cfg.d, cfg.k, cfg.m);
    assert_eq!(codes.m, m);
    let cb = &params.get("codebooks").data_f32;
    let mut xhat = vec![0.0f32; n * d];
    let mut c = vec![0.0f32; n * d];
    for step in 0..m {
        for i in 0..n {
            let code = codes.row(i)[step] as usize;
            let src = (step * k + code) * d;
            c[i * d..(i + 1) * d].copy_from_slice(&cb[src..src + d]);
        }
        let f = f_step(params, step, &c, &xhat, n);
        for (x, &fv) in xhat.iter_mut().zip(&f) {
            *x += fv;
        }
    }
    Matrix::from_vec(n, d, xhat)
}

/// Bulk decode through the shared [`crate::nn`] kernels — what
/// [`super::native::RustDecoder`] and the native runtime backend serve.
pub fn decode(params: &ParamStore, codes: &Codes) -> Matrix {
    decode_with(params, codes, f_theta)
}

/// Oracle decode through the scalar loop — what [`ReferenceDecoder`]
/// serves, kept numerically independent of the nn kernels.
pub fn decode_scalar(params: &ParamStore, codes: &Codes) -> Matrix {
    decode_with(params, codes, f_theta_scalar)
}

/// [`StageDecoder`] over the scalar-oracle QINCo2 decode — the default
/// (and infallible) stage-3 of every [`crate::index::SearchIndex`], and
/// the numerical baseline the nn-backed
/// [`RustDecoder`](super::native::RustDecoder) is validated against.
/// Thread-safe: it holds only parameter tensors, so one instance is
/// shared across all serving workers.
pub struct ReferenceDecoder {
    pub params: Arc<ParamStore>,
}

impl StageDecoder for ReferenceDecoder {
    fn decode(&self, codes: &Codes) -> Result<Matrix> {
        Ok(decode_scalar(&self.params, codes))
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

/// The default [`DecoderFactory`]: hands every worker a (cheap, shared
/// parameter store) [`ReferenceDecoder`]. Infallible — this is the
/// factory the server falls back to when no runtime factory is
/// configured.
pub struct ReferenceDecoderFactory {
    pub params: Arc<ParamStore>,
}

impl DecoderFactory for ReferenceDecoderFactory {
    fn make(&self) -> Result<Box<dyn StageDecoder>> {
        Ok(Box::new(ReferenceDecoder { params: self.params.clone() }))
    }
}

/// Greedy encode (A=K, B=1) in pure Rust — slow, for tests only.
pub fn encode_greedy(params: &ParamStore, xs: &Matrix) -> Codes {
    let cfg = &params.cfg;
    let (d, k, m) = (cfg.d, cfg.k, cfg.m);
    let cb = &params.get("codebooks").data_f32;
    let mut codes = Codes::zeros(xs.rows, m);
    for i in 0..xs.rows {
        let x = xs.row(i);
        let mut xhat = vec![0.0f32; d];
        for step in 0..m {
            // evaluate f over all K candidates at once
            let mut cands = vec![0.0f32; k * d];
            for c in 0..k {
                cands[c * d..(c + 1) * d]
                    .copy_from_slice(&cb[(step * k + c) * d..(step * k + c + 1) * d]);
            }
            let xh_b: Vec<f32> = (0..k).flat_map(|_| xhat.iter().copied()).collect();
            let f = f_theta(params, step, &cands, &xh_b, k);
            let mut best = (0usize, f32::INFINITY);
            for c in 0..k {
                let mut err = 0.0f32;
                for j in 0..d {
                    let nv = xhat[j] + f[c * d + j];
                    let dd = x[j] - nv;
                    err += dd * dd;
                }
                if err < best.1 {
                    best = (c, err);
                }
            }
            codes.row_mut(i)[step] = best.0 as u32;
            for j in 0..d {
                xhat[j] += f[best.0 * d + j];
            }
        }
    }
    codes
}

/// Beam-search encode with codeword pre-selection (the paper's Sec. 3.2
/// encoding contribution, pure Rust): keep `b` hypotheses per step; each
/// hypothesis proposes its `a` nearest codewords under the cheap RQ
/// proxy `‖(x − x̂) − c‖²` (no `f_theta`), the proposals are scored
/// exactly with one batched `f_theta` call, and the best `b` extensions
/// survive under the total (err, hypothesis, codeword) order.
///
/// `a == K` skips pre-selection entirely (candidates are visited in
/// codeword order), so `encode_beam(.., K, 1)` is **bit-identical** to
/// [`encode_greedy`]: same `f_theta` batch layout, same per-candidate
/// error expression, same first-strict-min tie-break. The live-index
/// ingest path relies on this to keep mutation bit-identity with
/// greedy-encoded fresh builds.
pub fn encode_beam(params: &ParamStore, xs: &Matrix, a: usize, b: usize) -> Codes {
    let cfg = &params.cfg;
    let (d, k, m) = (cfg.d, cfg.k, cfg.m);
    assert!(
        1 <= b && b <= a && a <= k,
        "beam parameters must satisfy 1 <= b <= a <= K (got a={a}, b={b}, K={k})"
    );
    let cb = &params.get("codebooks").data_f32;
    let mut codes = Codes::zeros(xs.rows, m);
    // per-hypothesis state: (xhat, code path)
    for i in 0..xs.rows {
        let x = xs.row(i);
        let mut hyps: Vec<(Vec<f32>, Vec<u32>)> = vec![(vec![0.0f32; d], Vec::new())];
        for step in 0..m {
            let step_cb = &cb[step * k * d..(step + 1) * k * d];
            // candidate codewords per hypothesis, ascending codeword order
            let cand_sets: Vec<Vec<usize>> = hyps
                .iter()
                .map(|(xhat, _)| {
                    if a == k {
                        (0..k).collect()
                    } else {
                        // pre-select `a` by the RQ proxy, then restore
                        // ascending codeword order so the exact-scoring
                        // tie-break is independent of proxy ranking
                        let mut proxy: Vec<(f32, usize)> = (0..k)
                            .map(|c| {
                                let cw = &step_cb[c * d..(c + 1) * d];
                                let mut e = 0.0f32;
                                for j in 0..d {
                                    let r = x[j] - xhat[j] - cw[j];
                                    e += r * r;
                                }
                                (e, c)
                            })
                            .collect();
                        proxy.sort_unstable_by(|p, q| {
                            p.0.total_cmp(&q.0).then(p.1.cmp(&q.1))
                        });
                        let mut sel: Vec<usize> =
                            proxy[..a].iter().map(|&(_, c)| c).collect();
                        sel.sort_unstable();
                        sel
                    }
                })
                .collect();
            // one batched f_theta over every (hypothesis, candidate) pair
            let n_pairs: usize = cand_sets.iter().map(|s| s.len()).sum();
            let mut pair_hc: Vec<(usize, usize)> = Vec::with_capacity(n_pairs);
            let mut cands = vec![0.0f32; n_pairs * d];
            let mut xh_b = vec![0.0f32; n_pairs * d];
            for (h, set) in cand_sets.iter().enumerate() {
                for &c in set {
                    let p = pair_hc.len();
                    cands[p * d..(p + 1) * d].copy_from_slice(&step_cb[c * d..(c + 1) * d]);
                    xh_b[p * d..(p + 1) * d].copy_from_slice(&hyps[h].0);
                    pair_hc.push((h, c));
                }
            }
            let f = f_theta(params, step, &cands, &xh_b, n_pairs);
            // exact error per pair — the same float expression as greedy
            let mut scored: Vec<(f32, usize)> = Vec::with_capacity(n_pairs);
            for (p, &(h, _)) in pair_hc.iter().enumerate() {
                let xhat = &hyps[h].0;
                let mut err = 0.0f32;
                for j in 0..d {
                    let nv = xhat[j] + f[p * d + j];
                    let dd = x[j] - nv;
                    err += dd * dd;
                }
                scored.push((err, p));
            }
            // keep the best `b` under (err, hypothesis, codeword):
            // pair index order is already (h asc, c asc)
            scored.sort_unstable_by(|p, q| p.0.total_cmp(&q.0).then(p.1.cmp(&q.1)));
            scored.truncate(b);
            hyps = scored
                .iter()
                .map(|&(_, p)| {
                    let (h, c) = pair_hc[p];
                    let mut xhat = hyps[h].0.clone();
                    for j in 0..d {
                        xhat[j] += f[p * d + j];
                    }
                    let mut path = hyps[h].1.clone();
                    path.push(c as u32);
                    (xhat, path)
                })
                .collect();
        }
        // survivors are sorted best-first by the final selection
        codes.row_mut(i).copy_from_slice(&hyps[0].1);
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::runtime::manifest::Manifest;

    fn setup() -> (ParamStore, Matrix) {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        let man = Manifest::load(&p).unwrap();
        let spec = man.model("test").unwrap();
        let train = generate(Flavor::Deep, 200, spec.cfg.d, 1);
        let ps = ParamStore::init(spec, "test", &train, 5);
        (ps, train)
    }

    #[test]
    fn decode_is_deterministic_and_finite() {
        let (ps, xs) = setup();
        let codes = encode_greedy(&ps, &xs.gather_rows(&(0..20).collect::<Vec<_>>()));
        let d1 = decode(&ps, &codes);
        let d2 = decode(&ps, &codes);
        assert_eq!(d1.data, d2.data);
        assert!(d1.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_downproj_init_reduces_to_rq_plus_projections() {
        // at init, down_w = 0 so the residual blocks are identity; with
        // identity P (d == de for the test model) and cond_w random, f
        // still depends on xhat — but with cond_w zeroed f(c|x) = 2c.
        let (mut ps, xs) = setup();
        for v in ps.get_mut("cond_w").data_f32.iter_mut() {
            *v = 0.0;
        }
        let codes = encode_greedy(&ps, &xs.gather_rows(&[0, 1, 2]));
        let dec = decode(&ps, &codes);
        // check against manual 2*sum(codewords) reconstruction
        let cfg = ps.cfg.clone();
        let cb = &ps.get("codebooks").data_f32;
        for i in 0..3 {
            for j in 0..cfg.d {
                let mut want = 0.0f32;
                for step in 0..cfg.m {
                    let c = codes.row(i)[step] as usize;
                    want += 2.0 * cb[(step * cfg.k + c) * cfg.d + j];
                }
                assert!((dec.row(i)[j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn beam_with_full_preselection_and_width_one_is_greedy() {
        // a = K, b = 1 must reproduce greedy bit-for-bit — the ingest
        // path's bit-identity with fresh greedy builds rests on this
        let (ps, xs) = setup();
        let sample = xs.gather_rows(&(0..40).collect::<Vec<_>>());
        let greedy = encode_greedy(&ps, &sample);
        let beam = encode_beam(&ps, &sample, ps.cfg.k, 1);
        assert_eq!(greedy, beam);
    }

    #[test]
    fn beam_encode_is_deterministic_valid_and_no_worse() {
        let (ps, xs) = setup();
        let sample = xs.gather_rows(&(0..40).collect::<Vec<_>>());
        let k = ps.cfg.k;
        let greedy_mse = crate::tensor::mse(&sample, &decode(&ps, &encode_greedy(&ps, &sample)));
        for (a, b) in [(k, 2), (4, 2), (4, 4), (2, 1)] {
            let c1 = encode_beam(&ps, &sample, a, b);
            let c2 = encode_beam(&ps, &sample, a, b);
            assert_eq!(c1, c2, "beam encode must be deterministic (a={a}, b={b})");
            assert!(c1.data.iter().all(|&c| (c as usize) < k), "codes out of range");
            if a == k {
                // with full pre-selection a wider beam explores a
                // superset of greedy's path per step; allow only slack
                // for float noise, not regressions
                let mse = crate::tensor::mse(&sample, &decode(&ps, &c1));
                assert!(
                    mse <= greedy_mse * 1.05 + 1e-5,
                    "beam (a={a}, b={b}) much worse than greedy: {mse} vs {greedy_mse}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "1 <= b <= a <= K")]
    fn beam_rejects_width_above_preselection() {
        let (ps, xs) = setup();
        encode_beam(&ps, &xs.gather_rows(&[0]), 2, 4);
    }

    #[test]
    fn greedy_encode_improves_over_steps_on_trained_like_init() {
        // with RQ-initialized codebooks and near-identity f, multi-step
        // decode must beat single-step on training data
        let (ps, xs) = setup();
        let sample = xs.gather_rows(&(0..50).collect::<Vec<_>>());
        let codes = encode_greedy(&ps, &sample);
        let full = decode(&ps, &codes);
        let e_full = crate::tensor::mse(&sample, &full);
        // 1-step decode: truncate codes, build a 1-step param view is not
        // needed — compare against the norm of the data instead
        let e0: f64 = (0..sample.rows)
            .map(|i| crate::tensor::sqnorm(sample.row(i)) as f64)
            .sum::<f64>()
            / sample.rows as f64;
        assert!(e_full < e0, "decode must reduce error: {e_full} vs {e0}");
    }
}
