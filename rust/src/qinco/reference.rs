//! Pure-Rust reference implementation of the QINCo2 decoder (Eqs. 10-13).
//!
//! Serves two purposes: (1) an end-to-end numerical check of the whole
//! Python→HLO→PJRT path (integration tests assert the XLA decode matches
//! this to float tolerance), and (2) pad-free decoding of tiny shortlists
//! on the search hot path where a fixed-batch artifact would waste work.

use super::params::ParamStore;
use crate::quantizers::{Codes, DecoderFactory, StageDecoder};
use crate::tensor::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// y[rows, cols_out] = x[rows, cols_in] @ w[cols_in, cols_out], with w
/// given as a flat slice.
fn matmul_into(x: &[f32], rows: usize, cin: usize, w: &[f32], cout: usize, y: &mut [f32]) {
    y[..rows * cout].fill(0.0);
    for r in 0..rows {
        let xr = &x[r * cin..(r + 1) * cin];
        let yr = &mut y[r * cout..(r + 1) * cout];
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * cout..(i + 1) * cout];
            for (o, &wv) in yr.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// f_theta(c | xhat) for a batch of rows, using step `step`'s weights.
/// `c` and `xhat` are [rows, d] flattened; result is [rows, d].
pub fn f_theta(params: &ParamStore, step: usize, c: &[f32], xhat: &[f32], rows: usize) -> Vec<f32> {
    let cfg = &params.cfg;
    let (d, de, dh, l) = (cfg.d, cfg.de, cfg.dh, cfg.l);
    let in_w = &params.get("in_w").data_f32[step * d * de..(step + 1) * d * de];
    let cond_w =
        &params.get("cond_w").data_f32[step * (de + d) * de..(step + 1) * (de + d) * de];
    let cond_b = &params.get("cond_b").data_f32[step * de..(step + 1) * de];
    let up_w = &params.get("up_w").data_f32[step * l * de * dh..(step + 1) * l * de * dh];
    let down_w = &params.get("down_w").data_f32[step * l * dh * de..(step + 1) * l * dh * de];
    let out_w = &params.get("out_w").data_f32[step * de * d..(step + 1) * de * d];

    // c_emb = c @ in_w
    let mut c_emb = vec![0.0f32; rows * de];
    matmul_into(c, rows, d, in_w, de, &mut c_emb);
    // concat [c_emb; xhat] @ cond_w + cond_b
    let mut cat = vec![0.0f32; rows * (de + d)];
    for r in 0..rows {
        cat[r * (de + d)..r * (de + d) + de].copy_from_slice(&c_emb[r * de..(r + 1) * de]);
        cat[r * (de + d) + de..(r + 1) * (de + d)].copy_from_slice(&xhat[r * d..(r + 1) * d]);
    }
    let mut v = vec![0.0f32; rows * de];
    matmul_into(&cat, rows, de + d, cond_w, de, &mut v);
    for r in 0..rows {
        for j in 0..de {
            v[r * de + j] += cond_b[j] + c_emb[r * de + j];
        }
    }
    // residual blocks
    let mut hidden = vec![0.0f32; rows * dh];
    let mut delta = vec![0.0f32; rows * de];
    for blk in 0..l {
        let up = &up_w[blk * de * dh..(blk + 1) * de * dh];
        let down = &down_w[blk * dh * de..(blk + 1) * dh * de];
        matmul_into(&v, rows, de, up, dh, &mut hidden);
        for h in hidden.iter_mut() {
            if *h < 0.0 {
                *h = 0.0;
            }
        }
        matmul_into(&hidden, rows, dh, down, de, &mut delta);
        for (vv, &dv) in v.iter_mut().zip(&delta) {
            *vv += dv;
        }
    }
    // out = c + v @ out_w
    let mut out = vec![0.0f32; rows * d];
    matmul_into(&v, rows, de, out_w, d, &mut out);
    for (o, &cv) in out.iter_mut().zip(c) {
        *o += cv;
    }
    out
}

/// Full decode of a code table (Eq. 4): xhat^m = xhat^{m-1} + f_theta(c^m).
pub fn decode(params: &ParamStore, codes: &Codes) -> Matrix {
    let cfg = &params.cfg;
    let (n, d, k, m) = (codes.n, cfg.d, cfg.k, cfg.m);
    assert_eq!(codes.m, m);
    let cb = &params.get("codebooks").data_f32;
    let mut xhat = vec![0.0f32; n * d];
    let mut c = vec![0.0f32; n * d];
    for step in 0..m {
        for i in 0..n {
            let code = codes.row(i)[step] as usize;
            let src = (step * k + code) * d;
            c[i * d..(i + 1) * d].copy_from_slice(&cb[src..src + d]);
        }
        let f = f_theta(params, step, &c, &xhat, n);
        for (x, &fv) in xhat.iter_mut().zip(&f) {
            *x += fv;
        }
    }
    Matrix::from_vec(n, d, xhat)
}

/// [`StageDecoder`] over the pure-Rust reference implementation of the
/// QINCo2 decoder — the default (and infallible) stage-3 of every
/// [`crate::index::SearchIndex`]. Thread-safe: it holds only parameter
/// tensors, so one instance is shared across all serving workers.
pub struct ReferenceDecoder {
    pub params: Arc<ParamStore>,
}

impl StageDecoder for ReferenceDecoder {
    fn decode(&self, codes: &Codes) -> Result<Matrix> {
        Ok(decode(&self.params, codes))
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

/// The default [`DecoderFactory`]: hands every worker a (cheap, shared
/// parameter store) [`ReferenceDecoder`]. Infallible — this is the
/// factory the server falls back to when no runtime factory is
/// configured.
pub struct ReferenceDecoderFactory {
    pub params: Arc<ParamStore>,
}

impl DecoderFactory for ReferenceDecoderFactory {
    fn make(&self) -> Result<Box<dyn StageDecoder>> {
        Ok(Box::new(ReferenceDecoder { params: self.params.clone() }))
    }
}

/// Greedy encode (A=K, B=1) in pure Rust — slow, for tests only.
pub fn encode_greedy(params: &ParamStore, xs: &Matrix) -> Codes {
    let cfg = &params.cfg;
    let (d, k, m) = (cfg.d, cfg.k, cfg.m);
    let cb = &params.get("codebooks").data_f32;
    let mut codes = Codes::zeros(xs.rows, m);
    for i in 0..xs.rows {
        let x = xs.row(i);
        let mut xhat = vec![0.0f32; d];
        for step in 0..m {
            // evaluate f over all K candidates at once
            let mut cands = vec![0.0f32; k * d];
            for c in 0..k {
                cands[c * d..(c + 1) * d]
                    .copy_from_slice(&cb[(step * k + c) * d..(step * k + c + 1) * d]);
            }
            let xh_b: Vec<f32> = (0..k).flat_map(|_| xhat.iter().copied()).collect();
            let f = f_theta(params, step, &cands, &xh_b, k);
            let mut best = (0usize, f32::INFINITY);
            for c in 0..k {
                let mut err = 0.0f32;
                for j in 0..d {
                    let nv = xhat[j] + f[c * d + j];
                    let dd = x[j] - nv;
                    err += dd * dd;
                }
                if err < best.1 {
                    best = (c, err);
                }
            }
            codes.row_mut(i)[step] = best.0 as u32;
            for j in 0..d {
                xhat[j] += f[best.0 * d + j];
            }
        }
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::runtime::manifest::Manifest;

    fn setup() -> (ParamStore, Matrix) {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        let man = Manifest::load(&p).unwrap();
        let spec = man.model("test").unwrap();
        let train = generate(Flavor::Deep, 200, spec.cfg.d, 1);
        let ps = ParamStore::init(spec, "test", &train, 5);
        (ps, train)
    }

    #[test]
    fn decode_is_deterministic_and_finite() {
        let (ps, xs) = setup();
        let codes = encode_greedy(&ps, &xs.gather_rows(&(0..20).collect::<Vec<_>>()));
        let d1 = decode(&ps, &codes);
        let d2 = decode(&ps, &codes);
        assert_eq!(d1.data, d2.data);
        assert!(d1.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_downproj_init_reduces_to_rq_plus_projections() {
        // at init, down_w = 0 so the residual blocks are identity; with
        // identity P (d == de for the test model) and cond_w random, f
        // still depends on xhat — but with cond_w zeroed f(c|x) = 2c.
        let (mut ps, xs) = setup();
        for v in ps.get_mut("cond_w").data_f32.iter_mut() {
            *v = 0.0;
        }
        let codes = encode_greedy(&ps, &xs.gather_rows(&[0, 1, 2]));
        let dec = decode(&ps, &codes);
        // check against manual 2*sum(codewords) reconstruction
        let cfg = ps.cfg.clone();
        let cb = &ps.get("codebooks").data_f32;
        for i in 0..3 {
            for j in 0..cfg.d {
                let mut want = 0.0f32;
                for step in 0..cfg.m {
                    let c = codes.row(i)[step] as usize;
                    want += 2.0 * cb[(step * cfg.k + c) * cfg.d + j];
                }
                assert!((dec.row(i)[j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn greedy_encode_improves_over_steps_on_trained_like_init() {
        // with RQ-initialized codebooks and near-identity f, multi-step
        // decode must beat single-step on training data
        let (ps, xs) = setup();
        let sample = xs.gather_rows(&(0..50).collect::<Vec<_>>());
        let codes = encode_greedy(&ps, &sample);
        let full = decode(&ps, &codes);
        let e_full = crate::tensor::mse(&sample, &full);
        // 1-step decode: truncate codes, build a 1-step param view is not
        // needed — compare against the norm of the data instead
        let e0: f64 = (0..sample.rows)
            .map(|i| crate::tensor::sqnorm(sample.row(i)) as f64)
            .sum::<f64>()
            / sample.rows as f64;
        assert!(e_full < e0, "decode must reduce error: {e_full} vs {e0}");
    }
}
