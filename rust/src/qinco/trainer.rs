//! The training driver (App. A.2), running entirely in Rust over the
//! AOT train_step artifact:
//!
//!   for each epoch: for each batch:
//!     codes = encode(params, x)          # beam-search artifact, no grads
//!     params, moments, stats = train_step(params, moments, x, codes, lr, t)
//!   reset dead codewords from the epoch's usage histogram + residual stats
//!
//! The learning-rate schedule (cosine to 1e-3 * lr_max), gradient
//! clipping choice, optimizer variant (AdamW vs the old-recipe Adam) and
//! dead-codeword resets all live here — the HLO step is a pure function.

use super::codec::Codec;
use super::params::{usage_histogram, ParamStore};
use crate::runtime::Engine;
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use crate::util::qnpz::Tensor;
use anyhow::{Context, Result};

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub epochs: usize,
    /// max learning rate (paper: 8e-4; reduce to 1e-4 when unstable)
    pub lr_max: f32,
    /// optimizer artifact: "adamw" (new recipe) or "adam" (old recipe)
    pub optimizer: String,
    /// training-time encode setting
    pub a: usize,
    pub b: usize,
    pub seed: u64,
    /// print progress every n epochs (0 = silent)
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            epochs: 10,
            lr_max: 8e-4,
            optimizer: "adamw".into(),
            a: 8,
            b: 8,
            seed: 0xA11CE,
            log_every: 0,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    /// final-step training loss per epoch (mean over batches)
    pub epoch_losses: Vec<f64>,
    /// dead codewords reset per epoch
    pub resets: Vec<usize>,
    /// wall-clock seconds spent training
    pub secs: f64,
    pub steps: usize,
}

pub struct Trainer {
    pub cfg: TrainCfg,
    pub train_name: String,
    pub batch: usize,
    codec: Codec,
}

impl Trainer {
    pub fn new(engine: &Engine, model: &str, cfg: TrainCfg) -> Result<Trainer> {
        let train_name_prefix = format!("train_{}_{}", cfg.optimizer, model);
        let spec = engine
            .manifest
            .artifacts
            .values()
            .find(|s| s.kind == format!("train_{}", cfg.optimizer) && s.model == model)
            .with_context(|| format!("no {train_name_prefix} artifact"))?;
        let codec = Codec::new(engine, model, cfg.a, cfg.b)?;
        Ok(Trainer { batch: spec.n, train_name: spec.name.clone(), cfg, codec })
    }

    /// Train in place. `xs` is the (already normalized) training split.
    pub fn train(
        &self,
        engine: &mut Engine,
        params: &mut ParamStore,
        xs: &Matrix,
    ) -> Result<TrainStats> {
        let t0 = std::time::Instant::now();
        let cfg = &params.cfg;
        let (m, k, d) = (cfg.m, cfg.k, cfg.d);
        let nb = self.batch;
        let names = params.names.clone();
        let mut m_state = zeros_like(params);
        let mut v_state = zeros_like(params);
        let mut rng = Rng::new(self.cfg.seed);
        let mut stats = TrainStats::default();
        let n_batches = (xs.rows / nb).max(1);
        let total_steps = (self.cfg.epochs * n_batches).max(1);
        let mut t_step = 0usize;

        for epoch in 0..self.cfg.epochs {
            let mut order: Vec<usize> = (0..xs.rows).collect();
            rng.shuffle(&mut order);
            let mut usage = vec![vec![0u64; k]; m];
            let mut epoch_loss = 0.0f64;
            let mut last_mean = Matrix::zeros(m, d);
            let mut last_std = Matrix::zeros(m, d);
            for b in 0..n_batches {
                // assemble batch (wrap around if xs.rows < nb)
                let idx: Vec<usize> =
                    (0..nb).map(|j| order[(b * nb + j) % xs.rows]).collect();
                let batch = xs.gather_rows(&idx);
                // (1) inner problem: encode without gradients
                let (codes, _, _) = self.codec.encode(engine, params, &batch)?;
                for (step, u) in usage_histogram(&codes, m, k).into_iter().enumerate() {
                    for (c, cnt) in u.into_iter().enumerate() {
                        usage[step][c] += cnt;
                    }
                }
                // (2) outer problem: one optimizer step on fixed codes
                let lr = self.lr_at(t_step, total_steps);
                t_step += 1;
                let x_t = Tensor::f32(vec![nb, d], batch.data);
                let c_t = Tensor::i32(
                    vec![nb, m],
                    &codes.data.iter().map(|&c| c as i32).collect::<Vec<_>>(),
                );
                let lr_t = Tensor::f32(vec![], vec![lr]);
                let tt = Tensor::f32(vec![], vec![t_step as f32]);
                let mut inputs: Vec<&Tensor> = params.ordered();
                inputs.extend(m_state.ordered());
                inputs.extend(v_state.ordered());
                inputs.push(&x_t);
                inputs.push(&c_t);
                inputs.push(&lr_t);
                inputs.push(&tt);
                let out = engine.run(&self.train_name, &inputs)?;
                // outputs: params, m, v (np each), loss, step_losses,
                // res_mean, res_m2
                let np = names.len();
                for (i, name) in names.iter().enumerate() {
                    *params.get_mut(name) = out[i].clone();
                    *m_state.get_mut(name) = out[np + i].clone();
                    *v_state.get_mut(name) = out[2 * np + i].clone();
                }
                let loss = out[3 * np].data_f32[0] as f64;
                epoch_loss += loss;
                let res_mean = &out[3 * np + 2];
                let res_m2 = &out[3 * np + 3];
                for i in 0..m * d {
                    let mu = res_mean.data_f32[i];
                    let m2 = res_m2.data_f32[i];
                    last_mean.data[i] = mu;
                    last_std.data[i] = (m2 - mu * mu).max(0.0).sqrt();
                }
                stats.steps += 1;
            }
            // (3) dead-codeword resets from the epoch's usage histogram
            let resets = params.reset_dead_codewords(&usage, &last_mean, &last_std, &mut rng);
            stats.resets.push(resets);
            stats.epoch_losses.push(epoch_loss / n_batches as f64);
            if self.cfg.log_every > 0 && epoch % self.cfg.log_every == 0 {
                eprintln!(
                    "[train {}] epoch {epoch:3}: loss {:.5}, {} dead codewords reset",
                    self.codec.model,
                    epoch_loss / n_batches as f64,
                    resets
                );
            }
        }
        stats.secs = t0.elapsed().as_secs_f64();
        Ok(stats)
    }

    /// Cosine schedule from lr_max to 1e-3 * lr_max (paper A.2).
    fn lr_at(&self, step: usize, total: usize) -> f32 {
        let min_ratio = 1e-3f32;
        let progress = step as f32 / total.max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.cfg.lr_max * (min_ratio + (1.0 - min_ratio) * cos)
    }
}

fn zeros_like(params: &ParamStore) -> ParamStore {
    let mut s = params.clone();
    for t in s.store.tensors.values_mut() {
        t.data_f32.fill(0.0);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_endpoints() {
        let cfg = TrainCfg { lr_max: 1e-3, ..Default::default() };
        let codec = Codec {
            model: "x".into(),
            enc_name: "e".into(),
            dec_name: "d".into(),
            n_enc: 1,
            n_dec: 1,
            a: 1,
            b: 1,
        };
        let tr = Trainer { cfg, train_name: "t".into(), batch: 1, codec };
        let lr0 = tr.lr_at(0, 100);
        let lr_end = tr.lr_at(100, 100);
        assert!((lr0 - 1e-3).abs() < 1e-9);
        assert!(lr_end < 1e-3 * 2e-3, "end lr {lr_end}");
        assert!(tr.lr_at(50, 100) < lr0);
        assert!(tr.lr_at(50, 100) > lr_end);
    }
}
