//! The native stage-3 decoder: QINCo2 decode through the shared
//! [`crate::nn`] kernels, selected with `--stage3 rust`.
//!
//! Three stage-3 decoders now exist (see [`crate::qinco`] module docs):
//! the scalar-oracle [`ReferenceDecoder`](super::reference::ReferenceDecoder),
//! this [`RustDecoder`] (same weights, blocked/fused kernels), and the
//! engine-backed [`RuntimeDecoder`](super::codec::RuntimeDecoder) that
//! routes through the artifact ABI. All three consume the same
//! `Arc<ParamStore>`-held weights; the `rust_decoder_matches_reference`
//! suite below pins this decoder to the oracle within `1e-5` absolute
//! (they are expected bit-identical — the kernels preserve the oracle's
//! per-element summation order).

use super::params::ParamStore;
use super::reference;
use crate::nn::StepWeights;
use crate::quantizers::{Codes, DecoderFactory, StageDecoder};
use crate::tensor::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// Borrow step `step`'s weight slices out of a parameter store, in the
/// layout [`crate::nn::qinco_step`] consumes. The slicing matches the
/// manifest ABI: every network tensor is `[M, ...]` with the step as the
/// leading axis.
pub fn step_weights(params: &ParamStore, step: usize) -> StepWeights<'_> {
    let cfg = &params.cfg;
    let (d, de, dh, l) = (cfg.d, cfg.de, cfg.dh, cfg.l);
    StepWeights {
        d,
        de,
        dh,
        l,
        in_w: &params.get("in_w").data_f32[step * d * de..(step + 1) * d * de],
        cond_w: &params.get("cond_w").data_f32
            [step * (de + d) * de..(step + 1) * (de + d) * de],
        cond_b: &params.get("cond_b").data_f32[step * de..(step + 1) * de],
        up_w: &params.get("up_w").data_f32[step * l * de * dh..(step + 1) * l * de * dh],
        down_w: &params.get("down_w").data_f32[step * l * dh * de..(step + 1) * l * dh * de],
        out_w: &params.get("out_w").data_f32[step * de * d..(step + 1) * de * d],
    }
}

/// [`StageDecoder`] over the native nn kernels — the production stage-3
/// for `--stage3 rust` (and the index-held decoder behind
/// `--stage3 runtime`, whose per-worker engines are a serve-time
/// concern). Thread-safe and infallible like the reference decoder: it
/// holds only the shared parameter tensors.
pub struct RustDecoder {
    pub params: Arc<ParamStore>,
}

impl StageDecoder for RustDecoder {
    fn decode(&self, codes: &Codes) -> Result<Matrix> {
        Ok(reference::decode(&self.params, codes))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Factory handing every server worker a (cheap, parameter-sharing)
/// [`RustDecoder`] — the `--stage3 rust` serve path. Infallible: no
/// engine, no artifacts, just the weights already in memory.
pub struct RustDecoderFactory {
    pub params: Arc<ParamStore>,
}

impl DecoderFactory for RustDecoderFactory {
    fn make(&self) -> Result<Box<dyn StageDecoder>> {
        Ok(Box::new(RustDecoder { params: self.params.clone() }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::nn;
    use crate::quantizers::StageDecoder;
    use crate::runtime::manifest::{ModelCfg, ModelSpec, TensorSpec};
    use crate::util::prng::Rng;

    /// Documented agreement contract between the nn kernels and the
    /// scalar oracle (module docs; expected bit-identical in practice).
    const TOL: f32 = 1e-5;

    /// A synthetic model spec whose dims are *not* multiples of the
    /// kernel lane width, so the blocked matmul's remainder columns and
    /// the concat layout all get exercised (the in-repo `test` model is
    /// all powers of two).
    fn odd_spec() -> ModelSpec {
        let cfg = ModelCfg { d: 5, m: 3, k: 6, l: 2, de: 7, dh: 11, ls: 0, dhg: 0 };
        let (d, m, k, l, de, dh) = (cfg.d, cfg.m, cfg.k, cfg.l, cfg.de, cfg.dh);
        let p = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: "float32".to_string(),
        };
        let params = vec![
            p("codebooks", vec![m, k, d]),
            p("presel", vec![m, k, d]),
            p("in_w", vec![m, d, de]),
            p("cond_w", vec![m, de + d, de]),
            p("cond_b", vec![m, de]),
            p("up_w", vec![m, l, de, dh]),
            p("down_w", vec![m, l, dh, de]),
            p("out_w", vec![m, de, d]),
        ];
        let num_params = params.iter().map(|t| t.shape.iter().product::<usize>()).sum();
        ModelSpec { cfg, params, num_params }
    }

    /// Init from training data, then overwrite every tensor with random
    /// values so zero-initialized projections can't mask kernel bugs.
    fn random_store(seed: u64) -> ParamStore {
        let spec = odd_spec();
        let train = generate(Flavor::Deep, 64, spec.cfg.d, seed);
        let mut ps = ParamStore::init(&spec, "odd", &train, seed);
        let mut rng = Rng::new(seed ^ 0xBEEF);
        for name in ps.names.clone() {
            for v in ps.get_mut(&name).data_f32.iter_mut() {
                *v = rng.uniform(-0.4, 0.4);
            }
        }
        ps
    }

    fn random_codes(rng: &mut Rng, n: usize, m: usize, k: usize) -> Codes {
        let mut codes = Codes::zeros(n, m);
        for v in codes.data.iter_mut() {
            *v = rng.below(k) as u32;
        }
        codes
    }

    #[test]
    fn rust_decoder_matches_reference() {
        // RustDecoder (nn kernels) vs ReferenceDecoder (scalar oracle)
        // over random stores × batch sizes straddling the kernel row
        // tile (1, tile−1, tile, tile+1), so the zero-pad tail and the
        // whole-tile path both run
        for seed in [1u64, 2, 3] {
            let params = Arc::new(random_store(seed));
            let (m, k) = (params.cfg.m, params.cfg.k);
            let rust = RustDecoder { params: params.clone() };
            let reference = reference::ReferenceDecoder { params: params.clone() };
            let mut rng = Rng::new(seed * 977);
            for n in [1usize, nn::ROW_TILE - 1, nn::ROW_TILE, nn::ROW_TILE + 1] {
                let codes = random_codes(&mut rng, n, m, k);
                let got = rust.decode(&codes).unwrap();
                let want = reference.decode(&codes).unwrap();
                assert_eq!(got.rows, n);
                let worst = got
                    .data
                    .iter()
                    .zip(&want.data)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    worst <= TOL,
                    "seed {seed} n {n}: max |rust − reference| = {worst} > {TOL}"
                );
                assert!(got.data.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn nn_f_theta_matches_scalar_oracle_per_step() {
        // every step's weight slice, at batch sizes around the lane
        // width, against the scalar loop directly
        let params = random_store(7);
        let (d, m) = (params.cfg.d, params.cfg.m);
        let mut rng = Rng::new(101);
        for step in 0..m {
            for n in [1usize, 7, 8, 9] {
                let c: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let xhat: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let fast = reference::f_theta(&params, step, &c, &xhat, n);
                let slow = reference::f_theta_scalar(&params, step, &c, &xhat, n);
                for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                    assert!(
                        (a - b).abs() <= TOL,
                        "step {step} n {n} elem {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn greedy_ingest_path_stays_bit_identical_through_nn() {
        // the live-index ingest contract: beam (A=K, B=1) == greedy,
        // bit for bit, with both encoders routed through the nn kernels
        let params = random_store(11);
        let xs = generate(Flavor::Deep, 33, params.cfg.d, 5);
        let greedy = reference::encode_greedy(&params, &xs);
        let beam = reference::encode_beam(&params, &xs, params.cfg.k, 1);
        assert_eq!(greedy, beam);
        // and decoding those codes is deterministic across both decoders
        // within the documented tolerance
        let d_rust = reference::decode(&params, &greedy);
        let d_ref = reference::decode_scalar(&params, &greedy);
        for (a, b) in d_rust.data.iter().zip(&d_ref.data) {
            assert!((a - b).abs() <= TOL);
        }
    }

    #[test]
    fn rust_decoder_factory_hands_out_named_decoder() {
        let params = Arc::new(random_store(13));
        let dec = RustDecoderFactory { params }.make().unwrap();
        assert_eq!(dec.name(), "rust");
    }
}
