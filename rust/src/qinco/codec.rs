//! Batched encode/decode of QINCo2 codes through the artifact runtime.
//!
//! The codec speaks the manifest ABI and is backend-agnostic: on the
//! default native backend ([`Engine::open`]) every dispatch lands on the
//! in-crate [`crate::nn`] kernels (no HLO files, no PJRT); under the
//! `pjrt` feature the same calls execute the AOT-compiled HLO artifacts.
//! Artifacts have fixed batch sizes; the codec pads the last batch (by
//! repeating the first row) and strips the pad from the outputs, so any
//! dataset size works. One `Codec` wraps one model + one (A, B) encode
//! setting + the matching decode artifacts.

use super::params::ParamStore;
use crate::quantizers::{Codes, DecoderFactory, StageDecoder};
use crate::runtime::Engine;
use crate::tensor::Matrix;
use crate::util::qnpz::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::Arc;

pub struct Codec {
    pub model: String,
    /// encode artifact name (fixes A, B, N_enc)
    pub enc_name: String,
    /// decode artifact name (fixes N_dec)
    pub dec_name: String,
    pub n_enc: usize,
    pub n_dec: usize,
    pub a: usize,
    pub b: usize,
}

impl Codec {
    /// Pick artifacts for `model` with encode setting (a, b) from the
    /// manifest (largest available batch sizes).
    pub fn new(engine: &Engine, model: &str, a: usize, b: usize) -> Result<Codec> {
        let enc = engine
            .manifest
            .find_encode(model, a, b)
            .with_context(|| format!("no encode artifact for {model} A={a} B={b}"))?;
        let dec = engine
            .manifest
            .artifacts
            .values()
            .filter(|s| s.kind == "decode" && s.model == model)
            .max_by_key(|s| s.n)
            .with_context(|| format!("no decode artifact for {model}"))?;
        Ok(Codec {
            model: model.to_string(),
            enc_name: enc.name.clone(),
            dec_name: dec.name.clone(),
            n_enc: enc.n,
            n_dec: dec.n,
            a,
            b,
        })
    }

    /// Encode vectors into codes; also returns reconstructions and
    /// per-vector squared errors (free outputs of the artifact).
    pub fn encode(
        &self,
        engine: &mut Engine,
        params: &ParamStore,
        xs: &Matrix,
    ) -> Result<(Codes, Matrix, Vec<f32>)> {
        let cfg = &params.cfg;
        if xs.cols != cfg.d {
            bail!("encode: dim {} != model dim {}", xs.cols, cfg.d);
        }
        let exe = engine.load(&self.enc_name)?;
        let n = xs.rows;
        let nb = self.n_enc;
        let mut codes = Codes::zeros(n, cfg.m);
        let mut xhat = Matrix::zeros(n, cfg.d);
        let mut errs = vec![0.0f32; n];
        let p_inputs = params.ordered();
        let mut lo = 0;
        while lo < n {
            let hi = (lo + nb).min(n);
            // pad the batch by repeating the first row
            let mut batch = Vec::with_capacity(nb * cfg.d);
            for i in lo..hi {
                batch.extend_from_slice(xs.row(i));
            }
            for _ in hi..lo + nb {
                batch.extend_from_slice(xs.row(lo));
            }
            let x_t = Tensor::f32(vec![nb, cfg.d], batch);
            let mut inputs = p_inputs.clone();
            inputs.push(&x_t);
            let out = exe.run(&inputs)?;
            let (c_t, xh_t, e_t) = (&out[0], &out[1], &out[2]);
            let c_i32 = c_t.as_i32();
            for (bi, i) in (lo..hi).enumerate() {
                for s in 0..cfg.m {
                    codes.row_mut(i)[s] = c_i32[bi * cfg.m + s] as u32;
                }
                xhat.row_mut(i)
                    .copy_from_slice(&xh_t.data_f32[bi * cfg.d..(bi + 1) * cfg.d]);
                errs[i] = e_t.data_f32[bi];
            }
            lo = hi;
        }
        Ok((codes, xhat, errs))
    }

    /// Decode codes back to vectors.
    pub fn decode(&self, engine: &mut Engine, params: &ParamStore, codes: &Codes) -> Result<Matrix> {
        let cfg = &params.cfg;
        if codes.m != cfg.m {
            bail!("decode: {} positions != model M {}", codes.m, cfg.m);
        }
        // prefer the smallest decode batch that covers the request to cut
        // padding waste on shortlist re-ranks
        let dec = engine
            .manifest
            .artifacts
            .values()
            .filter(|s| s.kind == "decode" && s.model == self.model && s.n >= codes.n.min(self.n_dec))
            .min_by_key(|s| s.n)
            .map(|s| (s.name.clone(), s.n))
            .unwrap_or((self.dec_name.clone(), self.n_dec));
        let (dec_name, nb) = dec;
        let exe = engine.load(&dec_name)?;
        let p_inputs = decode_params(params);
        let n = codes.n;
        let mut out = Matrix::zeros(n, cfg.d);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + nb).min(n);
            let mut batch: Vec<i32> = Vec::with_capacity(nb * cfg.m);
            for i in lo..hi {
                batch.extend(codes.row(i).iter().map(|&c| c as i32));
            }
            for _ in hi..lo + nb {
                batch.extend(codes.row(lo).iter().map(|&c| c as i32));
            }
            let c_t = Tensor::i32(vec![nb, cfg.m], &batch);
            let mut inputs = p_inputs.clone();
            inputs.push(&c_t);
            let res = exe.run(&inputs)?;
            for (bi, i) in (lo..hi).enumerate() {
                out.row_mut(i)
                    .copy_from_slice(&res[0].data_f32[bi * cfg.d..(bi + 1) * cfg.d]);
            }
            lo = hi;
        }
        Ok(out)
    }

    /// Multi-rate decode: reconstructions after every step (Fig. S3).
    /// Returns a vec of [n, d] matrices, one per step 1..=M.
    pub fn decode_partial(
        &self,
        engine: &mut Engine,
        params: &ParamStore,
        codes: &Codes,
    ) -> Result<Vec<Matrix>> {
        let cfg = &params.cfg;
        let spec = engine
            .manifest
            .artifacts
            .values()
            .filter(|s| s.kind == "decode_partial" && s.model == self.model)
            .max_by_key(|s| s.n)
            .with_context(|| format!("no decode_partial artifact for {}", self.model))?;
        let (name, nb) = (spec.name.clone(), spec.n);
        let exe = engine.load(&name)?;
        let p_inputs = decode_params(params);
        let n = codes.n;
        let mut out: Vec<Matrix> = (0..cfg.m).map(|_| Matrix::zeros(n, cfg.d)).collect();
        let mut lo = 0;
        while lo < n {
            let hi = (lo + nb).min(n);
            let mut batch: Vec<i32> = Vec::with_capacity(nb * cfg.m);
            for i in lo..hi {
                batch.extend(codes.row(i).iter().map(|&c| c as i32));
            }
            for _ in hi..lo + nb {
                batch.extend(codes.row(lo).iter().map(|&c| c as i32));
            }
            let c_t = Tensor::i32(vec![nb, cfg.m], &batch);
            let mut inputs = p_inputs.clone();
            inputs.push(&c_t);
            let res = exe.run(&inputs)?;
            // output [M, nb, d]
            let data = &res[0].data_f32;
            for step in 0..cfg.m {
                for (bi, i) in (lo..hi).enumerate() {
                    let src = step * nb * cfg.d + bi * cfg.d;
                    out[step].row_mut(i).copy_from_slice(&data[src..src + cfg.d]);
                }
            }
            lo = hi;
        }
        Ok(out)
    }
}

/// [`StageDecoder`] over the artifact runtime: one engine dispatch per
/// batch through [`Codec::decode`] — native kernels by default, one
/// padded XLA dispatch under the `pjrt` feature. The engine inside is
/// thread-confined (PJRT clients are `Rc`-based, and the executable
/// cache uses `Rc` either way), so a `RuntimeDecoder` is pinned to the
/// thread that built it — construct one per serving worker via
/// [`RuntimeDecoderFactory`], never share one across threads. The
/// `RefCell` is sound for the same reason: the decoder is thread-local
/// by construction and `decode` is the only borrower.
pub struct RuntimeDecoder {
    engine: RefCell<Engine>,
    codec: Codec,
    params: Arc<ParamStore>,
}

impl RuntimeDecoder {
    /// Open the artifact directory, pick decode artifacts for `model`
    /// with encode setting `(a, b)`, and bind the parameter store.
    pub fn open(
        artifacts_dir: impl Into<PathBuf>,
        model: &str,
        a: usize,
        b: usize,
        params: Arc<ParamStore>,
    ) -> Result<RuntimeDecoder> {
        let engine = Engine::open(artifacts_dir)?;
        let codec = Codec::new(&engine, model, a, b)?;
        Ok(RuntimeDecoder { engine: RefCell::new(engine), codec, params })
    }
}

impl StageDecoder for RuntimeDecoder {
    fn decode(&self, codes: &Codes) -> Result<Matrix> {
        self.codec.decode(&mut self.engine.borrow_mut(), &self.params, codes)
    }

    fn name(&self) -> &'static str {
        "runtime"
    }
}

/// Engine-per-worker factory: each server worker thread calls [`make`]
/// once at startup and gets a [`RuntimeDecoder`] with its *own* engine +
/// artifact cache (engines are thread-confined). On the default native
/// backend construction only needs `manifest.json`; construction fails
/// cleanly when the manifest is absent or names no matching artifacts,
/// and the server then falls back to the index-held decoder for that
/// worker.
///
/// [`make`]: DecoderFactory::make
pub struct RuntimeDecoderFactory {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub a: usize,
    pub b: usize,
    pub params: Arc<ParamStore>,
}

impl DecoderFactory for RuntimeDecoderFactory {
    fn make(&self) -> Result<Box<dyn StageDecoder>> {
        let dec = RuntimeDecoder::open(
            self.artifacts_dir.clone(),
            &self.model,
            self.a,
            self.b,
            self.params.clone(),
        )?;
        Ok(Box::new(dec))
    }
}

/// Decode artifacts take the subset [codebooks, in_w, cond_w, cond_b,
/// up_w, down_w, out_w] (no pre-selection tensors).
pub fn decode_params(params: &ParamStore) -> Vec<&Tensor> {
    ["codebooks", "in_w", "cond_w", "cond_b", "up_w", "down_w", "out_w"]
        .iter()
        .map(|n| params.get(n))
        .collect()
}
