//! Parameter store: the Rust-owned weights of a QINCo2 model.
//!
//! Initialization follows App. A.2: codebooks = 10-iteration RQ k-means
//! on the (normalized) training data plus N(0, (0.025 s)^2) noise with s
//! the per-feature std of the RQ codebooks; pre-selection codebooks start
//! as a copy; network weights are Kaiming-uniform with zero biases, zero
//! down-projections, and identity P projections when square.

use crate::clustering::{kmeans, KMeansCfg};
use crate::quantizers::Codes;
use crate::runtime::manifest::{ModelCfg, ModelSpec};
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use crate::util::qnpz::{Store, Tensor};
use anyhow::{bail, Result};
use std::path::Path;

/// Named parameter tensors in manifest (ABI) order.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub model: String,
    pub cfg: ModelCfg,
    /// ABI order of names (from the manifest)
    pub names: Vec<String>,
    pub store: Store,
}

impl ParamStore {
    /// Zero-initialized tensors with manifest shapes (for Adam moments).
    pub fn zeros_like(spec: &ModelSpec, model: &str) -> ParamStore {
        let mut store = Store::new();
        for p in &spec.params {
            store.insert(&p.name, Tensor::f32(p.shape.clone(), vec![0.0; p.shape.iter().product()]));
        }
        ParamStore {
            model: model.to_string(),
            cfg: spec.cfg.clone(),
            names: spec.params.iter().map(|p| p.name.clone()).collect(),
            store,
        }
    }

    /// Paper initialization from training data (see module docs).
    pub fn init(spec: &ModelSpec, model: &str, train: &Matrix, seed: u64) -> ParamStore {
        let cfg = &spec.cfg;
        assert_eq!(train.cols, cfg.d, "training data dim mismatch");
        let mut rng = Rng::new(seed ^ 0x1217);
        let (m, k, d, de, dh, l) = (cfg.m, cfg.k, cfg.d, cfg.de, cfg.dh, cfg.l);

        // --- RQ codebook init: 10 k-means iterations per step ---
        let sample = if train.rows > 20_000 {
            train.gather_rows(&rng.sample_indices(train.rows, 20_000))
        } else {
            train.clone()
        };
        let mut resid = sample.clone();
        let mut codebooks = vec![0.0f32; m * k * d];
        for step in 0..m {
            let km = kmeans(&resid, &KMeansCfg::new(k).iters(10).seed(seed ^ (step as u64)));
            // actual k may be < requested when data is tiny; tile it out
            for c in 0..k {
                let src = km.centroids.row(c % km.centroids.rows);
                codebooks[(step * k + c) * d..(step * k + c + 1) * d].copy_from_slice(src);
            }
            for i in 0..resid.rows {
                let a = km.assign[i] as usize;
                let crow = km.centroids.row(a).to_vec();
                tensor::sub_assign(resid.row_mut(i), &crow);
            }
        }
        // noise: sigma = 0.025 * per-feature std of the RQ codebooks
        let mut feat_std = vec![0.0f32; d];
        for f in 0..d {
            let mut s = 0.0f64;
            let mut s2 = 0.0f64;
            let nn = (m * k) as f64;
            for i in 0..m * k {
                let v = codebooks[i * d + f] as f64;
                s += v;
                s2 += v * v;
            }
            feat_std[f] = ((s2 / nn - (s / nn) * (s / nn)).max(0.0)).sqrt() as f32;
        }
        let presel = codebooks.clone();
        let mut noisy = codebooks;
        for i in 0..m * k {
            for f in 0..d {
                noisy[i * d + f] += 0.025 * feat_std[f] * rng.normal_f32();
            }
        }

        // --- network weights ---
        let kaiming = |rng: &mut Rng, rows: usize, numel: usize| -> Vec<f32> {
            let bound = (6.0 / rows as f32).sqrt();
            (0..numel).map(|_| rng.uniform(-bound, bound)).collect()
        };
        let proj = |rng: &mut Rng, rows: usize, cols: usize, m: usize, zero: bool| -> Vec<f32> {
            let mut out = Vec::with_capacity(m * rows * cols);
            for _ in 0..m {
                if rows == cols {
                    let eye = Matrix::eye(rows);
                    out.extend_from_slice(&eye.data);
                } else if zero {
                    out.extend(std::iter::repeat(0.0f32).take(rows * cols));
                } else {
                    out.extend(kaiming(rng, rows, rows * cols));
                }
            }
            out
        };

        let mut store = Store::new();
        store.insert("codebooks", Tensor::f32(vec![m, k, d], noisy));
        store.insert("presel", Tensor::f32(vec![m, k, d], presel));
        store.insert("in_w", Tensor::f32(vec![m, d, de], proj(&mut rng, d, de, m, false)));
        // cond_w starts at zero: f is then independent of xhat at init, so
        // the M-step recursion cannot compound (a Kaiming-initialized
        // conditioning path has per-step gain > 1 and diverges by step 16
        // — see EXPERIMENTS.md §Perf L2). It trains away from zero through
        // the out_w path.
        store.insert(
            "cond_w",
            Tensor::f32(vec![m, de + d, de], vec![0.0; m * (de + d) * de]),
        );
        store.insert("cond_b", Tensor::f32(vec![m, de], vec![0.0; m * de]));
        store.insert(
            "up_w",
            Tensor::f32(vec![m, l, de, dh], kaiming(&mut rng, de, m * l * de * dh)),
        );
        store.insert("down_w", Tensor::f32(vec![m, l, dh, de], vec![0.0; m * l * dh * de]));
        // zero-init when de != d so f_theta(c|x) == c at init (training
        // starts at the RQ operating point — the QINCo guarantee; avoids
        // M-step compounding of random projections, which diverges)
        store.insert("out_w", Tensor::f32(vec![m, de, d], proj(&mut rng, de, d, m, true)));
        if cfg.ls > 0 {
            let (ls, dhg) = (cfg.ls, cfg.dhg);
            store.insert(
                "g_cond_w",
                Tensor::f32(vec![m, 2 * d, d], kaiming(&mut rng, 2 * d, m * 2 * d * d)),
            );
            store.insert("g_cond_b", Tensor::f32(vec![m, d], vec![0.0; m * d]));
            store.insert(
                "g_up_w",
                Tensor::f32(vec![m, ls, d, dhg], kaiming(&mut rng, d, m * ls * d * dhg)),
            );
            store.insert("g_down_w", Tensor::f32(vec![m, ls, dhg, d], vec![0.0; m * ls * dhg * d]));
        }

        let ps = ParamStore {
            model: model.to_string(),
            cfg: cfg.clone(),
            names: spec.params.iter().map(|p| p.name.clone()).collect(),
            store,
        };
        ps.validate(spec).expect("init shapes must match manifest");
        ps
    }

    /// Check every tensor matches the manifest inventory.
    pub fn validate(&self, spec: &ModelSpec) -> Result<()> {
        for p in &spec.params {
            let t = self.store.get(&p.name)?;
            if t.shape != p.shape {
                bail!("param {} shape {:?} != manifest {:?}", p.name, t.shape, p.shape);
            }
        }
        Ok(())
    }

    /// Tensors in ABI order (for artifact input assembly).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.names.iter().map(|n| self.store.get(n).unwrap()).collect()
    }

    /// Fetch a parameter tensor.
    ///
    /// # Panics
    /// If `name` is not a parameter of this model — a programming error
    /// (the manifest fixes the inventory at load time), reported with
    /// the key and model so the bad call site is identifiable.
    pub fn get(&self, name: &str) -> &Tensor {
        self.store.get(name).unwrap_or_else(|_| {
            panic!("ParamStore::get: no parameter {name:?} in model {:?}", self.model)
        })
    }

    /// Mutable variant of [`ParamStore::get`]; same panic contract.
    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let ParamStore { model, store, .. } = self;
        store.tensors.get_mut(name).unwrap_or_else(|| {
            panic!("ParamStore::get_mut: no parameter {name:?} in model {model:?}")
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut s = self.store.clone();
        // stash the model name for checkpoint self-description
        s.insert(
            "__model",
            Tensor::i32(vec![self.model.len()], &self.model.bytes().map(|b| b as i32).collect::<Vec<_>>()),
        );
        s.save(path)
    }

    pub fn load(path: &Path, spec: &ModelSpec, model: &str) -> Result<ParamStore> {
        let mut store = Store::load(path)?;
        store.tensors.remove("__model");
        let ps = ParamStore {
            model: model.to_string(),
            cfg: spec.cfg.clone(),
            names: spec.params.iter().map(|p| p.name.clone()).collect(),
            store,
        };
        ps.validate(spec)?;
        Ok(ps)
    }

    /// Reset unused codewords (paper: end of each epoch) from the
    /// residual statistics of step m: uniform with the residuals' mean
    /// and std, U(mu - sqrt(3) s, mu + sqrt(3) s). Also refreshes the
    /// matching pre-selection codeword. Returns number of resets.
    pub fn reset_dead_codewords(
        &mut self,
        usage: &[Vec<u64>],
        res_mean: &Matrix,
        res_std: &Matrix,
        rng: &mut Rng,
    ) -> usize {
        let (m, k, d) = (self.cfg.m, self.cfg.k, self.cfg.d);
        assert_eq!(usage.len(), m);
        let mut resets = 0;
        for step in 0..m {
            for c in 0..k {
                if usage[step][c] != 0 {
                    continue;
                }
                resets += 1;
                for f in 0..d {
                    let mu = res_mean.data[step * d + f];
                    let s = res_std.data[step * d + f];
                    let half = 3.0f32.sqrt() * s;
                    let v = rng.uniform(mu - half, mu + half);
                    let idx = (step * k + c) * d + f;
                    self.get_mut("codebooks").data_f32[idx] = v;
                    self.get_mut("presel").data_f32[idx] = v;
                }
            }
        }
        resets
    }
}

/// Per-step code usage histogram [M][K] accumulated from encode outputs.
pub fn usage_histogram(codes: &Codes, m: usize, k: usize) -> Vec<Vec<u64>> {
    let mut usage = vec![vec![0u64; k]; m];
    for i in 0..codes.n {
        for (step, &c) in codes.row(i).iter().enumerate() {
            usage[step][c as usize] += 1;
        }
    }
    usage
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        Manifest::load(&p).unwrap()
    }

    #[test]
    fn init_matches_manifest_shapes() {
        let man = manifest();
        let spec = man.model("test").unwrap();
        let train = generate(Flavor::Deep, 300, spec.cfg.d, 1);
        let ps = ParamStore::init(spec, "test", &train, 42);
        ps.validate(spec).unwrap();
        // down projections and biases start at zero
        assert!(ps.get("down_w").data_f32.iter().all(|&v| v == 0.0));
        assert!(ps.get("cond_b").data_f32.iter().all(|&v| v == 0.0));
        // identity projections when d == de (test cfg: 8 == 8)
        let inw = ps.get("in_w");
        assert_eq!(inw.shape, vec![3, 8, 8]);
        assert_eq!(inw.data_f32[0], 1.0);
        assert_eq!(inw.data_f32[1], 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let man = manifest();
        let spec = man.model("test").unwrap();
        let train = generate(Flavor::Deep, 200, spec.cfg.d, 2);
        let ps = ParamStore::init(spec, "test", &train, 7);
        let dir = std::env::temp_dir().join(format!("qinco_ps_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.qnpz");
        ps.save(&path).unwrap();
        let ps2 = ParamStore::load(&path, spec, "test").unwrap();
        assert_eq!(ps.get("codebooks").data_f32, ps2.get("codebooks").data_f32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_codeword_reset_only_touches_unused() {
        let man = manifest();
        let spec = man.model("test").unwrap();
        let train = generate(Flavor::Deep, 200, spec.cfg.d, 3);
        let mut ps = ParamStore::init(spec, "test", &train, 8);
        let before = ps.get("codebooks").data_f32.clone();
        let (m, k, d) = (spec.cfg.m, spec.cfg.k, spec.cfg.d);
        let mut usage = vec![vec![1u64; k]; m];
        usage[1][3] = 0; // one dead codeword
        let res_mean = Matrix::zeros(m, d);
        let res_std = Matrix::from_vec(m, d, vec![1.0; m * d]);
        let mut rng = Rng::new(9);
        let resets = ps.reset_dead_codewords(&usage, &res_mean, &res_std, &mut rng);
        assert_eq!(resets, 1);
        let after = ps.get("codebooks").data_f32.clone();
        for step in 0..m {
            for c in 0..k {
                let range = (step * k + c) * d..(step * k + c + 1) * d;
                let changed = before[range.clone()] != after[range];
                assert_eq!(changed, step == 1 && c == 3, "step {step} code {c}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "no parameter \"no_such_param\" in model \"test\"")]
    fn get_panics_with_key_and_model() {
        let man = manifest();
        let spec = man.model("test").unwrap();
        let ps = ParamStore::zeros_like(spec, "test");
        let _ = ps.get("no_such_param");
    }

    #[test]
    #[should_panic(expected = "no parameter \"no_such_param\" in model \"test\"")]
    fn get_mut_panics_with_key_and_model() {
        let man = manifest();
        let spec = man.model("test").unwrap();
        let mut ps = ParamStore::zeros_like(spec, "test");
        let _ = ps.get_mut("no_such_param");
    }

    #[test]
    fn usage_histogram_counts() {
        let codes = Codes::from_vec(3, 2, vec![0, 1, 0, 1, 2, 1]);
        let u = usage_histogram(&codes, 2, 4);
        assert_eq!(u[0], vec![2, 0, 1, 0]);
        assert_eq!(u[1], vec![0, 3, 0, 0]);
    }
}
