//! The QINCo2 model driver: parameter store management, RQ-based
//! initialization (App. A.2), batched encode/decode through the PJRT
//! runtime, the full training loop (AdamW + cosine schedule + gradient
//! clipping + dead-codeword resets), and a pure-Rust reference decoder
//! used both for validating the HLO path and for decoding small
//! shortlists without batch padding.

pub mod codec;
pub mod params;
pub mod reference;
pub mod trainer;

pub use codec::{Codec, RuntimeDecoder, RuntimeDecoderFactory};
pub use params::ParamStore;
pub use reference::{ReferenceDecoder, ReferenceDecoderFactory};
pub use trainer::{TrainCfg, TrainStats, Trainer};
