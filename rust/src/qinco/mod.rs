//! The QINCo2 model driver: parameter store management, RQ-based
//! initialization (App. A.2), batched encode/decode, and the full
//! training loop (AdamW + cosine schedule + gradient clipping +
//! dead-codeword resets).
//!
//! # Three stage-3 decoders, one weight store
//!
//! All decode paths consume the same [`ParamStore`] (shared via `Arc`):
//!
//! * [`ReferenceDecoder`] — the scalar oracle. Plain nested loops
//!   ([`reference::f_theta_scalar`]), kept deliberately naive so every
//!   other path has a trustworthy baseline to diff against.
//! * [`RustDecoder`] — the production native path (`--stage3 rust`).
//!   Same math routed through the shared [`crate::nn`] kernels
//!   (blocked matmul + fused `qinco_step`); pinned to the oracle within
//!   `1e-5` by `native::tests::rust_decoder_matches_reference`.
//! * [`RuntimeDecoder`] — decode through the artifact runtime's
//!   manifest ABI ([`crate::runtime::Engine`]). On the default native
//!   backend this also lands on the [`crate::nn`] kernels (no HLO files
//!   needed); under the `pjrt` feature it executes the AOT-compiled HLO
//!   artifacts instead.
//!
//! Bulk encode ([`reference::encode_beam`] / `encode_greedy`) routes
//! through the same nn kernels via [`reference::f_theta`], so encode and
//! native decode share one numerical path; training runs either
//! in-crate ([`Trainer`]) or through PJRT-only training artifacts.

pub mod codec;
pub mod native;
pub mod params;
pub mod reference;
pub mod trainer;

pub use codec::{Codec, RuntimeDecoder, RuntimeDecoderFactory};
pub use native::{RustDecoder, RustDecoderFactory};
pub use params::ParamStore;
pub use reference::{ReferenceDecoder, ReferenceDecoderFactory};
pub use trainer::{TrainCfg, TrainStats, Trainer};
