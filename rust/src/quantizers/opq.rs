//! Optimized Product Quantization (Ge et al., 2013): learn an orthogonal
//! rotation R jointly with a PQ codebook by alternating (1) PQ training
//! on rotated data and (2) orthogonal Procrustes for R.

use super::pq::{Pq, PqScorer};
use super::{ApproxScorer, Codes, VectorQuantizer};
use crate::linalg::eig::procrustes;
use crate::tensor::Matrix;

pub struct Opq {
    pub rotation: Matrix, // [d, d], applied as x @ R
    pub pq: Pq,
}

impl Opq {
    pub fn train(xs: &Matrix, m: usize, k: usize, iters: usize, seed: u64) -> Opq {
        let d = xs.cols;
        let mut r = Matrix::eye(d);
        let mut pq = Pq::train(xs, m, k, seed);
        for it in 0..iters.max(1) {
            let xr = xs.matmul(&r);
            pq = Pq::train(&xr, m, k, seed ^ (it as u64 + 1));
            let codes = pq.encode(&xr);
            let xhat = pq.decode(&codes);
            // R <- argmin ||X R - Xhat||_F over orthogonal R
            r = procrustes(xs, &xhat);
        }
        Opq { rotation: r, pq }
    }

    fn rotate(&self, xs: &Matrix) -> Matrix {
        xs.matmul(&self.rotation)
    }

    /// Flat LUT for asymmetric search (`lut[s * k + c]`, squared slice
    /// distances): rotate the query once, then the PQ LUT.
    pub fn lut(&self, q: &[f32]) -> Vec<f32> {
        let qm = Matrix::from_vec(1, q.len(), q.to_vec());
        let qr = self.rotate(&qm);
        self.pq.lut(qr.row(0))
    }
}

/// Flat-LUT [`ApproxScorer`] adapter for [`Opq`]: rotate the query once
/// per LUT build, then score exactly like [`PqScorer`] in rotated space.
/// The contract holds in the *original* space because the rotation is
/// orthogonal: `⟨qR, x̂_rot⟩ = ⟨q, x̂_rot Rᵀ⟩ = ⟨q, decode(code)⟩` and
/// reconstruction norms are rotation-invariant.
pub struct OpqScorer {
    pub rotation: Matrix,
    pub pq_scorer: PqScorer,
}

impl OpqScorer {
    pub fn new(opq: Opq) -> OpqScorer {
        OpqScorer { rotation: opq.rotation, pq_scorer: PqScorer(opq.pq) }
    }

    fn rotate_q(&self, q: &[f32]) -> Vec<f32> {
        let qm = Matrix::from_vec(1, q.len(), q.to_vec());
        qm.matmul(&self.rotation).data
    }
}

impl ApproxScorer for OpqScorer {
    fn lut_len(&self) -> usize {
        self.pq_scorer.lut_len()
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        self.pq_scorer.lut_into(&self.rotate_q(q), out)
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        self.pq_scorer.score(lut, code, t)
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        // the rotation only affects LUT construction; block scoring is
        // the inner PQ kernel over the already-rotated pack
        self.pq_scorer.score_block(luts, stride, members, code, term, out)
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        // like score_block: the rotation is baked into the pack at LUT
        // build time, so the transposed kernel is the inner PQ one
        self.pq_scorer.score_block_transposed(tlut, code, term, out)
    }

    // no packed4_geometry override: deliberately NOT delegated to the
    // inner PQ — OPQ is excluded from Packed4 (requesting it must be a
    // build-time error naming the family, never a silent fallback), so
    // the default None stands even though the inner PQ would qualify

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        self.pq_scorer.score_direct(&self.rotate_q(q), code, t)
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        // decode in rotated space, rotate back with Rᵀ (R orthogonal)
        self.pq_scorer.0.decode(codes).matmul(&self.rotation.transpose())
    }

    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        let rot = xs.matmul(&self.rotation);
        Some(self.pq_scorer.0.encode(&rot))
    }
}

impl VectorQuantizer for Opq {
    fn code_len(&self) -> usize {
        self.pq.m
    }

    fn k(&self) -> usize {
        self.pq.k
    }

    fn encode(&self, xs: &Matrix) -> Codes {
        self.pq.encode(&self.rotate(xs))
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        // decode in rotated space, rotate back with R^T (R orthogonal)
        self.pq.decode(codes).matmul(&self.rotation.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn rotation_is_orthogonal() {
        let xs = generate(Flavor::Contriever, 300, 8, 1);
        let opq = Opq::train(&xs, 2, 8, 3, 2);
        let rtr = opq.rotation.transpose().matmul(&opq.rotation);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((rtr.data[i * 8 + j] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn opq_no_worse_than_pq_on_correlated_data() {
        // Contriever-like data has strong cross-dimension correlation,
        // exactly where OPQ helps PQ (paper Table 3 ordering).
        let xs = generate(Flavor::Contriever, 1500, 16, 3);
        let pq = Pq::train(&xs, 4, 8, 4);
        let opq = Opq::train(&xs, 4, 8, 4, 4);
        let (e_pq, e_opq) = (pq.eval_mse(&xs), opq.eval_mse(&xs));
        assert!(e_opq < e_pq * 1.05, "OPQ {e_opq} much worse than PQ {e_pq}");
    }

    #[test]
    fn decode_inverts_rotation() {
        let xs = generate(Flavor::Deep, 120, 8, 5);
        let opq = Opq::train(&xs, 2, 16, 2, 6);
        let codes = opq.encode(&xs);
        let dec = opq.decode(&codes);
        // reconstruction error in original space == error in rotated space
        let xr = xs.matmul(&opq.rotation);
        let dec_r = opq.pq.decode(&codes);
        let e1 = crate::tensor::mse(&xs, &dec);
        let e2 = crate::tensor::mse(&xr, &dec_r);
        assert!((e1 - e2).abs() < 1e-3, "{e1} vs {e2}");
    }
}
