//! Residual Quantization (Chen et al., 2010) with beam-search encoding
//! (Babenko & Lempitsky, 2014) — the structural ancestor of QINCo2 and
//! the strongest classical baseline in Table 3 / Fig. 6.

use super::{ApproxScorer, Codes, VectorQuantizer};
use crate::clustering::{kmeans, KMeansCfg};
use crate::tensor::{self, Matrix};
use crate::util::pool;

pub struct Rq {
    pub d: usize,
    pub m: usize,
    pub k: usize,
    /// beam width used at encode time (1 = greedy)
    pub beam: usize,
    /// per-step codebooks, each [k, d]
    pub codebooks: Vec<Matrix>,
}

impl Rq {
    /// Sequential training: k-means on the residual of the previous steps
    /// (greedy assignments during training, like Faiss' default).
    pub fn train(xs: &Matrix, m: usize, k: usize, beam: usize, seed: u64) -> Rq {
        let mut resid = xs.clone();
        let mut codebooks = Vec::with_capacity(m);
        for step in 0..m {
            let km = kmeans(&resid, &KMeansCfg::new(k).iters(12).seed(seed ^ (step as u64) << 8));
            for i in 0..resid.rows {
                let c = km.assign[i] as usize;
                let crow = km.centroids.row(c).to_vec();
                tensor::sub_assign(resid.row_mut(i), &crow);
            }
            codebooks.push(km.centroids);
        }
        Rq { d: xs.cols, m, k, beam, codebooks }
    }

    /// Beam-search encode a single vector; returns (codes, final error).
    pub fn encode_one(&self, x: &[f32], beam: usize) -> (Vec<u32>, f32) {
        let b = beam.max(1);
        // hypotheses: (codes, xhat, err)
        let mut hyps: Vec<(Vec<u32>, Vec<f32>, f32)> =
            vec![(Vec::new(), vec![0.0; self.d], tensor::sqnorm(x))];
        for step in 0..self.m {
            let cb = &self.codebooks[step];
            let mut cands: Vec<(usize, u32, f32)> = Vec::with_capacity(hyps.len() * self.k);
            for (hi, (_codes, xhat, _)) in hyps.iter().enumerate() {
                // residual = x - xhat; err(c) = ||residual - c||^2
                let resid: Vec<f32> = x.iter().zip(xhat).map(|(a, b)| a - b).collect();
                for c in 0..cb.rows {
                    cands.push((hi, c as u32, tensor::l2_sq(&resid, cb.row(c))));
                }
            }
            cands.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
            // dedupe identical (hypothesis, code) prefixes is unnecessary:
            // each (hi, c) pair is unique by construction.
            let keep = cands.len().min(b);
            let mut next = Vec::with_capacity(keep);
            for &(hi, c, err) in cands.iter().take(keep) {
                let (codes, xhat, _) = &hyps[hi];
                let mut codes2 = codes.clone();
                codes2.push(c);
                let mut xhat2 = xhat.clone();
                tensor::add_assign(&mut xhat2, self.codebooks[step].row(c as usize));
                next.push((codes2, xhat2, err));
            }
            hyps = next;
        }
        let best = hyps
            .into_iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        (best.0, best.2)
    }
}

/// Flat-LUT [`ApproxScorer`] adapter for [`Rq`], completing the baseline
/// scorer matrix (ROADMAP): residual-quantizer codebooks are additive, so
/// the unitary position-major LUT (`lut[p·k + c] = ⟨q, C_p[c]⟩`) makes
/// the "approximate" score exact for the RQ reconstruction — the same
/// layout and kernels as [`super::aq_lut::AdditiveDecoder`], scanning the
/// RQ's *own* code table as a pipeline stage 1 ([`crate::index::Stage1Kind::Rq`]).
pub struct RqScorer(pub Rq);

impl ApproxScorer for RqScorer {
    fn lut_len(&self) -> usize {
        self.0.m * self.0.k
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        super::additive_lut_into(&self.0.codebooks, self.0.k, q, out)
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        debug_assert_eq!(lut.len(), self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        super::additive_flat_score(self.0.k, lut, code, t)
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(stride, self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_block_lanes(
            luts,
            stride,
            members,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        debug_assert_eq!(tlut.len(), self.lut_len() * super::SCORE_BLOCK);
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_tblock_lanes(
            tlut,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    // additive position-major walk, so RQ nibble-packs when k fits
    fn packed4_geometry(&self) -> Option<(usize, usize)> {
        (self.0.k <= 16).then_some((self.0.m, self.0.k))
    }

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        let mut ip = 0.0f32;
        for (p, &c) in code.iter().enumerate() {
            ip += tensor::dot(q, self.0.codebooks[p].row(c as usize));
        }
        t - 2.0 * ip
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        VectorQuantizer::decode(&self.0, codes)
    }

    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        super::stage2_use_lut(n_cands, self.0.m, self.0.k, d)
    }

    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        Some(self.0.encode(xs))
    }
}

impl VectorQuantizer for Rq {
    fn code_len(&self) -> usize {
        self.m
    }

    fn k(&self) -> usize {
        self.k
    }

    fn encode(&self, xs: &Matrix) -> Codes {
        let mut codes = Codes::zeros(xs.rows, self.m);
        let ptr = codes.data.as_mut_ptr() as usize;
        pool::scope_chunks(xs.rows, pool::default_threads(), |lo, hi| {
            for i in lo..hi {
                let (c, _) = self.encode_one(xs.row(i), self.beam);
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        c.as_ptr(),
                        (ptr as *mut u32).add(i * self.m),
                        self.m,
                    );
                }
            }
        });
        codes
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        assert_eq!(codes.m, self.m);
        let mut out = Matrix::zeros(codes.n, self.d);
        for i in 0..codes.n {
            let row = out.row_mut(i);
            for (s, &c) in codes.row(i).iter().enumerate() {
                tensor::add_assign(row, self.codebooks[s].row(c as usize));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn rq_beats_single_step() {
        let xs = generate(Flavor::Deep, 500, 12, 1);
        let rq1 = Rq::train(&xs, 1, 16, 1, 2);
        let rq4 = Rq::train(&xs, 4, 16, 1, 2);
        assert!(rq4.eval_mse(&xs) < rq1.eval_mse(&xs));
    }

    #[test]
    fn beam_no_worse_than_greedy() {
        let xs = generate(Flavor::BigAnn, 300, 8, 3);
        let rq = Rq::train(&xs, 4, 8, 1, 4);
        let mut worse = 0;
        for i in 0..50 {
            let (_, e1) = rq.encode_one(xs.row(i), 1);
            let (_, e8) = rq.encode_one(xs.row(i), 8);
            assert!(e8 <= e1 + 1e-5, "beam worse on row {i}: {e8} > {e1}");
            if e8 < e1 - 1e-6 {
                worse += 1;
            }
        }
        // beam must strictly help on at least some vectors
        assert!(worse > 0, "beam never improved anything");
    }

    #[test]
    fn encode_decode_consistent_with_reported_error() {
        let xs = generate(Flavor::Deep, 100, 8, 5);
        let rq = Rq::train(&xs, 3, 8, 2, 6);
        let codes = rq.encode(&xs);
        let dec = rq.decode(&codes);
        for i in 0..20 {
            let (c, err) = rq.encode_one(xs.row(i), 2);
            assert_eq!(&c[..], codes.row(i));
            let exact = tensor::l2_sq(xs.row(i), dec.row(i));
            assert!((err - exact).abs() < 1e-3, "{err} vs {exact}");
        }
    }

    #[test]
    fn greedy_encoding_is_stepwise_nearest() {
        let xs = generate(Flavor::Deep, 60, 6, 7);
        let rq = Rq::train(&xs, 2, 8, 1, 8);
        let codes = rq.encode(&xs);
        for i in 0..xs.rows {
            let x = xs.row(i);
            let (c0, _) = tensor::argmin_l2(x, &rq.codebooks[0]);
            assert_eq!(codes.row(i)[0], c0 as u32);
        }
    }

    #[test]
    fn residual_training_shrinks_residual_norm() {
        let xs = generate(Flavor::Contriever, 400, 8, 9);
        let rq = Rq::train(&xs, 6, 16, 1, 10);
        let codes = rq.encode(&xs);
        // prefix errors must decrease with more steps on average
        let mut prev = f64::INFINITY;
        for m in 1..=6 {
            let partial = Rq {
                d: rq.d,
                m,
                k: rq.k,
                beam: 1,
                codebooks: rq.codebooks[..m].to_vec(),
            };
            let e = crate::tensor::mse(&xs, &partial.decode(&codes.truncate(m)));
            assert!(e <= prev + 1e-9, "step {m}: {e} > {prev}");
            prev = e;
        }
    }
}
