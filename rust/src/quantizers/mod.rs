//! Multi-codebook quantizers: the paper's baselines (PQ, OPQ, RQ, LSQ)
//! plus the additive LUT machinery and the pairwise additive decoder
//! (the paper's Sec. 3.3 contribution). The QINCo2 neural quantizer
//! itself lives in [`crate::qinco`]; everything here is pure Rust.

pub mod aq_lut;
pub mod lsq;
pub mod opq;
pub mod pairwise;
pub mod pq;
pub mod rq;

use crate::tensor::Matrix;

/// Code array: n vectors x m code positions, values in [0, K).
#[derive(Clone, Debug, PartialEq)]
pub struct Codes {
    pub n: usize,
    pub m: usize,
    pub data: Vec<u32>,
}

impl Codes {
    pub fn zeros(n: usize, m: usize) -> Codes {
        Codes { n, m, data: vec![0; n * m] }
    }

    pub fn from_vec(n: usize, m: usize, data: Vec<u32>) -> Codes {
        assert_eq!(data.len(), n * m);
        Codes { n, m, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.data[i * self.m..(i + 1) * self.m]
    }

    /// Keep only the first `m` code positions (multi-rate truncation).
    pub fn truncate(&self, m: usize) -> Codes {
        assert!(m <= self.m);
        let mut out = Codes::zeros(self.n, m);
        for i in 0..self.n {
            out.row_mut(i).copy_from_slice(&self.row(i)[..m]);
        }
        out
    }
}

/// Common interface of all trained quantizers.
pub trait VectorQuantizer {
    /// Number of code positions per vector.
    fn code_len(&self) -> usize;
    /// Codebook size per position.
    fn k(&self) -> usize;
    fn encode(&self, xs: &Matrix) -> Codes;
    fn decode(&self, codes: &Codes) -> Matrix;

    /// Bits per encoded vector.
    fn bits(&self) -> usize {
        self.code_len() * (usize::BITS - (self.k() - 1).leading_zeros()) as usize
    }

    /// Reconstruction MSE over a dataset.
    fn eval_mse(&self, xs: &Matrix) -> f64 {
        let codes = self.encode(xs);
        crate::tensor::mse(xs, &self.decode(&codes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip_and_truncate() {
        let c = Codes::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(c.row(1), &[4, 5, 6]);
        let t = c.truncate(2);
        assert_eq!(t.row(0), &[1, 2]);
        assert_eq!(t.row(1), &[4, 5]);
    }
}
