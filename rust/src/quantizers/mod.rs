//! Multi-codebook quantizers: the paper's baselines (PQ, OPQ, RQ, LSQ)
//! plus the additive LUT machinery and the pairwise additive decoder
//! (the paper's Sec. 3.3 contribution). The QINCo2 neural quantizer
//! itself lives in [`crate::qinco`]; everything here is pure Rust.
//!
//! # The stage traits
//!
//! The paper's search pipeline (Sec. 3.3, Fig. 3) is explicitly staged:
//! an approximate LUT scan, a pairwise re-ranking pass, and an exact
//! neural decode of the survivors. Two object-safe traits make each
//! stage pluggable instead of hard-wired to one concrete type:
//!
//! * [`ApproxScorer`] — anything that can score `||q − decode(code)||²`
//!   approximately from a per-query lookup table. Implemented by the
//!   unitary [`aq_lut::AdditiveDecoder`], the joint
//!   [`pairwise::PairwiseDecoder`], and the flat-LUT adapters
//!   [`pq::PqScorer`] / [`opq::OpqScorer`]. Stage 1 and stage 2 of
//!   [`crate::index::SearchIndex`] each hold one `Box<dyn ApproxScorer>`.
//! * [`StageDecoder`] — a batch decoder `Codes → Matrix` for the exact
//!   re-ranking stage. Implemented by the scalar-oracle reference QINCo2
//!   decoder ([`crate::qinco::ReferenceDecoder`]), the native nn-kernel
//!   [`crate::qinco::RustDecoder`], [`pairwise::PairwiseDecoder`], and
//!   the engine-backed [`crate::qinco::RuntimeDecoder`].
//!
//! Artifact engines are thread-confined (PJRT clients are `Rc`-based),
//! so a runtime decoder cannot be shared across serving threads.
//! [`DecoderFactory`] closes that gap: the factory itself is
//! `Send + Sync` and each server worker calls [`DecoderFactory::make`]
//! **once at thread startup**, giving every worker its own decoder
//! (engine-per-worker for `RuntimeDecoder`; `RustDecoder`'s factory just
//! shares the weights).

pub mod aq_lut;
pub mod lsq;
pub mod opq;
pub mod pairwise;
pub mod pq;
pub mod rq;

use crate::tensor::Matrix;
use anyhow::Result;

/// Code array: n vectors x m code positions, values in [0, K).
#[derive(Clone, Debug, PartialEq)]
pub struct Codes {
    pub n: usize,
    pub m: usize,
    pub data: Vec<u32>,
}

impl Codes {
    pub fn zeros(n: usize, m: usize) -> Codes {
        Codes { n, m, data: vec![0; n * m] }
    }

    pub fn from_vec(n: usize, m: usize, data: Vec<u32>) -> Codes {
        assert_eq!(data.len(), n * m);
        Codes { n, m, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.data[i * self.m..(i + 1) * self.m]
    }

    /// Keep only the first `m` code positions (multi-rate truncation).
    pub fn truncate(&self, m: usize) -> Codes {
        assert!(m <= self.m);
        let mut out = Codes::zeros(self.n, m);
        for i in 0..self.n {
            out.row_mut(i).copy_from_slice(&self.row(i)[..m]);
        }
        out
    }
}

/// Common interface of all trained quantizers.
pub trait VectorQuantizer {
    /// Number of code positions per vector.
    fn code_len(&self) -> usize;
    /// Codebook size per position.
    fn k(&self) -> usize;
    fn encode(&self, xs: &Matrix) -> Codes;
    fn decode(&self, codes: &Codes) -> Matrix;

    /// Bits per encoded vector.
    fn bits(&self) -> usize {
        self.code_len() * (usize::BITS - (self.k() - 1).leading_zeros()) as usize
    }

    /// Reconstruction MSE over a dataset.
    fn eval_mse(&self, xs: &Matrix) -> f64 {
        let codes = self.encode(xs);
        crate::tensor::mse(xs, &self.decode(&codes))
    }
}

/// Stage-2 cost model: should a query build a joint LUT, or score
/// candidates with direct dot products?
///
/// LUT: `steps·K²·d` multiplies up front, then ~1 flop per (candidate,
/// step). Direct: `steps·d` multiplies per candidate. The LUT amortizes
/// when `n_cands ≳ K²·d/(d−1)`. Every [`ApproxScorer`] consults this same
/// function from [`ApproxScorer::use_lut`], so the per-query and batched
/// execution paths make the same choice — and see the same float
/// rounding — for any shortlist size.
pub fn stage2_use_lut(n_cands: usize, n_steps: usize, k: usize, d: usize) -> bool {
    if n_cands == 0 || n_steps == 0 {
        return false;
    }
    let lut_cost = n_steps
        .saturating_mul(k)
        .saturating_mul(k)
        .saturating_mul(d)
        .saturating_add(n_cands.saturating_mul(n_steps));
    let direct_cost = n_cands.saturating_mul(n_steps).saturating_mul(d);
    lut_cost < direct_cost
}

/// Queries scored per [`ApproxScorer::score_block`] lane pass — the
/// accumulator width of the multi-query kernels. The batch engine
/// splits a bucket group's co-probed queries into blocks of this size.
pub const SCORE_BLOCK: usize = 8;

/// Shared lane-parallel kernel behind the [`ApproxScorer::score_block`]
/// overrides: score one code row against up to [`SCORE_BLOCK`] member
/// queries per pass. `offsets` yields the LUT entry offsets the code row
/// selects (the same sequence the scalar `score` walks — position-major
/// `p·k + c` for the additive family, `s·k² + joint` for the pairwise
/// family); the member base offsets act as a virtual transpose of the
/// flat LUT pack: for each offset the kernel reads that entry from every
/// member's LUT slice into independent accumulator lanes, so the adds
/// vectorize across members instead of serializing per query. Each lane
/// accumulates in exactly the scalar order and finishes with the same
/// `t − 2·ip` expression, keeping block scores bit-identical to
/// [`ApproxScorer::score`].
#[inline]
pub(crate) fn score_block_lanes<I: Iterator<Item = usize>>(
    luts: &[f32],
    stride: usize,
    members: &[u32],
    offsets: impl Fn() -> I,
    term: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(members.len(), out.len());
    debug_assert!(members
        .iter()
        .all(|&qi| (qi as usize + 1) * stride <= luts.len()));
    for (mchunk, ochunk) in members.chunks(SCORE_BLOCK).zip(out.chunks_mut(SCORE_BLOCK)) {
        let mut base = [0usize; SCORE_BLOCK];
        for (l, &qi) in mchunk.iter().enumerate() {
            base[l] = qi as usize * stride;
        }
        let mut acc = [0.0f32; SCORE_BLOCK];
        if mchunk.len() == SCORE_BLOCK {
            // full block: fixed-width lanes, unrolled + vectorized
            for off in offsets() {
                for l in 0..SCORE_BLOCK {
                    acc[l] += unsafe { *luts.get_unchecked(base[l] + off) };
                }
            }
        } else {
            for off in offsets() {
                for l in 0..mchunk.len() {
                    acc[l] += unsafe { *luts.get_unchecked(base[l] + off) };
                }
            }
        }
        for (o, &a) in ochunk.iter_mut().zip(&acc) {
            *o = term - 2.0 * a;
        }
    }
}

/// Flat position-major LUT fill shared by the additive scorer family
/// (`AdditiveDecoder` and the LSQ/RQ adapters): `out[p·k + c] = ⟨q,
/// codebooks[p][c]⟩` with stride `k` per position.
pub(crate) fn additive_lut_into(
    codebooks: &[crate::tensor::Matrix],
    k: usize,
    q: &[f32],
    out: &mut [f32],
) {
    assert_eq!(out.len(), codebooks.len() * k);
    for (p, cb) in codebooks.iter().enumerate() {
        for c in 0..k {
            out[p * k + c] = crate::tensor::dot(q, cb.row(c));
        }
    }
}

/// Flat position-major LUT score shared by the additive scorer family:
/// `t − 2·Σ_p lut[p·k + code_p]`. Unchecked lookups under the trait's
/// score preconditions (callers `debug_assert` them).
#[inline]
pub(crate) fn additive_flat_score(k: usize, lut: &[f32], code: &[u32], t: f32) -> f32 {
    let mut ip = 0.0f32;
    for (p, &c) in code.iter().enumerate() {
        ip += unsafe { *lut.get_unchecked(p * k + c as usize) };
    }
    t - 2.0 * ip
}

/// An approximate distance scorer over a fixed code table — the
/// pluggable interface of pipeline stages 1 and 2.
///
/// # Score contract
///
/// Implementations approximate squared L2 distance to their own
/// reconstruction. With `lut` built from query `q` by
/// [`lut_into`](Self::lut_into) and `t` any additive offset:
///
/// ```text
/// score(lut, code, t) = t − 2⟨q, decode(code)⟩
/// ```
///
/// so passing `t = ||decode(code)||²` (the cached [`norms`](Self::norms)
/// entry) gives `score + ||q||² = ||q − decode(code)||²` — the constant
/// `||q||²` is dropped because it never changes a per-query ranking.
/// Linearity in `t` is part of the contract: the IVF pipeline passes
/// `t = ||x̂||² + 2⟨centroid, x̂⟩` to fold the coarse term in for free.
/// [`score_direct`](Self::score_direct) must equal
/// `score(lut(q), code, t)` up to float tolerance (it may associate the
/// dot products differently). The `tests/scorer_conformance.rs` property
/// suite pins this contract for every in-tree implementation.
///
/// # Ordering contract
///
/// Scores are ranked under the **total `(score, id)` order** of
/// [`crate::util::topk::Shortlist`] (`f32::total_cmp`, ties by id).
/// Because that order is total, any scorer that satisfies the score
/// contract is automatically *visit-order independent*: the batched
/// engine may scan candidates bucket-grouped while the per-query path
/// scans probe-ordered, and both keep the identical shortlist. This is
/// what keeps `search` and `search_batch` result-identical for every
/// `ApproxScorer` implementation — do not rank trait scores with a
/// partial comparison.
///
/// Scorers are shared read-only across serving threads, hence the
/// `Send + Sync` supertrait.
pub trait ApproxScorer: Send + Sync {
    /// Size of one flat per-query LUT, for batch buffer sizing.
    fn lut_len(&self) -> usize;

    /// Fill a pre-allocated `lut_len()` slice with the flat LUT for `q` —
    /// the batch engine packs one slice per query into one contiguous
    /// buffer.
    fn lut_into(&self, q: &[f32], out: &mut [f32]);

    /// Allocate and fill a fresh LUT for `q`.
    fn lut(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.lut_len()];
        self.lut_into(q, &mut out);
        out
    }

    /// Approximate distance score from a LUT (see the score contract).
    ///
    /// Preconditions (the pipeline upholds both, and implementations may
    /// elide bounds checks on the strength of them — checked via
    /// `debug_assert` in the in-tree scorers): `lut` was produced by
    /// *this* scorer's [`lut_into`](Self::lut_into) (so `lut.len() ==
    /// lut_len()`), and every value in `code` is a valid codeword index
    /// for its position.
    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32;

    /// Multi-query fast path: score **one code row** against a block of
    /// co-probed queries' LUT slices in one pass.
    ///
    /// `luts` is the batch engine's flat LUT pack — one
    /// [`lut_into`](Self::lut_into) slice of length `stride ==
    /// lut_len()` per query — and `members[b]` selects the b-th block
    /// query's slice. Writes `out[b] = score(lut_of(members[b]), code,
    /// term)` for every member, **bit-identically** to the scalar
    /// [`score`](Self::score) path (pinned by `tests/scorer_conformance.rs`):
    /// implementations must accumulate each lane in the scalar walk
    /// order. The default loops `score`; the in-tree scorers override it
    /// with unrolled [`SCORE_BLOCK`]-lane kernels (the crate-private
    /// `score_block_lanes` helper) that read the code row once and
    /// vectorize the LUT gathers across members.
    ///
    /// Same preconditions as `score`, plus `members.len() == out.len()`
    /// and every member index addressing a full slice inside `luts`.
    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(members.len(), out.len());
        for (o, &qi) in out.iter_mut().zip(members) {
            let lo = qi as usize * stride;
            *o = self.score(&luts[lo..lo + stride], code, term);
        }
    }

    /// LUT-free scoring: `t − 2⟨q, decode(code)⟩` via direct dot
    /// products. Used when [`use_lut`](Self::use_lut) says a per-query
    /// LUT would not amortize over the candidate count.
    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32;

    /// The reconstruction whose distance the scores approximate.
    fn decode(&self, codes: &Codes) -> Matrix;

    /// Cached squared reconstruction norms for a code table — the
    /// canonical third argument to [`score`](Self::score).
    fn norms(&self, codes: &Codes) -> Vec<f32> {
        let dec = ApproxScorer::decode(self, codes);
        (0..codes.n).map(|i| crate::tensor::sqnorm(dec.row(i))).collect()
    }

    /// Should scoring `n_cands` candidates of dimension `d` build a LUT
    /// ([`score`](Self::score)) or go direct
    /// ([`score_direct`](Self::score_direct))? Both the per-query and the
    /// batched path consult this, so the choice never diverges.
    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        let _ = (n_cands, d);
        true
    }

    /// Encode raw vectors into this scorer's own code space — the live
    /// ingest path's hook for extending a side code table one row at a
    /// time. `None` (the default) means the scorer scans an externally
    /// produced table and owns no encoder: the additive AQ scorer scans
    /// the QINCo2 codes themselves, and the pairwise stage-2 scorer's
    /// table is derived by [`crate::quantizers::pairwise::append_positions`].
    /// The quantizer-backed adapters (PQ/OPQ/LSQ/RQ) override this with
    /// their [`VectorQuantizer::encode`]. All of those but LSQ are
    /// per-row deterministic (LSQ's ICM sweep seeds its RNG per batch
    /// chunk), which is why the mutation bit-identity invariant covers
    /// AQ/PQ/OPQ/RQ stage-1 pipelines and excludes LSQ.
    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        let _ = xs;
        None
    }
}

/// A batch decoder for the exact re-ranking stage (stage 3): reconstruct
/// every row of a code table in one call. The batched engine invokes this
/// at most once per batch, on the deduplicated union of all surviving
/// shortlists. Decoding may fail (a PJRT-backed decoder can hit missing
/// artifacts or a stubbed runtime); the serving workers fall back to the
/// index's own infallible decoder in that case.
pub trait StageDecoder {
    /// Reconstruct all `codes.n` rows; returns an `[n, d]` matrix.
    fn decode(&self, codes: &Codes) -> Result<Matrix>;

    /// Short human-readable name for logs and bench tables.
    fn name(&self) -> &'static str {
        "decoder"
    }
}

/// Builds one [`StageDecoder`] per serving thread.
///
/// PJRT clients are `Rc`-based and not `Send`, so an engine-backed
/// decoder cannot be constructed once and shared. The factory is the
/// `Send + Sync` half: the server clones it into every worker and each
/// worker calls [`make`](Self::make) exactly once at thread startup,
/// giving each worker a thread-local engine + codec (engine-per-worker).
/// If `make` fails on a worker (e.g. the vendored stub `xla` crate
/// cannot open a PJRT client), that worker serves with the index's own
/// stage-3 decoder instead.
pub trait DecoderFactory: Send + Sync {
    /// Construct a fresh decoder owned by the calling thread.
    fn make(&self) -> Result<Box<dyn StageDecoder>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_boundaries() {
        // degenerate inputs never pick the LUT
        assert!(!stage2_use_lut(0, 4, 8, 8));
        assert!(!stage2_use_lut(100, 0, 8, 8));
        // tiny shortlists cannot amortize K²·d LUT entries per step
        assert!(!stage2_use_lut(4, 6, 256, 32));
        // k=8, d=8, 6 steps: build 3072 flops vs 48/candidate direct —
        // breakeven near |S| ≈ 73
        assert!(!stage2_use_lut(64, 6, 8, 8));
        assert!(stage2_use_lut(128, 6, 8, 8));
        // larger codebooks push the breakeven far beyond the shortlist
        assert!(!stage2_use_lut(128, 6, 64, 8));
    }

    #[test]
    fn cost_model_monotone_in_candidates() {
        // once the LUT pays off it keeps paying off as |S| grows
        let mut prev = false;
        for n in [1usize, 8, 32, 64, 128, 512, 4096] {
            let now = stage2_use_lut(n, 6, 8, 8);
            assert!(now || !prev, "LUT choice flapped at n={n}");
            prev = now;
        }
        assert!(prev, "LUT must win for huge shortlists");
    }

    #[test]
    fn codes_roundtrip_and_truncate() {
        let c = Codes::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(c.row(1), &[4, 5, 6]);
        let t = c.truncate(2);
        assert_eq!(t.row(0), &[1, 2]);
        assert_eq!(t.row(1), &[4, 5]);
    }
}
