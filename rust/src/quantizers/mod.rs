//! Multi-codebook quantizers: the paper's baselines (PQ, OPQ, RQ, LSQ)
//! plus the additive LUT machinery and the pairwise additive decoder
//! (the paper's Sec. 3.3 contribution). The QINCo2 neural quantizer
//! itself lives in [`crate::qinco`]; everything here is pure Rust.
//!
//! # The stage traits
//!
//! The paper's search pipeline (Sec. 3.3, Fig. 3) is explicitly staged:
//! an approximate LUT scan, a pairwise re-ranking pass, and an exact
//! neural decode of the survivors. Two object-safe traits make each
//! stage pluggable instead of hard-wired to one concrete type:
//!
//! * [`ApproxScorer`] — anything that can score `||q − decode(code)||²`
//!   approximately from a per-query lookup table. Implemented by the
//!   unitary [`aq_lut::AdditiveDecoder`], the joint
//!   [`pairwise::PairwiseDecoder`], and the flat-LUT adapters
//!   [`pq::PqScorer`] / [`opq::OpqScorer`]. Stage 1 and stage 2 of
//!   [`crate::index::SearchIndex`] each hold one `Box<dyn ApproxScorer>`.
//! * [`StageDecoder`] — a batch decoder `Codes → Matrix` for the exact
//!   re-ranking stage. Implemented by the scalar-oracle reference QINCo2
//!   decoder ([`crate::qinco::ReferenceDecoder`]), the native nn-kernel
//!   [`crate::qinco::RustDecoder`], [`pairwise::PairwiseDecoder`], and
//!   the engine-backed [`crate::qinco::RuntimeDecoder`].
//!
//! # Scan layouts
//!
//! The stage-1 bucket scan is the hot loop of every request, and the
//! memory layout its kernels walk is a first-class, explicitly chosen
//! artifact: [`ScanLayout`] selects it per request (threaded through
//! `SearchParams`), and this module owns the pack containers and lane
//! kernels for all three layouts.
//!
//! **`Flat`** (the default, and the bit-exact reference): one
//! [`ApproxScorer::lut_into`] slice per query, packed back to back in a
//! [`LutPack`]. The block kernel's member base offsets are a *virtual*
//! transpose — each accumulate gathers at stride `lut_len`:
//!
//! ```text
//! luts:  [ q0: e0 e1 e2 … | q1: e0 e1 e2 … | q2: e0 e1 e2 … | … ]
//! kernel: acc[l] += luts[member[l]·stride + off]        (strided gather)
//! ```
//!
//! **`Transposed`**: per bucket group and per ≤[`SCORE_BLOCK`]-member
//! chunk, [`LutPack::fill_transposed`] physically transposes the chunk's
//! LUT slices so entry `off` of all co-probed members is contiguous.
//! The inner loop becomes one unit-stride 8-wide load per code position
//! — same values, same per-lane add order, **bit-identical to `Flat` by
//! contract** (pinned by `tests/scorer_conformance.rs` and
//! `tests/layout_equivalence.rs`):
//!
//! ```text
//! tlut:  [ e0: m0 m1 … m7 | e1: m0 m1 … m7 | e2: m0 m1 … m7 | … ]
//! kernel: acc[l] += tlut[off·8 + l]                (unit-stride 8-wide)
//! ```
//!
//! **`Packed4`**: the André-et-al.-style 4-bit fast-scan endpoint for
//! the cheap additive stage-1 families (PQ/RQ with k ≤ 16 codewords per
//! position — [`ApproxScorer::packed4_geometry`]). Code rows are
//! nibble-packed two positions per byte ([`PackedCodes`]) and the LUTs
//! are u8-quantized per query ([`QuantLutPack`], 16 entries per position
//! so a position's sub-table stays register/L1-resident), transposed per
//! chunk exactly like `Transposed`:
//!
//! ```text
//! codes: [ p1p0 | p3p2 | … ]            (two 4-bit positions per byte)
//! t8:    [ p0c0: m0…m7 | p0c1: m0…m7 | … p0c15 | p1c0: m0…m7 | … ]
//! kernel: acc[l] += t8[(p·16 + c_p)·8 + l] as u32
//! score:  term − 2·(lo[l] + delta[l]·acc[l])
//! ```
//!
//! Quantized scores cannot be bit-identical to exact ones, so `Packed4`
//! is a **versioned scoring mode** ([`PACKED4_SCORING_VERSION`]) with a
//! documented bounded-error contract instead: per query the absolute
//! score error is at most `m·delta` (see [`QuantLutPack`]), and
//! `tests/layout_equivalence.rs` pins both the bound and top-k rank
//! agreement against the exact layouts.
//!
//! Artifact engines are thread-confined (PJRT clients are `Rc`-based),
//! so a runtime decoder cannot be shared across serving threads.
//! [`DecoderFactory`] closes that gap: the factory itself is
//! `Send + Sync` and each server worker calls [`DecoderFactory::make`]
//! **once at thread startup**, giving every worker its own decoder
//! (engine-per-worker for `RuntimeDecoder`; `RustDecoder`'s factory just
//! shares the weights).

pub mod aq_lut;
pub mod lsq;
pub mod opq;
pub mod pairwise;
pub mod pq;
pub mod rq;

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Code array: n vectors x m code positions, values in [0, K).
#[derive(Clone, Debug, PartialEq)]
pub struct Codes {
    pub n: usize,
    pub m: usize,
    pub data: Vec<u32>,
}

impl Codes {
    pub fn zeros(n: usize, m: usize) -> Codes {
        Codes { n, m, data: vec![0; n * m] }
    }

    pub fn from_vec(n: usize, m: usize, data: Vec<u32>) -> Codes {
        assert_eq!(data.len(), n * m);
        Codes { n, m, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.data[i * self.m..(i + 1) * self.m]
    }

    /// Keep only the first `m` code positions (multi-rate truncation).
    pub fn truncate(&self, m: usize) -> Codes {
        assert!(m <= self.m);
        let mut out = Codes::zeros(self.n, m);
        for i in 0..self.n {
            out.row_mut(i).copy_from_slice(&self.row(i)[..m]);
        }
        out
    }
}

/// Common interface of all trained quantizers.
pub trait VectorQuantizer {
    /// Number of code positions per vector.
    fn code_len(&self) -> usize;
    /// Codebook size per position.
    fn k(&self) -> usize;
    fn encode(&self, xs: &Matrix) -> Codes;
    fn decode(&self, codes: &Codes) -> Matrix;

    /// Bits per encoded vector.
    fn bits(&self) -> usize {
        self.code_len() * (usize::BITS - (self.k() - 1).leading_zeros()) as usize
    }

    /// Reconstruction MSE over a dataset.
    fn eval_mse(&self, xs: &Matrix) -> f64 {
        let codes = self.encode(xs);
        crate::tensor::mse(xs, &self.decode(&codes))
    }
}

/// Stage-2 cost model: should a query build a joint LUT, or score
/// candidates with direct dot products?
///
/// LUT: `steps·K²·d` multiplies up front, then ~1 flop per (candidate,
/// step). Direct: `steps·d` multiplies per candidate. The LUT amortizes
/// when `n_cands ≳ K²·d/(d−1)`. Every [`ApproxScorer`] consults this same
/// function from [`ApproxScorer::use_lut`], so the per-query and batched
/// execution paths make the same choice — and see the same float
/// rounding — for any shortlist size.
pub fn stage2_use_lut(n_cands: usize, n_steps: usize, k: usize, d: usize) -> bool {
    if n_cands == 0 || n_steps == 0 {
        return false;
    }
    let lut_cost = n_steps
        .saturating_mul(k)
        .saturating_mul(k)
        .saturating_mul(d)
        .saturating_add(n_cands.saturating_mul(n_steps));
    let direct_cost = n_cands.saturating_mul(n_steps).saturating_mul(d);
    lut_cost < direct_cost
}

/// Queries scored per [`ApproxScorer::score_block`] lane pass — the
/// accumulator width of the multi-query kernels. The batch engine
/// splits a bucket group's co-probed queries into blocks of this size.
pub const SCORE_BLOCK: usize = 8;

/// Memory layout of the stage-1 bucket scan — see the module-level
/// [scan layouts](self#scan-layouts) section for the diagrams.
///
/// Selected per request through `SearchParams::scan_layout` (and at
/// build time through `BuildCfg::scan_layout`, which decides whether
/// the shards carry the nibble-packed side table `Packed4` scans).
/// `Flat` and `Transposed` are **bit-identical by contract** for every
/// scorer; `Packed4` is the explicitly versioned quantized mode
/// ([`PACKED4_SCORING_VERSION`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ScanLayout {
    /// One flat LUT slice per query; the block kernel gathers entries
    /// at stride `lut_len`. The default and the bit-exact reference.
    #[default]
    Flat,
    /// Query-major transposed LUT pack per bucket-group chunk:
    /// unit-stride 8-wide loads, bit-identical to `Flat`.
    Transposed,
    /// 4-bit packed codes + u8-quantized transposed LUTs (PQ/RQ with
    /// k ≤ 16 only). Quantized scores under the versioned bounded-error
    /// contract; requires an index built with this layout.
    Packed4,
}

impl ScanLayout {
    /// The `--scan-layout` flag spelling of this layout.
    pub fn name(self) -> &'static str {
        match self {
            ScanLayout::Flat => "flat",
            ScanLayout::Transposed => "transposed",
            ScanLayout::Packed4 => "packed4",
        }
    }

    /// Parse a `--scan-layout` flag value. Unknown names are a hard
    /// error naming the flag (matching the CLI's malformed-flag policy
    /// — a silent fallback would benchmark the wrong kernel).
    pub fn parse(name: &str) -> Result<ScanLayout> {
        match name {
            "flat" => Ok(ScanLayout::Flat),
            "transposed" => Ok(ScanLayout::Transposed),
            "packed4" => Ok(ScanLayout::Packed4),
            other => bail!(
                "--scan-layout: unknown scan layout {other:?} (expected flat|transposed|packed4)"
            ),
        }
    }

    /// Stable wire discriminant (the frame protocol serializes
    /// `SearchParams` field by field).
    pub fn wire_code(self) -> u32 {
        match self {
            ScanLayout::Flat => 0,
            ScanLayout::Transposed => 1,
            ScanLayout::Packed4 => 2,
        }
    }

    /// Inverse of [`wire_code`](Self::wire_code); `None` for codes this
    /// build does not know (the frame decoder turns that into a typed
    /// protocol error, never a silent default).
    pub fn from_wire(code: u32) -> Option<ScanLayout> {
        match code {
            0 => Some(ScanLayout::Flat),
            1 => Some(ScanLayout::Transposed),
            2 => Some(ScanLayout::Packed4),
            _ => None,
        }
    }
}

/// Version of the `Packed4` quantized scoring mode. Bump this whenever
/// the quantization scheme (per-position min, global per-query `delta`,
/// round-to-nearest u8, `score = term − 2·(lo + delta·acc)`) or its
/// error bound changes, and re-review `tests/layout_equivalence.rs` —
/// the suite asserts against this exact contract.
pub const PACKED4_SCORING_VERSION: u32 = 1;

/// The batch engine's flat per-slot LUT pack: one
/// [`ApproxScorer::lut_into`] slice of length `stride` per query,
/// `n_queries` slices back to back.
///
/// The constructor is the **bounds proof** for the scan kernels: it
/// checks `luts.len() == stride · n_queries` once at pack build, and
/// [`check_members`](Self::check_members) pins each scanned group's
/// member indices inside `n_queries` once per group. After those two
/// checks every `member·stride + off` access with `off < stride` is in
/// bounds, so the per-row inner loops stay unchecked without trusting a
/// bad `lut_slot` in release builds (this replaced a per-call
/// `debug_assert!` that vanished in release).
#[derive(Clone, Debug)]
pub struct LutPack {
    stride: usize,
    n_queries: usize,
    luts: Vec<f32>,
}

impl LutPack {
    /// Wrap a filled flat pack. Panics unless
    /// `luts.len() == stride · n_queries` — the invariant every scan
    /// kernel relies on.
    pub fn new(stride: usize, n_queries: usize, luts: Vec<f32>) -> LutPack {
        let want = stride
            .checked_mul(n_queries)
            .expect("LutPack: stride * n_queries overflows usize");
        assert_eq!(
            luts.len(),
            want,
            "LutPack: buffer holds {} floats, want stride {stride} * n_queries {n_queries}",
            luts.len()
        );
        LutPack { stride, n_queries, luts }
    }

    /// The pack of an unused LUT slot: zero queries, zero stride. Any
    /// attempt to scan it fails [`check_members`](Self::check_members)
    /// loudly instead of reading out of bounds.
    pub fn empty() -> LutPack {
        LutPack { stride: 0, n_queries: 0, luts: Vec::new() }
    }

    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    #[inline]
    pub fn luts(&self) -> &[f32] {
        &self.luts
    }

    /// Once-per-group scan precondition: the pack was built for this
    /// scorer (`stride == lut_len`) and every member query index owns a
    /// slice inside the pack. O(members) — amortized over the
    /// `rows × members` scores the group scan then computes unchecked.
    pub fn check_members(&self, lut_len: usize, members: impl IntoIterator<Item = u32>) {
        assert_eq!(
            self.stride, lut_len,
            "LutPack: pack stride {} does not match the scorer's lut_len {lut_len} \
             (wrong lut_slot?)",
            self.stride
        );
        for qi in members {
            assert!(
                (qi as usize) < self.n_queries,
                "LutPack: member query {qi} outside the pack's {} queries",
                self.n_queries
            );
        }
    }

    /// Transpose one ≤[`SCORE_BLOCK`]-member chunk into the query-major
    /// layout: `tlut[off·SCORE_BLOCK + l] = lut_of(members[l])[off]`.
    /// Unused lanes of a partial chunk are zero-filled so the lane
    /// kernels can run branch-free over all [`SCORE_BLOCK`] lanes.
    /// `tlut.len()` must be `stride · SCORE_BLOCK`.
    pub fn fill_transposed(&self, members: &[u32], tlut: &mut [f32]) {
        assert!(members.len() <= SCORE_BLOCK);
        assert_eq!(tlut.len(), self.stride * SCORE_BLOCK);
        if members.len() < SCORE_BLOCK {
            tlut.fill(0.0);
        }
        for (l, &qi) in members.iter().enumerate() {
            let src = &self.luts[qi as usize * self.stride..][..self.stride];
            for (off, &v) in src.iter().enumerate() {
                tlut[off * SCORE_BLOCK + l] = v;
            }
        }
    }
}

/// u8-quantized per-slot LUT pack for [`ScanLayout::Packed4`] —
/// scoring-mode version [`PACKED4_SCORING_VERSION`].
///
/// Per query `qi`, position `p` and codeword `c` of an additive
/// position-major LUT (`m` positions × `k ≤ 16` codewords, padded to 16
/// entries per position):
///
/// ```text
/// delta[qi] = max_p (max_c lut[p,c] − min_c lut[p,c]) / 255   (≥ tiny)
/// q8[qi][p·16 + c] = round((lut[p,c] − min_c lut[p,c]) / delta[qi])
/// lo[qi] = Σ_p min_c lut[p,c]
/// ```
///
/// so `lo + delta·Σ_p q8[p, c_p]` reconstructs the inner product with
/// per-position error ≤ `delta/2`, and the score
/// `term − 2·(lo + delta·acc)` deviates from the exact
/// [`ApproxScorer::score`] by at most
/// [`score_error_bound`](Self::score_error_bound)` = m·delta`.
#[derive(Clone, Debug)]
pub struct QuantLutPack {
    m: usize,
    n_queries: usize,
    /// `n_queries · m · 16` codes, position-major, 16-padded per position.
    q8: Vec<u8>,
    /// Per-query `Σ_p min_p`.
    lo: Vec<f32>,
    /// Per-query quantization step.
    delta: Vec<f32>,
}

impl QuantLutPack {
    /// Quantize a flat pack built for an additive scorer with geometry
    /// `(m, k)` (see [`ApproxScorer::packed4_geometry`]). Panics if
    /// `k > 16` or the pack's stride is not `m·k` — both are build-time
    /// validated long before a scan gets here.
    pub fn quantize(pack: &LutPack, m: usize, k: usize) -> QuantLutPack {
        assert!(k <= 16, "QuantLutPack: k={k} codewords per position do not fit a nibble");
        assert_eq!(
            pack.stride(),
            m * k,
            "QuantLutPack: pack stride {} is not m {m} * k {k}",
            pack.stride()
        );
        let nq = pack.n_queries();
        let mut q8 = vec![0u8; nq * m * 16];
        let mut lo = vec![0.0f32; nq];
        let mut delta = vec![0.0f32; nq];
        let mut mins = vec![0.0f32; m];
        for qi in 0..nq {
            let lut = &pack.luts()[qi * pack.stride()..][..pack.stride()];
            let mut span = 0.0f32;
            for (p, mn) in mins.iter_mut().enumerate() {
                let row = &lut[p * k..(p + 1) * k];
                let lo_p = row.iter().fold(f32::INFINITY, |a, &b| a.min(b));
                let hi_p = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                *mn = lo_p;
                span = span.max(hi_p - lo_p);
            }
            // a zero span (constant LUT) quantizes exactly with any
            // positive step; 1.0 keeps the error bound finite
            let d = if span > 0.0 { span / 255.0 } else { 1.0 };
            lo[qi] = mins.iter().sum();
            delta[qi] = d;
            let dst = &mut q8[qi * m * 16..(qi + 1) * m * 16];
            for (p, &mn) in mins.iter().enumerate() {
                for c in 0..k {
                    let q = ((lut[p * k + c] - mn) / d).round().clamp(0.0, 255.0);
                    dst[p * 16 + c] = q as u8;
                }
            }
        }
        QuantLutPack { m, n_queries: nq, q8, lo, delta }
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    /// The documented bound on `|quantized score − exact score|` for
    /// query `qi`: `m · delta` (each of `m` positions rounds by at most
    /// `delta/2`, and the score doubles the inner product).
    pub fn score_error_bound(&self, qi: u32) -> f32 {
        self.m as f32 * self.delta[qi as usize]
    }

    /// Once-per-group precondition, mirroring [`LutPack::check_members`].
    pub fn check_members(&self, m: usize, members: impl IntoIterator<Item = u32>) {
        assert_eq!(
            self.m, m,
            "QuantLutPack: pack built for {} positions, scorer scans {m}",
            self.m
        );
        for qi in members {
            assert!(
                (qi as usize) < self.n_queries,
                "QuantLutPack: member query {qi} outside the pack's {} queries",
                self.n_queries
            );
        }
    }

    /// Transpose one ≤[`SCORE_BLOCK`]-member chunk: `t8[(p·16 + c)·8 +
    /// l]` plus the per-lane `lo`/`delta`. Unused lanes zero-fill like
    /// [`LutPack::fill_transposed`]. `t8.len()` must be
    /// `m · 16 · SCORE_BLOCK`; `lo`/`delta` hold `SCORE_BLOCK` lanes.
    pub fn fill_transposed(&self, members: &[u32], t8: &mut [u8], lo: &mut [f32], delta: &mut [f32]) {
        assert!(members.len() <= SCORE_BLOCK);
        assert_eq!(t8.len(), self.m * 16 * SCORE_BLOCK);
        assert_eq!(lo.len(), SCORE_BLOCK);
        assert_eq!(delta.len(), SCORE_BLOCK);
        if members.len() < SCORE_BLOCK {
            t8.fill(0);
            lo.fill(0.0);
            delta.fill(0.0);
        }
        for (l, &qi) in members.iter().enumerate() {
            let qi = qi as usize;
            let src = &self.q8[qi * self.m * 16..][..self.m * 16];
            for (e, &v) in src.iter().enumerate() {
                t8[e * SCORE_BLOCK + l] = v;
            }
            lo[l] = self.lo[qi];
            delta[l] = self.delta[qi];
        }
    }
}

/// Nibble-packed stage-1 code table for [`ScanLayout::Packed4`]: two
/// 4-bit positions per byte, position `2j` in the low nibble of byte
/// `j`, position `2j+1` in the high nibble (an odd last position leaves
/// the final high nibble zero). Built at index assembly from the
/// stage-1 scan table and kept in sync by the live mutation paths
/// (append on ingest, gather on compaction).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    n: usize,
    m: usize,
    data: Vec<u8>,
}

impl PackedCodes {
    /// Bytes per packed row for `m` code positions.
    pub fn bytes_per_row(m: usize) -> usize {
        m.div_ceil(2)
    }

    /// An empty table ready for [`push_row`](Self::push_row).
    pub fn new(m: usize) -> PackedCodes {
        PackedCodes { n: 0, m, data: Vec::new() }
    }

    /// Pack a full code table. Panics if any codeword exceeds a nibble
    /// — build-time validation guarantees `k ≤ 16` first.
    pub fn pack(codes: &Codes) -> PackedCodes {
        let mut out = PackedCodes {
            n: 0,
            m: codes.m,
            data: Vec::with_capacity(codes.n * Self::bytes_per_row(codes.m)),
        };
        for i in 0..codes.n {
            out.push_row(codes.row(i));
        }
        out
    }

    /// Append one row (the live-ingest hook).
    pub fn push_row(&mut self, code: &[u32]) {
        assert_eq!(code.len(), self.m, "PackedCodes: row has {} positions, table {}", code.len(), self.m);
        for pair in code.chunks(2) {
            let lo = pair[0];
            let hi = if pair.len() == 2 { pair[1] } else { 0 };
            assert!(
                lo < 16 && hi < 16,
                "PackedCodes: codeword does not fit a nibble (k must be <= 16)"
            );
            self.data.push(lo as u8 | (hi as u8) << 4);
        }
        self.n += 1;
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        let bpr = Self::bytes_per_row(self.m);
        &self.data[i * bpr..(i + 1) * bpr]
    }

    /// Row-gather for compaction: the packed analogue of
    /// `gather_codes`.
    pub fn gather(&self, keep: &[usize]) -> PackedCodes {
        let bpr = Self::bytes_per_row(self.m);
        let mut data = Vec::with_capacity(keep.len() * bpr);
        for &i in keep {
            data.extend_from_slice(self.row(i));
        }
        PackedCodes { n: keep.len(), m: self.m, data }
    }
}

/// One LUT slot's scan-ready pack, shaped by the request's
/// [`ScanLayout`]. Built by the batch engine's `scan_shortlists` and
/// consumed by `IndexShard::scan_group`, which dispatches to the
/// matching kernel. The `Transposed` variant carries the *flat* pack —
/// transposition happens per bucket-group chunk at scan time (the
/// transposed view is chunk-local by construction).
#[derive(Debug)]
pub enum ScanPack {
    Flat(LutPack),
    Transposed(LutPack),
    Packed4(QuantLutPack),
}

/// Shared lane-parallel kernel behind the [`ApproxScorer::score_block`]
/// overrides: score one code row against up to [`SCORE_BLOCK`] member
/// queries per pass. `offsets` yields the LUT entry offsets the code row
/// selects (the same sequence the scalar `score` walks — position-major
/// `p·k + c` for the additive family, `s·k² + joint` for the pairwise
/// family); the member base offsets act as a virtual transpose of the
/// flat LUT pack: for each offset the kernel reads that entry from every
/// member's LUT slice into independent accumulator lanes, so the adds
/// vectorize across members instead of serializing per query. Each lane
/// accumulates in exactly the scalar order and finishes with the same
/// `t − 2·ip` expression, keeping block scores bit-identical to
/// [`ApproxScorer::score`].
///
/// # Safety of the unchecked loads
///
/// Member-index and pack-length bounds are proven **once at pack
/// build** by [`LutPack::new`] + [`LutPack::check_members`] (the
/// once-per-group scan precondition), not re-checked per call — the
/// inner loop stays unchecked in release builds without a window for a
/// bad `lut_slot` to read out of bounds.
#[inline]
pub(crate) fn score_block_lanes<I: Iterator<Item = usize>>(
    luts: &[f32],
    stride: usize,
    members: &[u32],
    offsets: impl Fn() -> I,
    term: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(members.len(), out.len());
    for (mchunk, ochunk) in members.chunks(SCORE_BLOCK).zip(out.chunks_mut(SCORE_BLOCK)) {
        let mut base = [0usize; SCORE_BLOCK];
        for (l, &qi) in mchunk.iter().enumerate() {
            base[l] = qi as usize * stride;
        }
        let mut acc = [0.0f32; SCORE_BLOCK];
        if mchunk.len() == SCORE_BLOCK {
            // full block: fixed-width lanes, unrolled + vectorized
            for off in offsets() {
                for l in 0..SCORE_BLOCK {
                    acc[l] += unsafe { *luts.get_unchecked(base[l] + off) };
                }
            }
        } else {
            for off in offsets() {
                for l in 0..mchunk.len() {
                    acc[l] += unsafe { *luts.get_unchecked(base[l] + off) };
                }
            }
        }
        for (o, &a) in ochunk.iter_mut().zip(&acc) {
            *o = term - 2.0 * a;
        }
    }
}

/// Transposed twin of [`score_block_lanes`]: the pack is already
/// query-major (`tlut[off·SCORE_BLOCK + l]`, one chunk of ≤8 members —
/// [`LutPack::fill_transposed`]), so every offset the code row selects
/// is one unit-stride 8-wide load. Unused lanes of a partial chunk are
/// zero-filled by the pack fill, letting the accumulate run branch-free
/// over all [`SCORE_BLOCK`] lanes; only `out.len()` lanes are written
/// back. Per-lane add order equals the flat kernel's (same offsets
/// sequence, one add per offset), keeping scores **bit-identical** to
/// [`ApproxScorer::score_block`] and the scalar
/// [`ApproxScorer::score`].
///
/// Bounds: `tlut` spans `stride · SCORE_BLOCK` entries
/// ([`LutPack::fill_transposed`] asserts it) and `offsets` yields
/// values `< stride` (the scorer's code-validity precondition), so the
/// unchecked loads stay in bounds.
#[inline]
pub(crate) fn score_tblock_lanes<I: Iterator<Item = usize>>(
    tlut: &[f32],
    offsets: impl Fn() -> I,
    term: f32,
    out: &mut [f32],
) {
    debug_assert!(out.len() <= SCORE_BLOCK);
    debug_assert_eq!(tlut.len() % SCORE_BLOCK, 0);
    let mut acc = [0.0f32; SCORE_BLOCK];
    for off in offsets() {
        let base = off * SCORE_BLOCK;
        for l in 0..SCORE_BLOCK {
            acc[l] += unsafe { *tlut.get_unchecked(base + l) };
        }
    }
    for (o, &a) in out.iter_mut().zip(&acc) {
        *o = term - 2.0 * a;
    }
}

/// The [`ScanLayout::Packed4`] row kernel: score one nibble-packed code
/// row against a transposed u8 chunk (`t8[(p·16 + c)·SCORE_BLOCK + l]`,
/// filled by [`QuantLutPack::fill_transposed`]) with per-lane
/// dequantization `term − 2·(lo[l] + delta[l]·acc[l])`. Accumulates in
/// `u32` (exact for any realistic `m`: ≤ 255·m per lane), branch-free
/// over all [`SCORE_BLOCK`] lanes; only `out.len()` lanes are written.
///
/// Bounds: every nibble is < 16 and `p < m`, so `(p·16 + c)·8 + l <
/// m·16·8 == t8.len()` — the loads stay unchecked on the strength of
/// the pack-fill assertion.
#[inline]
pub(crate) fn score_packed4_lanes(
    t8: &[u8],
    prow: &[u8],
    m: usize,
    lo: &[f32],
    delta: &[f32],
    term: f32,
    out: &mut [f32],
) {
    debug_assert!(out.len() <= SCORE_BLOCK);
    debug_assert_eq!(t8.len(), m * 16 * SCORE_BLOCK);
    debug_assert_eq!(prow.len(), PackedCodes::bytes_per_row(m));
    debug_assert!(lo.len() >= SCORE_BLOCK && delta.len() >= SCORE_BLOCK);
    let mut acc = [0u32; SCORE_BLOCK];
    for (j, &byte) in prow.iter().enumerate() {
        let p = 2 * j;
        let base = (p * 16 + (byte & 0x0F) as usize) * SCORE_BLOCK;
        for l in 0..SCORE_BLOCK {
            acc[l] += unsafe { *t8.get_unchecked(base + l) } as u32;
        }
        if p + 1 < m {
            let base = ((p + 1) * 16 + (byte >> 4) as usize) * SCORE_BLOCK;
            for l in 0..SCORE_BLOCK {
                acc[l] += unsafe { *t8.get_unchecked(base + l) } as u32;
            }
        }
    }
    for (l, o) in out.iter_mut().enumerate() {
        *o = term - 2.0 * (lo[l] + delta[l] * acc[l] as f32);
    }
}

/// Flat position-major LUT fill shared by the additive scorer family
/// (`AdditiveDecoder` and the LSQ/RQ adapters): `out[p·k + c] = ⟨q,
/// codebooks[p][c]⟩` with stride `k` per position.
pub(crate) fn additive_lut_into(
    codebooks: &[crate::tensor::Matrix],
    k: usize,
    q: &[f32],
    out: &mut [f32],
) {
    assert_eq!(out.len(), codebooks.len() * k);
    for (p, cb) in codebooks.iter().enumerate() {
        for c in 0..k {
            out[p * k + c] = crate::tensor::dot(q, cb.row(c));
        }
    }
}

/// Flat position-major LUT score shared by the additive scorer family:
/// `t − 2·Σ_p lut[p·k + code_p]`. Unchecked lookups under the trait's
/// score preconditions (callers `debug_assert` them).
#[inline]
pub(crate) fn additive_flat_score(k: usize, lut: &[f32], code: &[u32], t: f32) -> f32 {
    let mut ip = 0.0f32;
    for (p, &c) in code.iter().enumerate() {
        ip += unsafe { *lut.get_unchecked(p * k + c as usize) };
    }
    t - 2.0 * ip
}

/// An approximate distance scorer over a fixed code table — the
/// pluggable interface of pipeline stages 1 and 2.
///
/// # Score contract
///
/// Implementations approximate squared L2 distance to their own
/// reconstruction. With `lut` built from query `q` by
/// [`lut_into`](Self::lut_into) and `t` any additive offset:
///
/// ```text
/// score(lut, code, t) = t − 2⟨q, decode(code)⟩
/// ```
///
/// so passing `t = ||decode(code)||²` (the cached [`norms`](Self::norms)
/// entry) gives `score + ||q||² = ||q − decode(code)||²` — the constant
/// `||q||²` is dropped because it never changes a per-query ranking.
/// Linearity in `t` is part of the contract: the IVF pipeline passes
/// `t = ||x̂||² + 2⟨centroid, x̂⟩` to fold the coarse term in for free.
/// [`score_direct`](Self::score_direct) must equal
/// `score(lut(q), code, t)` up to float tolerance (it may associate the
/// dot products differently). The `tests/scorer_conformance.rs` property
/// suite pins this contract for every in-tree implementation.
///
/// # Ordering contract
///
/// Scores are ranked under the **total `(score, id)` order** of
/// [`crate::util::topk::Shortlist`] (`f32::total_cmp`, ties by id).
/// Because that order is total, any scorer that satisfies the score
/// contract is automatically *visit-order independent*: the batched
/// engine may scan candidates bucket-grouped while the per-query path
/// scans probe-ordered, and both keep the identical shortlist. This is
/// what keeps `search` and `search_batch` result-identical for every
/// `ApproxScorer` implementation — do not rank trait scores with a
/// partial comparison.
///
/// Scorers are shared read-only across serving threads, hence the
/// `Send + Sync` supertrait.
pub trait ApproxScorer: Send + Sync {
    /// Size of one flat per-query LUT, for batch buffer sizing.
    fn lut_len(&self) -> usize;

    /// Fill a pre-allocated `lut_len()` slice with the flat LUT for `q` —
    /// the batch engine packs one slice per query into one contiguous
    /// buffer.
    fn lut_into(&self, q: &[f32], out: &mut [f32]);

    /// Allocate and fill a fresh LUT for `q`.
    fn lut(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.lut_len()];
        self.lut_into(q, &mut out);
        out
    }

    /// Approximate distance score from a LUT (see the score contract).
    ///
    /// Preconditions (the pipeline upholds both, and implementations may
    /// elide bounds checks on the strength of them — checked via
    /// `debug_assert` in the in-tree scorers): `lut` was produced by
    /// *this* scorer's [`lut_into`](Self::lut_into) (so `lut.len() ==
    /// lut_len()`), and every value in `code` is a valid codeword index
    /// for its position.
    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32;

    /// Multi-query fast path: score **one code row** against a block of
    /// co-probed queries' LUT slices in one pass ([`ScanLayout::Flat`]).
    ///
    /// `luts` is the batch engine's flat LUT pack — one
    /// [`lut_into`](Self::lut_into) slice of length `stride ==
    /// lut_len()` per query — and `members[b]` selects the b-th block
    /// query's slice. Writes `out[b] = score(lut_of(members[b]), code,
    /// term)` for every member, **bit-identically** to the scalar
    /// [`score`](Self::score) path (pinned by `tests/scorer_conformance.rs`):
    /// implementations must accumulate each lane in the scalar walk
    /// order. The default loops `score`; the in-tree scorers override it
    /// with unrolled [`SCORE_BLOCK`]-lane kernels (the crate-private
    /// `score_block_lanes` helper) that read the code row once and
    /// vectorize the LUT gathers across members.
    ///
    /// Same preconditions as `score`, plus `members.len() == out.len()`
    /// and every member index addressing a full slice inside `luts` —
    /// the batch engine proves the latter once per pack/group through
    /// [`LutPack`].
    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(members.len(), out.len());
        for (o, &qi) in out.iter_mut().zip(members) {
            let lo = qi as usize * stride;
            *o = self.score(&luts[lo..lo + stride], code, term);
        }
    }

    /// [`ScanLayout::Transposed`] twin of [`score_block`](Self::score_block):
    /// score one code row against one query-major transposed chunk
    /// (`tlut[off·SCORE_BLOCK + lane]`, built by
    /// [`LutPack::fill_transposed`] for `out.len() ≤ SCORE_BLOCK`
    /// members; unused lanes zero-filled). Must be **bit-identical** to
    /// the flat paths — same per-lane accumulation order, same
    /// `t − 2·ip` finish (pinned by `tests/scorer_conformance.rs`).
    ///
    /// The default de-transposes each lane back into a scratch flat LUT
    /// and calls [`score`](Self::score) — bit-exact for any third-party
    /// scorer, but slow; the in-tree scorers override it with the
    /// unit-stride `score_tblock_lanes` kernel.
    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        let stride = self.lut_len();
        debug_assert_eq!(tlut.len(), stride * SCORE_BLOCK);
        debug_assert!(out.len() <= SCORE_BLOCK);
        let mut flat = vec![0.0f32; stride];
        for (l, o) in out.iter_mut().enumerate() {
            for (off, f) in flat.iter_mut().enumerate() {
                *f = tlut[off * SCORE_BLOCK + l];
            }
            *o = self.score(&flat, code, term);
        }
    }

    /// [`ScanLayout::Packed4`] eligibility: `Some((m, k))` iff this
    /// scorer walks an additive position-major LUT of `m` positions ×
    /// `k ≤ 16` codewords (offset `p·k + c`), so its codes nibble-pack
    /// and its LUTs quantize into a [`QuantLutPack`]. The default
    /// `None` marks the layout unsupported — index assembly turns that
    /// into a hard error naming the stage-1 family, never a silent
    /// fallback. In tree only the PQ and RQ adapters (with small
    /// enough k) qualify; AQ scans full-width QINCo2 codes, OPQ rotates
    /// the query (its inner PQ geometry is not the scan geometry
    /// callers see), LSQ is excluded with them as the non-deterministic
    /// encoder, and the pairwise stage-2 scorer walks joint `k²`
    /// sub-tables.
    fn packed4_geometry(&self) -> Option<(usize, usize)> {
        None
    }

    /// LUT-free scoring: `t − 2⟨q, decode(code)⟩` via direct dot
    /// products. Used when [`use_lut`](Self::use_lut) says a per-query
    /// LUT would not amortize over the candidate count.
    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32;

    /// The reconstruction whose distance the scores approximate.
    fn decode(&self, codes: &Codes) -> Matrix;

    /// Cached squared reconstruction norms for a code table — the
    /// canonical third argument to [`score`](Self::score).
    fn norms(&self, codes: &Codes) -> Vec<f32> {
        let dec = ApproxScorer::decode(self, codes);
        (0..codes.n).map(|i| crate::tensor::sqnorm(dec.row(i))).collect()
    }

    /// Should scoring `n_cands` candidates of dimension `d` build a LUT
    /// ([`score`](Self::score)) or go direct
    /// ([`score_direct`](Self::score_direct))? Both the per-query and the
    /// batched path consult this, so the choice never diverges.
    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        let _ = (n_cands, d);
        true
    }

    /// Encode raw vectors into this scorer's own code space — the live
    /// ingest path's hook for extending a side code table one row at a
    /// time. `None` (the default) means the scorer scans an externally
    /// produced table and owns no encoder: the additive AQ scorer scans
    /// the QINCo2 codes themselves, and the pairwise stage-2 scorer's
    /// table is derived by [`crate::quantizers::pairwise::append_positions`].
    /// The quantizer-backed adapters (PQ/OPQ/LSQ/RQ) override this with
    /// their [`VectorQuantizer::encode`]. All of those but LSQ are
    /// per-row deterministic (LSQ's ICM sweep seeds its RNG per batch
    /// chunk), which is why the mutation bit-identity invariant covers
    /// AQ/PQ/OPQ/RQ stage-1 pipelines and excludes LSQ.
    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        let _ = xs;
        None
    }
}

/// A batch decoder for the exact re-ranking stage (stage 3): reconstruct
/// every row of a code table in one call. The batched engine invokes this
/// at most once per batch, on the deduplicated union of all surviving
/// shortlists. Decoding may fail (a PJRT-backed decoder can hit missing
/// artifacts or a stubbed runtime); the serving workers fall back to the
/// index's own infallible decoder in that case.
pub trait StageDecoder {
    /// Reconstruct all `codes.n` rows; returns an `[n, d]` matrix.
    fn decode(&self, codes: &Codes) -> Result<Matrix>;

    /// Short human-readable name for logs and bench tables.
    fn name(&self) -> &'static str {
        "decoder"
    }
}

/// Builds one [`StageDecoder`] per serving thread.
///
/// PJRT clients are `Rc`-based and not `Send`, so an engine-backed
/// decoder cannot be constructed once and shared. The factory is the
/// `Send + Sync` half: the server clones it into every worker and each
/// worker calls [`make`](Self::make) exactly once at thread startup,
/// giving each worker a thread-local engine + codec (engine-per-worker).
/// If `make` fails on a worker (e.g. the vendored stub `xla` crate
/// cannot open a PJRT client), that worker serves with the index's own
/// stage-3 decoder instead.
pub trait DecoderFactory: Send + Sync {
    /// Construct a fresh decoder owned by the calling thread.
    fn make(&self) -> Result<Box<dyn StageDecoder>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_boundaries() {
        // degenerate inputs never pick the LUT
        assert!(!stage2_use_lut(0, 4, 8, 8));
        assert!(!stage2_use_lut(100, 0, 8, 8));
        // tiny shortlists cannot amortize K²·d LUT entries per step
        assert!(!stage2_use_lut(4, 6, 256, 32));
        // k=8, d=8, 6 steps: build 3072 flops vs 48/candidate direct —
        // breakeven near |S| ≈ 73
        assert!(!stage2_use_lut(64, 6, 8, 8));
        assert!(stage2_use_lut(128, 6, 8, 8));
        // larger codebooks push the breakeven far beyond the shortlist
        assert!(!stage2_use_lut(128, 6, 64, 8));
    }

    #[test]
    fn cost_model_monotone_in_candidates() {
        // once the LUT pays off it keeps paying off as |S| grows
        let mut prev = false;
        for n in [1usize, 8, 32, 64, 128, 512, 4096] {
            let now = stage2_use_lut(n, 6, 8, 8);
            assert!(now || !prev, "LUT choice flapped at n={n}");
            prev = now;
        }
        assert!(prev, "LUT must win for huge shortlists");
    }

    #[test]
    fn codes_roundtrip_and_truncate() {
        let c = Codes::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(c.row(1), &[4, 5, 6]);
        let t = c.truncate(2);
        assert_eq!(t.row(0), &[1, 2]);
        assert_eq!(t.row(1), &[4, 5]);
    }

    #[test]
    fn scan_layout_parse_and_wire_roundtrip() {
        for layout in [ScanLayout::Flat, ScanLayout::Transposed, ScanLayout::Packed4] {
            assert_eq!(ScanLayout::parse(layout.name()).unwrap(), layout);
            assert_eq!(ScanLayout::from_wire(layout.wire_code()), Some(layout));
        }
        assert_eq!(ScanLayout::default(), ScanLayout::Flat);
        // unknown names hard-error naming the flag
        let err = ScanLayout::parse("simd").unwrap_err().to_string();
        assert!(err.contains("--scan-layout") && err.contains("simd"), "{err}");
        // unknown wire codes are None, not a default
        assert_eq!(ScanLayout::from_wire(3), None);
        assert_eq!(ScanLayout::from_wire(u32::MAX), None);
    }

    #[test]
    fn lut_pack_constructor_is_the_bounds_proof() {
        let p = LutPack::new(3, 2, vec![0.0; 6]);
        assert_eq!((p.stride(), p.n_queries()), (3, 2));
        p.check_members(3, [0u32, 1, 1, 0]);
        // length mismatch: caught at build, not at scan
        let bad = std::panic::catch_unwind(|| LutPack::new(3, 2, vec![0.0; 5]));
        assert!(bad.is_err());
        // stride mismatch (wrong lut_slot) and member out of range:
        // caught by the once-per-group check
        let p2 = LutPack::new(3, 2, vec![0.0; 6]);
        assert!(std::panic::catch_unwind(|| p2.check_members(4, [0u32])).is_err());
        let p3 = LutPack::new(3, 2, vec![0.0; 6]);
        assert!(std::panic::catch_unwind(|| p3.check_members(3, [2u32])).is_err());
        // the empty pack refuses every scan
        let e = LutPack::empty();
        assert!(std::panic::catch_unwind(|| e.check_members(3, [0u32])).is_err());
    }

    #[test]
    fn transposed_fill_matches_the_flat_pack() {
        // 2 queries x stride 4, recognizable values
        let luts: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let p = LutPack::new(4, 2, luts);
        let mut tlut = vec![f32::NAN; 4 * SCORE_BLOCK];
        // partial chunk with a duplicated member
        let members = [1u32, 0, 1];
        p.fill_transposed(&members, &mut tlut);
        for (l, &qi) in members.iter().enumerate() {
            for off in 0..4 {
                assert_eq!(tlut[off * SCORE_BLOCK + l], (qi as usize * 4 + off) as f32);
            }
        }
        // unused lanes are zeroed, not stale
        for off in 0..4 {
            for l in members.len()..SCORE_BLOCK {
                assert_eq!(tlut[off * SCORE_BLOCK + l], 0.0);
            }
        }
    }

    #[test]
    fn packed_codes_roundtrip_gather_and_nibble_guard() {
        // odd m: last byte's high nibble stays zero
        let c = Codes::from_vec(2, 3, vec![1, 2, 3, 15, 0, 7]);
        let p = PackedCodes::pack(&c);
        assert_eq!((p.n(), p.m()), (2, 3));
        assert_eq!(PackedCodes::bytes_per_row(3), 2);
        assert_eq!(p.row(0), &[0x21, 0x03]);
        assert_eq!(p.row(1), &[0x0F, 0x07]);
        // gather keeps row payloads byte-identical
        let g = p.gather(&[1]);
        assert_eq!(g.row(0), p.row(1));
        // push_row appends the same encoding pack() produces
        let mut inc = PackedCodes::new(3);
        inc.push_row(c.row(0));
        inc.push_row(c.row(1));
        assert_eq!(inc, p);
        // a codeword outside the nibble range is a loud panic
        let wide = Codes::from_vec(1, 2, vec![16, 0]);
        assert!(std::panic::catch_unwind(|| PackedCodes::pack(&wide)).is_err());
    }

    #[test]
    fn quantized_pack_respects_the_error_bound() {
        // a deliberately uneven additive LUT: 2 queries, m=3, k=4
        let (m, k, nq) = (3usize, 4usize, 2usize);
        let mut luts = Vec::new();
        for qi in 0..nq {
            for e in 0..m * k {
                luts.push(((qi * 31 + e * 7) % 13) as f32 * 0.37 - 1.9);
            }
        }
        let flat = LutPack::new(m * k, nq, luts.clone());
        let q = QuantLutPack::quantize(&flat, m, k);
        assert_eq!((q.m(), q.n_queries()), (m, nq));
        // reconstruct every (query, code row) score and compare to exact
        let mut t8 = vec![0u8; m * 16 * SCORE_BLOCK];
        let mut lo = vec![0.0f32; SCORE_BLOCK];
        let mut delta = vec![0.0f32; SCORE_BLOCK];
        let members = [0u32, 1];
        q.fill_transposed(&members, &mut t8, &mut lo, &mut delta);
        let codes: [&[u32]; 3] = [&[0, 0, 0], &[3, 1, 2], &[1, 3, 3]];
        for code in codes {
            let packed = {
                let mut pc = PackedCodes::new(m);
                pc.push_row(code);
                pc
            };
            let mut out = vec![0.0f32; members.len()];
            score_packed4_lanes(&t8, packed.row(0), m, &lo, &delta, 0.5, &mut out);
            for (l, &qi) in members.iter().enumerate() {
                let lut = &luts[qi as usize * m * k..(qi as usize + 1) * m * k];
                let exact = additive_flat_score(k, lut, code, 0.5);
                let bound = q.score_error_bound(qi) + 1e-5;
                assert!(
                    (out[l] - exact).abs() <= bound,
                    "query {qi} code {code:?}: |{} - {exact}| > {bound}",
                    out[l]
                );
            }
        }
        // the bound itself is the documented m·delta
        assert_eq!(PACKED4_SCORING_VERSION, 1);
        // geometry mismatches are loud
        let flat2 = LutPack::new(m * k, nq, luts);
        assert!(std::panic::catch_unwind(|| QuantLutPack::quantize(&flat2, m, 17)).is_err());
    }
}
