//! Product Quantization (Jégou et al., 2010): slice vectors into M
//! sub-vectors, k-means each slice independently. The fastest baseline in
//! Fig. 6 and the coarse substrate of the IVF-PQ pipeline.

use super::{ApproxScorer, Codes, VectorQuantizer};
use crate::clustering::{kmeans, KMeansCfg};
use crate::tensor::{self, Matrix};
use crate::util::pool;

pub struct Pq {
    pub d: usize,
    pub m: usize,
    pub k: usize,
    /// per-subspace codebooks, each [k, sub_dim]
    pub codebooks: Vec<Matrix>,
    /// subspace boundaries: sub m covers [splits[m], splits[m+1])
    pub splits: Vec<usize>,
}

impl Pq {
    /// Train on `xs`: d is split into `m` near-equal slices, each getting
    /// a `k`-centroid k-means codebook.
    pub fn train(xs: &Matrix, m: usize, k: usize, seed: u64) -> Pq {
        let d = xs.cols;
        assert!(m <= d, "more subquantizers than dimensions");
        let splits: Vec<usize> = (0..=m).map(|i| i * d / m).collect();
        let mut codebooks = Vec::with_capacity(m);
        for s in 0..m {
            let (lo, hi) = (splits[s], splits[s + 1]);
            let mut sub = Matrix::zeros(xs.rows, hi - lo);
            for i in 0..xs.rows {
                sub.row_mut(i).copy_from_slice(&xs.row(i)[lo..hi]);
            }
            let km = kmeans(&sub, &KMeansCfg::new(k).iters(12).seed(seed ^ s as u64));
            codebooks.push(km.centroids);
        }
        Pq { d, m, k, codebooks, splits }
    }

    /// Asymmetric distance lookup table for a query, flat and
    /// subspace-major: `lut[s * k + c]` = squared distance between the
    /// query's slice `s` and codeword `c`. One contiguous allocation
    /// (every subspace has exactly `k` codewords), so the inner scan loop
    /// walks one cache-friendly buffer instead of `m` separate `Vec`s.
    pub fn lut(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m * self.k];
        for s in 0..self.m {
            let (lo, hi) = (self.splits[s], self.splits[s + 1]);
            let cb = &self.codebooks[s];
            for c in 0..self.k {
                out[s * self.k + c] = tensor::l2_sq(&q[lo..hi], cb.row(c));
            }
        }
        out
    }

    /// Exact asymmetric distance from a flat LUT (stride `k`). Indexing
    /// stays checked here: unlike the scorer hot paths, `lut`, `code`
    /// *and* the stride are all caller-supplied, so a mismatched `k`
    /// must panic rather than read out of bounds.
    #[inline]
    pub fn lut_distance(lut: &[f32], code: &[u32], k: usize) -> f32 {
        let mut acc = 0.0f32;
        for (s, &c) in code.iter().enumerate() {
            acc += lut[s * k + c as usize];
        }
        acc
    }
}

/// Flat-LUT [`ApproxScorer`] adapter for [`Pq`], so a product quantizer
/// can slot into pipeline stage 1 (or 2) next to the additive decoders.
///
/// The trait's score contract is inner-product shaped
/// (`t − 2⟨q, decode(code)⟩`), while `Pq::lut` stores squared slice
/// distances — so the adapter builds its own LUT of per-subspace inner
/// products `⟨q_s, c⟩`; summing over subspaces gives `⟨q, decode(code)⟩`
/// exactly (subspaces are disjoint), which makes the PQ "approximate"
/// score exact for its own reconstruction.
pub struct PqScorer(pub Pq);

impl ApproxScorer for PqScorer {
    fn lut_len(&self) -> usize {
        self.0.m * self.0.k
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.lut_len());
        let pq = &self.0;
        for s in 0..pq.m {
            let (lo, hi) = (pq.splits[s], pq.splits[s + 1]);
            let cb = &pq.codebooks[s];
            for c in 0..pq.k {
                out[s * pq.k + c] = tensor::dot(&q[lo..hi], cb.row(c));
            }
        }
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        // hot path: unchecked lookups under the trait's score
        // preconditions (lut from self.lut_into, codes in [0, k))
        debug_assert_eq!(lut.len(), self.lut_len());
        debug_assert!(code.len() <= self.0.m && code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        let mut ip = 0.0f32;
        for (s, &c) in code.iter().enumerate() {
            ip += unsafe { *lut.get_unchecked(s * k + c as usize) };
        }
        t - 2.0 * ip
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(stride, self.lut_len());
        debug_assert!(code.len() <= self.0.m && code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_block_lanes(
            luts,
            stride,
            members,
            || code.iter().enumerate().map(move |(s, &c)| s * k + c as usize),
            term,
            out,
        );
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        debug_assert_eq!(tlut.len(), self.lut_len() * super::SCORE_BLOCK);
        debug_assert!(code.len() <= self.0.m && code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_tblock_lanes(
            tlut,
            || code.iter().enumerate().map(move |(s, &c)| s * k + c as usize),
            term,
            out,
        );
    }

    // subspace-major `s·k + c` offsets are exactly the additive
    // position-major walk, so PQ nibble-packs when k fits
    fn packed4_geometry(&self) -> Option<(usize, usize)> {
        (self.0.k <= 16).then_some((self.0.m, self.0.k))
    }

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        let pq = &self.0;
        let mut ip = 0.0f32;
        for (s, &c) in code.iter().enumerate() {
            let (lo, hi) = (pq.splits[s], pq.splits[s + 1]);
            ip += tensor::dot(&q[lo..hi], pq.codebooks[s].row(c as usize));
        }
        t - 2.0 * ip
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        self.0.decode(codes)
    }

    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        Some(self.0.encode(xs))
    }

    // default `use_lut` (always true): a PQ LUT costs only k·d flops to
    // build — the subspaces partition the d dimensions — so it amortizes
    // even for tiny shortlists.
}

impl VectorQuantizer for Pq {
    fn code_len(&self) -> usize {
        self.m
    }

    fn k(&self) -> usize {
        self.k
    }

    fn encode(&self, xs: &Matrix) -> Codes {
        assert_eq!(xs.cols, self.d);
        let mut codes = Codes::zeros(xs.rows, self.m);
        let m = self.m;
        let codes_ptr = codes.data.as_mut_ptr() as usize;
        pool::scope_chunks(xs.rows, pool::default_threads(), |lo_r, hi_r| {
            for i in lo_r..hi_r {
                for s in 0..m {
                    let (lo, hi) = (self.splits[s], self.splits[s + 1]);
                    let (best, _) = tensor::argmin_l2(&xs.row(i)[lo..hi], &self.codebooks[s]);
                    unsafe {
                        *(codes_ptr as *mut u32).add(i * m + s) = best as u32;
                    }
                }
            }
        });
        codes
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        assert_eq!(codes.m, self.m);
        let mut out = Matrix::zeros(codes.n, self.d);
        for i in 0..codes.n {
            let row = out.row_mut(i);
            for (s, &c) in codes.row(i).iter().enumerate() {
                let (lo, hi) = (self.splits[s], self.splits[s + 1]);
                row[lo..hi].copy_from_slice(self.codebooks[s].row(c as usize));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn pq_reduces_error_with_more_centroids() {
        let xs = generate(Flavor::Deep, 600, 16, 1);
        let pq4 = Pq::train(&xs, 4, 4, 2);
        let pq16 = Pq::train(&xs, 4, 16, 2);
        let e4 = pq4.eval_mse(&xs);
        let e16 = pq16.eval_mse(&xs);
        assert!(e16 < e4, "{e16} !< {e4}");
    }

    #[test]
    fn decode_uses_selected_codewords() {
        let xs = generate(Flavor::BigAnn, 200, 8, 3);
        let pq = Pq::train(&xs, 2, 8, 4);
        let codes = pq.encode(&xs);
        let dec = pq.decode(&codes);
        for i in [0usize, 57, 199] {
            let c = codes.row(i);
            assert_eq!(&dec.row(i)[0..4], pq.codebooks[0].row(c[0] as usize));
            assert_eq!(&dec.row(i)[4..8], pq.codebooks[1].row(c[1] as usize));
        }
    }

    #[test]
    fn encoding_is_nearest_per_subspace() {
        let xs = generate(Flavor::Deep, 100, 8, 5);
        let pq = Pq::train(&xs, 2, 4, 6);
        let codes = pq.encode(&xs);
        for i in 0..xs.rows {
            for s in 0..2 {
                let (lo, hi) = (pq.splits[s], pq.splits[s + 1]);
                let (best, _) = tensor::argmin_l2(&xs.row(i)[lo..hi], &pq.codebooks[s]);
                assert_eq!(codes.row(i)[s], best as u32);
            }
        }
    }

    #[test]
    fn lut_distance_matches_explicit() {
        let xs = generate(Flavor::Deep, 150, 12, 7);
        let pq = Pq::train(&xs, 3, 8, 8);
        let codes = pq.encode(&xs);
        let dec = pq.decode(&codes);
        let q = xs.row(0).to_vec();
        let lut = pq.lut(&q);
        assert_eq!(lut.len(), pq.m * pq.k, "flat subspace-major layout");
        for i in 0..20 {
            let lut_d = Pq::lut_distance(&lut, codes.row(i), pq.k);
            let exact = tensor::l2_sq(&q, dec.row(i));
            assert!((lut_d - exact).abs() < 1e-3, "{lut_d} vs {exact}");
        }
    }

    #[test]
    fn scorer_adapter_matches_lut_distance_up_to_query_norm() {
        // PqScorer follows the ApproxScorer contract (t − 2⟨q, x̂⟩): adding
        // ||q||² must recover the exact flat-LUT distance
        let xs = generate(Flavor::Deep, 120, 12, 13);
        let pq = Pq::train(&xs, 3, 8, 14);
        let codes = pq.encode(&xs);
        let q = xs.row(1).to_vec();
        let dist_lut = pq.lut(&q);
        let k = pq.k;
        let scorer = PqScorer(pq);
        let norms = ApproxScorer::norms(&scorer, &codes);
        let ip_lut = scorer.lut(&q);
        let qn = tensor::sqnorm(&q);
        for i in 0..30 {
            let s = scorer.score(&ip_lut, codes.row(i), norms[i]) + qn;
            let d = Pq::lut_distance(&dist_lut, codes.row(i), k);
            assert!((s - d).abs() < 1e-3, "{s} vs {d}");
        }
    }

    #[test]
    fn uneven_dimension_split() {
        let xs = generate(Flavor::Contriever, 100, 10, 9);
        let pq = Pq::train(&xs, 3, 4, 10); // 10 = 3+3+4 split
        assert_eq!(pq.splits, vec![0, 3, 6, 10]);
        let codes = pq.encode(&xs);
        let dec = pq.decode(&codes);
        assert_eq!(dec.cols, 10);
        assert!(crate::tensor::mse(&xs, &dec).is_finite());
    }

    #[test]
    fn bits_accounting() {
        let xs = generate(Flavor::Deep, 64, 8, 11);
        let pq = Pq::train(&xs, 4, 16, 12);
        assert_eq!(pq.bits(), 4 * 4);
    }
}
