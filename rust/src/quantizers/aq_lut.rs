//! Additive (AQ-style) lookup decoders over *fixed* codes.
//!
//! The paper's search pipeline (Sec. 3.3) re-interprets QINCo2 codes as
//! additive quantizer codes: codebooks are re-estimated from (vector,
//! code) pairs so that `x ~= sum_m C_m[code_m]`, enabling O(M) LUT
//! distance evaluation per database vector. Two fits are compared in
//! Table 4:
//!   * [`AdditiveDecoder::fit_aq`]: one joint least-squares system
//!     (Amara et al., 2022) — most accurate single-code fit, slow to train;
//!   * [`AdditiveDecoder::fit_rq`]: sequential per-position residual
//!     bucket means — nearly as good, much cheaper.
//!
//! Asymmetric distances use `||q - x_hat||^2 = ||q||^2 - 2<q, x_hat> +
//! ||x_hat||^2`; the inner product unrolls over per-position LUTs and the
//! reconstruction norm is cached per database vector (Faiss' `Nqint8`
//! trick, kept in f32 here).

use super::{ApproxScorer, Codes};
use crate::linalg::lstsq_onehot;
use crate::tensor::{self, Matrix};
use anyhow::Result;

pub struct AdditiveDecoder {
    pub d: usize,
    pub k: usize,
    /// per-position codebooks [k, d]
    pub codebooks: Vec<Matrix>,
}

impl AdditiveDecoder {
    /// Joint least-squares fit of all positions (the "AQ" row of Table 4).
    pub fn fit_aq(xs: &Matrix, codes: &Codes, k: usize) -> Result<AdditiveDecoder> {
        assert_eq!(xs.rows, codes.n);
        let m = codes.m;
        let active: Vec<Vec<u32>> = (0..codes.n)
            .map(|i| {
                codes
                    .row(i)
                    .iter()
                    .enumerate()
                    .map(|(p, &c)| (p * k) as u32 + c)
                    .collect()
            })
            .collect();
        let w = lstsq_onehot(&active, xs, m * k, 1e-3)?;
        let codebooks = (0..m)
            .map(|p| {
                let mut cb = Matrix::zeros(k, xs.cols);
                for c in 0..k {
                    cb.row_mut(c).copy_from_slice(w.row(p * k + c));
                }
                cb
            })
            .collect();
        Ok(AdditiveDecoder { d: xs.cols, k, codebooks })
    }

    /// Sequential fit: position by position, each codebook is the
    /// per-bucket mean of the residual (exact LS for a one-hot design)
    /// — the "RQ" row of Table 4.
    pub fn fit_rq(xs: &Matrix, codes: &Codes, k: usize) -> AdditiveDecoder {
        assert_eq!(xs.rows, codes.n);
        let mut resid = xs.clone();
        let mut codebooks = Vec::with_capacity(codes.m);
        for p in 0..codes.m {
            let mut sums = Matrix::zeros(k, xs.cols);
            let mut counts = vec![0usize; k];
            for i in 0..codes.n {
                let c = codes.row(i)[p] as usize;
                counts[c] += 1;
                tensor::add_assign(sums.row_mut(c), resid.row(i));
            }
            let mut cb = Matrix::zeros(k, xs.cols);
            for c in 0..k {
                if counts[c] > 0 {
                    let inv = 1.0 / counts[c] as f32;
                    for (o, &s) in cb.row_mut(c).iter_mut().zip(sums.row(c)) {
                        *o = s * inv;
                    }
                }
            }
            for i in 0..codes.n {
                let c = codes.row(i)[p] as usize;
                let crow = cb.row(c).to_vec();
                tensor::sub_assign(resid.row_mut(i), &crow);
            }
            codebooks.push(cb);
        }
        AdditiveDecoder { d: xs.cols, k, codebooks }
    }

    pub fn decode(&self, codes: &Codes) -> Matrix {
        assert_eq!(codes.m, self.codebooks.len());
        let mut out = Matrix::zeros(codes.n, self.d);
        for i in 0..codes.n {
            let row = out.row_mut(i);
            for (p, &c) in codes.row(i).iter().enumerate() {
                tensor::add_assign(row, self.codebooks[p].row(c as usize));
            }
        }
        out
    }

    /// Cached squared reconstruction norms for a code table.
    pub fn norms(&self, codes: &Codes) -> Vec<f32> {
        let dec = self.decode(codes);
        (0..codes.n).map(|i| tensor::sqnorm(dec.row(i))).collect()
    }

    /// Inner-product lookup tables for a query: `lut[p*k + c] = <q, C_p[c]>`
    /// (flat for cache-friendly scanning).
    pub fn lut(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codebooks.len() * self.k];
        self.lut_into(q, &mut out);
        out
    }

    /// Size of one flat LUT (`m * k`), for batch buffer sizing.
    pub fn lut_len(&self) -> usize {
        self.codebooks.len() * self.k
    }

    /// Fill a pre-allocated `m * k` slice with the flat LUT — the batch
    /// engine packs one slice per query into a contiguous buffer.
    pub fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.lut_len());
        for (p, cb) in self.codebooks.iter().enumerate() {
            for c in 0..self.k {
                out[p * self.k + c] = tensor::dot(q, cb.row(c));
            }
        }
    }

    /// Approximate distance score from LUTs: `norm - 2 sum_p lut[p][code_p]`
    /// (the constant ||q||^2 is dropped — ranking is unaffected).
    #[inline]
    pub fn score(&self, lut: &[f32], code: &[u32], norm: f32) -> f32 {
        debug_assert_eq!(lut.len(), self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let mut ip = 0.0f32;
        for (p, &c) in code.iter().enumerate() {
            ip += unsafe { *lut.get_unchecked(p * self.k + c as usize) };
        }
        norm - 2.0 * ip
    }
}

/// Stage-1/stage-2 scorer interface: delegates to the inherent methods
/// (which remain the concrete-type API). See the [`ApproxScorer`] score
/// contract — `score(lut, code, t) = t − 2⟨q, decode(code)⟩`.
impl ApproxScorer for AdditiveDecoder {
    fn lut_len(&self) -> usize {
        AdditiveDecoder::lut_len(self)
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        AdditiveDecoder::lut_into(self, q, out)
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        AdditiveDecoder::score(self, lut, code, t)
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(stride, AdditiveDecoder::lut_len(self));
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let k = self.k;
        super::score_block_lanes(
            luts,
            stride,
            members,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        debug_assert_eq!(tlut.len(), AdditiveDecoder::lut_len(self) * super::SCORE_BLOCK);
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let k = self.k;
        super::score_tblock_lanes(
            tlut,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    // no packed4_geometry override: the AQ decoder scans full-width
    // QINCo2 codes (k is the model's K, not a nibble), so Packed4
    // stays a build-time error for this family

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        let mut ip = 0.0f32;
        for (p, &c) in code.iter().enumerate() {
            ip += tensor::dot(q, self.codebooks[p].row(c as usize));
        }
        t - 2.0 * ip
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        AdditiveDecoder::decode(self, codes)
    }

    fn norms(&self, codes: &Codes) -> Vec<f32> {
        AdditiveDecoder::norms(self, codes)
    }

    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        super::stage2_use_lut(n_cands, self.codebooks.len(), self.k, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::quantizers::rq::Rq;
    use crate::quantizers::VectorQuantizer;

    fn setup() -> (Matrix, Codes, usize) {
        let xs = generate(Flavor::Deep, 800, 8, 1);
        let rq = Rq::train(&xs, 4, 8, 1, 2);
        let codes = rq.encode(&xs);
        (xs, codes, 8)
    }

    #[test]
    fn aq_fit_beats_rq_fit_or_close() {
        let (xs, codes, k) = setup();
        let aq = AdditiveDecoder::fit_aq(&xs, &codes, k).unwrap();
        let rq = AdditiveDecoder::fit_rq(&xs, &codes, k);
        let e_aq = crate::tensor::mse(&xs, &aq.decode(&codes));
        let e_rq = crate::tensor::mse(&xs, &rq.decode(&codes));
        // joint LS is optimal for this decode family (up to ridge epsilon)
        assert!(e_aq <= e_rq * 1.02, "AQ {e_aq} worse than RQ {e_rq}");
    }

    #[test]
    fn rq_refit_of_rq_codes_matches_rq_decode() {
        // refitting an RQ decoder on codes produced by actual RQ recovers
        // (approximately) the original codebooks' reconstruction quality
        let xs = generate(Flavor::BigAnn, 600, 8, 3);
        let rq = Rq::train(&xs, 3, 8, 1, 4);
        let codes = rq.encode(&xs);
        let e_orig = crate::tensor::mse(&xs, &rq.decode(&codes));
        let refit = AdditiveDecoder::fit_rq(&xs, &codes, 8);
        let e_refit = crate::tensor::mse(&xs, &refit.decode(&codes));
        assert!(e_refit <= e_orig * 1.05, "{e_refit} vs {e_orig}");
    }

    #[test]
    fn score_ranks_like_exact_distance_on_decoded_vectors() {
        let (xs, codes, k) = setup();
        let dec = AdditiveDecoder::fit_rq(&xs, &codes, k);
        let norms = dec.norms(&codes);
        let decoded = dec.decode(&codes);
        let q = xs.row(5);
        let lut = dec.lut(q);
        let qn = tensor::sqnorm(q);
        for i in 0..50 {
            let s = dec.score(&lut, codes.row(i), norms[i]);
            let exact = tensor::l2_sq(q, decoded.row(i));
            // score + ||q||^2 == exact distance to the decoded vector
            assert!((s + qn - exact).abs() < 1e-2, "{} vs {}", s + qn, exact);
        }
    }

    #[test]
    fn lut_layout_is_flat_position_major() {
        let (xs, codes, k) = setup();
        let dec = AdditiveDecoder::fit_aq(&xs, &codes, k).unwrap();
        let q = xs.row(0);
        let lut = dec.lut(q);
        assert_eq!(lut.len(), codes.m * k);
        assert!((lut[k + 3] - tensor::dot(q, dec.codebooks[1].row(3))).abs() < 1e-5);
    }
}
