//! Pairwise additive decoding — the paper's fast approximate decoder for
//! QINCo2 codes (Sec. 3.3, Eqs. 8-9; Tables 4, S3).
//!
//! A unitary additive decoder ignores all dependencies between code
//! positions. This decoder instead looks up *pairs* of codes: the joint
//! index `I^{i,j} = I^i * K + I^j` addresses a K^2-entry codebook, which
//! can capture the pairwise dependency structure the QINCo2 network
//! created. Pairs are chosen greedily: at each step, try candidate pairs
//! (i, j), fit the K^2 codebook by per-bucket residual means (the exact
//! least-squares solution for a one-hot design), and keep the pair with
//! the lowest residual MSE. Codes may be reused across steps or not used
//! at all. IVF integration RQ-quantizes the coarse centroid into extra
//! virtual code positions that join the pair pool (Table S3's `~i`).

use super::{ApproxScorer, Codes, StageDecoder};
use crate::tensor::{self, Matrix};
use crate::util::pool;
use anyhow::Result;

/// One selected pair and its joint codebook.
#[derive(Clone)]
pub struct PairStep {
    pub i: usize,
    pub j: usize,
    /// [k*k, d] joint codebook; row `ci * k + cj`
    pub codebook: Matrix,
    /// training MSE after this step (Table S3's per-step trace)
    pub mse: f64,
}

#[derive(Clone)]
pub struct PairwiseDecoder {
    pub d: usize,
    pub k: usize,
    /// total number of code positions (original M + IVF-derived M~)
    pub positions: usize,
    pub steps: Vec<PairStep>,
}

/// Pseudo-count for shrinking joint-bucket means toward the additive
/// marginals. The K^2 buckets are sparsely populated when the fit set is
/// small relative to K^2 (the paper fits on millions of vectors; our
/// scaled runs may have ~1 sample/bucket) — empirical-Bayes shrinkage
/// C'[b] = (sum_b + TAU * prior_b) / (n_b + TAU) keeps unseen buckets at
/// the unitary-additive estimate instead of zero, preserving the
/// "at least as good as the unitary decoder" guarantee out-of-sample.
const TAU: f32 = 4.0;

/// Fit a K^2 joint codebook over positions (i, j): shrunk per-bucket
/// means of `resid`; returns (codebook, achieved MSE).
fn fit_pair(resid: &Matrix, codes: &Codes, i: usize, j: usize, k: usize) -> (Matrix, f64) {
    let kk = k * k;
    let d = resid.cols;
    // additive-marginal prior: mean per code at position i, then per code
    // at position j on what the first marginal leaves over
    let mut mean_i = Matrix::zeros(k, d);
    let mut cnt_i = vec![0u32; k];
    for r in 0..codes.n {
        let ci = codes.row(r)[i] as usize;
        cnt_i[ci] += 1;
        tensor::add_assign(mean_i.row_mut(ci), resid.row(r));
    }
    for c in 0..k {
        if cnt_i[c] > 0 {
            let inv = 1.0 / cnt_i[c] as f32;
            for v in mean_i.row_mut(c) {
                *v *= inv;
            }
        }
    }
    let mut mean_j = Matrix::zeros(k, d);
    let mut cnt_j = vec![0u32; k];
    for r in 0..codes.n {
        let row = codes.row(r);
        let (ci, cj) = (row[i] as usize, row[j] as usize);
        cnt_j[cj] += 1;
        let mi = mean_i.row(ci).to_vec();
        let rr: Vec<f32> = resid.row(r).iter().zip(&mi).map(|(a, b)| a - b).collect();
        tensor::add_assign(mean_j.row_mut(cj), &rr);
    }
    for c in 0..k {
        if cnt_j[c] > 0 {
            let inv = 1.0 / cnt_j[c] as f32;
            for v in mean_j.row_mut(c) {
                *v *= inv;
            }
        }
    }
    // joint bucket sums, shrunk toward prior = mean_i[ci] + mean_j[cj]
    let mut sums = Matrix::zeros(kk, d);
    let mut counts = vec![0u32; kk];
    for r in 0..codes.n {
        let row = codes.row(r);
        let idx = row[i] as usize * k + row[j] as usize;
        counts[idx] += 1;
        tensor::add_assign(sums.row_mut(idx), resid.row(r));
    }
    let mut cb = Matrix::zeros(kk, d);
    for ci in 0..k {
        for cj in 0..k {
            let b = ci * k + cj;
            let inv = 1.0 / (counts[b] as f32 + TAU);
            let (mi, mj) = (mean_i.row(ci), mean_j.row(cj));
            for f in 0..d {
                cb.data[b * d + f] = (sums.data[b * d + f] + TAU * (mi[f] + mj[f])) * inv;
            }
        }
    }
    // MSE after subtracting the shrunk bucket means
    let mut acc = 0.0f64;
    for r in 0..codes.n {
        let row = codes.row(r);
        let idx = row[i] as usize * k + row[j] as usize;
        acc += tensor::l2_sq(resid.row(r), cb.row(idx)) as f64;
    }
    (cb, acc / codes.n.max(1) as f64)
}

impl PairwiseDecoder {
    /// Greedy pair selection (Eq. 8-9): `n_steps` pairs drawn from all
    /// ordered (i < j) position pairs, codes reusable across steps.
    /// `codes` may include extra IVF-derived positions (see
    /// [`append_positions`]).
    pub fn train(xs: &Matrix, codes: &Codes, k: usize, n_steps: usize) -> PairwiseDecoder {
        let m = codes.m;
        let mut resid = xs.clone();
        let mut steps: Vec<PairStep> = Vec::with_capacity(n_steps);
        // candidate pool: all unordered pairs, stored as (i, j) with i < j
        let pairs: Vec<(usize, usize)> = (0..m)
            .flat_map(|i| (i + 1..m).map(move |j| (i, j)))
            .collect();
        for _step in 0..n_steps {
            // evaluate every candidate pair in parallel, keep the best
            let mut results: Vec<(f64, usize)> = vec![(f64::INFINITY, 0); pairs.len()];
            {
                let resid_ref = &resid;
                pool::par_map_into(&mut results, pool::default_threads(), |pi, slot| {
                    let (i, j) = pairs[pi];
                    let (_, mse) = fit_pair(resid_ref, codes, i, j, k);
                    *slot = (mse, pi);
                });
            }
            let &(best_mse, best_pi) = results
                .iter()
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap();
            let (i, j) = pairs[best_pi];
            let (cb, _) = fit_pair(&resid, codes, i, j, k);
            // subtract this step's contribution from the residual
            for r in 0..codes.n {
                let row = codes.row(r);
                let idx = row[i] as usize * k + row[j] as usize;
                let crow = cb.row(idx).to_vec();
                tensor::sub_assign(resid.row_mut(r), &crow);
            }
            steps.push(PairStep { i, j, codebook: cb, mse: best_mse });
        }
        PairwiseDecoder { d: xs.cols, k, positions: m, steps }
    }

    /// Fixed consecutive pairing ((0,1), (2,3), ...) — the paper's
    /// "M/2 consecutive code-pairs" baseline in Table 4.
    pub fn train_consecutive(xs: &Matrix, codes: &Codes, k: usize) -> PairwiseDecoder {
        let mut resid = xs.clone();
        let mut steps = Vec::new();
        let mut p = 0;
        while p + 1 < codes.m {
            let (cb, mse) = fit_pair(&resid, codes, p, p + 1, k);
            for r in 0..codes.n {
                let row = codes.row(r);
                let idx = row[p] as usize * k + row[p + 1] as usize;
                let crow = cb.row(idx).to_vec();
                tensor::sub_assign(resid.row_mut(r), &crow);
            }
            steps.push(PairStep { i: p, j: p + 1, codebook: cb, mse });
            p += 2;
        }
        PairwiseDecoder { d: xs.cols, k, positions: codes.m, steps }
    }

    pub fn decode(&self, codes: &Codes) -> Matrix {
        assert_eq!(codes.m, self.positions);
        let mut out = Matrix::zeros(codes.n, self.d);
        for r in 0..codes.n {
            let row = out.row_mut(r);
            let code = codes.row(r);
            for s in &self.steps {
                let idx = code[s.i] as usize * self.k + code[s.j] as usize;
                tensor::add_assign(row, s.codebook.row(idx));
            }
        }
        out
    }

    /// Cached squared reconstruction norms.
    pub fn norms(&self, codes: &Codes) -> Vec<f32> {
        let dec = self.decode(codes);
        (0..codes.n).map(|i| tensor::sqnorm(dec.row(i))).collect()
    }

    /// Flat inner-product LUT: `lut[s * k^2 + joint]` = <q, C'_s[joint]>.
    pub fn lut(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.lut_len()];
        self.lut_into(q, &mut out);
        out
    }

    /// Size of one flat joint LUT (`steps * k^2`), for batch buffers.
    pub fn lut_len(&self) -> usize {
        self.steps.len() * self.k * self.k
    }

    /// Fill a pre-allocated `steps * k^2` slice with the flat joint LUT.
    pub fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.lut_len());
        let kk = self.k * self.k;
        for (si, s) in self.steps.iter().enumerate() {
            for b in 0..kk {
                out[si * kk + b] = tensor::dot(q, s.codebook.row(b));
            }
        }
    }

    /// LUT distance score (constant ||q||^2 dropped).
    #[inline]
    pub fn score(&self, lut: &[f32], code: &[u32], norm: f32) -> f32 {
        debug_assert_eq!(lut.len(), self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let kk = self.k * self.k;
        let mut ip = 0.0f32;
        for (s_idx, s) in self.steps.iter().enumerate() {
            let joint = code[s.i] as usize * self.k + code[s.j] as usize;
            ip += unsafe { *lut.get_unchecked(s_idx * kk + joint) };
        }
        norm - 2.0 * ip
    }

    /// Per-step (pair, mse) trace — regenerates Table S3.
    pub fn trace(&self) -> Vec<(usize, usize, f64)> {
        self.steps.iter().map(|s| (s.i, s.j, s.mse)).collect()
    }
}

/// Stage-2 scorer interface (the paper's default re-ranker). The direct
/// path accumulates one dot product per pair step — float-identical to
/// the historical in-line stage-2 loop of the search pipeline.
impl ApproxScorer for PairwiseDecoder {
    fn lut_len(&self) -> usize {
        PairwiseDecoder::lut_len(self)
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        PairwiseDecoder::lut_into(self, q, out)
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        PairwiseDecoder::score(self, lut, code, t)
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(stride, PairwiseDecoder::lut_len(self));
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let (k, kk) = (self.k, self.k * self.k);
        super::score_block_lanes(
            luts,
            stride,
            members,
            || {
                self.steps.iter().enumerate().map(move |(s_idx, s)| {
                    s_idx * kk + code[s.i] as usize * k + code[s.j] as usize
                })
            },
            term,
            out,
        );
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        debug_assert_eq!(tlut.len(), PairwiseDecoder::lut_len(self) * super::SCORE_BLOCK);
        debug_assert!(code.iter().all(|&c| (c as usize) < self.k));
        let (k, kk) = (self.k, self.k * self.k);
        super::score_tblock_lanes(
            tlut,
            || {
                self.steps.iter().enumerate().map(move |(s_idx, s)| {
                    s_idx * kk + code[s.i] as usize * k + code[s.j] as usize
                })
            },
            term,
            out,
        );
    }

    // no packed4_geometry override: joint k² sub-tables are not the
    // additive position-major walk Packed4 nibble-packs

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        let mut ip = 0.0f32;
        for s in &self.steps {
            let joint = code[s.i] as usize * self.k + code[s.j] as usize;
            ip += tensor::dot(q, s.codebook.row(joint));
        }
        t - 2.0 * ip
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        PairwiseDecoder::decode(self, codes)
    }

    fn norms(&self, codes: &Codes) -> Vec<f32> {
        PairwiseDecoder::norms(self, codes)
    }

    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        super::stage2_use_lut(n_cands, self.steps.len(), self.k, d)
    }
}

/// Stage-3 interface: a pairwise decoder can also serve as the exact
/// re-rank decoder over its own (extended) code table — the "fast mode"
/// middle ground between LUT-only and a full neural decode.
impl StageDecoder for PairwiseDecoder {
    fn decode(&self, codes: &Codes) -> Result<Matrix> {
        Ok(PairwiseDecoder::decode(self, codes))
    }

    fn name(&self) -> &'static str {
        "pairwise"
    }
}

/// Concatenate extra code positions (e.g. RQ-quantized IVF centroids)
/// onto an existing code table: result has `codes.m + extra.m` positions.
pub fn append_positions(codes: &Codes, extra: &Codes) -> Codes {
    assert_eq!(codes.n, extra.n);
    let m = codes.m + extra.m;
    let mut out = Codes::zeros(codes.n, m);
    for i in 0..codes.n {
        out.row_mut(i)[..codes.m].copy_from_slice(codes.row(i));
        out.row_mut(i)[codes.m..].copy_from_slice(extra.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};
    use crate::quantizers::aq_lut::AdditiveDecoder;
    use crate::quantizers::rq::Rq;
    use crate::quantizers::VectorQuantizer;

    fn setup() -> (Matrix, Codes) {
        let xs = generate(Flavor::Deep, 900, 8, 1);
        let rq = Rq::train(&xs, 4, 8, 1, 2);
        let codes = rq.encode(&xs);
        (xs, codes)
    }

    #[test]
    fn pairwise_beats_unitary_additive() {
        // the paper's key claim for Table 4: pairwise decoding with 2M
        // optimized pairs is far more accurate than unitary AQ
        let (xs, codes) = setup();
        let aq = AdditiveDecoder::fit_aq(&xs, &codes, 8).unwrap();
        let pw = PairwiseDecoder::train(&xs, &codes, 8, 2 * codes.m);
        let e_aq = crate::tensor::mse(&xs, &aq.decode(&codes));
        let e_pw = crate::tensor::mse(&xs, &pw.decode(&codes));
        assert!(e_pw < e_aq, "pairwise {e_pw} !< AQ {e_aq}");
    }

    #[test]
    fn optimized_pairs_beat_consecutive() {
        let (xs, codes) = setup();
        let cons = PairwiseDecoder::train_consecutive(&xs, &codes, 8);
        let opt = PairwiseDecoder::train(&xs, &codes, 8, cons.steps.len());
        let e_cons = crate::tensor::mse(&xs, &cons.decode(&codes));
        let e_opt = crate::tensor::mse(&xs, &opt.decode(&codes));
        assert!(e_opt <= e_cons + 1e-9, "optimized {e_opt} > consecutive {e_cons}");
    }

    #[test]
    fn per_step_mse_nonincreasing() {
        // Eq. 9: each greedy step minimizes the residual; the Table S3
        // trace must be monotone
        let (xs, codes) = setup();
        let pw = PairwiseDecoder::train(&xs, &codes, 8, 6);
        let trace = pw.trace();
        for w in trace.windows(2) {
            assert!(w[1].2 <= w[0].2 + 1e-9, "{:?}", trace);
        }
    }

    #[test]
    fn score_matches_decoded_distance() {
        let (xs, codes) = setup();
        let pw = PairwiseDecoder::train(&xs, &codes, 8, 4);
        let decoded = pw.decode(&codes);
        let norms = pw.norms(&codes);
        let q = xs.row(3);
        let lut = pw.lut(q);
        let qn = tensor::sqnorm(q);
        for i in 0..40 {
            let s = pw.score(&lut, codes.row(i), norms[i]);
            let exact = tensor::l2_sq(q, decoded.row(i));
            assert!((s + qn - exact).abs() < 1e-2);
        }
    }

    #[test]
    fn append_positions_layout() {
        let a = Codes::from_vec(2, 2, vec![1, 2, 3, 4]);
        let b = Codes::from_vec(2, 1, vec![9, 8]);
        let j = append_positions(&a, &b);
        assert_eq!(j.row(0), &[1, 2, 9]);
        assert_eq!(j.row(1), &[3, 4, 8]);
    }

    #[test]
    fn pair_guarantee_at_least_unitary() {
        // a single pair step (i,j) must fit at least as well as the best
        // single-position RQ step on i or j (paper: "guaranteed to be at
        // least as good as the unitary decoder")
        let (xs, codes) = setup();
        let (_, pair_mse) = fit_pair(&xs, &codes, 0, 1, 8);
        for pos in [0usize, 1] {
            let single = AdditiveDecoder::fit_rq(
                &xs,
                &codes.truncate(pos + 1).truncate(pos + 1),
                8,
            );
            let _ = single;
            // fit a unitary bucket-mean on position `pos` directly:
            let mut sums = Matrix::zeros(8, xs.cols);
            let mut counts = vec![0u32; 8];
            for r in 0..codes.n {
                let c = codes.row(r)[pos] as usize;
                counts[c] += 1;
                tensor::add_assign(sums.row_mut(c), xs.row(r));
            }
            let mut acc = 0.0f64;
            for r in 0..codes.n {
                let c = codes.row(r)[pos] as usize;
                let mean: Vec<f32> = sums
                    .row(c)
                    .iter()
                    .map(|&s| s / counts[c].max(1) as f32)
                    .collect();
                acc += tensor::l2_sq(xs.row(r), &mean) as f64;
            }
            let unit_mse = acc / codes.n as f64;
            assert!(pair_mse <= unit_mse + 1e-9, "{pair_mse} > {unit_mse}");
        }
    }
}
