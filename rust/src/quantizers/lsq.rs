//! LSQ-style additive quantization (Martinez et al., 2018): RQ
//! initialization, then alternating (1) joint least-squares codebook
//! re-estimation and (2) ICM encoding sweeps with annealed random
//! restarts. The strongest classical baseline in Table 3.

use super::{aq_lut::AdditiveDecoder, rq::Rq, ApproxScorer, Codes, VectorQuantizer};
use crate::tensor::{self, Matrix};
use crate::util::{pool, prng::Rng};

pub struct Lsq {
    pub d: usize,
    pub m: usize,
    pub k: usize,
    pub codebooks: Vec<Matrix>,
    /// ICM sweeps per encode call
    pub icm_iters: usize,
    /// annealing perturbations per encode call (LSQ++'s random restarts)
    pub perturbations: usize,
    seed: u64,
}

impl Lsq {
    pub fn train(xs: &Matrix, m: usize, k: usize, train_iters: usize, seed: u64) -> Lsq {
        // init from greedy RQ
        let rq = Rq::train(xs, m, k, 1, seed);
        let mut lsq = Lsq {
            d: xs.cols,
            m,
            k,
            codebooks: rq.codebooks,
            icm_iters: 3,
            perturbations: 2,
            seed,
        };
        let mut codes = rq_like_encode(&lsq, xs);
        for _it in 0..train_iters {
            // (1) codebook update: joint LS on current codes
            if let Ok(dec) = AdditiveDecoder::fit_aq(xs, &codes, k) {
                lsq.codebooks = dec.codebooks;
            }
            // (2) code update: ICM sweeps
            codes = lsq.encode(xs);
        }
        lsq
    }

    /// One ICM pass over positions in random order: re-pick each code
    /// with all others held fixed. `xhat` is kept in sync incrementally.
    fn icm_sweep(&self, x: &[f32], code: &mut [u32], xhat: &mut [f32], rng: &mut Rng) {
        let mut order: Vec<usize> = (0..self.m).collect();
        rng.shuffle(&mut order);
        for &p in &order {
            let cb = &self.codebooks[p];
            // remove current contribution
            let cur = code[p] as usize;
            let cur_row = cb.row(cur).to_vec();
            tensor::sub_assign(xhat, &cur_row);
            // residual target for this position
            let resid: Vec<f32> = x.iter().zip(xhat.iter()).map(|(a, b)| a - b).collect();
            let (best, _) = tensor::argmin_l2(&resid, cb);
            code[p] = best as u32;
            let best_row = cb.row(best).to_vec();
            tensor::add_assign(xhat, &best_row);
        }
    }

    fn encode_one(&self, x: &[f32], init: &[u32], rng: &mut Rng) -> (Vec<u32>, f32) {
        let mut best_code = init.to_vec();
        let mut xhat = self.partial_decode(&best_code);
        for _ in 0..self.icm_iters {
            self.icm_sweep(x, &mut best_code, &mut xhat, rng);
        }
        let mut best_err = tensor::l2_sq(x, &xhat);
        // annealed perturbations: kick a random position, re-ICM, keep if
        // better (LSQ++'s random restart flavour)
        for _ in 0..self.perturbations {
            let mut code = best_code.clone();
            let p = rng.below(self.m);
            code[p] = rng.below(self.k) as u32;
            let mut xh = self.partial_decode(&code);
            for _ in 0..self.icm_iters {
                self.icm_sweep(x, &mut code, &mut xh, rng);
            }
            let err = tensor::l2_sq(x, &xh);
            if err < best_err {
                best_err = err;
                best_code = code;
            }
        }
        (best_code, best_err)
    }

    fn partial_decode(&self, code: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        for (p, &c) in code.iter().enumerate() {
            tensor::add_assign(&mut out, self.codebooks[p].row(c as usize));
        }
        out
    }
}

/// Greedy residual encoding with LSQ codebooks (used for initial codes).
fn rq_like_encode(lsq: &Lsq, xs: &Matrix) -> Codes {
    let mut codes = Codes::zeros(xs.rows, lsq.m);
    for i in 0..xs.rows {
        let mut resid = xs.row(i).to_vec();
        for p in 0..lsq.m {
            let (best, _) = tensor::argmin_l2(&resid, &lsq.codebooks[p]);
            codes.row_mut(i)[p] = best as u32;
            let row = lsq.codebooks[p].row(best).to_vec();
            tensor::sub_assign(&mut resid, &row);
        }
    }
    codes
}

/// Flat-LUT [`ApproxScorer`] adapter for [`Lsq`], completing the baseline
/// scorer matrix (ROADMAP): LSQ codebooks are additive like RQ's, so the
/// unitary position-major LUT is exact for the LSQ reconstruction. Shares
/// the additive-family layout and kernels; scans the LSQ's own (ICM-
/// encoded) code table as a pipeline stage 1
/// ([`crate::index::Stage1Kind::Lsq`]).
pub struct LsqScorer(pub Lsq);

impl ApproxScorer for LsqScorer {
    fn lut_len(&self) -> usize {
        self.0.m * self.0.k
    }

    fn lut_into(&self, q: &[f32], out: &mut [f32]) {
        super::additive_lut_into(&self.0.codebooks, self.0.k, q, out)
    }

    fn score(&self, lut: &[f32], code: &[u32], t: f32) -> f32 {
        debug_assert_eq!(lut.len(), self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        super::additive_flat_score(self.0.k, lut, code, t)
    }

    fn score_block(
        &self,
        luts: &[f32],
        stride: usize,
        members: &[u32],
        code: &[u32],
        term: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(stride, self.lut_len());
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_block_lanes(
            luts,
            stride,
            members,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    fn score_block_transposed(&self, tlut: &[f32], code: &[u32], term: f32, out: &mut [f32]) {
        debug_assert_eq!(tlut.len(), self.lut_len() * super::SCORE_BLOCK);
        debug_assert!(code.iter().all(|&c| (c as usize) < self.0.k));
        let k = self.0.k;
        super::score_tblock_lanes(
            tlut,
            || code.iter().enumerate().map(move |(p, &c)| p * k + c as usize),
            term,
            out,
        );
    }

    // no packed4_geometry override: LSQ rides with the excluded families
    // (its ICM encoder is also the one non-deterministic ingest path)

    fn score_direct(&self, q: &[f32], code: &[u32], t: f32) -> f32 {
        let mut ip = 0.0f32;
        for (p, &c) in code.iter().enumerate() {
            ip += tensor::dot(q, self.0.codebooks[p].row(c as usize));
        }
        t - 2.0 * ip
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        VectorQuantizer::decode(&self.0, codes)
    }

    fn use_lut(&self, n_cands: usize, d: usize) -> bool {
        super::stage2_use_lut(n_cands, self.0.m, self.0.k, d)
    }

    fn encode_rows(&self, xs: &Matrix) -> Option<Codes> {
        // note: the ICM sweep seeds its RNG per batch chunk, so LSQ
        // ingest is valid but not bit-identical to a fresh batch encode
        // — the mutation bit-identity invariant excludes LSQ pipelines
        Some(self.0.encode(xs))
    }
}

impl VectorQuantizer for Lsq {
    fn code_len(&self) -> usize {
        self.m
    }

    fn k(&self) -> usize {
        self.k
    }

    fn encode(&self, xs: &Matrix) -> Codes {
        let init = rq_like_encode(self, xs);
        let mut codes = Codes::zeros(xs.rows, self.m);
        let ptr = codes.data.as_mut_ptr() as usize;
        pool::scope_chunks(xs.rows, pool::default_threads(), |lo, hi| {
            let mut rng = Rng::new(self.seed ^ (lo as u64) << 20);
            for i in lo..hi {
                let (c, _) = self.encode_one(xs.row(i), init.row(i), &mut rng);
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        c.as_ptr(),
                        (ptr as *mut u32).add(i * self.m),
                        self.m,
                    );
                }
            }
        });
        codes
    }

    fn decode(&self, codes: &Codes) -> Matrix {
        let mut out = Matrix::zeros(codes.n, self.d);
        for i in 0..codes.n {
            let dec = self.partial_decode(codes.row(i));
            out.row_mut(i).copy_from_slice(&dec);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Flavor};

    #[test]
    fn lsq_no_worse_than_rq() {
        // Table 3 ordering: LSQ <= RQ in MSE (usually strictly better)
        let xs = generate(Flavor::Deep, 700, 12, 1);
        let rq = Rq::train(&xs, 4, 8, 1, 2);
        let lsq = Lsq::train(&xs, 4, 8, 3, 2);
        let (e_rq, e_lsq) = (rq.eval_mse(&xs), lsq.eval_mse(&xs));
        assert!(e_lsq <= e_rq * 1.02, "LSQ {e_lsq} worse than RQ {e_rq}");
    }

    #[test]
    fn icm_never_increases_error() {
        let xs = generate(Flavor::BigAnn, 200, 8, 3);
        let lsq = Lsq::train(&xs, 3, 8, 2, 4);
        let init = rq_like_encode(&lsq, &xs);
        let mut rng = Rng::new(5);
        for i in 0..30 {
            let x = xs.row(i);
            let e_init = tensor::l2_sq(x, &lsq.partial_decode(init.row(i)));
            let (_, e_icm) = lsq.encode_one(x, init.row(i), &mut rng);
            assert!(e_icm <= e_init + 1e-5, "row {i}: {e_icm} > {e_init}");
        }
    }

    #[test]
    fn encode_decode_shapes() {
        let xs = generate(Flavor::Ssnpp, 120, 8, 6);
        let lsq = Lsq::train(&xs, 4, 8, 1, 7);
        let codes = lsq.encode(&xs);
        assert_eq!((codes.n, codes.m), (120, 4));
        assert!(codes.data.iter().all(|&c| c < 8));
        let dec = lsq.decode(&codes);
        assert_eq!((dec.rows, dec.cols), (120, 8));
    }
}
