//! Small dense linear algebra: Cholesky factorization and least-squares
//! via normal equations. Used to fit the AQ/pairwise decoder codebooks
//! (paper Sec. 3.3: "estimated by solving a least-squares system").

pub mod eig;

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// In-place Cholesky factorization A = L L^T for symmetric positive
/// definite A (row-major, n x n). Returns the lower-triangular factor.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.data[i * n + j] as f64;
            for k in 0..j {
                sum -= (l.data[i * n + k] * l.data[j * n + k]) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum})");
                }
                l.data[i * n + i] = (sum.sqrt()) as f32;
            } else {
                l.data[i * n + j] = (sum / l.data[j * n + j] as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= (l.data[i * n + k] * y[k]) as f64;
        }
        y[i] = (sum / l.data[i * n + i] as f64) as f32;
    }
    y
}

/// Solve L^T x = y (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in i + 1..n {
            sum -= (l.data[k * n + i] * x[k]) as f64;
        }
        x[i] = (sum / l.data[i * n + i] as f64) as f32;
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Result<Vec<f32>> {
    let l = cholesky(a)?;
    Ok(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Least squares: minimize ||D w - y||^2 over w, for a sparse "few-hot"
/// design matrix given as per-row active column indices (every active
/// entry is 1.0). This is exactly the AQ codebook estimation problem:
/// each data row activates one column per codebook (its code), and the
/// target y is the data vector; solving per output dimension shares the
/// same Gram matrix.
///
/// Returns the [n_cols, dim] solution matrix. `ridge` adds Tikhonov
/// damping to keep the (often rank-deficient) Gram matrix SPD.
pub fn lstsq_onehot(
    active: &[Vec<u32>],
    targets: &Matrix,
    n_cols: usize,
    ridge: f32,
) -> Result<Matrix> {
    assert_eq!(active.len(), targets.rows);
    let dim = targets.cols;
    // Gram matrix G = D^T D (n_cols x n_cols) and RHS = D^T Y (n_cols x dim)
    let mut gram = Matrix::zeros(n_cols, n_cols);
    let mut rhs = Matrix::zeros(n_cols, dim);
    for (row, cols) in active.iter().enumerate() {
        for &ci in cols {
            let ci = ci as usize;
            for &cj in cols {
                gram.data[ci * n_cols + cj as usize] += 1.0;
            }
            crate::tensor::add_assign(rhs.row_mut(ci), targets.row(row));
        }
    }
    for i in 0..n_cols {
        gram.data[i * n_cols + i] += ridge.max(1e-6);
    }
    let l = cholesky(&gram)?;
    let mut out = Matrix::zeros(n_cols, dim);
    // solve per output dimension
    let mut b = vec![0.0f32; n_cols];
    for j in 0..dim {
        for i in 0..n_cols {
            b[i] = rhs.data[i * dim + j];
        }
        let x = solve_lower_t(&l, &solve_lower(&l, &b));
        for i in 0..n_cols {
            out.data[i * dim + j] = x[i];
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Matrix {
        // A = B B^T + n*I
        let mut b = Matrix::zeros(n, n);
        rng.fill_normal(&mut b.data, 0.0, 1.0);
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.data[i * n + i] += n as f32;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(7);
        for n in [1, 2, 5, 12] {
            let a = spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let llt = l.matmul(&l.transpose());
            for (x, y) in a.data.iter().zip(&llt.data) {
                assert!((x - y).abs() < 1e-2 * (1.0 + x.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn solve_spd_matches_direct() {
        let mut rng = Rng::new(8);
        let a = spd(6, &mut rng);
        let mut x_true = vec![0.0f32; 6];
        rng.fill_normal(&mut x_true, 0.0, 1.0);
        let b: Vec<f32> = (0..6)
            .map(|i| crate::tensor::dot(a.row(i), &x_true))
            .collect();
        let x = solve_spd(&a, &b).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-3, "{xs} vs {xt}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn lstsq_onehot_recovers_means() {
        // single codebook: LS solution is the per-bucket mean
        let active = vec![vec![0u32], vec![0], vec![1]];
        let targets = Matrix::from_vec(3, 2, vec![1., 1., 3., 3., 10., 0.]);
        let w = lstsq_onehot(&active, &targets, 2, 1e-4).unwrap();
        assert!((w.data[0] - 2.0).abs() < 1e-2);
        assert!((w.data[1] - 2.0).abs() < 1e-2);
        assert!((w.data[2] - 10.0).abs() < 1e-1);
        assert!(w.data[3].abs() < 1e-1);
    }

    #[test]
    fn lstsq_onehot_two_codebooks_additive() {
        // y = c1[a] + c2[b] exactly; LS must fit with ~zero residual
        let mut rng = Rng::new(11);
        let k = 4;
        let mut c1 = Matrix::zeros(k, 3);
        let mut c2 = Matrix::zeros(k, 3);
        rng.fill_normal(&mut c1.data, 0.0, 1.0);
        rng.fill_normal(&mut c2.data, 0.0, 1.0);
        let mut active = Vec::new();
        let mut targets = Matrix::zeros(200, 3);
        for i in 0..200 {
            let a = rng.below(k);
            let b = rng.below(k);
            active.push(vec![a as u32, (k + b) as u32]);
            let row = targets.row_mut(i);
            for j in 0..3 {
                row[j] = c1.data[a * 3 + j] + c2.data[b * 3 + j];
            }
        }
        let w = lstsq_onehot(&active, &targets, 2 * k, 1e-4).unwrap();
        // check residuals near zero
        for (i, cols) in active.iter().enumerate() {
            let mut pred = [0.0f32; 3];
            for &c in cols {
                for j in 0..3 {
                    pred[j] += w.data[c as usize * 3 + j];
                }
            }
            for j in 0..3 {
                assert!((pred[j] - targets.data[i * 3 + j]).abs() < 5e-2);
            }
        }
    }
}
