//! Symmetric eigendecomposition (cyclic Jacobi) and square-matrix SVD,
//! used for the OPQ rotation (orthogonal Procrustes). Dimensions here are
//! data-dimension sized (d <= a few hundred), where Jacobi is plenty.

use crate::tensor::Matrix;

/// Jacobi eigendecomposition of a symmetric matrix. Returns
/// (eigenvalues descending, eigenvectors as columns of the returned
/// matrix: `v.data[i*n + j]` = component i of eigenvector j).
pub fn eig_sym(a: &Matrix) -> (Vec<f32>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _sweep in 0..100 {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for i in 0..n {
                    let aip = m[i * n + p];
                    let aiq = m[i * n + q];
                    m[i * n + p] = c * aip - s * aiq;
                    m[i * n + q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let api = m[p * n + i];
                    let aqi = m[q * n + i];
                    m[p * n + i] = c * api - s * aqi;
                    m[q * n + i] = s * api + c * aqi;
                }
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }
    // sort by descending eigenvalue
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[j * n + j].partial_cmp(&m[i * n + i]).unwrap());
    let vals: Vec<f32> = order.iter().map(|&i| m[i * n + i] as f32).collect();
    let mut vecs = Matrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vecs.data[i * n + new_j] = v[i * n + old_j] as f32;
        }
    }
    (vals, vecs)
}

/// Thin SVD of a square matrix: A = U diag(s) V^T.
/// Built from eig_sym(A^T A) -> V, then U = A V / s (with a Gram-Schmidt
/// fallback for near-zero singular values).
pub fn svd_square(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let ata = a.transpose().matmul(a);
    let (vals, v) = eig_sym(&ata);
    let s: Vec<f32> = vals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    let av = a.matmul(&v);
    let mut u = Matrix::zeros(n, n);
    for j in 0..n {
        if s[j] > 1e-6 {
            for i in 0..n {
                u.data[i * n + j] = av.data[i * n + j] / s[j];
            }
        } else {
            // degenerate direction: orthogonalize a unit vector against
            // the existing columns
            let mut col = vec![0.0f32; n];
            col[j % n] = 1.0;
            for jj in 0..j {
                let mut dot = 0.0f32;
                for i in 0..n {
                    dot += col[i] * u.data[i * n + jj];
                }
                for i in 0..n {
                    col[i] -= dot * u.data[i * n + jj];
                }
            }
            let norm = col.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
            for i in 0..n {
                u.data[i * n + j] = col[i] / norm;
            }
        }
    }
    (u, s, v)
}

/// Orthogonal Procrustes: the rotation R minimizing ||A R - B||_F,
/// R = U V^T where U S V^T = svd(A^T B).
pub fn procrustes(a: &Matrix, b: &Matrix) -> Matrix {
    let m = a.transpose().matmul(b);
    let (u, _s, v) = svd_square(&m);
    u.matmul(&v.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_mat(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(n, n);
        rng.fill_normal(&mut m.data, 0.0, 1.0);
        m
    }

    #[test]
    fn eig_reconstructs() {
        let b = rand_mat(6, 1);
        let a = b.matmul(&b.transpose()); // symmetric PSD
        let (vals, v) = eig_sym(&a);
        // A v_j = lambda_j v_j
        for j in 0..6 {
            let vj: Vec<f32> = (0..6).map(|i| v.data[i * 6 + j]).collect();
            for i in 0..6 {
                let av: f32 = (0..6).map(|k| a.data[i * 6 + k] * vj[k]).sum();
                assert!((av - vals[j] * vj[i]).abs() < 1e-3, "row {i} vec {j}");
            }
        }
        // descending order
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }

    #[test]
    fn svd_reconstructs() {
        let a = rand_mat(5, 2);
        let (u, s, v) = svd_square(&a);
        // A ~= U diag(s) V^T
        let mut us = u.clone();
        for i in 0..5 {
            for j in 0..5 {
                us.data[i * 5 + j] *= s[j];
            }
        }
        let rec = us.matmul(&v.transpose());
        for (x, y) in a.data.iter().zip(&rec.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn procrustes_recovers_rotation() {
        // B = A R_true => procrustes(A, B) ~= R_true
        let a = rand_mat(4, 3);
        // build an orthogonal matrix from QR-ish: use svd of random
        let (q, _, _) = svd_square(&rand_mat(4, 4));
        let b = a.matmul(&q);
        let r = procrustes(&a, &b);
        let diff: f32 = r.data.iter().zip(&q.data).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff < 1e-2, "diff {diff}");
    }

    #[test]
    fn procrustes_output_is_orthogonal() {
        let a = rand_mat(5, 6);
        let b = rand_mat(5, 7);
        let r = procrustes(&a, &b);
        let rtr = r.transpose().matmul(&r);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((rtr.data[i * 5 + j] - want).abs() < 1e-3);
            }
        }
    }
}
