//! Command-line interface (clap is unavailable offline; a small
//! flag parser lives here). Subcommands:
//!
//! ```text
//! qinco2 train   --model qinco2_xs --dataset bigann [--epochs N] [--out ckpt]
//! qinco2 eval    --model qinco2_xs --dataset bigann [--a A --b B]
//! qinco2 encode  --model qinco2_xs --dataset bigann --out codes.qnpz
//! qinco2 search  --model qinco2_xs --dataset bigann [--nprobe ..]
//! qinco2 serve   --model qinco2_xs --dataset bigann [--workers N] [--listen ADDR]
//! qinco2 bench-net --connect HOST:PORT [--conns N --requests N | --rate QPS]
//! qinco2 info
//! ```

use crate::data::Flavor;
use crate::experiments as exp;
use crate::index::{
    packed4_support, BuildCfg, EncodeParams, PipelineConfig, ScanLayout, SearchIndex, SearchParams,
};
use crate::net::{frame::MIN_FRAME_MAX, LoadCfg, NetCfg, NetClient, NetServer};
use crate::qinco::{Codec, ParamStore, RuntimeDecoderFactory, TrainCfg, Trainer};
use crate::runtime::Engine;
use crate::server::{Router, RouterError, ServerCfg};
use crate::util::deadline::Deadline;
use crate::util::qnpz::{Store, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Minimal `--flag value` / `--flag` parser.
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse `--name` as an unsigned integer, or `default` when absent.
    /// A present-but-malformed value is a **hard error** naming the flag
    /// (silently falling back would e.g. run `--stage1-m abc` with m=4
    /// and skew results).
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an unsigned integer, got {v:?}")),
        }
    }

    /// Parse `--name` as a float, or `default` when absent. Like
    /// [`Self::usize_or`], a malformed value is a hard error.
    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().with_context(|| format!("--{name} expects a number, got {v:?}"))
            }
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.get(name).is_some()
    }
}

fn flavor_of(args: &Args) -> Result<Flavor> {
    let name = args.str_or("dataset", "bigann");
    Flavor::parse(&name).with_context(|| format!("unknown dataset {name:?}"))
}

fn common_setup(args: &Args) -> Result<(Engine, String, Flavor, exp::Scale)> {
    let engine = Engine::open(exp::artifacts_dir())?;
    let model = args.str_or("model", "qinco2_xs");
    if !engine.manifest.models.contains_key(&model) {
        bail!(
            "model {model:?} not in manifest; available: {:?}",
            engine.manifest.models.keys().collect::<Vec<_>>()
        );
    }
    let flavor = flavor_of(args)?;
    let scale = scale_of(args)?;
    Ok((engine, model, flavor, scale))
}

fn scale_of(args: &Args) -> Result<exp::Scale> {
    let mut scale = exp::Scale::from_env();
    scale.n_train = args.usize_or("n-train", scale.n_train)?;
    scale.n_db = args.usize_or("n-db", scale.n_db)?;
    scale.n_query = args.usize_or("n-query", scale.n_query)?;
    scale.epochs = args.usize_or("epochs", scale.epochs)?;
    Ok(scale)
}

fn train_cfg(args: &Args, scale: &exp::Scale) -> Result<TrainCfg> {
    Ok(TrainCfg {
        epochs: scale.epochs,
        lr_max: args.f32_or("lr", 8e-4)?,
        optimizer: args.str_or("optimizer", "adamw"),
        a: args.usize_or("a", 8)?,
        b: args.usize_or("b", 8)?,
        seed: args.usize_or("seed", 0xA11CE)? as u64,
        log_every: 1,
    })
}

/// Search-time knobs shared by `search` and `serve` (the Fig. 6 axes
/// plus the engine's intra-batch `--batch-threads` parallelism and the
/// `--scan-layout` kernel selection).
fn search_params(args: &Args) -> Result<SearchParams> {
    Ok(SearchParams {
        nprobe: args.usize_or("nprobe", 8)?,
        ef_search: args.usize_or("ef", 64)?,
        n_aq: args.usize_or("n-aq", 256)?,
        n_pairs: args.usize_or("n-pairs", 32)?,
        n_final: args.usize_or("topk", 10)?,
        batch_threads: args.usize_or("batch-threads", 1)?,
        scan_layout: scan_layout_of(args)?,
    })
}

/// Parse `--scan-layout`. Unknown layout names are hard errors naming
/// the flag ([`ScanLayout::parse`]), matching the malformed-flag policy
/// of [`Args::usize_or`] — a silent fallback to `flat` would benchmark
/// (or serve) a different kernel than the one the operator asked for.
fn scan_layout_of(args: &Args) -> Result<ScanLayout> {
    ScanLayout::parse(&args.str_or("scan-layout", "flat"))
}

pub fn run(argv: Vec<String>) -> Result<()> {
    if argv.is_empty() {
        return cmd_help();
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "encode" => cmd_encode(&args),
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "bench-net" => cmd_bench_net(&args),
        "insert" => cmd_insert(&args),
        "delete" => cmd_delete(&args),
        "compact" => cmd_compact(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => cmd_help(),
        other => bail!("unknown subcommand {other:?} (try `qinco2 help`)"),
    }
}

fn cmd_help() -> Result<()> {
    println!("{}", HELP.trim());
    Ok(())
}

const HELP: &str = r#"
qinco2 — vector compression & billion-scale search with implicit neural codebooks

USAGE: qinco2 <subcommand> [--flag value ...]

SUBCOMMANDS
  train    train a QINCo2 model on a synthetic dataset flavor
  eval     MSE + recall of a trained model (trains/caches if needed)
  encode   encode a database split to codes (.qnpz)
  search   build the IVF search index and report recall/QPS
  serve    run the serving coordinator over a built index; with --listen
           it also fronts the router with the TCP frame protocol
  bench-net  load-generate against a `serve --listen` server over TCP
  insert   build the index, then live-ingest vectors (beam encode) + search
  delete   build the index, tombstone-delete rows, verify they vanish
  compact  full live cycle: insert -> search -> delete -> compact -> search,
           asserting deleted ids never reappear and rankings stay stable
  info     list models and artifacts in the manifest

COMMON FLAGS
  --model qinco2_xs|qinco2_s|qinco2_m|qinco1|test   (default qinco2_xs)
  --dataset bigann|deep|contriever|ssnpp            (default bigann)
  --n-train / --n-db / --n-query / --epochs         (default: QINCO2_SCALE)
  --a / --b      encode-time pre-selection + beam (must exist as artifact)
  --optimizer adamw|adam    --lr 8e-4    --seed N

SEARCH FLAGS
  --k-ivf 64  --nprobe 8  --ef 64  --n-aq 256  --n-pairs 32  --topk 10
  --shards 1             partition the index into N bucket-owned shards
                         (1 <= N <= k-ivf); probed buckets scatter to their
                         owning shards and shortlists gather-merge before
                         the single stage-3 decode — results bit-identical
                         for every N
  --encoder runtime|reference
                         database encoder: "reference" builds the index with
                         the pure-Rust greedy encoder and untrained params —
                         no PJRT runtime needed (CI smoke path)
PIPELINE FLAGS (search + serve)
  --stage1 aq|pq|opq|lsq|rq
                         stage-1 scorer (default aq; the others scan their
                         own table with --stage1-m subspaces/steps)
  --stage1-m 4           sub-quantizers/steps for a pq/opq/lsq/rq stage 1
  --no-stage2            skip the pairwise re-ranker
  --stage3 reference|rust|none|runtime
                         exact re-rank decoder; "reference" is the scalar
                         oracle, "rust" the native nn-kernel decoder,
                         "none" returns the stage-2 order; "runtime"
                         additionally gives each serve worker a
                         thread-local artifact-runtime engine via
                         DecoderFactory (native backend by default; HLO
                         under the pjrt feature)
  --batch-threads 1      intra-batch parallelism of one batched execute:
                         the stage-1 bucket-group scan (and per-query
                         stage-2/3 loops) split across N threads, results
                         bit-identical for every N
  --scan-layout flat|transposed|packed4
                         physical layout of the batched stage-1 scan:
                         "flat" is the per-slot LUT pack, "transposed"
                         repacks each bucket-group chunk query-major
                         (unit-stride loads, results bit-identical to
                         flat), "packed4" scans 4-bit packed codes
                         against u8-quantized LUTs — a bounded-error
                         quantized scoring mode that needs a pq/rq
                         stage 1 with K <= 16 and builds packed code
                         tables into the index
LIVE MUTATION FLAGS (insert / delete / compact)
  --a 0 / --b 0          ingest-encode pre-selection width A and beam width B
                         (0 = default: A=K, B=1 — greedy, bit-identical to a
                         fresh build; must satisfy 1 <= B <= A <= K)
  --n-insert 64          vectors to live-ingest
  --n-delete 32          rows to tombstone-delete
SERVE FLAGS
  --workers N  --queries N
NETWORK FLAGS (serve --listen / bench-net)
  --listen HOST:PORT     serve only: front the router with the TCP frame
                         protocol (port 0 picks an ephemeral port; the
                         bound address is printed and, with --addr-file,
                         written to a file). The process runs until a
                         client sends a Drain frame (bench-net --drain)
  --addr-file PATH       serve only: write the bound address to PATH
                         (how scripts find an ephemeral --listen port)
  --max-conns 0          concurrent connections before typed refusal
                         (0 = default 64)
  --frame-max-bytes 0    per-frame payload ceiling; nonzero values must
                         be >= 4096 (0 = default 8 MiB)
  --conn-inflight 0      per-connection in-flight request cap before TCP
                         backpressure (0 = default 32)
  --connect HOST:PORT    bench-net only (required): the server address
  --conns 4              bench-net: concurrent load connections
  --requests 256         bench-net: total requests (closed-loop mode)
  --pipeline 1           bench-net: per-connection in-flight window
  --rate 0               bench-net: offered load in QPS across all
                         connections (0 = closed loop)
  --duration-s 5         bench-net: wall-clock run time (fixed-rate mode)
  --n-query 64           bench-net: distinct query vectors in the pool
                         (dimension is discovered from the server)
  --drain                bench-net: send a Drain frame after the run so
                         the server answers in-flight work and exits
ROBUSTNESS FLAGS (search + serve)
  --deadline-ms 0        per-request deadline in milliseconds (0 = disabled).
                         A request already expired when picked up gets a typed
                         DeadlineExceeded reply; one that expires mid-pipeline
                         returns its stage-1/2 shortlist ranking flagged
                         `degraded` instead of running stage 3 long
  --shed-watermark 0     serve only: refuse new submissions with Overloaded
                         (carrying a retry-after hint) once this many requests
                         are in flight (0 = disabled)
  --retries 0            serve only: bounded retry count (jittered backoff)
                         the blocking helpers use for shed/saturated
                         submissions before giving up
"#;

fn cmd_info() -> Result<()> {
    let engine = Engine::open(exp::artifacts_dir())?;
    println!("platform: {}", engine.platform());
    println!("models:");
    for (name, spec) in &engine.manifest.models {
        let c = &spec.cfg;
        println!(
            "  {name:12} d={} M={} K={} L={} de={} dh={} ({} params)",
            c.d, c.m, c.k, c.l, c.de, c.dh, spec.num_params
        );
        let settings = engine.manifest.encode_settings(name);
        println!("               encode settings (A,B,N): {settings:?}");
    }
    println!("artifacts: {}", engine.manifest.artifacts.len());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (mut engine, model, flavor, scale) = common_setup(args)?;
    let spec = engine.manifest.model(&model)?.clone();
    let ds = exp::dataset(flavor, spec.cfg.d, &scale);
    let cfg = train_cfg(args, &scale)?;
    let mut params = ParamStore::init(&spec, &model, &ds.train, cfg.seed);
    let trainer = Trainer::new(&engine, &model, cfg)?;
    let stats = trainer.train(&mut engine, &mut params, &ds.train)?;
    let out = args.str_or(
        "out",
        exp::artifacts_dir().join(format!("models/{model}_{}.qnpz", flavor.name())).to_str().unwrap(),
    );
    std::fs::create_dir_all(std::path::Path::new(&out).parent().unwrap()).ok();
    params.save(std::path::Path::new(&out))?;
    println!(
        "trained {model} on {}: {} steps in {:.1}s, final loss {:.5}; saved {out}",
        flavor.name(),
        stats.steps,
        stats.secs,
        stats.epoch_losses.last().unwrap_or(&f64::NAN)
    );
    Ok(())
}

fn load_or_train(
    engine: &mut Engine,
    args: &Args,
    model: &str,
    flavor: Flavor,
    scale: &exp::Scale,
    train: &crate::tensor::Matrix,
) -> Result<ParamStore> {
    if let Some(ckpt) = args.get("ckpt") {
        let spec = engine.manifest.model(model)?.clone();
        return ParamStore::load(std::path::Path::new(ckpt), &spec, model);
    }
    let cfg = train_cfg(args, scale)?;
    exp::trained_model(engine, model, flavor.name(), train, &cfg)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (mut engine, model, flavor, scale) = common_setup(args)?;
    let spec = engine.manifest.model(&model)?.clone();
    let ds = exp::dataset(flavor, spec.cfg.d, &scale);
    let params = load_or_train(&mut engine, args, &model, flavor, &scale, &ds.train)?;
    let (a, b) = (args.usize_or("a", 16)?, args.usize_or("b", 16)?);
    let codec = Codec::new(&engine, &model, a, b)?;
    let ev = exp::eval_compression(&mut engine, &codec, &params, &ds.database, &ds.queries, &ds.ground_truth)?;
    println!(
        "{model} on {}1M-scaled (A={a}, B={b}): MSE {:.5}  R@1 {:.1}%  R@10 {:.1}%  R@100 {:.1}%",
        flavor.name(),
        ev.mse,
        100.0 * ev.r1,
        100.0 * ev.r10,
        100.0 * ev.r100
    );
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    let (mut engine, model, flavor, scale) = common_setup(args)?;
    let spec = engine.manifest.model(&model)?.clone();
    let ds = exp::dataset(flavor, spec.cfg.d, &scale);
    let params = load_or_train(&mut engine, args, &model, flavor, &scale, &ds.train)?;
    let (a, b) = (args.usize_or("a", 16)?, args.usize_or("b", 16)?);
    let codec = Codec::new(&engine, &model, a, b)?;
    let t0 = std::time::Instant::now();
    let (codes, _, errs) = codec.encode(&mut engine, &params, &ds.database)?;
    let secs = t0.elapsed().as_secs_f64();
    let out = args.str_or("out", "codes.qnpz");
    let mut store = Store::new();
    store.insert(
        "codes",
        Tensor::i32(vec![codes.n, codes.m], &codes.data.iter().map(|&c| c as i32).collect::<Vec<_>>()),
    );
    store.insert("errs", Tensor::f32(vec![errs.len()], errs.clone()));
    store.save(std::path::Path::new(&out))?;
    let mse: f64 = errs.iter().map(|&e| e as f64).sum::<f64>() / errs.len() as f64;
    println!(
        "encoded {} vectors in {:.2}s ({:.1} µs/vec), MSE {:.5}; wrote {out}",
        codes.n,
        secs,
        secs * 1e6 / codes.n as f64,
        mse
    );
    Ok(())
}

/// Pipeline selection shared by `search` and `serve`: `--stage1`,
/// `--stage1-m`, `--no-stage2`, `--stage3`.
fn pipeline_of(args: &Args) -> Result<PipelineConfig> {
    PipelineConfig::from_flags(
        &args.str_or("stage1", "aq"),
        args.usize_or("stage1-m", 4)?,
        !args.flag("no-stage2"),
        &args.str_or("stage3", "reference"),
    )
}

/// Validate `--shards` against the bucket count: the index partitions
/// into bucket-owned shards, so the count must be in `1..=k_ivf`.
/// Out-of-range values are hard errors naming the flag (matching the
/// malformed-numeric-flag policy of [`Args::usize_or`]), not silent
/// clamps — `--shards 0` would otherwise build an index with no shards
/// and `--shards > k_ivf` one with empty shards.
fn shards_of(args: &Args, k_ivf: usize) -> Result<usize> {
    let shards = args.usize_or("shards", 1)?;
    if shards == 0 {
        bail!("--shards must be at least 1, got 0");
    }
    if shards > k_ivf {
        bail!(
            "--shards {shards} exceeds the IVF bucket count (--k-ivf {k_ivf}): \
             every shard must own at least one bucket"
        );
    }
    Ok(shards)
}

/// Validate the ingest-encode knobs `--a` (codeword pre-selection width)
/// and `--b` (beam width) against the model's codebook size K. `0` (the
/// default) means "model default": A=K, B=1 — the greedy encode. Like
/// [`shards_of`], out-of-range values are hard errors naming the flag,
/// not silent clamps — a clamped `--b` would silently change which codes
/// the ingest path produces.
fn encode_params_of(args: &Args, k: usize) -> Result<EncodeParams> {
    let a = args.usize_or("a", 0)?;
    let b = args.usize_or("b", 0)?;
    let ea = if a == 0 { k } else { a };
    let eb = if b == 0 { 1 } else { b };
    if ea > k {
        bail!("--a {ea} exceeds the model's codebook size K={k} (1 <= b <= a <= K)");
    }
    if eb > ea {
        bail!(
            "--b {eb} exceeds the pre-selection width --a {ea}: beam hypotheses \
             are drawn from the pre-selected candidates (1 <= b <= a <= K)"
        );
    }
    Ok(EncodeParams { a, b })
}

/// Validate the network-tier knobs `--max-conns`, `--frame-max-bytes`
/// and `--conn-inflight`. `0` (the default) means "server default"
/// ([`NetCfg::default`]); nonzero values replace it. Like [`shards_of`],
/// out-of-range values are hard errors naming the flag — a silently
/// clamped `--frame-max-bytes` would accept frames the operator asked
/// to refuse.
fn net_cfg_of(args: &Args) -> Result<NetCfg> {
    let mut cfg = NetCfg::default();
    let max_conns = args.usize_or("max-conns", 0)?;
    if max_conns != 0 {
        cfg.max_conns = max_conns;
    }
    let frame_max = args.usize_or("frame-max-bytes", 0)?;
    if frame_max != 0 {
        if frame_max < MIN_FRAME_MAX {
            bail!(
                "--frame-max-bytes {frame_max} is below the protocol minimum {MIN_FRAME_MAX}: \
                 even a header-only frame plus a small search body must fit"
            );
        }
        cfg.frame_max_bytes = frame_max;
    }
    let conn_inflight = args.usize_or("conn-inflight", 0)?;
    if conn_inflight != 0 {
        cfg.conn_inflight = conn_inflight;
    }
    Ok(cfg)
}

fn build_index(
    args: &Args,
    engine: &mut Engine,
    model: &str,
    flavor: Flavor,
    scale: &exp::Scale,
) -> Result<(SearchIndex, crate::data::Dataset)> {
    let spec = engine.manifest.model(model)?.clone();
    let ds = exp::dataset(flavor, spec.cfg.d, scale);
    let k_ivf = args.usize_or("k-ivf", 64)?;
    let bcfg = BuildCfg {
        k_ivf,
        m_tilde: args.usize_or("m-tilde", 2)?,
        pipeline: pipeline_of(args)?,
        shards: shards_of(args, k_ivf)?,
        scan_layout: scan_layout_of(args)?,
        ..Default::default()
    };
    // a packed4 request against an incompatible stage-1 family must be
    // a clean CLI error naming the family, before any expensive work
    if bcfg.scan_layout == ScanLayout::Packed4 {
        packed4_support(&bcfg.pipeline.stage1, spec.cfg.k)?;
    }
    // the fine quantizer is trained on IVF residuals (Fig. 3 pipeline)
    let ivf = crate::index::ivf::Ivf::build(&ds.train, &ds.train, bcfg.k_ivf, bcfg.seed);
    let residuals = ivf.residuals(&ds.train);
    let mut cfg = train_cfg(args, scale)?;
    cfg.seed ^= 0x1F; // distinct cache key from the raw-data model
    let params = exp::trained_model(engine, model, &format!("{}_ivfres", flavor.name()), &residuals, &cfg)?;
    let codec = Codec::new(engine, model, args.usize_or("a", cfg.a)?, args.usize_or("b", cfg.b)?)?;
    let index = SearchIndex::build(engine, &codec, params, &ds.train, &ds.database, &bcfg)?;
    Ok((index, ds))
}

/// Engine-free index build for `--encoder reference`: model spec from
/// the manifest, freshly initialized parameters, database codes from the
/// pure-Rust greedy reference encoder. No training, no PJRT runtime —
/// recall is that of an untrained model, but every pipeline/engine knob
/// is exercised end-to-end (this is the CI smoke path).
fn build_index_reference(
    args: &Args,
    model: &str,
    flavor: Flavor,
) -> Result<(SearchIndex, crate::data::Dataset)> {
    let manifest =
        crate::runtime::manifest::Manifest::load(&exp::artifacts_dir().join("manifest.json"))?;
    let spec = manifest.model(model)?.clone();
    let scale = scale_of(args)?;
    let ds = exp::dataset(flavor, spec.cfg.d, &scale);
    let params = ParamStore::init(&spec, model, &ds.train, args.usize_or("seed", 0xA11CE)? as u64);
    let k_ivf = args.usize_or("k-ivf", 64)?;
    let bcfg = BuildCfg {
        k_ivf,
        m_tilde: args.usize_or("m-tilde", 2)?,
        pipeline: pipeline_of(args)?,
        shards: shards_of(args, k_ivf)?,
        scan_layout: scan_layout_of(args)?,
        ..Default::default()
    };
    if bcfg.scan_layout == ScanLayout::Packed4 {
        packed4_support(&bcfg.pipeline.stage1, spec.cfg.k)?;
    }
    Ok((SearchIndex::build_reference(params, &ds.train, &ds.database, &bcfg), ds))
}

/// Build an index through the encoder selected by `--encoder` — shared
/// by `search` and the mutation subcommands. `reference` is the
/// engine-free path (manifest spec + pure-Rust greedy encoder) that runs
/// without any PJRT runtime or HLO artifacts; the CI smoke jobs exercise
/// the whole pipeline (and the live mutation cycle) through it.
fn built_index(args: &Args) -> Result<(SearchIndex, crate::data::Dataset, String, Flavor)> {
    match args.str_or("encoder", "runtime").as_str() {
        "reference" => {
            let model = args.str_or("model", "qinco2_xs");
            let flavor = flavor_of(args)?;
            let (index, ds) = build_index_reference(args, &model, flavor)?;
            Ok((index, ds, model, flavor))
        }
        "runtime" => {
            let (mut engine, model, flavor, scale) = common_setup(args)?;
            let (index, ds) = build_index(args, &mut engine, &model, flavor, &scale)?;
            Ok((index, ds, model, flavor))
        }
        other => bail!("unknown encoder {other:?} (expected runtime|reference)"),
    }
}

/// Structural self-check shared by `search` and the mutation
/// subcommands (the CI smoke jobs rely on it): every result list must be
/// ranked under the total (score, id) order with ids inside the index's
/// id space, and — unless the knobs legitimately return nothing
/// (`--topk 0` / `--n-aq 0` / `--nprobe 0`, an empty live set, or a
/// `degraded` reply whose deadline expired before anything was scanned)
/// — at least one list must be non-empty. Ranking and id-space checks
/// always apply: a degraded reply is still a valid (truncated) ranking.
fn check_results(
    results: &[Vec<(f32, u32)>],
    index: &SearchIndex,
    sp: &SearchParams,
    degraded: bool,
) -> Result<()> {
    let id_space = index.db_len();
    let mut non_empty = 0usize;
    for (i, r) in results.iter().enumerate() {
        non_empty += usize::from(!r.is_empty());
        if let Some(&(_, bad)) = r.iter().find(|&&(_, id)| id as usize >= id_space) {
            bail!("result list {i} references out-of-range id {bad}");
        }
        for w in r.windows(2) {
            if w[1].0.total_cmp(&w[0].0).then(w[1].1.cmp(&w[0].1)).is_lt() {
                bail!("result list {i} is not ranked under the (score, id) order");
            }
        }
    }
    let expect_results = !results.is_empty()
        && !degraded
        && index.live_len() > 0
        && sp.n_final > 0
        && sp.n_aq > 0
        && sp.nprobe > 0;
    if expect_results && non_empty == 0 {
        bail!("search produced only empty result lists");
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let (index, ds, model, flavor) = built_index(args)?;
    let sp = search_params(args)?;
    let deadline_ms = args.usize_or("deadline-ms", 0)? as u64;
    let t0 = std::time::Instant::now();
    let (results, degraded) =
        index.search_batch_within(&ds.queries, &sp, Deadline::from_ms(deadline_ms))?;
    let secs = t0.elapsed().as_secs_f64();
    check_results(&results, &index, &sp, degraded)?;
    let (r1, r10, r100) =
        crate::metrics::recall_triple(&crate::metrics::ids_only(&results), &ds.ground_truth);
    println!(
        "IVF-{model} on {}: R@1 {:.1}%  R@10 {:.1}%  R@100 {:.1}%  ({:.0} QPS, {} queries)",
        flavor.name(),
        100.0 * r1,
        100.0 * r10,
        100.0 * r100,
        ds.queries.rows as f64 / secs,
        ds.queries.rows
    );
    let snap = index.snapshot();
    println!(
        "shards: {}  (stage-1 scans per shard: {:?})",
        snap.n_shards(),
        snap.scan_counts()
    );
    if degraded {
        println!(
            "degraded: --deadline-ms {deadline_ms} expired mid-pipeline; the rankings \
             above are the stage-1/2 shortlist order (stage 3 skipped whole)"
        );
    }
    Ok(())
}

/// Ids in `results` that were tombstoned must never reappear — the
/// mutation subcommands assert this after every post-delete search.
fn check_no_deleted(results: &[Vec<(f32, u32)>], deleted: &[u32], when: &str) -> Result<()> {
    for (i, r) in results.iter().enumerate() {
        if let Some(&(_, bad)) = r.iter().find(|&&(_, id)| deleted.contains(&id)) {
            bail!("result list {i} resurrected deleted id {bad} ({when})");
        }
    }
    Ok(())
}

fn cmd_insert(args: &Args) -> Result<()> {
    let (index, ds, model, flavor) = built_index(args)?;
    let ep = encode_params_of(args, index.params.cfg.k)?;
    let n = args.usize_or("n-insert", 64)?;
    let d = index.params.cfg.d;
    // a fresh draw (distinct seed) so the ingested vectors are new, not
    // re-encodes of rows the index already holds
    let fresh = crate::data::generate(flavor, n, d, args.usize_or("seed", 0xA11CE)? as u64 ^ 0xF00D);
    let before = (index.epoch(), index.live_len());
    let t0 = std::time::Instant::now();
    let gids = index.insert(&fresh, &ep)?;
    let secs = t0.elapsed().as_secs_f64();
    let sp = search_params(args)?;
    let results = index.search_batch(&ds.queries, &sp)?;
    check_results(&results, &index, &sp, false)?;
    println!(
        "IVF-{model} on {}: ingested {n} vectors in {:.2}ms ({:.0} vec/s) with A={} B={}",
        flavor.name(),
        secs * 1e3,
        n as f64 / secs,
        if ep.a == 0 { index.params.cfg.k } else { ep.a },
        if ep.b == 0 { 1 } else { ep.b },
    );
    println!(
        "ids {}..{}  epoch {} -> {}  live rows {} -> {}",
        gids.first().copied().unwrap_or(0),
        gids.last().copied().unwrap_or(0),
        before.0,
        index.epoch(),
        before.1,
        index.live_len()
    );
    Ok(())
}

fn cmd_delete(args: &Args) -> Result<()> {
    let (index, ds, model, flavor) = built_index(args)?;
    let db_len = index.db_len();
    let n = args.usize_or("n-delete", 32)?.min(db_len);
    // spread the victims across the id space so every shard sees churn
    let ids: Vec<u32> = (0..n).map(|j| (j * db_len / n.max(1)) as u32).collect();
    let before = (index.epoch(), index.live_len());
    let deleted = index.delete(&ids)?;
    let sp = search_params(args)?;
    let results = index.search_batch(&ds.queries, &sp)?;
    check_results(&results, &index, &sp, false)?;
    check_no_deleted(&results, &ids, "after delete")?;
    println!(
        "IVF-{model} on {}: tombstoned {deleted} of {n} requested rows; \
         epoch {} -> {}  live rows {} -> {}",
        flavor.name(),
        before.0,
        index.epoch(),
        before.1,
        index.live_len()
    );
    Ok(())
}

/// The full live cycle the CI smoke job drives: fresh search -> ingest
/// -> delete (originals + some of the just-ingested) -> search (deleted
/// ids must vanish) -> compact -> search again, asserting the compacted
/// epoch returns **bit-identical** results to the tombstoned one
/// (compaction only reclaims space, it never changes what a scan sees).
fn cmd_compact(args: &Args) -> Result<()> {
    let (index, ds, model, flavor) = built_index(args)?;
    let sp = search_params(args)?;
    let baseline = index.search_batch(&ds.queries, &sp)?;
    check_results(&baseline, &index, &sp, false)?;

    // ingest
    let ep = encode_params_of(args, index.params.cfg.k)?;
    let n_ins = args.usize_or("n-insert", 64)?;
    let d = index.params.cfg.d;
    let fresh =
        crate::data::generate(flavor, n_ins, d, args.usize_or("seed", 0xA11CE)? as u64 ^ 0xF00D);
    let gids = index.insert(&fresh, &ep)?;

    // delete: spread originals plus every other ingested row
    let n_orig = index.db_len() - gids.len();
    let n_del = args.usize_or("n-delete", 32)?.min(n_orig);
    let mut victims: Vec<u32> = (0..n_del).map(|j| (j * n_orig / n_del.max(1)) as u32).collect();
    victims.extend(gids.iter().step_by(2));
    let deleted = index.delete(&victims)?;

    let tombstoned = index.search_batch(&ds.queries, &sp)?;
    check_results(&tombstoned, &index, &sp, false)?;
    check_no_deleted(&tombstoned, &victims, "after delete, before compaction")?;

    let epoch_tomb = index.epoch();
    let reclaimed = index.compact();
    let compacted = index.search_batch(&ds.queries, &sp)?;
    check_results(&compacted, &index, &sp, false)?;
    check_no_deleted(&compacted, &victims, "after compaction")?;
    // the pinned invariant: compaction is invisible to search
    for (qi, (t, c)) in tombstoned.iter().zip(&compacted).enumerate() {
        if t != c {
            bail!(
                "query {qi}: compaction changed the result list\n  tombstoned: {t:?}\n  compacted:  {c:?}"
            );
        }
    }
    println!(
        "IVF-{model} on {}: live cycle ok — inserted {}  tombstoned {deleted}  \
         reclaimed {reclaimed}  epoch {} -> {}  live rows {}",
        flavor.name(),
        gids.len(),
        epoch_tomb,
        index.epoch(),
        index.live_len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // `built_index` honors --encoder, so `serve --listen --encoder
    // reference` is the engine-free network smoke path just like
    // `search --encoder reference` is for the pipeline
    let (index, ds, model, _flavor) = built_index(args)?;
    let workers = args.usize_or("workers", crate::util::pool::default_threads())?;
    // robustness knobs (0 = disabled; malformed values hard-error naming
    // the flag via usize_or)
    let deadline_ms = args.usize_or("deadline-ms", 0)? as u64;
    let shed_watermark = args.usize_or("shed-watermark", 0)?;
    let retries = args.usize_or("retries", 0)?;
    // --stage3 rust/runtime: hand every worker its own stage-3 decoder
    // through a factory. "rust" shares the in-memory weights (cheap,
    // infallible, engine-free); "runtime" gives each worker thread its
    // own artifact-runtime engine + codec (engine-per-worker; native
    // backend by default, so this no longer requires HLO artifacts or
    // PJRT — see server docs). Workers fall back to the index-held
    // decoder if a factory's make() fails.
    let decoder_factory: Option<Arc<dyn crate::quantizers::DecoderFactory>> =
        match args.str_or("stage3", "reference").as_str() {
            "rust" => Some(Arc::new(crate::qinco::RustDecoderFactory {
                params: index.params.clone(),
            })),
            "runtime" => {
                let scale = scale_of(args)?;
                let cfg = train_cfg(args, &scale)?;
                Some(Arc::new(RuntimeDecoderFactory {
                    artifacts_dir: exp::artifacts_dir(),
                    model: model.clone(),
                    a: args.usize_or("a", cfg.a)?,
                    b: args.usize_or("b", cfg.b)?,
                    params: index.params.clone(),
                }))
            }
            _ => None,
        };
    let router = Arc::new(Router::start(
        Arc::new(index),
        ServerCfg {
            workers,
            decoder_factory,
            shed_watermark,
            blocking_retries: retries,
            ..Default::default()
        },
    ));
    if args.get("listen").is_some() {
        return serve_network(args, router);
    }
    // --batch-threads > 1 rides along in each request's SearchParams:
    // workers split a big dispatched group's bucket scan across threads
    let sp = search_params(args)?;
    let n = args.usize_or("queries", ds.queries.rows)?;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n);
    // each request gets a *fresh* deadline at submission time, like a
    // network frontend stamping arrival + budget would
    let mut shed = 0usize;
    for i in 0..n {
        let q = ds.queries.row(i % ds.queries.rows).to_vec();
        match router.submit_within(q, sp, Deadline::from_ms(deadline_ms)) {
            Ok(rx) => pending.push(rx),
            Err(RouterError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e.into()),
        }
    }
    // every pushed receiver gets exactly one reply: Ok(response) or a
    // typed error. DeadlineExceeded is an expected outcome under
    // --deadline-ms; anything else fails the command.
    let (mut ok, mut degraded, mut expired) = (0usize, 0usize, 0usize);
    for rx in pending {
        match rx.recv().map_err(|_| anyhow::anyhow!("worker died"))? {
            Ok(resp) => {
                ok += 1;
                degraded += usize::from(resp.degraded);
            }
            Err(RouterError::DeadlineExceeded) => expired += 1,
            Err(e) => return Err(anyhow::anyhow!("request failed: {e}")),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = router.stats();
    println!(
        "served {ok}/{n} queries with {workers} workers: {:.0} QPS, mean {:.2?}, p50 {:.2?}, p99 {:.2?}",
        ok as f64 / secs,
        stats.mean_latency,
        stats.p50,
        stats.p99
    );
    println!(
        "shards: {}  (stage-1 scans per shard: {:?})",
        stats.shard_scans.len(),
        stats.shard_scans
    );
    println!(
        "robustness: degraded {degraded}  deadline-exceeded {expired}  shed {shed}  \
         (counters: shed {}  deadline_exceeded {}  degraded {}  panics {}  respawns {})",
        stats.shed, stats.deadline_exceeded, stats.degraded, stats.panics, stats.respawns
    );
    drop(router); // last Arc: Drop stops the workers
    Ok(())
}

/// The `serve --listen` tail: bind the TCP front-end, publish the bound
/// address (stdout + optional `--addr-file`), and block until a client
/// drains the server (`bench-net --drain` or a raw Drain frame). The
/// router is torn down only after the network tier has answered every
/// in-flight frame.
fn serve_network(args: &Args, router: Arc<Router>) -> Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:0");
    let cfg = net_cfg_of(args)?;
    let server = NetServer::bind(&listen, router.clone(), cfg)
        .with_context(|| format!("--listen {listen:?} is not a bindable address"))?;
    let addr = server.local_addr();
    println!("listening on {addr}");
    if let Some(path) = args.get("addr-file") {
        std::fs::write(path, addr.to_string())
            .with_context(|| format!("--addr-file {path:?} is not writable"))?;
    }
    while !server.is_draining() {
        std::thread::sleep(Duration::from_millis(100));
    }
    let ns = server.drain();
    let s = &ns.stats;
    println!(
        "drained: served {}  mean {:.2?}  p50 {:.2?}  p99 {:.2?}",
        s.served, s.mean_latency, s.p50, s.p99
    );
    println!(
        "net: connections {}  frames_in {}  frames_out {}  protocol_errors {}",
        s.connections, s.frames_in, s.frames_out, s.protocol_errors
    );
    println!(
        "robustness: shed {}  deadline_exceeded {}  degraded {}  panics {}  respawns {}",
        s.shed, s.deadline_exceeded, s.degraded, s.panics, s.respawns
    );
    drop(router); // last Arc: Drop stops the workers
    Ok(())
}

/// `bench-net`: the wire-level load generator. Discovers the query
/// dimension from the server's stats frame (no flag to get wrong),
/// generates a deterministic query pool, runs the configured load
/// ([`crate::net::loadgen`]), and prints QPS/latency plus the typed
/// outcome counts. `--drain` then shuts the server down over the wire.
fn cmd_bench_net(args: &Args) -> Result<()> {
    let addr = match args.get("connect") {
        Some(a) => a.to_string(),
        None => bail!(
            "bench-net needs --connect HOST:PORT (the address `serve --listen` printed)"
        ),
    };
    let conns = args.usize_or("conns", 4)?;
    if conns == 0 {
        bail!("--conns must be at least 1, got 0");
    }
    let requests = args.usize_or("requests", 256)?;
    let rate = args.f32_or("rate", 0.0)? as f64;
    if rate < 0.0 {
        bail!("--rate must be >= 0, got {rate}");
    }
    if rate == 0.0 && requests == 0 {
        bail!("--requests must be at least 1 in closed-loop mode (--rate 0)");
    }
    let deadline_ms = args.usize_or("deadline-ms", 0)? as u64;
    let sp = search_params(args)?;
    let flavor = flavor_of(args)?;
    // one probe connection up front: discover the index dimension (and
    // fail fast with a connect error before spawning load threads)
    let mut probe = NetClient::connect(&addr)?;
    let before = probe.stats()?;
    let d = before.dim as usize;
    let n_query = args.usize_or("n-query", 64)?.max(1);
    let queries =
        crate::data::generate(flavor, n_query, d, args.usize_or("seed", 0xA11CE)? as u64 ^ 0xBE7C);
    let cfg = LoadCfg {
        addr: addr.clone(),
        conns,
        requests,
        pipeline: args.usize_or("pipeline", 1)?,
        rate,
        duration: Duration::from_secs(args.usize_or("duration-s", 5)? as u64),
        sp,
        deadline_ms,
        queries,
    };
    let report = crate::net::loadgen::run(&cfg)?;
    println!(
        "bench-net {addr} (dim {d}, {} live rows): sent {}  completed {}  wall {:.2?}",
        before.live_rows, report.sent, report.completed, report.wall
    );
    println!(
        "  {:.0} QPS  mean {:.2?}  p50 {:.2?}  p99 {:.2?}",
        report.qps, report.mean, report.p50, report.p99
    );
    println!(
        "  ok {}  degraded {}  shed {}  deadline-exceeded {}  worker-died {}  stopped {}",
        report.ok,
        report.degraded,
        report.shed,
        report.deadline_exceeded,
        report.worker_died,
        report.stopped
    );
    let after = probe.stats()?;
    println!(
        "  server: connections {}  frames_in {}  frames_out {}  protocol_errors {}",
        after.stats.connections,
        after.stats.frames_in,
        after.stats.frames_out,
        after.stats.protocol_errors
    );
    if report.completed > 0 && report.ok == 0 {
        bail!("no request succeeded ({} replies, all typed errors)", report.completed);
    }
    if args.flag("drain") {
        probe.drain_server()?;
        println!("  server drained");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parser_flags_and_positional() {
        let argv: Vec<String> =
            ["pos1", "--a", "5", "--flag", "--b", "x", "pos2"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.usize_or("a", 0).unwrap(), 5);
        assert!(a.flag("flag"));
        assert_eq!(a.str_or("b", ""), "x");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn malformed_numeric_flags_are_hard_errors_naming_the_flag() {
        // regression: `--stage1-m abc` used to silently run with m=4
        let argv: Vec<String> = ["--stage1-m", "abc", "--lr", "fast", "--nprobe", "8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        let err = a.usize_or("stage1-m", 4).unwrap_err().to_string();
        assert!(err.contains("stage1-m") && err.contains("abc"), "{err}");
        let err = a.f32_or("lr", 8e-4).unwrap_err().to_string();
        assert!(err.contains("lr") && err.contains("fast"), "{err}");
        // well-formed and absent flags still parse
        assert_eq!(a.usize_or("nprobe", 1).unwrap(), 8);
        assert_eq!(a.usize_or("absent", 3).unwrap(), 3);
        // a valueless `--flag` treated as numeric is malformed, not 0
        let b = Args::parse(&["--batch-threads".to_string()]);
        assert!(b.usize_or("batch-threads", 1).is_err());
    }

    #[test]
    fn shards_flag_is_validated_against_the_bucket_count() {
        // in range: parses through
        let a = Args::parse(&["--shards".to_string(), "3".to_string()]);
        assert_eq!(shards_of(&a, 16).unwrap(), 3);
        // absent: defaults to one shard
        assert_eq!(shards_of(&Args::parse(&[]), 16).unwrap(), 1);
        // --shards 0 is a hard error naming the flag
        let zero = Args::parse(&["--shards".to_string(), "0".to_string()]);
        let err = shards_of(&zero, 16).unwrap_err().to_string();
        assert!(err.contains("--shards") && err.contains("at least 1"), "{err}");
        // --shards > k-ivf is a hard error naming both flags
        let big = Args::parse(&["--shards".to_string(), "17".to_string()]);
        let err = shards_of(&big, 16).unwrap_err().to_string();
        assert!(err.contains("--shards 17") && err.contains("--k-ivf 16"), "{err}");
        // boundary: exactly k-ivf shards is allowed
        assert_eq!(shards_of(&big, 17).unwrap(), 17);
        // malformed values ride the usize_or hard-error policy
        let bad = Args::parse(&["--shards".to_string(), "two".to_string()]);
        let err = shards_of(&bad, 16).unwrap_err().to_string();
        assert!(err.contains("shards") && err.contains("two"), "{err}");
    }

    #[test]
    fn scan_layout_flag_is_validated() {
        // absent: flat (the seed layout) is the default
        assert_eq!(scan_layout_of(&Args::parse(&[])).unwrap(), ScanLayout::Flat);
        for (name, layout) in [
            ("flat", ScanLayout::Flat),
            ("transposed", ScanLayout::Transposed),
            ("packed4", ScanLayout::Packed4),
        ] {
            let a = Args::parse(&["--scan-layout".to_string(), name.to_string()]);
            assert_eq!(scan_layout_of(&a).unwrap(), layout);
        }
        // unknown names are hard errors naming the flag, not fallbacks
        let bad = Args::parse(&["--scan-layout".to_string(), "diagonal".to_string()]);
        let err = scan_layout_of(&bad).unwrap_err().to_string();
        assert!(err.contains("--scan-layout") && err.contains("diagonal"), "{err}");
    }

    #[test]
    fn packed4_build_requests_are_validated_against_the_family() {
        use crate::index::Stage1Kind;
        // the CLI-level guard reuses packed4_support: incompatible
        // stage-1 families error naming the family, never fall back
        let err = packed4_support(&Stage1Kind::Aq, 8).unwrap_err().to_string();
        assert!(err.contains("packed4") && err.contains("\"aq\""), "{err}");
        let err = packed4_support(&Stage1Kind::Pq { m: 4 }, 32).unwrap_err().to_string();
        assert!(err.contains("K=32"), "{err}");
        assert!(packed4_support(&Stage1Kind::Pq { m: 4 }, 16).is_ok());
        assert!(packed4_support(&Stage1Kind::Rq { m: 3 }, 8).is_ok());
    }

    #[test]
    fn encode_params_are_validated_against_the_codebook() {
        // absent: 0/0 means "model default" (A=K, B=1 at resolve time)
        assert_eq!(encode_params_of(&Args::parse(&[]), 16).unwrap(), EncodeParams { a: 0, b: 0 });
        // explicit in-range values pass through unresolved
        let a = Args::parse(&["--a".to_string(), "8".to_string(), "--b".to_string(), "4".to_string()]);
        assert_eq!(encode_params_of(&a, 16).unwrap(), EncodeParams { a: 8, b: 4 });
        // --a > K is a hard error naming the flag and K
        let big_a = Args::parse(&["--a".to_string(), "17".to_string()]);
        let err = encode_params_of(&big_a, 16).unwrap_err().to_string();
        assert!(err.contains("--a 17") && err.contains("K=16"), "{err}");
        // --b > --a is a hard error naming both flags
        let big_b =
            Args::parse(&["--a".to_string(), "4".to_string(), "--b".to_string(), "5".to_string()]);
        let err = encode_params_of(&big_b, 16).unwrap_err().to_string();
        assert!(err.contains("--b 5") && err.contains("--a 4"), "{err}");
        // --b alone is checked against the default A=K
        let only_b = Args::parse(&["--b".to_string(), "17".to_string()]);
        assert!(encode_params_of(&only_b, 16).is_err());
        assert_eq!(
            encode_params_of(&Args::parse(&["--b".to_string(), "16".to_string()]), 16).unwrap(),
            EncodeParams { a: 0, b: 16 }
        );
        // malformed values ride the usize_or hard-error policy
        let bad = Args::parse(&["--a".to_string(), "wide".to_string()]);
        assert!(encode_params_of(&bad, 16).is_err());
    }

    #[test]
    fn robustness_flags_are_validated() {
        // absent: all three default to 0 = disabled
        let none = Args::parse(&[]);
        assert_eq!(none.usize_or("deadline-ms", 0).unwrap(), 0);
        assert_eq!(none.usize_or("shed-watermark", 0).unwrap(), 0);
        assert_eq!(none.usize_or("retries", 0).unwrap(), 0);
        // Deadline::from_ms(0) is "no deadline", never "already expired"
        assert!(Deadline::from_ms(0).is_none());
        assert!(!Deadline::from_ms(0).expired());
        // well-formed values parse through
        let a = Args::parse(
            &["--deadline-ms", "250", "--shed-watermark", "64", "--retries", "3"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        );
        assert_eq!(a.usize_or("deadline-ms", 0).unwrap(), 250);
        assert_eq!(a.usize_or("shed-watermark", 0).unwrap(), 64);
        assert_eq!(a.usize_or("retries", 0).unwrap(), 3);
        // malformed values hard-error naming the flag
        let bad = Args::parse(&["--deadline-ms".to_string(), "soon".to_string()]);
        let err = bad.usize_or("deadline-ms", 0).unwrap_err().to_string();
        assert!(err.contains("deadline-ms") && err.contains("soon"), "{err}");
        let bad = Args::parse(&["--shed-watermark".to_string(), "-1".to_string()]);
        assert!(bad.usize_or("shed-watermark", 0).is_err());
        let bad = Args::parse(&["--retries".to_string(), "3.5".to_string()]);
        let err = bad.usize_or("retries", 0).unwrap_err().to_string();
        assert!(err.contains("retries") && err.contains("3.5"), "{err}");
    }

    #[test]
    fn net_flags_are_validated() {
        // absent (or explicit 0): server defaults
        let d = NetCfg::default();
        let cfg = net_cfg_of(&Args::parse(&[])).unwrap();
        assert_eq!(
            (cfg.max_conns, cfg.frame_max_bytes, cfg.conn_inflight),
            (d.max_conns, d.frame_max_bytes, d.conn_inflight)
        );
        let zeros: Vec<String> =
            ["--max-conns", "0", "--frame-max-bytes", "0", "--conn-inflight", "0"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = net_cfg_of(&Args::parse(&zeros)).unwrap();
        assert_eq!(cfg.max_conns, d.max_conns);
        assert_eq!(cfg.frame_max_bytes, d.frame_max_bytes);
        // nonzero values replace the defaults
        let set: Vec<String> =
            ["--max-conns", "2", "--frame-max-bytes", "65536", "--conn-inflight", "8"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = net_cfg_of(&Args::parse(&set)).unwrap();
        assert_eq!((cfg.max_conns, cfg.frame_max_bytes, cfg.conn_inflight), (2, 65536, 8));
        // a nonzero frame cap below the protocol minimum is a hard
        // error naming the flag, not a silent clamp
        let low = Args::parse(&["--frame-max-bytes".to_string(), "100".to_string()]);
        let err = net_cfg_of(&low).unwrap_err().to_string();
        assert!(err.contains("--frame-max-bytes 100"), "{err}");
        assert!(err.contains(&MIN_FRAME_MAX.to_string()), "{err}");
        // the boundary value itself is accepted
        let edge =
            Args::parse(&["--frame-max-bytes".to_string(), MIN_FRAME_MAX.to_string()]);
        assert_eq!(net_cfg_of(&edge).unwrap().frame_max_bytes, MIN_FRAME_MAX);
        // malformed values ride the usize_or hard-error policy
        let bad = Args::parse(&["--max-conns".to_string(), "many".to_string()]);
        let err = net_cfg_of(&bad).unwrap_err().to_string();
        assert!(err.contains("max-conns") && err.contains("many"), "{err}");
    }

    #[test]
    fn flavor_parse() {
        let a = Args::parse(&["--dataset".to_string(), "deep".to_string()]);
        assert_eq!(flavor_of(&a).unwrap(), Flavor::Deep);
        let bad = Args::parse(&["--dataset".to_string(), "nope".to_string()]);
        assert!(flavor_of(&bad).is_err());
    }
}
