//! Shared experiment harness: dataset sizing, model training with
//! checkpoint caching, and evaluation helpers used by the CLI, the
//! examples and every bench target (one per paper table/figure).

use crate::data::{self, Dataset, Flavor};
use crate::metrics;
use crate::qinco::{Codec, ParamStore, TrainCfg, Trainer};
use crate::quantizers::Codes;
use crate::runtime::Engine;
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Experiment scale. Defaults reproduce every table/figure in minutes on
/// CPU; set `QINCO2_SCALE=large` for a closer-to-paper run.
#[derive(Clone, Debug)]
pub struct Scale {
    pub n_train: usize,
    pub n_db: usize,
    pub n_query: usize,
    pub epochs: usize,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("QINCO2_SCALE").as_deref() {
            Ok("large") => Scale { n_train: 100_000, n_db: 200_000, n_query: 2_000, epochs: 40 },
            Ok("small") => Scale { n_train: 4_000, n_db: 8_000, n_query: 400, epochs: 6 },
            _ => Scale { n_train: 20_000, n_db: 50_000, n_query: 1_000, epochs: 15 },
        }
    }

    /// Bench defaults: every table/figure regenerates in minutes while
    /// preserving the paper's orderings. `QINCO2_SCALE` overrides.
    pub fn bench() -> Scale {
        if std::env::var("QINCO2_SCALE").is_ok() {
            return Scale::from_env();
        }
        Scale { n_train: 4_000, n_db: 4_000, n_query: 500, epochs: 5 }
    }
}

/// A training job for [`parallel_train`].
pub struct TrainJob {
    pub model: String,
    pub tag: String,
    pub train: Matrix,
    pub cfg: TrainCfg,
}

/// Train several models concurrently, one PJRT Engine per thread (the
/// CPU client executes mostly single-threaded, so model-level parallelism
/// is the effective axis — EXPERIMENTS.md §Perf L3). Results come back in
/// job order; failures surface as Err per job.
pub fn parallel_train(jobs: Vec<TrainJob>) -> Vec<Result<ParamStore>> {
    let max_par = crate::util::pool::default_threads().min(jobs.len()).max(1);
    let mut results: Vec<Option<Result<ParamStore>>> = jobs.iter().map(|_| None).collect();
    let jobs: Vec<_> = jobs.into_iter().enumerate().collect();
    for wave in jobs.chunks(max_par) {
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (i, job) in wave {
                handles.push((*i, s.spawn(move || -> Result<ParamStore> {
                    let mut engine = Engine::open(artifacts_dir())?;
                    trained_model(&mut engine, &job.model, &job.tag, &job.train, &job.cfg)
                })));
            }
            for (i, h) in handles {
                let r = h.join().unwrap_or_else(|_| Err(anyhow::anyhow!("train thread panicked")));
                results[i] = Some(r);
            }
        });
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Root of the artifact tree (HLO + manifest + model checkpoints).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("QINCO2_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn bench_out_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Load the standard dataset for a flavor at the model's dimension.
pub fn dataset(flavor: Flavor, d: usize, scale: &Scale) -> Dataset {
    data::load(flavor, scale.n_train, scale.n_db, scale.n_query, d, 0xDA7A + flavor as u64)
}

/// Train (or load from the checkpoint cache) a QINCo2 model on `train`.
/// Cache key: model name + flavor + data fingerprint + train config.
pub fn trained_model(
    engine: &mut Engine,
    model: &str,
    tag: &str,
    train: &Matrix,
    cfg: &TrainCfg,
) -> Result<ParamStore> {
    let spec = engine.manifest.model(model)?.clone();
    let dir = artifacts_dir().join("models");
    std::fs::create_dir_all(&dir).ok();
    let key = format!(
        "{model}_{tag}_n{}_e{}_a{}b{}_{}",
        train.rows, cfg.epochs, cfg.a, cfg.b, cfg.optimizer
    );
    let path = dir.join(format!("{key}.qnpz"));
    if path.exists() {
        if let Ok(ps) = ParamStore::load(&path, &spec, model) {
            return Ok(ps);
        }
    }
    let mut params = ParamStore::init(&spec, model, train, 0x5EED ^ cfg.seed);
    let trainer = Trainer::new(engine, model, cfg.clone())
        .with_context(|| format!("trainer for {model}"))?;
    let stats = trainer.train(engine, &mut params, train)?;
    eprintln!(
        "[trained {key}: {} steps, {:.1}s, loss {:.5} -> {:.5}]",
        stats.steps,
        stats.secs,
        stats.epoch_losses.first().unwrap_or(&f64::NAN),
        stats.epoch_losses.last().unwrap_or(&f64::NAN)
    );
    params.save(&path)?;
    Ok(params)
}

/// Compression metrics of a codec on a database + query set:
/// (mse, r@1, r@10, r@100). Neighbor search is brute force over the
/// decoded database (the paper's 1M-scale protocol).
pub struct CompressionEval {
    pub mse: f64,
    pub r1: f64,
    pub r10: f64,
    pub r100: f64,
}

pub fn eval_compression(
    engine: &mut Engine,
    codec: &Codec,
    params: &ParamStore,
    db: &Matrix,
    queries: &Matrix,
    gt: &[u32],
) -> Result<CompressionEval> {
    let (codes, _, _) = codec.encode(engine, params, db)?;
    let decoded = codec.decode(engine, params, &codes)?;
    Ok(eval_decoded(&decoded, db, queries, gt))
}

/// Same metrics given an already-decoded database.
pub fn eval_decoded(decoded: &Matrix, db: &Matrix, queries: &Matrix, gt: &[u32]) -> CompressionEval {
    let mse = crate::tensor::mse(db, decoded);
    let results = data::brute_force_gt_k(decoded, queries, 100);
    let (r1, r10, r100) = metrics::recall_triple(&results, gt);
    CompressionEval { mse, r1, r10, r100 }
}

/// Multi-rate evaluation: MSE after each prefix of steps (Figs. S1/S3).
pub fn eval_multirate(
    engine: &mut Engine,
    codec: &Codec,
    params: &ParamStore,
    db: &Matrix,
) -> Result<Vec<f64>> {
    let (codes, _, _) = codec.encode(engine, params, db)?;
    let partials = codec.decode_partial(engine, params, &codes)?;
    Ok(partials.iter().map(|p| crate::tensor::mse(db, p)).collect())
}

/// Per-vector encode/decode wall-clock of a codec (µs), measured on a
/// fixed batch (Table S2, Figs. 4/5 time axes).
pub struct CodecTiming {
    pub encode_us: f64,
    pub decode_us: f64,
}

pub fn time_codec(
    engine: &mut Engine,
    codec: &Codec,
    params: &ParamStore,
    xs: &Matrix,
) -> Result<CodecTiming> {
    // warmup (compiles artifacts)
    let (codes, _, _) = codec.encode(engine, params, xs)?;
    codec.decode(engine, params, &codes)?;
    let t0 = std::time::Instant::now();
    let (codes, _, _) = codec.encode(engine, params, xs)?;
    let enc = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    codec.decode(engine, params, &codes)?;
    let dec = t1.elapsed().as_secs_f64();
    Ok(CodecTiming {
        encode_us: enc * 1e6 / xs.rows as f64,
        decode_us: dec * 1e6 / xs.rows as f64,
    })
}

/// Write a CSV file into bench_out/ (one per table/figure).
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
    let path = bench_out_dir().join(name);
    let mut body = String::from(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    std::fs::write(&path, body)?;
    Ok(path)
}

/// Codes→Codes helper reused by decoder experiments.
pub fn codes_subset(codes: &Codes, idx: &[usize]) -> Codes {
    crate::index::pipeline::gather_codes(codes, idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_env_parsing() {
        let s = Scale::from_env();
        assert!(s.n_train > 0 && s.n_db > 0 && s.n_query > 0);
    }

    #[test]
    fn csv_writer_creates_file() {
        let p = write_csv("test_tmp.csv", "a,b", &["1,2".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("a,b\n1,2\n"));
        std::fs::remove_file(p).ok();
    }
}
