//! Offline stand-in for the `anyhow` crate.
//!
//! crates.io is unavailable in this build environment (see DESIGN.md
//! §Substitutions), so the error-handling API subset the workspace uses
//! is implemented here from scratch: [`Error`] (a context chain),
//! [`Result`], [`anyhow!`]/[`bail!`]/[`ensure!`], and the [`Context`]
//! extension trait. Like the real crate, `Error` deliberately does NOT
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// An error with a chain of human-readable context frames.
/// `chain[0]` is the outermost context, `chain.last()` the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Push an outer context frame (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // capture the std source chain as context frames
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().context("loading config").unwrap_err();
        assert_eq!(e.chain().next(), Some("loading config"));
        assert!(format!("{e:#}").starts_with("loading config: "));
        assert_eq!(format!("{e}"), "loading config");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let e2: Error = anyhow!("code {}", 7);
        assert_eq!(format!("{e2}"), "code 7");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        fn ensures(x: u32) -> Result<()> {
            ensure!(x > 2, "x={x} too small");
            Ok(())
        }
        assert!(bails().is_err());
        assert!(ensures(1).is_err());
        assert!(ensures(3).is_ok());
    }
}
