//! Stub of the `xla` (xla_extension 0.5.1 / PJRT C API) bindings.
//!
//! The prebuilt `xla_extension` shared library is not available in this
//! offline build environment, so this crate mirrors the API surface
//! `qinco2::runtime` uses — [`PjRtClient`], [`HloModuleProto`],
//! [`XlaComputation`], [`Literal`] — and fails at *runtime* with a clear
//! error instead of failing the build. Everything that touches compiled
//! HLO artifacts (the `runtime_roundtrip` / `search_pipeline` integration
//! tests, the paper benches) is `#[ignore]`d or degrades gracefully when
//! the engine reports unavailability; the pure-Rust reference paths
//! (reference decoder, classical quantizers, the batched search engine)
//! are unaffected. Replacing this path dependency with the real crate
//! re-enables the XLA execution path with no source changes.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (qinco2 was built against the \
         vendored stub `xla` crate in rust/vendor/xla; link the real \
         xla_extension to execute HLO artifacts)"
    ))
}

/// Element types of the PJRT ABI. Mirrors the real crate's enum; marked
/// non-exhaustive so downstream matches keep their wildcard arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Dimensions + element type of an array-shaped literal.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side tensor value. The stub can never construct one (its only
/// constructor errors), so the accessors are unreachable but well-typed.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({:?})",
            path.as_ref()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
