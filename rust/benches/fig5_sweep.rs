//! Figure 5: joint architecture sweep — MSE vs encoding time across
//! (L, de, dh) × (A, B), marking the Pareto-optimal front.
//!
//! Uses the `sweep` artifact catalog (`make artifacts-sweep`); falls back
//! to the base models if the sweep catalog is absent.

#[path = "common.rs"]
mod common;

use qinco2::data::Flavor;
use qinco2::experiments as exp;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    common::banner("FIGURE 5 — architecture sweep pareto front", "Fig. 5");
    let scale = exp::Scale::bench();
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let mut ds = exp::dataset(Flavor::BigAnn, 32, &scale);
    // MSE-vs-encode-time sweep: a compact db keeps the grid affordable
    ds.database = ds.database.gather_rows(&(0..1536.min(ds.database.rows)).collect::<Vec<_>>());

    let sweep_models: Vec<String> = engine
        .manifest
        .models
        .keys()
        .filter(|n| n.starts_with("sw_"))
        .cloned()
        .collect();
    let models: Vec<String> = if sweep_models.is_empty() {
        println!("(sweep catalog not lowered; run `make artifacts-sweep` for the full grid — using base models)");
        vec!["qinco1".into(), "qinco2_xs".into(), "qinco2_s".into(), "qinco2_m".into()]
    } else {
        sweep_models
    };

    // train all sweep models concurrently
    let jobs: Vec<exp::TrainJob> = models
        .iter()
        .map(|m| exp::TrainJob {
            model: m.clone(),
            tag: "bigann_f5".into(),
            train: ds.train.clone(),
            cfg: TrainCfg { epochs: scale.epochs.min(4), a: 8, b: 8, ..Default::default() },
        })
        .collect();
    let trained = exp::parallel_train(jobs);

    let mut points: Vec<(String, usize, usize, f64, f64)> = Vec::new();
    for (model, params) in models.iter().zip(trained) {
        let params = params?;
        for (a, b, _) in engine.manifest.encode_settings(model) {
            if a * b > 256 {
                continue; // keep the grid affordable on CPU-XLA
            }
            let Ok(codec) = Codec::new(&engine, model, a, b) else { continue };
            let t0 = std::time::Instant::now();
            let (codes, _, _) = codec.encode(&mut engine, &params, &ds.database)?;
            let enc_us = t0.elapsed().as_secs_f64() * 1e6 / ds.database.rows as f64;
            let dec = codec.decode(&mut engine, &params, &codes)?;
            let mse = qinco2::tensor::mse(&ds.database, &dec);
            points.push((model.clone(), a, b, enc_us, mse));
        }
    }
    // mark the pareto front (min MSE for any encode time budget)
    points.sort_by(|x, y| x.3.partial_cmp(&y.3).unwrap());
    let mut best = f64::INFINITY;
    println!("{:<16} {:>4} {:>4} {:>12} {:>10}  pareto", "model", "A", "B", "enc µs/vec", "MSE");
    common::hr(62);
    let mut csv = Vec::new();
    for (model, a, b, enc_us, mse) in &points {
        let on_front = *mse < best;
        if on_front {
            best = *mse;
        }
        println!("{model:<16} {a:>4} {b:>4} {enc_us:>12.2} {mse:>10.5}  {}",
                 if on_front { "*" } else { "" });
        csv.push(format!("{model},{a},{b},{enc_us},{mse},{}", on_front as u8));
    }
    let path = exp::write_csv("fig5.csv", "model,a,b,enc_us,mse,pareto", &csv)?;
    println!("\n[csv] {}", path.display());
    Ok(())
}
