//! Figure S3: dynamic rates — a model trained with a large M used as a
//! multi-rate codec. Compares prefix-MSE of the M=16 model against
//! dedicated M=8 and M=4 models of the same architecture.

#[path = "common.rs"]
mod common;

use qinco2::data::Flavor;
use qinco2::experiments as exp;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    common::banner("FIGURE S3 — multi-rate decoding across trained M", "Fig. S3");
    let scale = exp::Scale::bench();
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let ds = exp::dataset(Flavor::Deep, 32, &scale);

    let variants = [("qinco2_xs_m4", 4usize), ("qinco2_xs_m8", 8), ("qinco2_xs", 16)];
    let jobs: Vec<exp::TrainJob> = variants
        .iter()
        .map(|(m, _)| exp::TrainJob {
            model: m.to_string(),
            tag: "deep_s3".into(),
            train: ds.train.clone(),
            cfg: TrainCfg { epochs: scale.epochs, a: 8, b: 8, ..Default::default() },
        })
        .collect();
    let trained = exp::parallel_train(jobs);

    let mut curves: Vec<(usize, Vec<f64>)> = Vec::new();
    for ((model, m_trained), params) in variants.iter().zip(trained) {
        let params = params?;
        let codec = Codec::new(&engine, model, 16, 16).or_else(|_| Codec::new(&engine, model, 8, 8))?;
        let curve = exp::eval_multirate(&mut engine, &codec, &params, &ds.database)?;
        curves.push((*m_trained, curve));
    }

    println!("{:>5} {:>14} {:>14} {:>14}", "m", "trained M=4", "trained M=8", "trained M=16");
    common::hr(52);
    let mut csv = Vec::new();
    for m in 1..=16usize {
        let cell = |mt: usize| -> String {
            curves
                .iter()
                .find(|(tm, _)| *tm == mt)
                .and_then(|(_, c)| c.get(m - 1))
                .map(|v| format!("{v:.5}"))
                .unwrap_or_else(|| "-".into())
        };
        println!("{m:>5} {:>14} {:>14} {:>14}", cell(4), cell(8), cell(16));
        csv.push(format!("{m},{},{},{}", cell(4), cell(8), cell(16)));
    }
    println!("\n(paper finding: for any prefix m, curves of models trained with M >= m");
    println!(" nearly coincide — the large-M model is a near-optimal multi-rate codec)");
    let path = exp::write_csv("fig_s3.csv", "m,trained_m4,trained_m8,trained_m16", &csv)?;
    println!("\n[csv] {}", path.display());
    Ok(())
}
