//! Shared helpers for the bench harnesses (criterion is unavailable
//! offline; each bench is a `harness = false` binary that prints the
//! paper's rows and writes a CSV under bench_out/).

#![allow(dead_code)]

use qinco2::data::Flavor;

/// Flavors to run, controllable via `QINCO2_DATASETS=bigann,deep`.
pub fn flavors() -> Vec<Flavor> {
    match std::env::var("QINCO2_DATASETS") {
        Ok(list) => list
            .split(',')
            .filter_map(|s| Flavor::parse(s.trim()))
            .collect(),
        Err(_) => vec![Flavor::BigAnn, Flavor::Deep],
    }
}

pub fn hr(width: usize) {
    println!("{}", "-".repeat(width));
}

/// Paper-style percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

pub fn banner(title: &str, paper_ref: &str) {
    println!();
    hr(78);
    println!("{title}");
    println!("(reproduces {paper_ref}; absolute values differ from the paper — synthetic");
    println!(" data at reduced scale — orderings and ratios are the comparison target)");
    hr(78);
}
