//! Batched dispatch vs per-query loop: serving QPS at equal recall.
//!
//! Backs the serving claim of the batched execution engine: forming
//! batches is only worth it if executing them *as* batches (flat LUT
//! packs, bucket-grouped scans, one union decode) beats looping
//! `search()` per request. Three dispatch modes over the same index and
//! knobs — results are asserted identical, so recall is equal by
//! construction and QPS is the only free variable:
//!
//!   per-query loop   one full `search()` per request (the old worker
//!                    inner loop), threaded across all cores
//!   batched engine   `search_batch`: same thread count, each thread
//!                    runs the batch engine over its chunk
//!   router           end-to-end through the serving coordinator's
//!                    dynamic batcher + batched workers
//!
//! Engine-free: the index is built with the pure-Rust reference encoder
//! and the in-repo `test` model spec, so this bench runs without HLO
//! artifacts or an XLA runtime (unlike the fig6 bench, which sweeps real
//! QINCo2 models). A final stage-3 section times the exact decoders
//! head-to-head (scalar-oracle `ReferenceDecoder` vs nn-kernel
//! `RustDecoder`) over the same weights and codes.

#[path = "common.rs"]
mod common;

use qinco2::data::{self, Flavor};
use qinco2::index::{
    BatchSearcher, BuildCfg, EncodeParams, PipelineConfig, QueryPlan, ScanLayout, SearchIndex,
    SearchParams, Stage1Kind, Stage3Kind,
};
use qinco2::metrics::{ids_only, recall_at};
use qinco2::net::{LoadCfg, NetCfg, NetClient, NetServer};
use qinco2::qinco::ParamStore;
use qinco2::quantizers::StageDecoder;
use qinco2::runtime::manifest::Manifest;
use qinco2::server::{Router, ServerCfg, WriteOp, WriteOutcome};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    common::banner(
        "BATCHED DISPATCH — QPS vs the per-query loop at equal recall",
        "Fig. 6 serving path; engine-free",
    );
    let manifest_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    let spec = Manifest::load(&manifest_path)?.model("test")?.clone();
    let (n_train, n_db, n_q) = match std::env::var("QINCO2_SCALE").as_deref() {
        Ok("large") => (4_000, 24_000, 2_000),
        Ok("small") => (800, 3_000, 400),
        _ => (1_500, 8_000, 800),
    };
    let ds = data::load(Flavor::Deep, n_train, n_db, n_q, spec.cfg.d, 17);
    let params = ParamStore::init(&spec, "test", &ds.train, 23);
    let cfg = BuildCfg { k_ivf: 64, m_tilde: 2, fit_sample: 1_000, ..Default::default() };
    let t_build = Instant::now();
    let index = SearchIndex::build_reference(params, &ds.train, &ds.database, &cfg);
    println!(
        "[build] reference-encoded index: {} vectors, K_IVF={} in {:.1}s",
        n_db,
        cfg.k_ivf,
        t_build.elapsed().as_secs_f64()
    );
    let index = Arc::new(index);
    let nthreads = qinco2::util::pool::default_threads();
    let mut csv = Vec::new();

    println!(
        "{:<18} {:>7} {:>6} {:>8} {:>10} {:>8} {:>9}",
        "dispatch", "nprobe", "naq", "npairs", "QPS", "R@1", "speedup"
    );
    common::hr(72);
    for (nprobe, n_aq, n_pairs) in [(4usize, 64usize, 16usize), (8, 128, 32), (16, 256, 64)] {
        let sp = SearchParams { nprobe, ef_search: 64, n_aq, n_pairs, n_final: 10, ..Default::default() };

        // --- (a) per-query loop, threaded across all cores ---
        let mut per_query: Vec<Vec<u32>> = vec![Vec::new(); ds.queries.rows];
        let t0 = Instant::now();
        qinco2::util::pool::par_map_into(&mut per_query, nthreads, |i, slot| {
            *slot = index
                .search(ds.queries.row(i), &sp)
                .into_iter()
                .map(|(_, id)| id)
                .collect();
        });
        let qps_loop = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
        let r1 = recall_at(&per_query, &ds.ground_truth, 1);

        // --- (b) batched engine, same thread count ---
        let t0 = Instant::now();
        let batched = ids_only(&index.search_batch(&ds.queries, &sp)?);
        let qps_batch = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
        assert_eq!(batched, per_query, "batched engine must be result-identical");

        // --- (c) end-to-end through the serving router ---
        let router = Router::start(
            index.clone(),
            ServerCfg { workers: nthreads, max_batch: 64, ..Default::default() },
        );
        let t0 = Instant::now();
        let pending: Vec<_> = (0..ds.queries.rows)
            .map(|i| {
                router
                    .submit(ds.queries.row(i).to_vec(), sp)
                    .expect("router accepting")
            })
            .collect();
        let routed: Vec<Vec<u32>> = pending
            .into_iter()
            .map(|rx| {
                let resp = rx.recv().expect("reply channel dropped").expect("typed reply");
                resp.results.into_iter().map(|(_, id)| id).collect()
            })
            .collect();
        let qps_router = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
        assert_eq!(routed, per_query, "router must be a pure wrapper");
        let stats = router.stats();
        router.shutdown();

        for (label, qps) in [
            ("per-query loop", qps_loop),
            ("batched engine", qps_batch),
            ("router (e2e)", qps_router),
        ] {
            println!(
                "{label:<18} {nprobe:>7} {n_aq:>6} {n_pairs:>8} {qps:>10.0} {:>8} {:>8.2}x",
                common::pct(r1),
                qps / qps_loop
            );
            csv.push(format!("{label},{nprobe},{n_aq},{n_pairs},{qps:.0},{r1:.4}"));
        }
        println!(
            "{:<18} p50 {:.2?}  p99 {:.2?}  mean {:.2?}",
            "  router latency", stats.p50, stats.p99, stats.mean_latency
        );
        common::hr(72);
    }
    // ---- stage-1 scan kernels: scalar vs block vs block+parallel ----
    // The scan is the engine's dominant cost at scale: every probed
    // inverted-list row is scored against every interested query. Three
    // kernels over identical plans — shortlists are asserted equal, so
    // recall is equal by construction and scan QPS is the only free
    // variable:
    //   scalar scan      one ApproxScorer::score call per (row, member)
    //   block scan       score_block: one call per row per ≤8-member
    //                    block; the code row is read once and the LUT
    //                    gathers vectorize across accumulator lanes
    //   block+parallel   block scan with the bucket groups split across
    //                    all cores (--batch-threads)
    println!();
    common::banner(
        "STAGE-1 SCAN KERNEL — multi-query block scoring + group-parallel scan",
        "bit-identical shortlists; scan-stage QPS",
    );
    println!(
        "{:<18} {:>7} {:>6} {:>10} {:>9}",
        "kernel", "nprobe", "naq", "scanQPS", "speedup"
    );
    common::hr(56);
    let searcher = BatchSearcher::new(&index);
    for (nprobe, n_aq) in [(4usize, 64usize), (8, 128), (16, 256)] {
        let sp = SearchParams { nprobe, ef_search: 64, n_aq, ..Default::default() };
        let plans: Vec<QueryPlan> =
            (0..ds.queries.rows).map(|i| searcher.plan(ds.queries.row(i), &sp)).collect();
        let reference = searcher.scan_stage1(&plans, &sp, 1, false);
        let scan_qps = |threads: usize, block: bool| {
            // warm-up + equality pin, then best-of-3 timing
            assert_eq!(
                searcher.scan_stage1(&plans, &sp, threads, block),
                reference,
                "kernels must stay bit-identical"
            );
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                let lists = searcher.scan_stage1(&plans, &sp, threads, block);
                best = best.min(t0.elapsed().as_secs_f64());
                std::hint::black_box(lists);
            }
            ds.queries.rows as f64 / best
        };
        let qps_scalar = scan_qps(1, false);
        let qps_block = scan_qps(1, true);
        let qps_par = scan_qps(nthreads, true);
        for (label, qps) in [
            ("scalar scan", qps_scalar),
            ("block scan", qps_block),
            ("block+parallel", qps_par),
        ] {
            println!(
                "{label:<18} {nprobe:>7} {n_aq:>6} {qps:>10.0} {:>8.2}x",
                qps / qps_scalar
            );
            csv.push(format!("kernel:{label},{nprobe},{n_aq},,{qps:.0},"));
        }
        common::hr(56);
    }

    // ---- scan layouts: flat vs query-major transposed vs 4-bit packed ----
    // The physical layout of the same scan: "flat" gathers each member's
    // LUT entry with a strided load, "transposed" repacks each ≤8-member
    // chunk query-major so the inner loop reads unit-stride (contractually
    // bit-identical), "packed4" scans nibble-packed codes against
    // u8-quantized LUTs — a versioned bounded-error scoring mode.
    // Correctness is pinned before any timing: transposed shortlists and
    // end-to-end results must equal flat exactly; packed4 must keep its
    // top-k rank agreement. The pins double as kernel warm-up, so the
    // best-of-3 timings below never include a cold first run.
    println!();
    common::banner(
        "SCAN LAYOUTS — flat vs transposed vs packed4 over a pq stage 1",
        "transposed bit-identical; packed4 quantized with rank agreement",
    );
    {
        // a PQ stage 1 over the K=8 test model fits the packed4 nibble
        // contract; a Packed4 build serves all three layout requests
        let bcfg = BuildCfg {
            k_ivf: 64,
            m_tilde: 2,
            fit_sample: 1_000,
            pipeline: PipelineConfig {
                stage1: Stage1Kind::Pq { m: 4 },
                stage2: true,
                stage3: Stage3Kind::Reference,
            },
            scan_layout: ScanLayout::Packed4,
            ..Default::default()
        };
        let params_l = ParamStore::init(&spec, "test", &ds.train, 23);
        let lidx = SearchIndex::build_reference(params_l, &ds.train, &ds.database, &bcfg);
        let lsearcher = BatchSearcher::new(&lidx);
        println!(
            "{:<18} {:>7} {:>6} {:>12} {:>10} {:>9}",
            "layout", "nprobe", "naq", "scan rows/s", "QPS", "overlap"
        );
        common::hr(72);
        for (nprobe, n_aq) in [(8usize, 128usize), (16, 256)] {
            let flat_sp = SearchParams {
                nprobe,
                ef_search: 64,
                n_aq,
                n_pairs: 32,
                n_final: 10,
                ..Default::default()
            };
            let plans: Vec<QueryPlan> = (0..ds.queries.rows)
                .map(|i| lsearcher.plan(ds.queries.row(i), &flat_sp))
                .collect();
            let flat_lists = lsearcher.scan_stage1(&plans, &flat_sp, 1, true);
            let flat_ids = ids_only(&lidx.search_batch(&ds.queries, &flat_sp)?);
            for layout in [ScanLayout::Flat, ScanLayout::Transposed, ScanLayout::Packed4] {
                let sp = SearchParams { scan_layout: layout, ..flat_sp };
                let lists = lsearcher.scan_stage1(&plans, &sp, 1, true);
                let ids = ids_only(&lidx.search_batch(&ds.queries, &sp)?);
                let overlap = match layout {
                    ScanLayout::Flat => 1.0,
                    ScanLayout::Transposed => {
                        assert_eq!(lists, flat_lists, "transposed shortlists diverged from flat");
                        assert_eq!(ids, flat_ids, "transposed results diverged from flat");
                        1.0
                    }
                    ScanLayout::Packed4 => {
                        let o = mean_overlap(&ids, &flat_ids);
                        assert!(
                            o >= 0.5,
                            "packed4 rank agreement collapsed: mean top-k overlap {o:.2}"
                        );
                        o
                    }
                };
                // rows/sec over the scan stage alone (already warm from
                // the pins above), best of 3; the per-shard scan counters
                // give the exact scored-row count per run
                let before: u64 = lidx.snapshot().scan_counts().iter().sum();
                let mut best = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = Instant::now();
                    let l = lsearcher.scan_stage1(&plans, &sp, 1, true);
                    best = best.min(t0.elapsed().as_secs_f64());
                    std::hint::black_box(l);
                }
                let rows_per_run: u64 =
                    (lidx.snapshot().scan_counts().iter().sum::<u64>() - before) / 3;
                let rps = rows_per_run as f64 / best;
                let t0 = Instant::now();
                let r = lidx.search_batch(&ds.queries, &sp)?;
                let qps = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
                std::hint::black_box(r);
                println!(
                    "{:<18} {nprobe:>7} {n_aq:>6} {rps:>12.0} {qps:>10.0} {:>9.3}",
                    layout.name(),
                    overlap
                );
                csv.push(format!("layout:{},{nprobe},{n_aq},,{rps:.0},", layout.name()));
            }
            common::hr(72);
        }
    }

    // ---- pipeline matrix: cost of each stage swap (trait API) ----
    // Three configurations over the same data, swept across knob rows so
    // QPS can be compared at matched recall: the row where a cheaper
    // pipeline reaches the reference pipeline's R@1 shows what the
    // skipped/swapped stage actually costs.
    println!();
    common::banner(
        "PIPELINE MATRIX — stage swaps through the trait API",
        "AQ→pair→reference vs AQ→pair-only vs PQ-stage1",
    );
    println!(
        "{:<20} {:>7} {:>6} {:>8} {:>10} {:>8}",
        "pipeline", "nprobe", "naq", "npairs", "QPS", "R@1"
    );
    common::hr(64);
    let pipelines: Vec<(&str, PipelineConfig)> = vec![
        ("aq+pair+reference", PipelineConfig::default()),
        (
            "aq+pair-only",
            PipelineConfig {
                stage1: Stage1Kind::Aq,
                stage2: true,
                stage3: Stage3Kind::Disabled,
            },
        ),
        (
            "pq-stage1",
            PipelineConfig {
                stage1: Stage1Kind::Pq { m: 4 },
                stage2: true,
                stage3: Stage3Kind::Reference,
            },
        ),
    ];
    for (label, pcfg) in pipelines {
        let bcfg = BuildCfg {
            k_ivf: 64,
            m_tilde: 2,
            fit_sample: 1_000,
            pipeline: pcfg,
            ..Default::default()
        };
        let params2 = ParamStore::init(&spec, "test", &ds.train, 23);
        let pidx = SearchIndex::build_reference(params2, &ds.train, &ds.database, &bcfg);
        for (nprobe, n_aq, n_pairs) in [(4usize, 64usize, 16usize), (8, 128, 32), (16, 256, 64)]
        {
            let sp = SearchParams { nprobe, ef_search: 64, n_aq, n_pairs, n_final: 10, ..Default::default() };
            let t0 = Instant::now();
            let res = ids_only(&pidx.search_batch(&ds.queries, &sp)?);
            let qps = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
            // the trait pipeline must stay batch/per-query identical
            let spot = pidx
                .search(ds.queries.row(0), &sp)
                .into_iter()
                .map(|(_, id)| id)
                .collect::<Vec<_>>();
            assert_eq!(res[0], spot, "{label}: batched diverged from per-query");
            let r1 = recall_at(&res, &ds.ground_truth, 1);
            println!(
                "{label:<20} {nprobe:>7} {n_aq:>6} {n_pairs:>8} {qps:>10.0} {:>8}",
                common::pct(r1)
            );
            csv.push(format!("pipeline:{label},{nprobe},{n_aq},{n_pairs},{qps:.0},{r1:.4}"));
        }
        common::hr(64);
    }

    // ---- shard scaling: scatter/gather cost at shards ∈ {1, 2, 4} ----
    // The shard layer is supposed to be free at this scale: same floats,
    // same merge order, just partitioned storage. Results are asserted
    // bit-identical (scores included) against the single-shard build, so
    // QPS is the only free variable and any scatter/gather overhead is
    // directly visible.
    println!();
    common::banner(
        "SHARD SCALING — bucket-owned shards behind scatter/gather",
        "bit-identical to shards=1 by construction; QPS per shard count",
    );
    println!(
        "{:<18} {:>7} {:>10} {:>9}  {}",
        "shards", "threads", "QPS", "speedup", "scan split"
    );
    common::hr(72);
    {
        let sp = SearchParams {
            nprobe: 8,
            ef_search: 64,
            n_aq: 128,
            n_pairs: 32,
            n_final: 10,
            ..Default::default()
        };
        let mut baseline: Option<Vec<Vec<(f32, u32)>>> = None;
        let mut qps_one_shard = 0.0f64;
        for shards in [1usize, 2, 4] {
            let bcfg = BuildCfg {
                k_ivf: 64,
                m_tilde: 2,
                fit_sample: 1_000,
                shards,
                ..Default::default()
            };
            let params_s = ParamStore::init(&spec, "test", &ds.train, 23);
            let sidx = SearchIndex::build_reference(params_s, &ds.train, &ds.database, &bcfg);
            // warm-up + equality pin, then best-of-3 timing
            let res = sidx.search_batch(&ds.queries, &sp)?;
            match &baseline {
                Some(base) => assert_eq!(
                    &res, base,
                    "sharded search must be bit-identical to the single-shard index"
                ),
                None => baseline = Some(res),
            }
            let scans_before = sidx.snapshot().scan_counts();
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                let r = sidx.search_batch(&ds.queries, &sp)?;
                best = best.min(t0.elapsed().as_secs_f64());
                std::hint::black_box(r);
            }
            let qps = ds.queries.rows as f64 / best;
            if shards == 1 {
                qps_one_shard = qps;
            }
            // per-shard scan counters show the bucket-ownership balance
            let scans: Vec<u64> = sidx
                .snapshot()
                .scan_counts()
                .iter()
                .zip(&scans_before)
                .map(|(a, b)| (a - b) / 3)
                .collect();
            println!(
                "{shards:<18} {:>7} {qps:>10.0} {:>8.2}x  {scans:?}",
                qinco2::util::pool::default_threads(),
                qps / qps_one_shard
            );
            csv.push(format!("shards:{shards},8,128,32,{qps:.0},"));
        }
    }
    common::hr(72);

    // ---- pipeline-matrix sweep: stage-1 family × stage-2 on/off ----
    // The ROADMAP's open sweep: nobody had mapped where the cheaper
    // stage-1 scorers pareto-dominate. Full cross of the five stage-1
    // families (AQ and the PQ/OPQ/LSQ/RQ side-table scorers) with the
    // pairwise stage 2 on and off, at three probe/shortlist knob points
    // — QPS + R@1 rows make the pareto regions visible: compare rows at
    // matched R@1 to read off what a stage swap costs or buys.
    println!();
    common::banner(
        "PIPELINE MATRIX SWEEP — stage-1 family × stage-2 on/off",
        "AQ/PQ/OPQ/LSQ/RQ × {pair, no-pair}; QPS + R@1 per knob point",
    );
    println!(
        "{:<20} {:>7} {:>6} {:>8} {:>10} {:>8}",
        "pipeline", "nprobe", "naq", "npairs", "QPS", "R@1"
    );
    common::hr(64);
    let stage1_families: Vec<(&str, Stage1Kind)> = vec![
        ("aq", Stage1Kind::Aq),
        ("pq4", Stage1Kind::Pq { m: 4 }),
        ("opq4", Stage1Kind::Opq { m: 4, iters: 4 }),
        ("lsq4", Stage1Kind::Lsq { m: 4 }),
        ("rq4", Stage1Kind::Rq { m: 4 }),
    ];
    for (s1_label, s1) in &stage1_families {
        for stage2 in [true, false] {
            let label = format!("{s1_label}{}", if stage2 { "+pair" } else { "-pair" });
            let bcfg = BuildCfg {
                k_ivf: 64,
                m_tilde: 2,
                fit_sample: 1_000,
                pipeline: PipelineConfig {
                    stage1: s1.clone(),
                    stage2,
                    stage3: Stage3Kind::Reference,
                },
                ..Default::default()
            };
            let params_m = ParamStore::init(&spec, "test", &ds.train, 23);
            let midx = SearchIndex::build_reference(params_m, &ds.train, &ds.database, &bcfg);
            for (nprobe, n_aq, n_pairs) in
                [(4usize, 64usize, 16usize), (8, 128, 32), (16, 256, 64)]
            {
                let sp = SearchParams {
                    nprobe,
                    ef_search: 64,
                    n_aq,
                    n_pairs: if stage2 { n_pairs } else { 0 },
                    n_final: 10,
                    ..Default::default()
                };
                let t0 = Instant::now();
                let res = ids_only(&midx.search_batch(&ds.queries, &sp)?);
                let qps = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
                let r1 = recall_at(&res, &ds.ground_truth, 1);
                println!(
                    "{label:<20} {nprobe:>7} {n_aq:>6} {:>8} {qps:>10.0} {:>8}",
                    sp.n_pairs,
                    common::pct(r1)
                );
                csv.push(format!(
                    "sweep:{label},{nprobe},{n_aq},{},{qps:.0},{r1:.4}",
                    sp.n_pairs
                ));
            }
            common::hr(64);
        }
    }

    // ---- live mutation: beam-encode ingest throughput ----
    // The write path of the epoch-snapshotted shard layer: encode fresh
    // vectors (codeword pre-selection A + beam B over the QINCo2 model),
    // assign buckets, and publish a new epoch. B=1 is the greedy encode
    // (bit-identical to a fresh build); wider beams buy reconstruction
    // accuracy at encode cost, so vec/s vs B is the tradeoff curve. Each
    // row retires its batch (delete + compact) so every beam starts from
    // the same index.
    println!();
    common::banner(
        "LIVE MUTATION — beam-search ingest + mixed read/write serving",
        "epoch-snapshotted shards; reads pin an epoch, writes ride their own lane",
    );
    let k = index.params.cfg.k;
    let d = spec.cfg.d;
    let n_ingest = 512usize;
    println!("{:<18} {:>5} {:>5} {:>10} {:>8}", "ingest", "A", "B", "vec/s", "epoch");
    common::hr(52);
    for beam in [1usize, 4, 16] {
        // the tiny test model has K=8: the effective beam clamps to K
        let ep = EncodeParams { a: k, b: beam.min(k) };
        let fresh = data::generate(Flavor::Deep, n_ingest, d, 400 + beam as u64);
        let t0 = Instant::now();
        let gids = index.insert(&fresh, &ep)?;
        let vps = n_ingest as f64 / t0.elapsed().as_secs_f64();
        println!(
            "{:<18} {k:>5} {:>5} {vps:>10.0} {:>8}",
            format!("beam={beam}"),
            ep.b,
            index.epoch()
        );
        csv.push(format!("ingest:beam{},,,,{vps:.0},", ep.b));
        index.delete(&gids)?;
        index.compact();
    }
    common::hr(52);

    // ---- mixed read/write through the router's write lane ----
    // Sustained churn while queries flow: every ~1/8th of the read
    // stream, a 32-vector chunk is ingested through the write lane and
    // its rows are scheduled for deletion. Reads keep pinning complete
    // epochs, so every response is well-formed mid-churn; after the
    // churn drains (delete + compact), the live set equals the original
    // database and results must be bit-identical to the pre-churn index.
    {
        let sp = SearchParams {
            nprobe: 8,
            ef_search: 64,
            n_aq: 128,
            n_pairs: 32,
            n_final: 10,
            ..Default::default()
        };
        let before = ids_only(&index.search_batch(&ds.queries, &sp)?);
        let r1_before = recall_at(&before, &ds.ground_truth, 1);
        let router = Router::start(
            index.clone(),
            ServerCfg { workers: nthreads, max_batch: 64, ..Default::default() },
        );
        let write_every = (ds.queries.rows / 8).max(1);
        let t0 = Instant::now();
        let mut read_pending = Vec::with_capacity(ds.queries.rows);
        let mut delete_pending = Vec::new();
        for i in 0..ds.queries.rows {
            if i % write_every == 0 {
                let chunk = data::generate(Flavor::Deep, 32, d, 900 + i as u64);
                let resp = router
                    .write_blocking(WriteOp::Insert {
                        vectors: chunk,
                        ep: EncodeParams::default(),
                    })
                    .expect("write lane accepting");
                match resp.outcome.expect("ingest failed") {
                    WriteOutcome::Inserted(gids) => delete_pending.push(
                        router
                            .submit_write(WriteOp::Delete { ids: gids })
                            .expect("write lane accepting"),
                    ),
                    other => panic!("insert returned {other:?}"),
                }
            }
            read_pending.push(
                router.submit(ds.queries.row(i).to_vec(), sp).expect("router accepting"),
            );
        }
        let mixed: Vec<Vec<u32>> = read_pending
            .into_iter()
            .map(|rx| {
                let resp = rx.recv().expect("reply channel dropped").expect("typed reply");
                resp.results.into_iter().map(|(_, id)| id).collect()
            })
            .collect();
        let read_qps = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
        for rx in delete_pending {
            rx.recv()
                .expect("reply channel dropped")
                .expect("typed write reply")
                .outcome
                .expect("delete failed");
        }
        router
            .write_blocking(WriteOp::Compact)
            .expect("write lane accepting")
            .outcome
            .expect("compaction failed");
        let stats = router.stats();
        router.shutdown();
        let r1_mixed = recall_at(&mixed, &ds.ground_truth, 1);
        // churn drained: the live set is the original database again, so
        // the mutated index must answer bit-identically to pre-churn
        let after = ids_only(&index.search_batch(&ds.queries, &sp)?);
        assert_eq!(after, before, "post-churn index diverged from the pre-churn results");
        println!(
            "{:<18} {:>10} {:>8} {:>8} {:>8} {:>8}",
            "mixed r/w", "readQPS", "p50", "p99", "ins", "del"
        );
        println!(
            "{:<18} {read_qps:>10.0} {:>8} {:>8} {:>8} {:>8}",
            format!("epoch={}", stats.epoch),
            format!("{:.1?}", stats.p50),
            format!("{:.1?}", stats.p99),
            stats.inserted,
            stats.deleted
        );
        println!(
            "  R@1 during churn {} (pre-churn {}); post-churn results bit-identical",
            common::pct(r1_mixed),
            common::pct(r1_before)
        );
        csv.push(format!("mixed:rw,8,128,32,{read_qps:.0},{r1_mixed:.4}"));
    }
    common::hr(72);

    // ---- network tier: loopback TCP through the frame protocol ----
    // The same router behind the socket boundary: a closed-loop load
    // generator over N connections shows what the frame codec + loopback
    // hop cost relative to the in-process "router (e2e)" rows above. A
    // spot-check pins wire replies bit-identical to direct search first,
    // so QPS is again the only free variable.
    println!();
    common::banner(
        "NETWORK TIER — loopback serving through the wire protocol",
        "wire replies bit-identical to in-process; QPS per connection count",
    );
    {
        let sp = SearchParams {
            nprobe: 8,
            ef_search: 64,
            n_aq: 128,
            n_pairs: 32,
            n_final: 10,
            ..Default::default()
        };
        let router = Arc::new(Router::start(
            index.clone(),
            ServerCfg { workers: nthreads, max_batch: 64, ..Default::default() },
        ));
        let server = NetServer::bind("127.0.0.1:0", router.clone(), NetCfg::default())?;
        let addr = server.local_addr().to_string();

        let mut probe = NetClient::connect(&addr)?;
        for i in 0..ds.queries.rows.min(16) {
            let q = ds.queries.row(i);
            let wire = probe.search(q, &sp, 0)?.expect("typed reply");
            assert_eq!(wire.results, index.search(q, &sp), "wire diverged from in-process");
        }
        drop(probe);

        println!(
            "{:<18} {:>7} {:>10} {:>9} {:>9} {:>9}",
            "connections", "reqs", "QPS", "p50", "p99", "errors"
        );
        common::hr(72);
        for conns in [1usize, 4, 8] {
            let lcfg = LoadCfg {
                addr: addr.clone(),
                conns,
                requests: ds.queries.rows,
                pipeline: 4,
                rate: 0.0,
                duration: Duration::ZERO,
                sp,
                deadline_ms: 0,
                queries: ds.queries.clone(),
            };
            let rep = qinco2::net::loadgen::run(&lcfg)?;
            // an unloaded loopback server sheds nothing and loses nothing
            assert_eq!(rep.completed, rep.sent, "every request must be answered");
            assert_eq!(rep.ok, rep.completed, "loopback serving must not shed or fail");
            println!(
                "{conns:<18} {:>7} {:>10.0} {:>9} {:>9} {:>9}",
                rep.completed,
                rep.qps,
                format!("{:.1?}", rep.p50),
                format!("{:.1?}", rep.p99),
                rep.completed - rep.ok
            );
            csv.push(format!("net:conns{conns},8,128,32,{:.0},", rep.qps));
        }
        let net_stats = server.drain();
        println!(
            "  net counters: {} connections, {} frames in, {} frames out, {} protocol errors",
            net_stats.stats.connections,
            net_stats.stats.frames_in,
            net_stats.stats.frames_out,
            net_stats.stats.protocol_errors
        );
        drop(router);
    }
    common::hr(72);

    // ---- stage-3 decode: scalar oracle vs native nn kernels ----
    // the re-rank stage decodes shortlist codes every query; this is the
    // per-decoder throughput behind `--stage3 reference` vs `--stage3 rust`
    {
        println!("\n[stage-3] exact decode throughput over {} db codes", 4096);
        let sample = data::generate(Flavor::Deep, 4096, spec.cfg.d, 29);
        let codes = qinco2::qinco::reference::encode_greedy(&index.params, &sample);
        let reference_dec = qinco2::qinco::ReferenceDecoder { params: index.params.clone() };
        let rust_dec = qinco2::qinco::RustDecoder { params: index.params.clone() };
        let a = reference_dec.decode(&codes)?;
        let b = rust_dec.decode(&codes)?;
        let worst =
            a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(worst <= 1e-5, "stage-3 decoders disagree: max |Δ| = {worst}");
        println!("{:<18} {:>12} {:>9}", "decoder", "vec/s", "speedup");
        common::hr(42);
        let mut base = 0.0f64;
        let pair: [(&str, &dyn StageDecoder); 2] =
            [("reference", &reference_dec), ("rust", &rust_dec)];
        for (name, dec) in pair {
            dec.decode(&codes)?; // warm
            let reps = 5;
            let t0 = Instant::now();
            for _ in 0..reps {
                dec.decode(&codes)?;
            }
            let vps = (reps * codes.n) as f64 / t0.elapsed().as_secs_f64();
            if base == 0.0 {
                base = vps;
            }
            println!("{name:<18} {vps:>12.0} {:>8.2}x", vps / base);
            csv.push(format!("stage3:{name},,,,{vps:.0},"));
        }
    }
    common::hr(72);

    let path = qinco2::experiments::write_csv(
        "bench_batch_qps.csv",
        "dispatch,nprobe,n_aq,n_pairs,qps,r1",
        &csv,
    )?;
    println!("[csv] {}", path.display());
    Ok(())
}

/// Mean per-query fraction of `base`'s result ids that also appear in
/// `other`'s list for the same query — order-insensitive top-k rank
/// agreement, the bench-level sanity pin for the packed4 quantized
/// scoring mode (the strict versioned contract lives in
/// `tests/layout_equivalence.rs`).
fn mean_overlap(other: &[Vec<u32>], base: &[Vec<u32>]) -> f64 {
    assert_eq!(other.len(), base.len());
    let mut total = 0.0f64;
    let mut counted = 0usize;
    for (o, b) in other.iter().zip(base) {
        if b.is_empty() {
            continue;
        }
        let hits = b.iter().filter(|id| o.contains(id)).count();
        total += hits as f64 / b.len() as f64;
        counted += 1;
    }
    if counted == 0 { 1.0 } else { total / counted as f64 }
}
