//! Figure S1: MSE as a function of bitrate (number of code steps) for
//! QINCo2 vs RQ/OPQ, plus the implied bitrate reduction at iso-MSE.

#[path = "common.rs"]
mod common;

use qinco2::data::Flavor;
use qinco2::experiments as exp;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::quantizers::{opq::Opq, rq::Rq, VectorQuantizer};
use qinco2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    common::banner("FIGURE S1 — MSE vs bitrate", "Fig. S1");
    let scale = exp::Scale::bench();
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let ds = exp::dataset(Flavor::BigAnn, 32, &scale);
    let mut csv = Vec::new();

    // QINCo2: one M=16 model, every prefix = one bitrate point
    let cfg = TrainCfg { epochs: scale.epochs, a: 8, b: 8, ..Default::default() };
    let params = exp::trained_model(&mut engine, "qinco2_xs", "bigann_s1", &ds.train, &cfg)?;
    let codec = Codec::new(&engine, "qinco2_xs", 16, 16)?;
    let q_curve = exp::eval_multirate(&mut engine, &codec, &params, &ds.database)?;

    // RQ / OPQ at a few explicit code counts
    println!("{:>6} {:>12} {:>12} {:>12}", "codes", "QINCo2", "RQ", "OPQ");
    common::hr(46);
    for m in [2usize, 4, 8, 12, 16] {
        let rq = Rq::train(&ds.train, m, 64, 5, 31);
        let e_rq = rq.eval_mse(&ds.database);
        let e_opq = if m >= 2 && 32 % m == 0 {
            let opq = Opq::train(&ds.train, m, 64, 3, 32);
            format!("{:.5}", opq.eval_mse(&ds.database))
        } else {
            "-".into()
        };
        println!("{m:>6} {:>12.5} {e_rq:>12.5} {e_opq:>12}", q_curve[m - 1]);
        csv.push(format!("{m},{},{e_rq},{e_opq}", q_curve[m - 1]));
    }
    // bitrate reduction: smallest QINCo2 prefix beating RQ at m codes
    println!("\nbitrate reduction at iso-MSE (vs RQ):");
    for m in [8usize, 16] {
        let rq = Rq::train(&ds.train, m, 64, 5, 31);
        let target = rq.eval_mse(&ds.database);
        if let Some(mq) = (1..=16).find(|&i| q_curve[i - 1] <= target) {
            println!("  RQ {m} codes (MSE {target:.5}) ~= QINCo2 {mq} codes  ({:.0}% fewer)",
                     100.0 * (m as f64 - mq as f64) / m as f64);
        }
    }
    let path = exp::write_csv("fig_s1.csv", "codes,qinco2,rq,opq", &csv)?;
    println!("\n[csv] {}", path.display());
    Ok(())
}
