//! Figure 6 (+ Fig. S2, §B latency): QPS vs R@1 pareto for IVF-PQ,
//! IVF-RQ and IVF-QINCo2 on the scaled billion-search setup.
//!
//! Sweeps the paper's knobs — nprobe, efSearch and the shortlist sizes —
//! and reports queries/second (batched, all cores) and R@1. Also prints
//! the single-query latency comparison of §B.

#[path = "common.rs"]
mod common;

use qinco2::data::{brute_force_gt_k, Flavor};
use qinco2::experiments as exp;
use qinco2::index::{BuildCfg, SearchIndex, SearchParams};
use qinco2::metrics::recall_at;
use qinco2::qinco::{Codec, TrainCfg};
use qinco2::quantizers::{pq::Pq, rq::Rq, VectorQuantizer};
use qinco2::runtime::Engine;
use qinco2::tensor::Matrix;
use qinco2::util::prng::Rng;
use std::time::Instant;

/// Simple IVF-PQ/RQ baseline searcher: probe + flat-LUT scan + top-k.
struct IvfLut {
    ivf: qinco2::index::ivf::Ivf,
    codes: qinco2::quantizers::Codes,
    terms: Vec<f32>,
    /// flat position-major LUT builder: `lut[p * k + c]`
    lut_of: Box<dyn Fn(&[f32]) -> Vec<f32> + Sync>,
    m: usize,
    k: usize,
}

impl IvfLut {
    fn search(&self, q: &[f32], nprobe: usize, ef: usize, topk: usize) -> Vec<u32> {
        let probes = self.ivf.probe(q, nprobe, ef);
        let tables = (self.lut_of)(q);
        let mut best: Vec<(f32, u32)> = Vec::with_capacity(topk + 1);
        let mut worst = f32::INFINITY;
        for &(probe_d, bucket) in &probes {
            for &id in &self.ivf.lists[bucket as usize] {
                let i = id as usize;
                let mut s = probe_d + self.terms[i];
                for (p, &c) in self.codes.row(i).iter().enumerate() {
                    s += tables[p * self.k + c as usize];
                }
                if best.len() < topk || s < worst {
                    let pos = best.partition_point(|&(d, _)| d <= s);
                    best.insert(pos, (s, id));
                    if best.len() > topk {
                        best.pop();
                    }
                    worst = best.last().unwrap().0;
                }
            }
        }
        best.into_iter().map(|(_, id)| id).collect()
    }
}

/// Build an IVF-RQ (or PQ) residual-coded baseline.
fn build_lut_baseline(
    train: &Matrix, db: &Matrix, k_ivf: usize, m: usize, use_pq: bool, seed: u64,
) -> IvfLut {
    let ivf = qinco2::index::ivf::Ivf::build(train, db, k_ivf, seed);
    let residuals = ivf.residuals(db);
    // train fine quantizer on train-split residuals
    let t_ivf_assign = qinco2::tensor::assign_all(train, &ivf.centroids, qinco2::util::pool::default_threads());
    let mut t_res = train.clone();
    for i in 0..t_res.rows {
        let c = ivf.centroids.row(t_ivf_assign[i] as usize).to_vec();
        qinco2::tensor::sub_assign(t_res.row_mut(i), &c);
    }
    if use_pq {
        let pq = Pq::train(&t_res, m, 64, seed ^ 1);
        let codes = pq.encode(&residuals);
        let dec = pq.decode(&codes);
        let terms = term_cache(&ivf, &dec);
        let k = pq.k;
        IvfLut {
            ivf,
            codes,
            terms,
            m,
            k,
            // LUT over ⟨q,·⟩ is folded into PQ's subspace distance form:
            // score = probe + Σ_s (||c_s||² - 2⟨q_s, c_s⟩) (+ const ||q||²)
            lut_of: Box::new(move |q: &[f32]| {
                // convert each flat slice distance to (-2⟨q_s,c⟩ + ||c||²):
                // ||q_s - c||² - ||q_s||²
                let mut lut = pq.lut(q);
                for s in 0..pq.m {
                    let (lo, hi) = (pq.splits[s], pq.splits[s + 1]);
                    let qn = qinco2::tensor::sqnorm(&q[lo..hi]);
                    for v in &mut lut[s * pq.k..(s + 1) * pq.k] {
                        *v -= qn;
                    }
                }
                lut
            }),
        }
    } else {
        let rq = Rq::train(&t_res, m, 64, 5, seed ^ 2);
        let codes = rq.encode(&residuals);
        let dec = rq.decode(&codes);
        let terms = term_cache(&ivf, &dec);
        let cbs: Vec<Matrix> = rq.codebooks.clone();
        let k = cbs[0].rows;
        IvfLut {
            ivf,
            codes,
            terms,
            m,
            k,
            lut_of: Box::new(move |q: &[f32]| {
                let mut lut = vec![0.0f32; cbs.len() * k];
                for (p, cb) in cbs.iter().enumerate() {
                    for c in 0..cb.rows {
                        lut[p * k + c] = -2.0 * qinco2::tensor::dot(q, cb.row(c));
                    }
                }
                lut
            }),
        }
    }
}

/// term_i = ||x̂_r||² + 2⟨cent_i, x̂_r⟩ (see pipeline.rs distance algebra).
fn term_cache(ivf: &qinco2::index::ivf::Ivf, dec: &Matrix) -> Vec<f32> {
    (0..dec.rows)
        .map(|i| {
            let cent = ivf.centroids.row(ivf.assign[i] as usize);
            qinco2::tensor::sqnorm(dec.row(i)) + 2.0 * qinco2::tensor::dot(cent, dec.row(i))
        })
        .collect()
}

fn qps_of<F: Fn(usize) -> Vec<u32> + Sync>(n_queries: usize, f: F) -> (f64, Vec<Vec<u32>>) {
    let mut results = vec![Vec::new(); n_queries];
    let t0 = Instant::now();
    qinco2::util::pool::par_map_into(&mut results, qinco2::util::pool::default_threads(), |i, slot| {
        *slot = f(i);
    });
    (n_queries as f64 / t0.elapsed().as_secs_f64(), results)
}

fn main() -> anyhow::Result<()> {
    common::banner("FIGURE 6 / S2 — QPS vs R@1 on the scaled billion-search setup", "Fig. 6, Fig. S2, §B");
    let mut scale = exp::Scale::bench();
    // search wants a bigger database than the compression benches
    // (QINCO2_SCALE=large raises this to the full configured size)
    scale.n_db = scale.n_db.max(10_000);
    let mut engine = Engine::open(exp::artifacts_dir())?;
    let mut csv = Vec::new();
    let k_ivf = 256;

    for flavor in common::flavors() {
        let ds = exp::dataset(flavor, 32, &scale);
        println!("\n=== {}1B-scaled: db {}, {} queries, K_IVF={k_ivf} ===",
                 flavor.name(), ds.database.rows, ds.queries.rows);
        println!("{:<14} {:>7} {:>6} {:>6} {:>8} {:>8} {:>8}",
                 "method", "nprobe", "ef", "naq", "npairs", "QPS", "R@1");
        common::hr(64);

        // ---- baselines ----
        for (label, use_pq) in [("IVF-PQ", true), ("IVF-RQ", false)] {
            let base = build_lut_baseline(&ds.train, &ds.database, k_ivf, 8, use_pq, 7);
            for (nprobe, ef) in [(1usize, 16usize), (4, 32), (16, 64), (64, 128)] {
                let (qps, results) =
                    qps_of(ds.queries.rows, |i| base.search(ds.queries.row(i), nprobe, ef, 10));
                let r1 = recall_at(&results, &ds.ground_truth, 1);
                println!("{label:<14} {nprobe:>7} {ef:>6} {:>6} {:>8} {qps:>8.0} {:>8}",
                         "-", "-", common::pct(r1));
                csv.push(format!("{},{label},{nprobe},{ef},0,0,{qps:.0},{r1:.4}", flavor.name()));
            }
        }

        // ---- IVF-QINCo2 (XS and S) ----
        for model in ["qinco2_xs", "qinco2_s"] {
            let bcfg = BuildCfg { k_ivf, m_tilde: 2, ..Default::default() };
            let ivf = qinco2::index::ivf::Ivf::build(&ds.train, &ds.train, k_ivf, bcfg.seed);
            let t_res = ivf.residuals(&ds.train);
            let cfg = TrainCfg { epochs: scale.epochs, a: 8, b: 8, seed: 0xA11CE ^ 0x1F, ..Default::default() };
            let params = exp::trained_model(
                &mut engine, model, &format!("{}_ivfres", flavor.name()), &t_res, &cfg)?;
            let codec = Codec::new(&engine, model, 8, 8)?;
            let index = SearchIndex::build(&mut engine, &codec, params, &ds.train, &ds.database, &bcfg)?;
            for (nprobe, ef, n_aq, n_pairs) in [
                (1usize, 16usize, 64usize, 16usize),
                (4, 32, 128, 32),
                (16, 64, 256, 64),
                (64, 128, 1024, 128),
            ] {
                let sp = SearchParams { nprobe, ef_search: ef, n_aq, n_pairs, n_final: 10, ..Default::default() };
                let (qps, results) = qps_of(ds.queries.rows, |i| {
                    index.search(ds.queries.row(i), &sp).into_iter().map(|(_, id)| id).collect()
                });
                let r1 = recall_at(&results, &ds.ground_truth, 1);
                let label = format!("IVF-{}", model.replace("qinco2_", "QINCo2-"));
                println!("{label:<14} {nprobe:>7} {ef:>6} {n_aq:>6} {n_pairs:>8} {qps:>8.0} {:>8}",
                         common::pct(r1));
                csv.push(format!("{},{label},{nprobe},{ef},{n_aq},{n_pairs},{qps:.0},{r1:.4}",
                                 flavor.name()));
                // same knobs through the batched engine (bucket-grouped
                // scans + union decode) — result-identical, so R@1 is
                // equal and the rows compare dispatch cost alone
                let t0 = Instant::now();
                let results_b =
                    qinco2::metrics::ids_only(&index.search_batch(&ds.queries, &sp)?);
                let qps_b = ds.queries.rows as f64 / t0.elapsed().as_secs_f64();
                assert_eq!(results_b, results, "batched dispatch diverged from per-query");
                let label_b = format!("{label}+batch");
                println!("{label_b:<14} {nprobe:>7} {ef:>6} {n_aq:>6} {n_pairs:>8} {qps_b:>8.0} {:>8}",
                         common::pct(r1));
                csv.push(format!("{},{label_b},{nprobe},{ef},{n_aq},{n_pairs},{qps_b:.0},{r1:.4}",
                                 flavor.name()));
            }

            // ---- §B: single-query latency at a matched operating point ----
            if model == "qinco2_xs" {
                let sp = SearchParams { nprobe: 16, ef_search: 64, n_aq: 256, n_pairs: 64, n_final: 10, ..Default::default() };
                let mut rng = Rng::new(1);
                let mut lat_q = Vec::new();
                for _ in 0..50 {
                    let qi = rng.below(ds.queries.rows);
                    let t0 = Instant::now();
                    std::hint::black_box(index.search(ds.queries.row(qi), &sp));
                    lat_q.push(t0.elapsed().as_secs_f64());
                }
                let base = build_lut_baseline(&ds.train, &ds.database, k_ivf, 8, false, 7);
                let mut lat_r = Vec::new();
                for _ in 0..50 {
                    let qi = rng.below(ds.queries.rows);
                    let t0 = Instant::now();
                    std::hint::black_box(base.search(ds.queries.row(qi), 64, 128, 10));
                    lat_r.push(t0.elapsed().as_secs_f64());
                }
                lat_q.sort_by(|a, b| a.partial_cmp(b).unwrap());
                lat_r.sort_by(|a, b| a.partial_cmp(b).unwrap());
                println!("[§B latency] single-query p50: IVF-QINCo2 {:.2} ms vs IVF-RQ(max-accuracy) {:.2} ms",
                         lat_q[25] * 1e3, lat_r[25] * 1e3);
            }
        }
        // recall ceiling for context
        let exact = brute_force_gt_k(&ds.database, &ds.queries, 1);
        println!("(exact-search ceiling R@1 = {})",
                 common::pct(recall_at(&exact, &ds.ground_truth, 1)));
    }
    let path = exp::write_csv("fig6.csv",
        "dataset,method,nprobe,ef,n_aq,n_pairs,qps,r1", &csv)?;
    println!("\n[csv] {}", path.display());
    Ok(())
}
